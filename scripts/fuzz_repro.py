#!/usr/bin/env python
"""Replay / drive the randomized snowflake fuzzer from the command line.

Two modes:

``--seed N``
    Replay ONE generated case (the seed a CI failure printed) with the
    full check matrix — fused/nonfused × segment/matmul against the
    float64 oracle, plus the append→refresh-vs-cold-rebuild and serving
    checks — and dump the generated schema/query so the failure is
    inspectable.  Exits nonzero on any mismatch.

``--cases K [--base-seed B]``
    Run a fresh fuzz campaign of K cases (the CI smoke/deep-fuzz entry
    point).  On mismatch, prints every failure plus the one-command
    replay line and exits nonzero.

``--seed N --rewrite-matrix``
    Replay one case through every backend combo with the IR rewrite
    engine on AND off, printing the fired-rule trail and comparing the
    two plans' results bit-for-bit (and both against the float64 oracle).
    The targeted triage mode when a mismatch implicates a rewrite rule.

Usage:
    PYTHONPATH=src python scripts/fuzz_repro.py --seed 12345
    PYTHONPATH=src python scripts/fuzz_repro.py --seed 12345 --rewrite-matrix
    PYTHONPATH=src python scripts/fuzz_repro.py --cases 200 --base-seed 0
"""
from __future__ import annotations

import argparse
import sys
import time


def _describe(case) -> str:
    q = case.query
    lines = [f"seed {case.seed}: fact rows={int(case.tables[q.fact].nvalid)}"
             f" preds={list(q.fact_preds)}"]
    for a in q.arms:
        lines.append(f"  arm {a.table} fk={a.fk_col} "
                     f"feats={list(a.feature_cols)} preds={list(a.preds)}")
        for lk in a.links:
            lines.append(f"    link {lk.table} parent={lk.parent or '<prev>'}"
                         f" fk={lk.fk_col} feats={list(lk.feature_cols)}"
                         f" preds={list(lk.preds)}")
    lines.append(f"  model={type(q.model).__name__ if q.model else None}"
                 f" group_keys={[(g.table, g.col) for g in q.group_keys]}"
                 f" aggs={[(a.op, a.name) for a in q.aggregates]}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--seed", type=int, help="replay one case by seed")
    mode.add_argument("--cases", type=int, help="run a K-case campaign")
    ap.add_argument("--base-seed", type=int, default=0,
                    help="campaign base seed (case i uses base*10000+i)")
    ap.add_argument("--full-every", type=int, default=4,
                    help="full-matrix check every Nth campaign case")
    ap.add_argument("--rewrite-matrix", action="store_true",
                    help="with --seed: compare rewrite on vs off across "
                         "every backend combo (and both vs the oracle)")
    args = ap.parse_args(argv)

    from repro.core.query.workload import check_case, generate_case, run_fuzz

    if args.rewrite_matrix:
        if args.seed is None:
            ap.error("--rewrite-matrix requires --seed")
        from repro.core.query import compile_query, rewrite_query
        from repro.core.query.workload import _compare, np_oracle
        case = generate_case(args.seed)
        print(_describe(case))
        rw = rewrite_query(case.tables, case.query)
        print("rewrite trail:", list(rw.trail) or "(nothing fired)")
        want = np_oracle(case.tables, case.query)
        bad = []
        t0 = time.time()
        for backend in ("fused", "nonfused"):
            for agg_backend in ("segment", "matmul"):
                res = {}
                for mode in ("on", "off"):
                    plan = compile_query(case.catalog(), case.query,
                                         backend=backend,
                                         agg_backend=agg_backend,
                                         rewrite=mode)
                    res[mode] = plan.run()
                    bad += _compare(res[mode], want, case.query,
                                    f"seed={args.seed} {backend}/"
                                    f"{agg_backend}/rewrite={mode}")
        dt = time.time() - t0
        if bad:
            print(f"FAIL ({len(bad)} mismatches, {dt:.1f}s):")
            for b in bad:
                print(" ", b)
            return 1
        print(f"OK: seed {args.seed} rewrite on == off == oracle across "
              f"all combos ({dt:.1f}s)")
        return 0

    if args.seed is not None:
        print(_describe(generate_case(args.seed)))
        t0 = time.time()
        bad = check_case(args.seed, full=True)
        dt = time.time() - t0
        if bad:
            print(f"FAIL ({len(bad)} mismatches, {dt:.1f}s):")
            for b in bad:
                print(" ", b)
            return 1
        print(f"OK: seed {args.seed} bit-exact across the full matrix "
              f"({dt:.1f}s)")
        return 0

    t0 = time.time()
    rep = run_fuzz(args.cases, seed=args.base_seed,
                   full_every=args.full_every)
    print(f"{rep.summary()} ({time.time() - t0:.1f}s)")
    for b in rep.failures:
        print(" ", b)
    return 0 if rep.ok else 1


if __name__ == "__main__":
    sys.exit(main())
