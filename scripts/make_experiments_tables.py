"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from the JSON grids."""
import glob
import json
import sys

ARCH_ORDER = ["whisper-tiny", "smollm-360m", "minitron-4b", "llama3.2-1b",
              "gemma-7b", "pixtral-12b", "qwen2-moe-a2.7b", "dbrx-132b",
              "jamba-1.5-large-398b", "xlstm-125m"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d):
    out = {}
    for f in glob.glob(f"{d}/*.json"):
        r = json.load(open(f))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_bytes(b):
    return f"{b/1e9:.1f}"


def roofline_table(recs, mesh):
    lines = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
        "| bottleneck | MODEL/HLO FLOPs | roofline frac | temp GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {a} | {s} | — | — | — | *skipped* "
                             f"(full-attention; see DESIGN.md) | — | — | — |")
                continue
            rf = r["roofline"]
            lines.append(
                f"| {a} | {s} | {rf['t_compute_s']:.3f} | "
                f"{rf['t_memory_s']:.3f} | {rf['t_collective_s']:.3f} | "
                f"{rf['bottleneck']} | {rf['useful_ratio']:.3f} | "
                f"{rf['roofline_fraction']:.4f} | "
                f"{fmt_bytes(r['memory']['temp_bytes'])} |")
    return "\n".join(lines)


def dryrun_table(recs, mesh):
    lines = [
        "| arch | shape | status | compile s | args GB | temp GB | "
        "HLO GFLOPs/dev | coll GB/dev | #coll |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None:
                continue
            if r["status"] != "ok":
                lines.append(f"| {a} | {s} | {r['status']} | — | — | — | — "
                             f"| — | — |")
                continue
            rf = r["roofline"]
            m = r["memory"]
            lines.append(
                f"| {a} | {s} | ok | {r['compile_s']:.0f} | "
                f"{fmt_bytes(m['argument_bytes'])} | "
                f"{fmt_bytes(m['temp_bytes'])} | "
                f"{rf['flops_per_dev']/1e9:.0f} | "
                f"{rf['coll_bytes_per_dev']/1e9:.1f} | "
                f"{int(rf['n_collectives'])} |")
    return "\n".join(lines)


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(d)
    which = sys.argv[2] if len(sys.argv) > 2 else "roofline"
    mesh = sys.argv[3] if len(sys.argv) > 3 else "pod"
    if which == "roofline":
        print(roofline_table(recs, mesh))
    else:
        print(dryrun_table(recs, mesh))
