#!/usr/bin/env bash
# Tier-1 verification — the exact invocations CI runs, for local parity.
# Usage: scripts/run_tier1.sh [extra pytest args...]   (e.g. -m 'not slow')
#        scripts/run_tier1.sh --lint    # ruff check + format gate (CI lint job)
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--lint" ]]; then
  # Repo-wide lint (rule set in pyproject [tool.ruff]).  The format gate
  # covers files already written in ruff-format style; grow this list as
  # legacy files are migrated rather than reformatting the repo wholesale.
  ruff check .
  ruff format --check \
    tests/test_serving.py \
    tests/test_serving_property.py \
    benchmarks/bench_serving.py
  exit 0
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
