#!/usr/bin/env bash
# Tier-1 verification — the exact invocation CI runs, for local parity.
# Usage: scripts/run_tier1.sh [extra pytest args...]   (e.g. -m 'not slow')
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
