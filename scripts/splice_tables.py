"""Splice generated dry-run/roofline tables into EXPERIMENTS.md markers."""
import subprocess, sys

def gen(which, mesh):
    return subprocess.run(
        [sys.executable, "scripts/make_experiments_tables.py",
         "experiments/dryrun", which, mesh],
        capture_output=True, text=True, check=True).stdout.strip()

md = open("EXPERIMENTS.md").read()
for marker, which, mesh in [
    ("<!--DRYRUN_POD-->", "dryrun", "pod"),
    ("<!--DRYRUN_MULTIPOD-->", "dryrun", "multipod"),
    ("<!--ROOFLINE_POD-->", "roofline", "pod"),
    ("<!--ROOFLINE_MULTIPOD-->", "roofline", "multipod"),
]:
    md = md.replace(marker, gen(which, mesh))
open("EXPERIMENTS.md", "w").write(md)
print("spliced")
