#!/usr/bin/env python
"""Memory-cap proof: under a hard address-space budget, the in-core program
OOMs and the streamed program completes — the ISSUE 8 out-of-core claim as
an executable check, run by the CI ``memcap`` job.

Both modes build the *same* synthetic star (the resident catalog tables are
a shared cost); the difference is the online program.  In-core lowers one
jitted program over the whole fact axis, materializing per-row
intermediates — gathered arm features, the prediction matrix, validity and
group vectors — for every row at once.  Streaming folds the same program
chunk-by-chunk through a carried segment accumulator, so its intermediate
footprint is one chunk's, not the table's.

Modes
-----
``--mode stream`` / ``--mode incore``
    Run one program under the *caller's* limits and exit 0 on success.
    The CI job applies the cap via ``ulimit -v`` in the step shell.
``--mode both`` (default)
    Self-contained driver: spawns each mode as a subprocess under
    ``RLIMIT_AS = --cap-mb`` and asserts stream passes AND in-core dies.
    Exits nonzero if either half of the proof fails.

The streamed run prints its aggregate checksum so the two CI legs can be
eyeballed against an uncapped run; bit-exactness vs in-core is covered by
tier-1 (the in-core leg here dies by design, there is nothing to compare).

Usage:  PYTHONPATH=src python scripts/memcap_proof.py [--cap-mb 2000]
        [--rows 12000000] [--budget-mb 64]
"""
from __future__ import annotations

import argparse
import os
import resource
import subprocess
import sys


def build_catalog(rows: int):
    """A 2-arm star whose fact dominates memory: ``rows`` x 6 float cols."""
    import numpy as np

    from repro.core.laq import Catalog, Table

    rng = np.random.default_rng(0)
    n_dim = 1024
    d1 = {"pk": np.arange(n_dim) * 2,
          "a": rng.normal(size=n_dim), "b": rng.normal(size=n_dim)}
    d2 = {"pk2": np.arange(n_dim),
          "c": rng.normal(size=n_dim),
          "g": rng.integers(0, 8, n_dim)}
    f = {"fk1": rng.integers(0, 2 * n_dim, rows),
         "fk2": rng.integers(0, n_dim, rows),
         "v0": rng.normal(size=rows).astype(np.float32),
         "v1": rng.normal(size=rows).astype(np.float32),
         "v2": rng.normal(size=rows).astype(np.float32),
         "v3": rng.normal(size=rows).astype(np.float32)}
    return Catalog({
        "d1": Table.from_columns("d1", d1, key_cols=("pk",)),
        "d2": Table.from_columns("d2", d2, key_cols=("pk2", "g")),
        "fact": Table.from_columns("fact", f, key_cols=("fk1", "fk2")),
    })


def the_query():
    import jax.numpy as jnp
    import numpy as np

    from repro.core.fusion import LinearOperator
    from repro.core.laq.selection import Pred
    from repro.core.query import (PREDICTION, Aggregate, ArmSpec, GroupKey,
                                  PredictiveQuery)

    # A wide head (l=32): the in-core program materializes the (rows, 32)
    # prediction matrix, the dominant per-row intermediate the streamed
    # program only ever holds one chunk of — so the proof window between
    # "streaming fits" and "in-core OOMs" widens with rows x l while the
    # shared catalog cost stays put.
    model = LinearOperator(jnp.asarray(
        np.random.default_rng(1).normal(size=(3, 32)), jnp.float32))
    return PredictiveQuery(
        fact="fact",
        arms=(ArmSpec("d1", "fk1", "pk", ("a", "b"),
                      (Pred("a", ">", -1.0),)),
              ArmSpec("d2", "fk2", "pk2", ("c",))),
        fact_preds=(Pred("v0", ">", -2.0),),
        model=model,
        group_keys=(GroupKey("d2", "g", 8),),
        aggregates=(Aggregate(PREDICTION, "sum", "pred"),
                    Aggregate("v1", "mean", "m1"),
                    Aggregate(("mul", "v2", "v3"), "sum", "x23"),
                    Aggregate("*", "count", "n")),
        num_groups=8)


def run_mode(mode: str, rows: int, budget_mb: int) -> int:
    import numpy as np

    from repro.core.query import compile_query

    cat = build_catalog(rows)
    q = the_query()
    if mode == "stream":
        plan = compile_query(cat, q,
                             memory_budget_bytes=budget_mb * 1024 * 1024)
        assert plan._stream is not None, "budget did not trigger streaming"
        print(f"[memcap] stream: {plan._stream.describe()}", flush=True)
    else:
        plan = compile_query(cat, q, backend="fused",
                             join_backend="gather", agg_backend="segment")
    out = plan.run()
    print(f"[memcap] {mode} ok: checksum "
          f"{float(np.sum(np.asarray(out['pred'], np.float64))):.6e} "
          f"n={np.asarray(out['n']).sum():.0f}", flush=True)
    return 0


def spawn_capped(mode: str, cap_mb: int, args) -> subprocess.CompletedProcess:
    cap = cap_mb * 1024 * 1024
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, __file__, "--mode", mode,
         "--rows", str(args.rows), "--budget-mb", str(args.budget_mb)],
        preexec_fn=lambda: resource.setrlimit(resource.RLIMIT_AS,
                                              (cap, cap)),
        env=env, capture_output=True, text=True)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("both", "stream", "incore"),
                    default="both")
    ap.add_argument("--rows", type=int, default=12_000_000)
    ap.add_argument("--budget-mb", type=int, default=64)
    ap.add_argument("--cap-mb", type=int, default=2000,
                    help="RLIMIT_AS for --mode both's subprocesses")
    args = ap.parse_args()

    if args.mode != "both":
        return run_mode(args.mode, args.rows, args.budget_mb)

    ok = True
    s = spawn_capped("stream", args.cap_mb, args)
    print(s.stdout, end="", flush=True)
    if s.returncode != 0:
        print(f"[memcap] FAIL: streaming died under the {args.cap_mb}MB "
              f"cap (rc={s.returncode})\n{s.stderr[-2000:]}")
        ok = False
    i = spawn_capped("incore", args.cap_mb, args)
    if i.returncode == 0:
        print(f"[memcap] FAIL: in-core survived the {args.cap_mb}MB cap — "
              "raise --rows or lower --cap-mb so the proof is non-vacuous")
        ok = False
    else:
        print(f"[memcap] in-core OOMs as expected (rc={i.returncode}): "
              + (i.stderr.strip().splitlines()[-1][:120]
                 if i.stderr.strip() else "killed"))
    if ok:
        print(f"[memcap] PROOF OK: cap={args.cap_mb}MB rows={args.rows} — "
              "in-core OOMs, streaming completes")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
