"""Selection as a binary filter vector (paper §2.2).

The paper builds a {0,1} vector over rows and notes that actually *multiplying*
by it wastes FLOPs; its CuPy implementation uses ``mask_select`` (predicate +
memory copy) instead.  The TPU/XLA analogue of ``mask_select`` under static
shapes is: compute the mask, compact the surviving row indices into a
fixed-capacity buffer (``jnp.nonzero(..., size=cap)``), and gather.

Predicates are simple (col, op, literal) terms combined with AND/OR — enough
for the full SSB query set.  Key columns compare exactly in int32.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp

from .table import PAD_KEY, Table

_OPS = {
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
}


@dataclasses.dataclass(frozen=True)
class Pred:
    """A single predicate term ``col <op> value`` (or ``col BETWEEN lo, hi``)."""

    col: str
    op: str  # one of _OPS | "between" | "in"
    value: object

    def mask(self, table: Table) -> jnp.ndarray:
        col = (
            table.key(self.col)
            if self.col in table.keys
            else table.col(self.col)
        )
        if self.op == "between":
            lo, hi = self.value
            m = (col >= lo) & (col <= hi)
        elif self.op == "in":
            vals = jnp.asarray(list(self.value), col.dtype)
            m = jnp.any(col[:, None] == vals[None, :], axis=1)
        else:
            m = _OPS[self.op](col, jnp.asarray(self.value, col.dtype))
        return m & table.valid_mask()


def selection_vector(table: Table, preds: Sequence[Pred],
                     combine: str = "and") -> jnp.ndarray:
    """The paper's binary filter vector (float {0,1}) over rows."""
    if not preds:
        return table.valid_mask().astype(jnp.float32)
    masks = [p.mask(table) for p in preds]
    out = masks[0]
    for m in masks[1:]:
        out = (out & m) if combine == "and" else (out | m)
    return out.astype(jnp.float32)


def select(table: Table, preds: Sequence[Pred], capacity: int | None = None,
           combine: str = "and") -> Table:
    """mask_select: compact rows passing ``preds`` into a capacity buffer."""
    cap = capacity if capacity is not None else table.capacity
    mask = selection_vector(table, preds, combine).astype(bool)
    # Compacted surviving row ids; fill with `capacity` (an out-of-range row)
    # so `take(..., mode="fill")` produces zero padding rows.
    (idx,) = jnp.nonzero(mask, size=cap, fill_value=table.capacity)
    nvalid = jnp.sum(mask.astype(jnp.int32))
    matrix = jnp.take(table.matrix, idx, axis=0, mode="fill", fill_value=0.0)
    keys = {
        c: jnp.take(v, idx, axis=0, mode="fill", fill_value=PAD_KEY)
        for c, v in table.keys.items()
    }
    return Table(table.name, table.columns, matrix, keys, nvalid)
