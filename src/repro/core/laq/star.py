"""Star join (paper §3.1): fact table ⋈ dimension tables via factored MM-Join.

``T = I₁BM₁ + I₂CM₂ + I₃DM₃`` — each dimension contributes its projected
columns to a disjoint slice of the target, selected by the row-matching
matrix I (kept factored as FK pointers).  This module materializes T either
faithfully (dense I, matmuls) or via gathers, and is the substrate the
operator-fusion engine (``repro.core.fusion``) pushes ML operators into.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax.numpy as jnp

from .join import FactoredJoin, join_factored
from .projection import mapping_matrix
from .table import Table


@dataclasses.dataclass(frozen=True)
class DimSpec:
    """One arm of the star: fact.fk_col joins dim.pk_col, keep feature_cols."""

    dim: Table
    fk_col: str          # FK column on the fact table
    pk_col: str          # PK column on the dimension table
    feature_cols: tuple  # dimension columns contributing features


@dataclasses.dataclass(frozen=True)
class StarJoin:
    """Resolved star join: factored matching matrices + combined validity."""

    fact: Table
    dims: Tuple[DimSpec, ...]
    joins: Tuple[FactoredJoin, ...]
    row_valid: jnp.ndarray  # fact rows with matches in *all* dimensions

    @property
    def feature_width(self) -> int:
        return sum(len(d.feature_cols) for d in self.dims)

    def mapping_matrices(self) -> Tuple[jnp.ndarray, ...]:
        """M_j ∈ {0,1}^{c_j × k}: dim-j columns → their slice of T's columns.

        Each dimension owns a disjoint block of the k target columns, so M_j
        has zero rows outside its block (Eq. 1's `+` composition is exact).
        """
        return dim_mapping_matrices(self.dims)

    def materialize(self) -> jnp.ndarray:
        """T = Σⱼ Iⱼ (Bⱼ Mⱼ) via gathers — (fact_capacity, k) float32.

        Rows that miss any dimension are zeroed (inner-join semantics with
        fixed capacity; ``row_valid`` carries liveness).
        """
        parts = []
        for d, fj in zip(self.dims, self.joins):
            proj = d.dim.matrix @ mapping_matrix(
                d.dim.columns, d.feature_cols)          # Bⱼ Mⱼ
            parts.append(fj.apply(proj))                # Iⱼ (Bⱼ Mⱼ)
        t = jnp.concatenate(parts, axis=1)
        return t * self.row_valid[:, None].astype(t.dtype)

    def materialize_matmul(self) -> jnp.ndarray:
        """Paper-faithful: dense Iⱼ one-hot matmuls (small inputs only)."""
        k = self.feature_width
        out = jnp.zeros((self.fact.capacity, k), jnp.float32)
        for d, fj, m in zip(self.dims, self.joins, self.mapping_matrices()):
            i_dense = fj.dense(d.dim.capacity)          # (r_fact, r_dim)
            out = out + i_dense @ (d.dim.matrix @ m)    # Iⱼ Bⱼ Mⱼ
        return out * self.row_valid[:, None]


def dim_mapping_matrices(dims: Sequence[DimSpec]) -> Tuple[jnp.ndarray, ...]:
    """M_j for a sequence of arms, independent of any fact table.

    The quasi-static half of Eq. 1 only needs the dimension tables, so the
    serving runtime can pre-fuse partials without ever resolving a join.
    """
    k = sum(len(d.feature_cols) for d in dims)
    mats = []
    offset = 0
    for d in dims:
        c = d.dim.ncols
        m = jnp.zeros((c, k), jnp.float32)
        for t, col in enumerate(d.feature_cols):
            m = m.at[d.dim.col_index(col), offset + t].set(1.0)
        mats.append(m)
        offset += len(d.feature_cols)
    return tuple(mats)


def shard_rows(x: jnp.ndarray, num_shards: int) -> jnp.ndarray:
    """Reshape ``(r, ...)`` row-wise into ``(num_shards, r/num_shards, ...)``.

    The contiguous-block layout matches ``shard_pk_index``: shard ``s`` of a
    prefused partial holds exactly the rows its PK-index slice resolves, so
    a shard-local probe + gather touches only device-local memory.
    """
    r = int(x.shape[0])
    if num_shards < 1 or r % num_shards:
        raise ValueError(
            f"cannot shard {r} rows into {num_shards} equal blocks")
    return x.reshape(num_shards, r // num_shards, *x.shape[1:])


def star_join(fact: Table, dims: Sequence[DimSpec]) -> StarJoin:
    """Resolve FK pointers for every dimension arm (multi-way join, §2.3.2).

    Following the paper, no intermediate table is materialized: each arm's
    matching matrix is computed independently against the fact table, and
    non-matching rows are dropped via the combined validity mask.
    """
    joins = []
    valid = fact.valid_mask()
    for d in dims:
        fj = join_factored(fact.key(d.fk_col), d.dim.key(d.pk_col))
        joins.append(fj)
        valid = valid & fj.found
    return StarJoin(fact=fact, dims=tuple(dims), joins=tuple(joins),
                    row_valid=valid)
