"""Group-by aggregation in LAQ (paper §2.4).

* ``groupby_sum_matmul`` — paper-faithful single-column aggregation (Fig. 4):
  fill the aggregated values into MAT_R, groups into MAT_S, multiply, reduce
  with a ones vector.  Dense matmuls on the MXU.
* ``groupby_sum_segment`` — the optimized path: map rows to dense group ids
  (sort-unique, as TQP does for multi-column groups) and ``segment_sum``.
* ``composite_code`` — multi-column group-by via composite integer encoding
  followed by the single-column machinery (paper §2.4.2's sort-unique
  procedure).
* ``groupby_codes`` / ``segment_aggregate`` / ``matmul_aggregate`` — the
  code-level backends the predictive-query compiler (``repro.core.query``)
  chooses between: resolve composite codes to dense group ids once
  (quasi-static), then reduce values — either with ``segment_sum`` or with
  the Fig. 4 one-hot matmul.  Both accept (n,) scalars and (n, l) prediction
  matrices, so a fused model head aggregates with the same machinery.

All functions are padding-aware: rows whose group code is PAD_GROUP are
dropped from every aggregate.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .domain import key_domain, positions

PAD_GROUP = jnp.int32(2**31 - 1)


# --------------------------------------------------------------------------
# Paper-faithful matmul path (single column, Fig. 4)
# --------------------------------------------------------------------------
def groupby_sum_matmul(keys_r: jnp.ndarray, values_r: jnp.ndarray,
                       keys_s: jnp.ndarray, groups_s: jnp.ndarray,
                       domain_size: int, num_groups: int
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SELECT SUM(R.val) FROM R JOIN S ON R.key=S.key GROUP BY S.val.

    Returns (group_values[num_groups] int32, sums[num_groups] float32);
    unused group slots hold PAD_GROUP / 0.
    """
    dom = key_domain([keys_r, keys_s], domain_size)
    n_dom = dom.shape[0]
    pos_r = positions(dom, keys_r)                     # (rR,)
    # MAT_R: values scattered to key-domain slots.
    mat_r = (pos_r[:, None] == jnp.arange(n_dom)[None, :]) * values_r[:, None]
    # Groups: unique S values.
    grp_vals = jnp.unique(groups_s.astype(jnp.int32), size=num_groups,
                          fill_value=PAD_GROUP)
    gid_s = positions(grp_vals, groups_s.astype(jnp.int32))  # (rS,)
    pos_s = positions(dom, keys_s)
    # MAT_S[g, d] = 1 iff some S row has key-slot d and group g.
    onehot_g = (gid_s[:, None] == jnp.arange(num_groups)[None, :])
    onehot_d = (pos_s[:, None] == jnp.arange(n_dom)[None, :])
    mat_s = (onehot_g.astype(jnp.float32).T @ onehot_d.astype(jnp.float32))
    mat_s = jnp.minimum(mat_s, 1.0)                    # de-duplicate keys
    # ones @ MAT_R @ MAT_Sᵀ : reduce rows, then map domain slots to groups.
    per_slot = jnp.sum(mat_r, axis=0)                  # (n_dom,)
    sums = mat_s @ per_slot                            # (num_groups,)
    return grp_vals, sums


def groupby_sum_segment(keys_r: jnp.ndarray, values_r: jnp.ndarray,
                        keys_s: jnp.ndarray, groups_s: jnp.ndarray,
                        domain_size: int, num_groups: int
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Optimized counterpart of ``groupby_sum_matmul`` (same signature).

    Maps each R row to its S group through the key domain and reduces with
    ``segment_sum`` instead of building MAT_R / MAT_S.  Requires unique live
    S keys (the PK side of a star schema) — with duplicate S keys mapping one
    key slot to several groups, only the matmul form can multi-count.
    """
    dom = key_domain([keys_r, keys_s], domain_size)
    n_dom = dom.shape[0]
    pos_r = positions(dom, keys_r)
    pos_s = positions(dom, keys_s)
    grp_vals = jnp.unique(groups_s.astype(jnp.int32), size=num_groups,
                          fill_value=PAD_GROUP)
    gid_s = positions(grp_vals, groups_s.astype(jnp.int32))
    # slot -> group id (one writer per slot: unique S keys); missing slots and
    # padded S rows land in the overflow segment.
    slot_gid = jnp.full((n_dom + 1,), num_groups, jnp.int32)
    slot_gid = slot_gid.at[jnp.minimum(pos_s, n_dom)].set(
        jnp.minimum(gid_s, num_groups))
    slot_gid = slot_gid.at[n_dom].set(num_groups)
    gid_r = jnp.take(slot_gid, pos_r)
    sums = jax.ops.segment_sum(values_r, gid_r,
                               num_segments=num_groups + 1)[:num_groups]
    return grp_vals, sums


# --------------------------------------------------------------------------
# Optimized path: composite codes + segment reduction
# --------------------------------------------------------------------------
def composite_code(cols: Sequence[jnp.ndarray], bounds: Sequence[int],
                   valid: jnp.ndarray) -> jnp.ndarray:
    """Encode multi-column group keys into one int32 code (row-major).

    ``bounds[i]`` must exceed every value of ``cols[i]``; the product of
    bounds must stay below 2**31 (checked at trace time).
    """
    total = 1
    for b in bounds:
        total *= int(b)
    if total >= 2**31:
        raise ValueError(f"composite code space {total} overflows int32")
    code = jnp.zeros_like(cols[0], dtype=jnp.int32)
    for c, b in zip(cols, bounds):
        code = code * jnp.int32(b) + c.astype(jnp.int32)
    return jnp.where(valid, code, PAD_GROUP)


def groupby_reduce(codes: jnp.ndarray, values: Sequence[jnp.ndarray],
                   num_groups: int, ops: Sequence[str] = ("sum",)
                   ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, ...]]:
    """Sort-unique group ids + segment reductions (sum/count/min/max/mean).

    Returns (group_codes[num_groups], per-op aggregate arrays).  Group codes
    come out sorted (the paper folds ORDER BY on group keys into this —
    §2.5: sorting the key domain sorts the result).
    """
    uniq = jnp.unique(codes, size=num_groups, fill_value=PAD_GROUP)
    gid = jnp.searchsorted(uniq, codes).astype(jnp.int32)
    live = codes != PAD_GROUP
    gid = jnp.where(live, gid, num_groups)  # padding → overflow segment
    outs = []
    for v, op in zip(values, ops):
        if op == "sum":
            o = jax.ops.segment_sum(v, gid, num_segments=num_groups + 1)[:-1]
        elif op == "count":
            o = jax.ops.segment_sum(jnp.ones_like(v), gid,
                                    num_segments=num_groups + 1)[:-1]
        elif op == "min":
            o = jax.ops.segment_min(jnp.where(live, v, jnp.inf), gid,
                                    num_segments=num_groups + 1)[:-1]
        elif op == "max":
            o = jax.ops.segment_max(jnp.where(live, v, -jnp.inf), gid,
                                    num_segments=num_groups + 1)[:-1]
        elif op == "mean":
            s = jax.ops.segment_sum(v, gid, num_segments=num_groups + 1)[:-1]
            c = jax.ops.segment_sum(jnp.ones_like(v), gid,
                                    num_segments=num_groups + 1)[:-1]
            o = s / jnp.maximum(c, 1.0)
        else:
            raise ValueError(f"unknown aggregation op {op!r}")
        outs.append(o)
    return uniq, tuple(outs)


# --------------------------------------------------------------------------
# Code-level backends for the predictive-query compiler
# --------------------------------------------------------------------------
def _live_code_count(codes: jnp.ndarray) -> "int | None":
    """Distinct live (non-PAD_GROUP) codes, or None when codes are traced."""
    try:
        concrete = np.asarray(codes)
    except (jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        return None
    return int(np.unique(concrete[concrete != int(PAD_GROUP)]).size)


def groupby_codes(codes: jnp.ndarray, num_groups: int, *,
                  n_live: "int | None" = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Resolve composite codes to (sorted unique codes, dense group ids).

    Padded codes (PAD_GROUP) map to the overflow segment ``num_groups``; both
    ``segment_aggregate`` and ``matmul_aggregate`` drop it.  The resolution is
    quasi-static for a fixed fact table, so the compiler runs it once offline
    — and on that concrete-array path the distinct live codes are *counted*:
    more than ``num_groups`` of them would silently collapse the overflow
    groups into the padded tail of ``unique(size=...)`` and drop them from
    every aggregate, so it raises instead.  Under an outer trace the count is
    abstract and the check is skipped (the caller owns sizing there).  A
    caller that already measured the domain (``auto_num_groups``) passes
    ``n_live`` to skip the redundant host-side count.

    Concrete codes resolve on the host in numpy: XLA's CPU sort makes the
    device ``unique``/``searchsorted`` an order of magnitude slower than
    numpy's at offline sizes, and this resolution is the per-plan floor of
    a multi-query compile sweep.  Both paths are bit-identical (same sort
    order, same 'left' searchsorted, same overflow clamp).
    """
    try:
        concrete = np.asarray(codes)
    except (jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        concrete = None
    if n_live is None and concrete is not None:
        n_live = int(np.unique(concrete[concrete != int(PAD_GROUP)]).size)
    if n_live is not None and n_live > num_groups:
        raise ValueError(
            f"group-by overflow: {n_live} distinct live group codes "
            f"exceed num_groups={num_groups}; the excess groups would "
            "silently vanish from every aggregate. Raise num_groups "
            f"(>= {n_live}) or coarsen the group keys.")
    if concrete is not None:
        u = np.unique(concrete)[:num_groups]
        uniq = np.full((num_groups,), int(PAD_GROUP), dtype=concrete.dtype)
        uniq[:u.size] = u
        gid = np.searchsorted(uniq, concrete).astype(np.int32)
        gid = np.where(concrete != int(PAD_GROUP),
                       np.minimum(gid, num_groups), num_groups)
        return jnp.asarray(uniq), jnp.asarray(gid.astype(np.int32))
    uniq = jnp.unique(codes, size=num_groups, fill_value=PAD_GROUP)
    gid = jnp.searchsorted(uniq, codes).astype(jnp.int32)
    gid = jnp.where(codes != PAD_GROUP,
                    jnp.minimum(gid, num_groups), num_groups)
    return uniq, gid


def auto_num_groups(codes: jnp.ndarray) -> int:
    """Measured group-domain size: distinct live codes on the concrete path.

    The ``num_groups="auto"`` resolution: the offline compiler holds the
    composite codes as concrete arrays, so the exact live-code count is one
    host-side ``unique`` away — sizing the group dimension to precisely the
    measured domain (never overflows, never over-allocates).  Under an outer
    trace the codes are abstract and no measurement exists; that caller owns
    sizing and must pass an explicit ``num_groups``.
    """
    n_live = _live_code_count(codes)
    if n_live is None:
        raise ValueError(
            "num_groups='auto' requires concrete group codes: under an "
            "outer trace the code domain is abstract, so pass an explicit "
            "num_groups instead")
    return max(n_live, 1)


def segment_aggregate(gid: jnp.ndarray, values: jnp.ndarray,
                      num_groups: int) -> jnp.ndarray:
    """Σ values per group via ``segment_sum``; values (n,) or (n, l)."""
    return segment_reduce(gid, values, num_groups, "sum")


_SEGMENT_OPS = {"sum": jax.ops.segment_sum, "min": jax.ops.segment_min,
                "max": jax.ops.segment_max}


def segment_reduce(gid: jnp.ndarray, values: jnp.ndarray, num_groups: int,
                   op: str = "sum") -> jnp.ndarray:
    """Per-group sum/min/max via segment ops; values (n,) or (n, l).

    The min/max lowering used by the compiler on *both* aggregation backends
    (one-hot matmuls have no min/max form — Fig. 4 is additive).  Rows whose
    gid is the overflow segment ``num_groups`` (padding, predicate failures)
    are dropped; group slots that receive no row come back as the segment
    identity (±inf for min/max) and are zeroed so downstream consumers never
    see infinities in dead slots.
    """
    if op not in _SEGMENT_OPS:
        raise ValueError(f"segment_reduce op {op!r} not one of "
                         f"{sorted(_SEGMENT_OPS)}")
    out = _SEGMENT_OPS[op](values, gid,
                           num_segments=num_groups + 1)[:num_groups]
    if op in ("min", "max"):
        out = jnp.where(jnp.isfinite(out), out, 0.0)
    return out


def matmul_aggregate(gid: jnp.ndarray, values: jnp.ndarray,
                     num_groups: int) -> jnp.ndarray:
    """Paper-faithful Fig. 4 aggregation: onehot(gid)ᵀ @ values on the MXU.

    Overflow rows (gid == num_groups) get an all-zero one-hot row, exactly
    mirroring the padded-key handling of ``onehot_keys``.
    """
    onehot = (gid[:, None] == jnp.arange(num_groups)[None, :])
    return onehot.astype(values.dtype).T @ values


def decode_composite(codes: jnp.ndarray, bounds: Sequence[int]
                     ) -> Tuple[jnp.ndarray, ...]:
    """Invert ``composite_code`` (for presenting results)."""
    cols = []
    rem = codes
    for b in reversed(list(bounds)):
        cols.append(rem % jnp.int32(b))
        rem = rem // jnp.int32(b)
    return tuple(reversed(cols))
