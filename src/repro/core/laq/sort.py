"""Sorting in LAQ (paper §2.5).

Sorting has no pure LA form; the paper integrates it into MM-Join by sorting
the key domain (our ``jnp.unique`` domains are *already* sorted, so any result
keyed on domain/group position comes out ordered — ``groupby_reduce`` relies
on this) and otherwise falls back to a GPU sort.  We do the same: order-by on
arbitrary expressions is an ``argsort`` + gather, padding rows last.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from .table import Table


def order_by(table: Table, cols: Sequence[str],
             descending: Sequence[bool] | None = None) -> Table:
    """ORDER BY with lexicographic priority of ``cols``; padding stays last."""
    descending = descending or [False] * len(cols)
    n = table.capacity
    valid = table.valid_mask()
    perm = jnp.arange(n)
    # Stable sorts applied from least- to most-significant key.
    for col, desc in reversed(list(zip(cols, descending))):
        vals = table.col(col)[perm]
        vals = jnp.where(desc, -vals, vals)
        vals = jnp.where(valid[perm], vals, jnp.inf)  # padding last
        order = jnp.argsort(vals, stable=True)
        perm = perm[order]
    matrix = jnp.take(table.matrix, perm, axis=0)
    keys = {c: jnp.take(v, perm) for c, v in table.keys.items()}
    return Table(table.name, table.columns, matrix, keys, table.nvalid)


def sorted_domain_order(values: jnp.ndarray) -> jnp.ndarray:
    """The paper's 'sort by sorting the key domain': rank of each value."""
    order = jnp.argsort(values)
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    return ranks
