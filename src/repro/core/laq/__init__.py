"""LAQ: relational query processing as linear algebra (paper §2)."""
from .table import Table, PAD_KEY
from .catalog import (Catalog, CatalogHistoryError, CatalogReadOnlyError,
                      ChangedSpans, TableDelta, changed_spans)
from .projection import mapping_matrix, project_matmul, project_gather
from .selection import Pred, select, selection_vector
from .domain import key_domain, positions, DomainCache, default_domain_cache
from .join import (FactoredJoin, PKIndex, ShardedPKIndex, join_factored,
                   pk_index, shard_pk_index,
                   mmjoin_dense, mmjoin_bcoo,
                   onehot_keys, matching_pairs, row_mapping_matrices,
                   materialize_matmul, materialize_gather)
from .aggregation import (groupby_sum_matmul, groupby_sum_segment,
                          groupby_reduce, groupby_codes, segment_aggregate,
                          segment_reduce, matmul_aggregate, auto_num_groups,
                          composite_code, decode_composite, PAD_GROUP)
from .sort import order_by, sorted_domain_order
from .star import (DimSpec, StarJoin, dim_mapping_matrices, shard_rows,
                   star_join)

__all__ = [
    "Table", "PAD_KEY",
    "Catalog", "CatalogHistoryError", "CatalogReadOnlyError", "ChangedSpans",
    "TableDelta", "changed_spans",
    "mapping_matrix", "project_matmul", "project_gather",
    "Pred", "select", "selection_vector", "key_domain", "positions",
    "DomainCache", "default_domain_cache", "FactoredJoin", "PKIndex",
    "ShardedPKIndex", "join_factored", "pk_index", "shard_pk_index",
    "mmjoin_dense", "mmjoin_bcoo", "onehot_keys", "matching_pairs",
    "row_mapping_matrices", "materialize_matmul", "materialize_gather",
    "groupby_sum_matmul", "groupby_sum_segment", "groupby_reduce",
    "groupby_codes", "segment_aggregate", "segment_reduce",
    "matmul_aggregate", "auto_num_groups",
    "composite_code", "decode_composite", "PAD_GROUP",
    "order_by", "sorted_domain_order",
    "DimSpec", "StarJoin", "dim_mapping_matrices", "shard_rows", "star_join",
]
