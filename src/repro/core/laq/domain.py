"""Common key-domain construction (paper Alg. 1 lines 1–3) + domain cache.

The paper identifies domain generation (set-union + binary search) as a major
cost (§4.2 Q3, Fig. 11) and suggests caching it as future work.  We implement
both: a vectorized sort/unique construction and an explicit cache keyed on the
participating relations, with incremental O(n + log n) refresh when keys are
appended (the paper's suggested improvement).
"""
from __future__ import annotations

from typing import Dict, Mapping, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .table import PAD_KEY


def key_domain(keys: Sequence[jnp.ndarray], size: int) -> jnp.ndarray:
    """Sorted union of key arrays, padded with PAD_KEY to ``size``.

    PAD_KEY-valued entries in the inputs (table padding) sort to the tail and
    collapse into the padding of the result.
    """
    allk = jnp.concatenate([k.reshape(-1) for k in keys])
    dom = jnp.unique(allk, size=size, fill_value=PAD_KEY)
    return dom


def positions(domain: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    """Map keys to their slots in the sorted domain (vectorized binary search).

    Returns int32 positions; padded keys (PAD_KEY) map to ``len(domain)``
    (an out-of-range slot) so one-hot rows for padding are all-zero.
    """
    pos = jnp.searchsorted(domain, keys).astype(jnp.int32)
    n = domain.shape[0]
    # A key absent from the domain (or PAD_KEY) must not alias slot of another
    # key: verify domain[pos] == key, else push out of range.
    hit = jnp.take(domain, jnp.clip(pos, 0, n - 1)) == keys
    pad = keys == PAD_KEY
    return jnp.where(hit & ~pad, pos, n)


class DomainCache:
    """Cache of key domains keyed by (relation, column) identity sets.

    ``get`` returns a cached domain when the same relation/column set was seen;
    ``refresh`` merges newly appended keys into a cached domain without a full
    rebuild (sorted-merge, O(n) — cheaper than the O(n log n) rebuild, the
    paper's §4.2 Q3 suggestion).
    """

    def __init__(self):
        self._store: Dict[Tuple, jnp.ndarray] = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def _key(names: Sequence[Tuple[str, str]]) -> Tuple:
        return tuple(sorted(names))

    def get_or_build(self, names, keys: Sequence[jnp.ndarray], size: int):
        k = self._key(names)
        if k in self._store and self._store[k].shape[0] >= size:
            self.hits += 1
            return self._store[k]
        self.misses += 1
        dom = key_domain(keys, size)
        self._store[k] = dom
        return dom

    def refresh(self, names, new_keys: jnp.ndarray, *,
                grow: bool = True) -> jnp.ndarray:
        """Merge appended keys into the cached domain (incremental update).

        The merge runs on host (refresh is an offline, concrete operation),
        so the merged unique count is measured exactly: when it exceeds the
        cached domain's capacity the domain *grows geometrically* (powers of
        two of the old capacity) instead of silently truncating the largest
        keys — the failure mode of a fixed-size ``jnp.unique(..., size=...)``.
        ``grow=False`` raises a capacity error instead, for callers whose
        compiled programs bake in the domain shape.
        """
        k = self._key(names)
        if k not in self._store:
            raise KeyError(f"no cached domain for {k}")
        dom = self._store[k]
        cap = int(dom.shape[0])
        merged = np.unique(np.concatenate(
            [np.asarray(dom).reshape(-1),
             np.asarray(new_keys).reshape(-1)]))
        live = merged[merged != PAD_KEY]  # pads sort last; drop, then re-pad
        if live.shape[0] > cap:
            if not grow:
                raise ValueError(
                    f"domain {k} capacity {cap} exceeded: merged unique key "
                    f"count is {live.shape[0]} — rebuild with a larger "
                    "size, or allow grow=True")
            while cap < live.shape[0]:
                cap *= 2
        out = np.full((cap,), PAD_KEY, dom.dtype)
        out[:live.shape[0]] = live
        out = jnp.asarray(out)
        self._store[k] = out
        return out

    def refresh_table(self, relation: str,
                      new_keys: Mapping[str, jnp.ndarray], *,
                      grow: bool = True) -> int:
        """Refresh every cached domain that references ``relation``.

        ``new_keys`` maps the relation's key columns to their appended
        values; each cached domain whose identity set contains one of those
        ``(relation, column)`` pairs is merged in place.  Returns the number
        of domains refreshed — the Catalog's append hook.
        """
        n = 0
        for key in list(self._store):
            cols = [c for (rel, c) in key if rel == relation and c in new_keys]
            if cols:
                self.refresh(key, jnp.concatenate(
                    [jnp.asarray(new_keys[c]).reshape(-1) for c in cols]),
                    grow=grow)
                n += 1
        return n


# Process-wide default cache (the paper's "domain caching strategies").
default_domain_cache = DomainCache()
