"""Table-as-matrix representation for Linear Algebra Query processing (LAQ).

The paper (SSDBM'23 §2) converts every relational input into a matrix before
evaluating relational operators as linear-algebra computations.  We keep two
synchronized views of a relation:

* ``matrix`` — the numeric (rows × cols) float32 matrix used by LA operators
  (projection matmuls, aggregation matmuls, fused ML operators).
* ``keys``   — exact int32 arrays for join/group keys.  The paper's CuPy
  implementation also keeps CSR *indices* as integers; on TPU we keep key
  columns as int32 so no key ever round-trips through a float (float32 is only
  exact below 2**24 — SSB date keys like 19920101 would silently corrupt).

Static shapes: XLA requires them, so a Table may be *padded*: ``nvalid`` rows
are live, the rest are padding (zero rows, key = ``PAD_KEY``).  Every LAQ
operator preserves this invariant.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax.numpy as jnp
import numpy as np

# Padding sentinel for key columns.  int32 max keeps padded keys sorted *after*
# every real key, which searchsorted-based domain construction relies on.
PAD_KEY = np.int32(2**31 - 1)


@dataclasses.dataclass(frozen=True)
class Table:
    """An immutable relation in LAQ (matrix) form.

    Attributes:
      name:    relation name (for plans / debugging).
      columns: ordered column names; ``matrix[:, i]`` is ``columns[i]``.
      matrix:  (capacity, len(columns)) float32 — the LA view.
      keys:    mapping key-column name -> (capacity,) int32 exact values.
               Key columns may also appear in ``matrix`` (rounded); joins and
               group-bys always read from ``keys``.
      nvalid:  number of live rows (int or traced scalar). Rows >= nvalid are
               padding.
    """

    name: str
    columns: tuple
    matrix: jnp.ndarray
    keys: Mapping[str, jnp.ndarray]
    nvalid: jnp.ndarray | int

    # -- construction -------------------------------------------------------
    @staticmethod
    def from_columns(
        name: str,
        cols: Mapping[str, np.ndarray | jnp.ndarray],
        key_cols: Sequence[str] = (),
        capacity: int | None = None,
    ) -> "Table":
        """Build a Table from named 1-D columns (all equal length)."""
        names = tuple(cols.keys())
        n = int(np.asarray(next(iter(cols.values()))).shape[0])
        cap = capacity if capacity is not None else n
        if cap < n:
            raise ValueError(f"capacity {cap} < rows {n}")
        mat = np.zeros((cap, len(names)), np.float32)
        for j, c in enumerate(names):
            mat[:n, j] = np.asarray(cols[c], np.float32)
        keys = {}
        for c in key_cols:
            k = np.full((cap,), PAD_KEY, np.int32)
            k[:n] = np.asarray(cols[c], np.int32)
            keys[c] = jnp.asarray(k)
        return Table(name, names, jnp.asarray(mat), keys, n)

    # -- accessors -----------------------------------------------------------
    @property
    def capacity(self) -> int:
        return int(self.matrix.shape[0])

    @property
    def ncols(self) -> int:
        return int(self.matrix.shape[1])

    def col_index(self, col: str) -> int:
        return self.columns.index(col)

    def col(self, col: str) -> jnp.ndarray:
        """Float view of a column."""
        return self.matrix[:, self.col_index(col)]

    def key(self, col: str) -> jnp.ndarray:
        """Exact int32 view of a key column."""
        return self.keys[col]

    def valid_mask(self) -> jnp.ndarray:
        return jnp.arange(self.capacity) < self.nvalid

    def with_matrix(self, matrix: jnp.ndarray, columns=None) -> "Table":
        return dataclasses.replace(
            self, matrix=matrix, columns=tuple(columns or self.columns)
        )

    def to_numpy_valid(self) -> np.ndarray:
        """Materialize the live rows on host (tests / oracles only)."""
        n = int(self.nvalid)
        return np.asarray(self.matrix)[:n]
