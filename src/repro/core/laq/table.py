"""Table-as-matrix representation for Linear Algebra Query processing (LAQ).

The paper (SSDBM'23 §2) converts every relational input into a matrix before
evaluating relational operators as linear-algebra computations.  We keep two
synchronized views of a relation:

* ``matrix`` — the numeric (rows × cols) float32 matrix used by LA operators
  (projection matmuls, aggregation matmuls, fused ML operators).
* ``keys``   — exact int32 arrays for join/group keys.  The paper's CuPy
  implementation also keeps CSR *indices* as integers; on TPU we keep key
  columns as int32 so no key ever round-trips through a float (float32 is only
  exact below 2**24 — SSB date keys like 19920101 would silently corrupt).

Static shapes: XLA requires them, so a Table may be *padded*: ``nvalid`` rows
are live, the rest are padding (zero rows, key = ``PAD_KEY``).  Every LAQ
operator preserves this invariant.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# Padding sentinel for key columns.  int32 max keeps padded keys sorted *after*
# every real key, which searchsorted-based domain construction relies on.
PAD_KEY = np.int32(2**31 - 1)


@dataclasses.dataclass(frozen=True)
class Table:
    """An immutable relation in LAQ (matrix) form.

    Attributes:
      name:    relation name (for plans / debugging).
      columns: ordered column names; ``matrix[:, i]`` is ``columns[i]``.
      matrix:  (capacity, len(columns)) float32 — the LA view.
      keys:    mapping key-column name -> (capacity,) int32 exact values.
               Key columns may also appear in ``matrix`` (rounded); joins and
               group-bys always read from ``keys``.
      nvalid:  number of live rows (int or traced scalar). Rows >= nvalid are
               padding.
      deleted: optional (capacity,) bool tombstone mask.  A tombstoned row
               keeps its slot, data and key (so no derived artifact changes
               shape or row placement — deletion is a pure validity fold);
               ``compact()``/``compacted()`` physically reclaims the slots.
    """

    name: str
    columns: tuple
    matrix: jnp.ndarray
    keys: Mapping[str, jnp.ndarray]
    nvalid: jnp.ndarray | int
    deleted: jnp.ndarray | None = None

    # -- construction -------------------------------------------------------
    @staticmethod
    def from_columns(
        name: str,
        cols: Mapping[str, np.ndarray | jnp.ndarray],
        key_cols: Sequence[str] = (),
        capacity: int | None = None,
    ) -> "Table":
        """Build a Table from named 1-D columns (all equal length)."""
        names = tuple(cols.keys())
        n = int(np.asarray(next(iter(cols.values()))).shape[0])
        cap = capacity if capacity is not None else n
        if cap < n:
            raise ValueError(f"capacity {cap} < rows {n}")
        mat = np.zeros((cap, len(names)), np.float32)
        for j, c in enumerate(names):
            mat[:n, j] = np.asarray(cols[c], np.float32)
        keys = {}
        for c in key_cols:
            k = np.full((cap,), PAD_KEY, np.int32)
            k[:n] = np.asarray(cols[c], np.int32)
            keys[c] = jnp.asarray(k)
        return Table(name, names, jnp.asarray(mat), keys, n)

    # -- accessors -----------------------------------------------------------
    @property
    def capacity(self) -> int:
        return int(self.matrix.shape[0])

    @property
    def ncols(self) -> int:
        return int(self.matrix.shape[1])

    def col_index(self, col: str) -> int:
        return self.columns.index(col)

    def col(self, col: str) -> jnp.ndarray:
        """Float view of a column."""
        return self.matrix[:, self.col_index(col)]

    def key(self, col: str) -> jnp.ndarray:
        """Exact int32 view of a key column."""
        return self.keys[col]

    def valid_mask(self) -> jnp.ndarray:
        m = jnp.arange(self.capacity) < self.nvalid
        if self.deleted is not None:
            m = m & ~self.deleted
        return m

    @property
    def num_deleted(self) -> int:
        """Count of tombstoned rows (0 when no deletions have happened)."""
        return 0 if self.deleted is None else int(jnp.sum(self.deleted))

    @property
    def num_live(self) -> int:
        """Live (non-deleted) rows; requires a concrete ``nvalid``."""
        return self._concrete_nvalid("count live rows of") - self.num_deleted

    def with_matrix(self, matrix: jnp.ndarray, columns=None) -> "Table":
        return dataclasses.replace(
            self, matrix=matrix, columns=tuple(columns or self.columns)
        )

    # -- functional mutation (the Catalog's append/update substrate) ---------
    def _concrete_nvalid(self, what: str) -> int:
        try:
            return int(self.nvalid)
        except jax.errors.ConcretizationTypeError:
            raise ValueError(
                f"cannot {what} table {self.name!r} under a trace: its "
                "nvalid is abstract — data mutation is an offline (concrete) "
                "operation") from None

    def append_rows(self, cols: Mapping[str, "np.ndarray | jnp.ndarray"],
                    *, capacity: int | None = None) -> "Table":
        """A new Table with ``cols`` appended after the live rows.

        ``cols`` must name every matrix column (key columns update both
        views).  Rows land in the padding region when they fit; otherwise
        ``capacity`` (default: geometric growth, ``max(2·cap, n+m)``)
        reallocates — shape growth, which downstream compiled artifacts
        handle by recompiling.  Purely functional: ``self`` is unchanged.
        """
        n = self._concrete_nvalid("append to")
        missing = [c for c in self.columns if c not in cols]
        if missing:
            raise ValueError(
                f"append to {self.name!r} missing columns {missing} "
                f"(need all of {list(self.columns)})")
        unknown = [c for c in cols if c not in self.columns]
        if unknown:
            raise ValueError(
                f"append to {self.name!r}: unknown columns {unknown} "
                f"(columns: {list(self.columns)})")
        vals = {c: np.asarray(cols[c]).reshape(-1) for c in cols}
        m = vals[self.columns[0]].shape[0]
        ragged = [c for c, v in vals.items() if v.shape[0] != m]
        if ragged:
            raise ValueError(
                f"append to {self.name!r}: ragged columns {ragged} "
                f"(expected {m} rows each)")
        new_n = n + m
        cap = self.capacity
        if new_n > cap:
            cap = capacity if capacity is not None else max(2 * cap, new_n)
        if new_n > cap:
            raise ValueError(
                f"append to {self.name!r}: {new_n} rows exceed requested "
                f"capacity {cap}")
        block = np.zeros((m, self.ncols), np.float32)
        for j, c in enumerate(self.columns):
            block[:, j] = vals[c].astype(np.float32)
        if cap == self.capacity:
            matrix = self.matrix.at[n:new_n].set(jnp.asarray(block))
            keys = {}
            for c, k in self.keys.items():
                keys[c] = k.at[n:new_n].set(
                    jnp.asarray(vals[c].astype(np.int32)))
        else:  # grown: reallocate both views (shape change)
            matrix = np.zeros((cap, self.ncols), np.float32)
            matrix[:n] = np.asarray(self.matrix)[:n]
            matrix[n:new_n] = block
            matrix = jnp.asarray(matrix)
            keys = {}
            for c, k in self.keys.items():
                buf = np.full((cap,), PAD_KEY, np.int32)
                buf[:n] = np.asarray(k)[:n]
                buf[n:new_n] = vals[c].astype(np.int32)
                keys[c] = jnp.asarray(buf)
        deleted = self.deleted
        if deleted is not None and cap != self.capacity:
            buf = np.zeros((cap,), bool)
            buf[:self.capacity] = np.asarray(deleted)
            deleted = jnp.asarray(buf)
        return Table(self.name, self.columns, matrix, keys, new_n, deleted)

    def delete_rows(self, row_ids) -> "Table":
        """A new Table with ``row_ids`` tombstoned (validity-masked out).

        Shapes, row placement, keys and data are all unchanged — deletion
        is a pure fold on :meth:`valid_mask`, so every derived artifact
        (PK indices, join pointers, prefused partials) stays valid and a
        compiled plan absorbs it as a shape-preserving delta.  The slots
        (and their keys) are reclaimed only by :meth:`compacted`.
        """
        n = self._concrete_nvalid("delete from")
        ids = np.asarray(row_ids, np.int64).reshape(-1)
        if ids.size and (ids.min() < 0 or ids.max() >= n):
            raise ValueError(
                f"delete_rows on {self.name!r}: row ids out of the live "
                f"range [0, {n})")
        dead = (np.zeros(self.capacity, bool) if self.deleted is None
                else np.array(self.deleted))
        dead[ids] = True
        return dataclasses.replace(self, deleted=jnp.asarray(dead))

    def compacted(self) -> "Table":
        """A new Table with tombstoned rows physically removed.

        Live rows pack down into ``[0, num_live)`` preserving order, the
        capacity is kept, and the tombstone mask is dropped.  Row ids (and
        therefore every pointer-based artifact) change — callers must
        rebuild derived indices, which is why :meth:`Catalog.compact` only
        triggers this past a tombstone-density threshold.
        """
        n = self._concrete_nvalid("compact")
        if self.deleted is None or not self.num_deleted:
            return dataclasses.replace(self, deleted=None)
        keep = ~np.array(self.deleted)[:n]
        new_n = int(keep.sum())
        matrix = np.zeros((self.capacity, self.ncols), np.float32)
        matrix[:new_n] = np.asarray(self.matrix)[:n][keep]
        keys = {}
        for c, k in self.keys.items():
            buf = np.full((self.capacity,), PAD_KEY, np.int32)
            buf[:new_n] = np.asarray(k)[:n][keep]
            keys[c] = jnp.asarray(buf)
        return Table(self.name, self.columns, jnp.asarray(matrix), keys,
                     new_n, None)

    def update_column(self, col: str, row_ids, values) -> "Table":
        """A new Table with ``col`` overwritten at ``row_ids``.

        Key columns cannot be updated in place — changing join keys would
        silently invalidate every PK index and prefused partial built over
        them; delete-and-append is the supported path for key churn.
        """
        n = self._concrete_nvalid("update")
        if col in self.keys:
            raise ValueError(
                f"update_column on key column {col!r} of {self.name!r} is "
                "not supported: key updates invalidate join indices — "
                "append corrected rows instead")
        if col not in self.columns:
            raise ValueError(
                f"unknown column {col!r} on table {self.name!r} "
                f"(columns: {list(self.columns)})")
        ids = np.asarray(row_ids, np.int64).reshape(-1)
        vals = np.asarray(values, np.float32).reshape(-1)
        if ids.shape[0] != vals.shape[0]:
            raise ValueError(
                f"update_column on {self.name!r}: {ids.shape[0]} row ids vs "
                f"{vals.shape[0]} values")
        if ids.size and (ids.min() < 0 or ids.max() >= n):
            raise ValueError(
                f"update_column on {self.name!r}: row ids out of the live "
                f"range [0, {n})")
        j = self.col_index(col)
        matrix = self.matrix.at[jnp.asarray(ids), j].set(jnp.asarray(vals))
        return dataclasses.replace(self, matrix=matrix)

    def to_numpy_valid(self) -> np.ndarray:
        """Materialize the live rows on host (tests / oracles only)."""
        n = int(self.nvalid)
        rows = np.asarray(self.matrix)[:n]
        if self.deleted is not None:
            rows = rows[~np.asarray(self.deleted)[:n]]
        return rows
