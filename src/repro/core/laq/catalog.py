"""Versioned ``Catalog``: the mutable, versioned data surface of the system.

The paper flags dimension-table update rates as the weak point of prefused
evaluation (§4.3, Q6/Q8): the Eq. 1 partials amortize beautifully while the
dimension tables are quasi-static, and not at all if every append forces a
rebuild.  This module makes the data side first-class so *incremental*
maintenance is possible at all:

* every table carries a **monotone version counter**, bumped by each
  transactional mutation (``append`` / ``update_column``),
* each bump records a :class:`TableDelta` — the appended row span, grown
  capacity, or dirtied column/rows — so a derived artifact built at version
  ``v`` can ask :meth:`Catalog.deltas_since` exactly what changed and apply
  the delta path (extend the PK index, prefuse only the new rows, scatter
  the new mask bits) instead of rebuilding,
* compiled plans and serving runtimes key their caches on
  :meth:`Catalog.versions`, so a stale artifact is *detectable* — the
  version-keyed cache can never serve pre-append partials.

``Catalog`` implements ``Mapping[str, Table]``, so every pre-existing call
site that took a plain ``{name: Table}`` dict keeps working; plain mappings
are auto-wrapped **read-only** (:meth:`Catalog.wrap`) — a read-only catalog
never changes version, so artifacts built over it are valid forever, which
is exactly the old frozen-dict contract.

Raven-style prediction-query optimizers (Park et al.) version data and model
artifacts into the plan cache; SystemML's fused-operator reuse conditions on
operand identity.  This is the same move for Eq. 1 partials.
"""
from __future__ import annotations

import dataclasses
from typing import (Dict, Iterator, List, Mapping, NamedTuple, Optional,
                    Sequence, Tuple)

import numpy as np

from .domain import DomainCache
from .table import Table


@dataclasses.dataclass(frozen=True)
class TableDelta:
    """One version bump of one table.

    ``kind`` is ``"append"`` (rows ``[lo, hi)`` are new; ``grew`` marks a
    capacity reallocation — a *shape* change downstream compiled programs
    cannot absorb without recompiling), ``"update"`` (``col`` overwritten
    at ``rows``; shapes unchanged), ``"delete"`` (rows tombstoned — a pure
    validity fold, shapes and row placement unchanged; ``rows`` holds the
    ids, or ``[lo, hi)`` a covering span for bulk deletes), or
    ``"compact"`` (tombstones physically reclaimed — row ids *moved*, so
    every pointer-based artifact must rebuild; ``grew`` is set because the
    rebuild contract is identical to a capacity change).
    """

    version: int                 # version this delta produced
    kind: str                    # "append" | "update" | "delete" | "compact"
    lo: int = 0                  # first appended/deleted row (append/delete)
    hi: int = 0                  # one past the last such row (append/delete)
    grew: bool = False           # shape/placement change (append/compact)
    col: Optional[str] = None    # updated column (update)
    rows: Tuple[int, ...] = ()   # dirtied/deleted row ids (update/delete)


class CatalogReadOnlyError(ValueError):
    """Mutation attempted on a read-only (auto-wrapped) catalog."""


class CatalogHistoryError(ValueError):
    """The delta log was compacted past the requested version.

    Raised by :meth:`Catalog.deltas_since` when an artifact asks for
    history older than the bounded log retains; refresh implementations
    treat it as "cannot delta" and fall back to a full rebuild.
    """


class Catalog(Mapping):
    """A versioned ``Mapping[str, Table]`` with transactional mutation.

    ``append``/``update_column`` validate fully before touching state, then
    atomically swap in the new Table, bump the table's version, and log the
    delta — so a raising call leaves the catalog (and every version) exactly
    as it was.  Zero-row mutations are version no-ops (nothing changed,
    nothing to refresh).  ``domain_cache`` optionally receives appended key
    values (``DomainCache.refresh_table``) so cached key domains stay warm.

    The per-table delta log is *bounded* (``MAX_DELTA_LOG`` entries): a
    long-lived streaming catalog stays O(1) in memory, and an artifact
    stale by more than the log's depth gets :class:`CatalogHistoryError`
    from ``deltas_since`` — its refresh falls back to a full rebuild, which
    needs no history.  Updates dirtying more than ``UPDATE_ROWS_MAX`` rows
    are logged as one covering span rather than per-row ids (refresh then
    recomputes the span — a correct over-approximation — instead of the
    catalog pinning huge id tuples forever).
    """

    #: Per-table delta-log depth; older entries compact away (class-level
    #: default, overridable per instance).
    MAX_DELTA_LOG = 256
    #: Updates dirtying more rows than this log a covering span instead.
    UPDATE_ROWS_MAX = 1024

    def __init__(self, tables: Mapping[str, Table], *,
                 read_only: bool = False,
                 domain_cache: Optional[DomainCache] = None):
        for name, t in tables.items():
            if not isinstance(t, Table):
                raise TypeError(f"catalog entry {name!r} is not a Table "
                                f"(got {type(t).__name__})")
        self._tables: Dict[str, Table] = dict(tables)
        self._versions: Dict[str, int] = {n: 0 for n in self._tables}
        self._deltas: Dict[str, List[TableDelta]] = {
            n: [] for n in self._tables}
        self._floor: Dict[str, int] = {n: 0 for n in self._tables}
        self._unique_cols: Dict[str, set] = {n: set() for n in self._tables}
        self.read_only = read_only
        self.domain_cache = domain_cache

    @staticmethod
    def wrap(catalog: "Mapping[str, Table] | Catalog") -> "Catalog":
        """``catalog`` itself if already a Catalog, else a read-only wrap.

        The back-compat shim behind ``Session``/``compile_query``/
        ``compile_serving``: plain mappings keep working unchanged, they
        just cannot be mutated (their versions are frozen at 0).
        """
        if isinstance(catalog, Catalog):
            return catalog
        return Catalog(catalog, read_only=True)

    # -- Mapping protocol ----------------------------------------------------
    def __getitem__(self, name: str) -> Table:
        return self._tables[name]

    def __iter__(self) -> Iterator[str]:
        return iter(self._tables)

    def __len__(self) -> int:
        return len(self._tables)

    def __repr__(self) -> str:
        inner = ", ".join(f"{n}@v{self._versions[n]}"
                          for n in sorted(self._tables))
        ro = ", read-only" if self.read_only else ""
        return f"Catalog({inner}{ro})"

    # -- versions ------------------------------------------------------------
    def version(self, name: str) -> int:
        """The table's monotone version (0 until first mutated)."""
        return self._versions[name]

    def versions(self, names: Optional[Sequence[str]] = None
                 ) -> Tuple[Tuple[str, int], ...]:
        """Sorted ``(name, version)`` pairs — the cache-key fragment."""
        names = sorted(self._tables if names is None else set(names))
        return tuple((n, self._versions[n]) for n in names)

    def stale_tables(self, versions: Mapping[str, int]) -> Tuple[str, ...]:
        """Names in ``versions`` whose current version differs, sorted.

        The staleness probe shared by every derived artifact (compiled
        plans, serving runtimes, pool entries): each records the versions
        it was built against and asks what moved since.
        """
        return tuple(sorted(n for n, v in versions.items()
                            if self._versions[n] != v))

    def deltas_since(self, name: str, version: int) -> Tuple[TableDelta, ...]:
        """Every delta applied to ``name`` after ``version``, in order.

        Raises :class:`CatalogHistoryError` when ``version`` predates the
        bounded log's retention — the caller must rebuild from the current
        tables instead of replaying deltas.
        """
        if version > self._versions[name]:
            raise ValueError(
                f"table {name!r} is at version {self._versions[name]}, "
                f"before the requested {version} — catalogs only move "
                "forward")
        if version < self._floor[name]:
            raise CatalogHistoryError(
                f"delta history of {name!r} was compacted up to version "
                f"{self._floor[name]} (log depth {self.MAX_DELTA_LOG}); "
                f"version {version} is too stale to delta-refresh — "
                "rebuild from the current table")
        return tuple(d for d in self._deltas[name] if d.version > version)

    def snapshot(self, names: Optional[Sequence[str]] = None
                 ) -> Dict[str, Table]:
        """A plain-dict view of (a subset of) the current tables."""
        names = list(self._tables if names is None else names)
        return {n: self._tables[n] for n in names}

    def note_unique(self, name: str, col: str):
        """Declare ``col`` of table ``name`` a unique (primary-key) column.

        The compiler/serving builders call this for every join arm's PK
        column, so by the time data streams in the catalog knows the join
        contract and :meth:`append` can reject a duplicate key *before*
        committing — otherwise the violation would only surface later,
        inside every artifact's refresh (``PKIndex.extend``), with the
        poisoned delta already in the log.
        """
        if name in self._unique_cols and col in self._tables[name].keys:
            self._unique_cols[name].add(col)

    def _check_unique(self, name: str, vals: Dict[str, np.ndarray]):
        table = self._tables[name]
        n = int(table.nvalid)
        for col in sorted(self._unique_cols[name] & set(vals)):
            new = np.asarray(vals[col], np.int64).reshape(-1)
            if np.unique(new).shape[0] != new.shape[0]:
                raise ValueError(
                    f"append to {name!r}: duplicate values within the "
                    f"appended block of unique key column {col!r}")
            # Tombstoned keys still occupy the PK indices (deletion keeps
            # row placement), so they stay reserved until compact().
            live = np.asarray(table.key(col))[:n]
            dup = new[np.isin(new, live)]
            if dup.size:
                raise ValueError(
                    f"append to {name!r}: keys {dup[:8].tolist()} already "
                    f"exist in unique key column {col!r} — PK uniqueness "
                    "is required by every join over this table (deleted "
                    "keys stay reserved by their tombstones; compact() "
                    "before re-appending them)")

    # -- transactional mutation ----------------------------------------------
    def _writable(self, what: str):
        if self.read_only:
            raise CatalogReadOnlyError(
                f"cannot {what}: this Catalog is read-only (plain mappings "
                "auto-wrap read-only — build a Catalog({...}) explicitly "
                "for a mutable data surface)")

    def append(self, name: str, rows: Mapping[str, np.ndarray], *,
               capacity: Optional[int] = None) -> int:
        """Append ``rows`` (column name → values) to table ``name``.

        Transactional: all validation (unknown table/columns, ragged
        lengths, capacity) happens before any state changes.  Rows landing
        inside the existing padding keep every array shape — downstream
        artifacts refresh without recompiling; overflowing the capacity
        reallocates geometrically and marks the delta ``grew`` (derived
        artifacts fall back to a recompile).  Returns the new version.
        """
        self._writable(f"append to {name!r}")
        if name not in self._tables:
            raise KeyError(f"unknown table {name!r}; catalog has "
                           f"{sorted(self._tables)}")
        self._check_unique(name, dict(rows))
        old = self._tables[name]
        lo = int(old.nvalid)
        new = old.append_rows(rows, capacity=capacity)
        hi = int(new.nvalid)
        if hi == lo:      # zero-row append: validated, but nothing changed
            return self._versions[name]
        grew = new.capacity != old.capacity
        self._commit(name, new, TableDelta(
            version=self._versions[name] + 1, kind="append",
            lo=lo, hi=hi, grew=grew))
        if self.domain_cache is not None:
            self.domain_cache.refresh_table(
                name, {c: np.asarray(rows[c], np.int32)
                       for c in old.keys if c in rows})
        return self._versions[name]

    def update_column(self, name: str, col: str, row_ids, values) -> int:
        """Overwrite ``col`` at ``row_ids`` on table ``name``.

        Non-key columns only (key updates would invalidate join indices —
        ``Table.update_column`` raises).  Shapes never change, so derived
        artifacts refresh by recomputing exactly the dirtied rows.  Returns
        the new version.
        """
        self._writable(f"update {name!r}.{col!r}")
        if name not in self._tables:
            raise KeyError(f"unknown table {name!r}; catalog has "
                           f"{sorted(self._tables)}")
        arr = np.asarray(row_ids).reshape(-1)
        if arr.size == 0:  # zero-row update: nothing changed
            self._tables[name].update_column(col, row_ids, values)
            return self._versions[name]
        new = self._tables[name].update_column(col, row_ids, values)
        if arr.size > self.UPDATE_ROWS_MAX:
            # Log a covering span, not a giant id tuple: refresh recomputes
            # the span (correct over-approximation), the log stays small.
            delta = TableDelta(
                version=self._versions[name] + 1, kind="update", col=col,
                lo=int(arr.min()), hi=int(arr.max()) + 1, rows=())
        else:
            delta = TableDelta(
                version=self._versions[name] + 1, kind="update", col=col,
                rows=tuple(int(i) for i in arr))
        self._commit(name, new, delta)
        return self._versions[name]

    def delete_rows(self, name: str, row_ids) -> int:
        """Tombstone ``row_ids`` on table ``name``.  Returns the new version.

        Deletion is a pure validity fold: shapes, row placement and keys
        are unchanged, so derived artifacts absorb it as a shape-preserving
        delta (the deleted rows drop out of every validity/dimension mask
        on refresh).  Already-deleted ids are ignored; a delete that
        removes nothing is a version no-op.  Deleted keys stay reserved
        (tombstones keep their index slots) until :meth:`compact`.
        """
        self._writable(f"delete from {name!r}")
        if name not in self._tables:
            raise KeyError(f"unknown table {name!r}; catalog has "
                           f"{sorted(self._tables)}")
        old = self._tables[name]
        arr = np.unique(np.asarray(row_ids, np.int64).reshape(-1))
        n = int(old.nvalid)
        if arr.size and (arr.min() < 0 or arr.max() >= n):
            raise ValueError(
                f"delete_rows on {name!r}: row ids out of the live "
                f"range [0, {n})")
        if old.deleted is not None and arr.size:
            arr = arr[~np.asarray(old.deleted)[arr]]
        if arr.size == 0:   # nothing newly deleted: version no-op
            return self._versions[name]
        new = old.delete_rows(arr)
        if arr.size > self.UPDATE_ROWS_MAX:
            # Covering span, like bulk updates: refresh *recomputes* the
            # span rows' validity from the current table (it never assumes
            # every span row is dead), so over-approximation is correct.
            delta = TableDelta(
                version=self._versions[name] + 1, kind="delete",
                lo=int(arr.min()), hi=int(arr.max()) + 1, rows=())
        else:
            delta = TableDelta(
                version=self._versions[name] + 1, kind="delete",
                rows=tuple(int(i) for i in arr))
        self._commit(name, new, delta)
        return self._versions[name]

    def tombstone_fraction(self, name: str) -> float:
        """Deleted fraction of the table's occupied rows (0.0 when clean)."""
        t = self._tables[name]
        n = int(t.nvalid)
        return t.num_deleted / n if n else 0.0

    def compact(self, name: str, *, threshold: float = 0.25) -> bool:
        """Reclaim tombstones on ``name`` once dense enough to pay for it.

        Below ``threshold`` tombstone density this is a no-op returning
        ``False`` — rebuilding every PK index / join pointer / partial for
        a handful of dead rows costs more than the masked rows do.  Past
        it, live rows pack down (``Table.compacted``), freeing the dead
        keys for re-append, and a ``"compact"`` delta is logged with the
        same rebuild contract as capacity growth (row ids moved: every
        pointer-based artifact must rebuild).  Returns ``True`` iff the
        table was rewritten.
        """
        self._writable(f"compact {name!r}")
        if name not in self._tables:
            raise KeyError(f"unknown table {name!r}; catalog has "
                           f"{sorted(self._tables)}")
        if not 0.0 <= threshold <= 1.0:
            raise ValueError(f"threshold {threshold} outside [0, 1]")
        if self.tombstone_fraction(name) < max(threshold,
                                               np.finfo(float).tiny):
            return False
        new = self._tables[name].compacted()
        self._commit(name, new, TableDelta(
            version=self._versions[name] + 1, kind="compact",
            lo=0, hi=int(new.nvalid), grew=True))
        return True

    def _commit(self, name: str, table: Table, delta: TableDelta):
        self._tables[name] = table
        self._versions[name] = delta.version
        log = self._deltas[name]
        log.append(delta)
        while len(log) > self.MAX_DELTA_LOG:
            self._floor[name] = log.pop(0).version


class ChangedSpans(NamedTuple):
    """:func:`changed_spans`'s fold of one table's pending deltas."""

    span: Optional[Tuple[int, int]]   # union [lo, hi) of appended rows
    dirty: Tuple[int, ...]            # sorted distinct updated row ids
    grew: bool                        # shapes/placement changed: rebuild
    deleted: Tuple[int, ...]          # sorted distinct tombstoned row ids


def changed_spans(deltas: Sequence[TableDelta]) -> ChangedSpans:
    """Fold a delta sequence into ``(append_span, dirty, grew, deleted)``.

    The refresh planner's view of "what happened since I was built":
    ``span`` is the union ``[lo, hi)`` of all appended rows (appends are
    contiguous, so the union is one span), ``dirty`` the sorted distinct
    updated row ids (span-logged bulk updates expand here, at refresh
    time, not in the persistent log), ``grew`` whether any append
    reallocated capacity or a compaction moved row ids — the signal that
    forces the rebuild fallback — and ``deleted`` the sorted distinct
    tombstoned row ids, kept **distinct from updates**: an updated row
    has fresh values to recompute, a deleted row must additionally drop
    out of every validity/dimension mask.  Span-logged bulk deletes
    expand here too; consumers must *recompute* those rows' liveness
    from the current table (the span is a covering over-approximation —
    some rows inside it may still be live).
    """
    lo = hi = None
    dirty = set()
    dead = set()
    grew = False
    for d in deltas:
        if d.kind == "append":
            lo = d.lo if lo is None else min(lo, d.lo)
            hi = d.hi if hi is None else max(hi, d.hi)
            grew = grew or d.grew
        elif d.kind == "compact":
            grew = True
        elif d.kind == "delete":
            dead.update(d.rows if d.rows else range(d.lo, d.hi))
        elif d.rows:
            dirty.update(d.rows)
        elif d.hi > d.lo:        # bulk update, logged as a covering span
            dirty.update(range(d.lo, d.hi))
    span = None if lo is None else (lo, hi)
    return ChangedSpans(span, tuple(sorted(dirty)), grew,
                        tuple(sorted(dead)))
