"""Projection as matrix multiplication (paper §2.1).

``π_{cols}(S)`` is evaluated as ``S · M`` where ``M ∈ {0,1}^{c×k}`` is the
*column-mapping matrix*: ``M[i, j] = 1`` iff source column ``i`` becomes target
column ``j``.  (The paper indexes M the other way around in prose but its
Figure 2 multiplies source @ M with M of shape c×k; we follow the figure.)

Two paths:
  * ``mapping_matrix`` + matmul — the paper-faithful LA form.  This is what
    the fusion engine composes with downstream ML operators (``M·L`` etc.).
  * ``project_gather`` — the TPU-optimized path: column projection is a
    gather of columns; XLA lowers it to a zero-FLOP slice/copy.
Both are exposed; tests assert they agree.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from .table import Table


def mapping_matrix(source_cols: Sequence[str], target_cols: Sequence[str],
                   dtype=jnp.float32) -> jnp.ndarray:
    """Build M ∈ {0,1}^{c×k} mapping source columns to target columns."""
    c, k = len(source_cols), len(target_cols)
    m = jnp.zeros((c, k), dtype)
    for j, name in enumerate(target_cols):
        i = list(source_cols).index(name)
        m = m.at[i, j].set(1)
    return m


def project_matmul(table: Table, target_cols: Sequence[str]) -> Table:
    """Paper-faithful projection: one (r×c)·(c×k) matmul on the MXU."""
    m = mapping_matrix(table.columns, target_cols, table.matrix.dtype)
    out = table.matrix @ m
    keys = {c: v for c, v in table.keys.items() if c in target_cols}
    return Table(table.name, tuple(target_cols), out, keys, table.nvalid)


def project_gather(table: Table, target_cols: Sequence[str]) -> Table:
    """Optimized projection: column gather (no FLOPs)."""
    idx = jnp.asarray([table.col_index(c) for c in target_cols])
    out = jnp.take(table.matrix, idx, axis=1)
    keys = {c: v for c, v in table.keys.items() if c in target_cols}
    return Table(table.name, tuple(target_cols), out, keys, table.nvalid)
