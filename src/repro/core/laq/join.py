"""MM-Join: equi-join as (sparse) matrix multiplication (paper §2.3, Alg. 1).

Three physical implementations of the same logical operator:

1. ``mmjoin_dense``   — paper-faithful: build one-hot key matrices MAT_R,
   MAT_S over the common key domain and compute the row-matching matrix
   ``I = MAT_R @ MAT_Sᵀ`` as a dense matmul.  On TPU this runs on the MXU;
   it is the direct analogue of the paper's cuSPARSE spMM (TPUs have no
   sparse engine — see DESIGN.md §2).  O(r_R · r_S · |dom|) FLOPs: only
   viable for small relations, exactly mirroring the paper's observation
   that MM-Join loses to hash join at scale.
2. ``mmjoin_bcoo``    — the same contraction through
   ``jax.experimental.sparse`` BCOO, the closest JAX analogue of the CSR
   spMM the paper uses.
3. ``join_factored``  — the TPU-native form used everywhere at scale: for
   PK–FK joins (the star-schema case, §3.1) the matching matrix I has at
   most one nonzero per fact row, so we store it *factored* as an int32
   pointer vector ``ptr`` with ``I = onehot(ptr)``; applying I is a gather.
   This is the paper's COO insight ("nnz = rows of the materialized table")
   pushed to its limit, and it is what operator fusion composes with.

Materialization (paper §2.3.3) is provided both as explicit row-mapping
matrices ``I_R, I_S`` (faithful) and as gathers (factored).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from .domain import key_domain, positions
from .table import PAD_KEY, Table


# --------------------------------------------------------------------------
# Paper-faithful path: dense one-hot / BCOO row-matching matrix
# --------------------------------------------------------------------------
def onehot_keys(keys: jnp.ndarray, domain: jnp.ndarray,
                dtype=jnp.float32) -> jnp.ndarray:
    """MAT ∈ {0,1}^{rows × |domain|}; all-zero row for padded/missing keys."""
    pos = positions(domain, keys)  # == len(domain) for misses
    return (pos[:, None] == jnp.arange(domain.shape[0])[None, :]).astype(dtype)


def mmjoin_dense(keys_r: jnp.ndarray, keys_s: jnp.ndarray,
                 domain_size: int) -> jnp.ndarray:
    """Row-matching matrix I[i,j] = 1 iff keys_r[i] == keys_s[j] (Alg. 1)."""
    dom = key_domain([keys_r, keys_s], domain_size)
    mat_r = onehot_keys(keys_r, dom)
    mat_s = onehot_keys(keys_s, dom)
    return mat_r @ mat_s.T


def mmjoin_bcoo(keys_r: jnp.ndarray, keys_s: jnp.ndarray, domain_size: int):
    """Faithful sparse path via BCOO spMM (JAX's CSR-equivalent)."""
    from jax.experimental import sparse as jsparse

    dom = key_domain([keys_r, keys_s], domain_size)
    pos_r = positions(dom, keys_r)
    pos_s = positions(dom, keys_s)
    n_dom = dom.shape[0]

    def to_bcoo(pos, nrows):
        rows = jnp.arange(nrows, dtype=jnp.int32)
        vals = (pos < n_dom).astype(jnp.float32)
        idx = jnp.stack([rows, jnp.minimum(pos, n_dom - 1)], axis=1)
        return jsparse.BCOO((vals, idx), shape=(nrows, n_dom))

    mat_r = to_bcoo(pos_r, keys_r.shape[0])
    mat_s = to_bcoo(pos_s, keys_s.shape[0])
    out = jsparse.bcoo_dot_general(
        mat_r, mat_s.todense().T,
        dimension_numbers=(((1,), (0,)), ((), ())))
    return out


# --------------------------------------------------------------------------
# Factored path: PK-FK pointer join (star schema)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FactoredJoin:
    """I = onehot(ptr) with a validity mask, never materialized.

    ptr[i]   = row of the PK-side relation matching FK row i (0 if miss —
               masked out by ``found``).
    found[i] = FK row i has a live match.
    """

    ptr: jnp.ndarray    # (r_fk,) int32
    found: jnp.ndarray  # (r_fk,) bool

    def apply(self, pk_matrix: jnp.ndarray) -> jnp.ndarray:
        """I @ pk_matrix as a gather (zero rows where no match)."""
        rows = jnp.take(pk_matrix, self.ptr, axis=0)
        return rows * self.found[:, None].astype(pk_matrix.dtype)

    def dense(self, pk_rows: int, dtype=jnp.float32) -> jnp.ndarray:
        """Materialize I (tests / faithful comparisons only)."""
        oh = (self.ptr[:, None] == jnp.arange(pk_rows)[None, :]).astype(dtype)
        return oh * self.found[:, None].astype(dtype)


@dataclasses.dataclass(frozen=True)
class PKIndex:
    """Sorted primary-key index: the quasi-static half of ``join_factored``.

    Building it costs the argsort; probing is a searchsorted + two gathers.
    The serving runtime builds one per arm at compile time and probes it
    per request batch — sharing this probe with ``join_factored`` is what
    keeps serving bit-identical to the compiled-query join.
    """

    sorted_pk: jnp.ndarray   # ascending (PAD_KEY sorts last)
    order: jnp.ndarray       # int32 argsort permutation

    def probe(self, fk: jnp.ndarray) -> FactoredJoin:
        pos = jnp.searchsorted(self.sorted_pk, fk).astype(jnp.int32)
        pos_c = jnp.clip(pos, 0, self.sorted_pk.shape[0] - 1)
        hit = (jnp.take(self.sorted_pk, pos_c) == fk) & (fk != PAD_KEY)
        ptr = jnp.take(self.order, pos_c).astype(jnp.int32)
        return FactoredJoin(ptr=jnp.where(hit, ptr, 0), found=hit)

    @property
    def n_live(self) -> int:
        """Number of live (non-PAD_KEY) keys in the index."""
        return int(np.searchsorted(np.asarray(self.sorted_pk), PAD_KEY))

    def extend(self, new_keys, new_row_ids) -> "PKIndex":
        """Sorted-merge appended ``(key, row)`` pairs into the index.

        The incremental half of the Catalog append path: instead of
        re-argsorting all ``capacity`` rows (O(r log r)), the m appended
        keys are sorted alone and merged into the live prefix via two
        searchsorteds (O(r + m log m)).  The result is *array-identical* to
        ``pk_index`` over the appended table — including the PAD_KEY tail,
        whose stable-argsort order is the remaining pad row ids ascending —
        so probes through an extended index are bitwise the cold rebuild's.
        ``new_row_ids`` must be the table's next contiguous row block (the
        Catalog append invariant; probe results are unaffected otherwise,
        but the pad tail would differ from a cold rebuild).  Runs on host:
        index maintenance is an offline, concrete operation.
        """
        sp = np.asarray(self.sorted_pk)
        od = np.asarray(self.order)
        cap = sp.shape[0]
        n_old = int(np.searchsorted(sp, PAD_KEY))
        nk = np.asarray(new_keys, np.int32).reshape(-1)
        nr = np.asarray(new_row_ids, np.int32).reshape(-1)
        if nk.shape[0] != nr.shape[0]:
            raise ValueError(
                f"extend: {nk.shape[0]} keys vs {nr.shape[0]} row ids")
        live = nk != PAD_KEY
        nk, nr = nk[live], nr[live]
        m = nk.shape[0]
        if n_old + m > cap:
            raise ValueError(
                f"extend: {n_old} live + {m} appended keys exceed index "
                f"capacity {cap} — rebuild with pk_index after growing")
        perm = np.argsort(nk, kind="stable")
        nk, nr = nk[perm], nr[perm]
        if np.any(nk[1:] == nk[:-1]):
            raise ValueError("extend: duplicate keys within the appended "
                             "block violate PK uniqueness")
        ins = np.searchsorted(sp[:n_old], nk, side="left")
        dup = np.take(sp, np.clip(ins, 0, max(n_old - 1, 0))) == nk
        if n_old and np.any(dup):
            raise ValueError(
                f"extend: appended keys {nk[dup][:8].tolist()} already "
                "exist in the index (PK uniqueness)")
        n_new = n_old + m
        out_pk = np.full((cap,), PAD_KEY, np.int32)
        out_od = np.zeros((cap,), np.int32)
        new_pos = ins + np.arange(m)
        old_pos = np.arange(n_old) + np.searchsorted(nk, sp[:n_old],
                                                     side="left")
        out_pk[old_pos] = sp[:n_old]
        out_od[old_pos] = od[:n_old]
        out_pk[new_pos] = nk
        out_od[new_pos] = nr
        # Stable-argsort pad tail: the remaining pad rows, ascending.
        out_od[n_new:] = np.arange(n_new, cap, dtype=np.int32)
        return PKIndex(sorted_pk=jnp.asarray(out_pk),
                       order=jnp.asarray(out_od))


def pk_index(pk: jnp.ndarray) -> PKIndex:
    """Sort the PK side once; ``pk`` must have unique live keys and padded
    entries (PAD_KEY) never match."""
    order = jnp.argsort(pk).astype(jnp.int32)
    return PKIndex(sorted_pk=jnp.take(pk, order), order=order)


@dataclasses.dataclass(frozen=True)
class ShardedPKIndex:
    """Row-sharded ``PKIndex``: one independent index slice per shard.

    Shard ``s`` owns the contiguous dimension rows ``[s·rps, (s+1)·rps)``
    and indexes *only* those: ``order`` holds shard-local row offsets, so a
    probe against one slice resolves to device-local rows with no global
    renumbering.  A key owned by another shard simply misses — combining the
    per-shard ``found`` masks (at most one shard can hit, live PKs being
    globally unique) reconstructs the global probe exactly.  This is what
    lets a row-sharded prefused partial be served by device-local
    searchsorted + gathers under ``shard_map``.
    """

    sorted_pk: jnp.ndarray   # (num_shards, rows_per_shard), ascending per row
    order: jnp.ndarray       # (num_shards, rows_per_shard) int32, shard-local

    @property
    def num_shards(self) -> int:
        return int(self.sorted_pk.shape[0])

    @property
    def rows_per_shard(self) -> int:
        return int(self.sorted_pk.shape[1])

    def shard(self, s: int) -> PKIndex:
        """The shard-local ``PKIndex`` slice (tests / host-side probes)."""
        return PKIndex(sorted_pk=self.sorted_pk[s], order=self.order[s])


def shard_pk_index(pk: jnp.ndarray, num_shards: int) -> ShardedPKIndex:
    """Build per-shard ``PKIndex`` slices over equal contiguous row blocks.

    The row count must divide ``num_shards`` — the placement planner's
    ``safe_spec`` fallback replicates non-divisible dimensions instead of
    ever calling this with ragged shards.
    """
    r = int(pk.shape[0])
    if num_shards < 1 or r % num_shards:
        raise ValueError(
            f"cannot shard {r} PK rows into {num_shards} equal slices")
    blocks = pk.reshape(num_shards, r // num_shards)
    order = jnp.argsort(blocks, axis=1).astype(jnp.int32)
    return ShardedPKIndex(
        sorted_pk=jnp.take_along_axis(blocks, order, axis=1), order=order)


def join_factored(fk: jnp.ndarray, pk: jnp.ndarray) -> FactoredJoin:
    """PK-FK equi-join: pointer from each FK row into the PK relation."""
    return pk_index(pk).probe(fk)


# --------------------------------------------------------------------------
# Materialization (paper §2.3.3)
# --------------------------------------------------------------------------
def matching_pairs(I: jnp.ndarray, capacity: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """COO of the row-matching matrix, padded to ``capacity``.

    Returns (rows_R, rows_S, nnz); padded entries point at index
    ``I.shape[*]`` so downstream `take(mode="fill")` yields zero rows.
    """
    ii, jj = jnp.nonzero(I > 0, size=capacity,
                         fill_value=max(I.shape))
    nnz = jnp.sum((I > 0).astype(jnp.int32))
    return ii.astype(jnp.int32), jj.astype(jnp.int32), nnz


def row_mapping_matrices(ii: jnp.ndarray, jj: jnp.ndarray, r_rows: int,
                         s_rows: int, dtype=jnp.float32):
    """Faithful I_R, I_S: target row m comes from R row ii[m] / S row jj[m]."""
    i_r = (ii[:, None] == jnp.arange(r_rows)[None, :]).astype(dtype)
    i_s = (jj[:, None] == jnp.arange(s_rows)[None, :]).astype(dtype)
    return i_r, i_s


def materialize_matmul(I: jnp.ndarray, r: Table, s: Table, capacity: int
                       ) -> Table:
    """Paper-faithful materialization: T = [I_R @ R.matrix | I_S @ S.matrix]."""
    ii, jj, nnz = matching_pairs(I, capacity)
    i_r, i_s = row_mapping_matrices(ii, jj, r.capacity, s.capacity)
    left = i_r @ r.matrix
    right = i_s @ s.matrix
    cols = tuple(f"{r.name}.{c}" for c in r.columns) + tuple(
        f"{s.name}.{c}" for c in s.columns)
    keys = {}
    for name, src, idx, cap in (("r", r, ii, r.capacity), ("s", s, jj, s.capacity)):
        for c, v in src.keys.items():
            keys[f"{src.name}.{c}"] = jnp.take(v, idx, mode="fill",
                                               fill_value=PAD_KEY)
    return Table(f"{r.name}_join_{s.name}", cols,
                 jnp.concatenate([left, right], axis=1), keys, nnz)


def materialize_gather(I: jnp.ndarray, r: Table, s: Table, capacity: int
                       ) -> Table:
    """Optimized materialization: gathers instead of one-hot matmuls."""
    ii, jj, nnz = matching_pairs(I, capacity)
    left = jnp.take(r.matrix, ii, axis=0, mode="fill", fill_value=0.0)
    right = jnp.take(s.matrix, jj, axis=0, mode="fill", fill_value=0.0)
    cols = tuple(f"{r.name}.{c}" for c in r.columns) + tuple(
        f"{s.name}.{c}" for c in s.columns)
    keys = {}
    for src, idx in ((r, ii), (s, jj)):
        for c, v in src.keys.items():
            keys[f"{src.name}.{c}"] = jnp.take(v, idx, mode="fill",
                                               fill_value=PAD_KEY)
    return Table(f"{r.name}_join_{s.name}", cols,
                 jnp.concatenate([left, right], axis=1), keys, nnz)
