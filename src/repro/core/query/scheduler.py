"""Async admission scheduler: open-loop traffic on top of ``ServingRuntime``.

``ServingRuntime.serve`` is a closed loop — one caller, one bucketed batch
at a time, nothing owning *admission*.  Production prediction queries arrive
the other way around (Park et al., arXiv 2206.00136): many concurrent
clients, a mix of point lookups and analytical scans, and a latency SLO per
class.  This module adds the missing admission layer:

Coalescing under an SLO
    Arriving FK requests queue per plan and are coalesced into one
    bucket-shaped batch per *admission step*.  A step fires when the queue
    holds a top bucket's worth of rows, when the oldest queued request has
    waited ``slo_ms`` (the flush deadline), or immediately for work already
    mid-flight — so under load, batches fill naturally while the previous
    step executes, and when idle a lone request waits at most the SLO.

Chunked admission (the sarathi-serve insight, applied to LAQ serving)
    One oversized analytical batch must not occupy the device for its whole
    duration.  Admission is capped at the top bucket per step and a large
    request is served as a *cursor* over consecutive steps, sharing each
    step with whatever interactive rows are pending: point lookups ride
    along in the padded slack instead of queueing behind the scan.

Priority lanes with starvation freedom
    Two lanes per plan — ``"interactive"`` (default) and ``"batch"``.
    Interactive rows are admitted first each step; the batch lane keeps a
    configurable row reservation (``batch_reserve_rows``) whenever it has
    work, so an interactive flood cannot starve analytical progress and an
    analytical scan cannot starve point lookups: both make guaranteed
    per-step progress.

Bounded queues with backpressure
    Each lane's queue is bounded in *rows* (``max_queued_rows``); a
    submission that would exceed the bound is rejected synchronously with
    :class:`SchedulerBackpressureError` — load sheds at admission, not by
    unbounded memory growth in a hidden queue.

Many plans, one drain loop
    Any number of compiled runtimes register with one scheduler
    (per-plan queues); a single drain thread forms and executes steps
    round-robin across plans, so one process serves many compiled plans
    concurrently without a thread per plan fighting over the device.

Refresh fencing (drain-then-swap)
    ``ServingRuntime.refresh`` swaps the quasi-static state pytree; doing
    that under an in-flight batch would hand one request rows from two data
    generations.  :meth:`AdmissionScheduler.refresh` fences: new admissions
    pause, *started* requests run to completion (their remaining chunks are
    the only admissible work), the swap happens on a drained device, then
    admission resumes.  Every request therefore sees exactly one catalog
    version, and scheduled results stay bit-exact vs synchronous
    ``serve`` on the same data generation.

Bit-exactness
    The bucket programs are row-independent (per-row probes + gathers +
    per-row model application), so coalescing, chunking, and lane
    interleaving never change any request's values — scheduled results are
    bitwise identical to ``ServingRuntime.serve`` of the same request, the
    property the tests and the open-loop bench assert.

Entry points: ``Session.scheduler()`` / ``QueryBuilder.serve(async_=True)``
(which returns a :class:`ScheduledPlan` handle), or construct an
:class:`AdmissionScheduler` directly and :meth:`~AdmissionScheduler.register`
any runtime.  ``submit`` returns a ``concurrent.futures.Future``.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Deque, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .explain import ExplainReport
from .serving import ServingRuntime

#: Default flush deadline: a queued request is admitted at most this many
#: milliseconds after submission even when the bucket has not filled.
DEFAULT_SLO_MS = 2.0

#: Default per-lane queue bound, in rows (not requests): backpressure
#: rejects submissions that would push a lane past this.
DEFAULT_MAX_QUEUED_ROWS = 16384

#: Priority lanes, admission order per step (after mid-flight work).
LANES = ("interactive", "batch")

#: Per-lane completed-request latency samples kept for percentiles.
STATS_WINDOW = 4096


class SchedulerBackpressureError(RuntimeError):
    """Submission rejected: the plan's lane queue is at its row bound.

    The named rejection error of the bounded-queue contract — callers shed
    or retry with their own policy instead of the scheduler buffering
    without limit.
    """


class SchedulerClosedError(RuntimeError):
    """The scheduler was closed; no further submissions are accepted."""


@dataclasses.dataclass
class _Pending:
    """One submitted request, from queue to resolved future.

    ``served`` is the admission cursor: requests larger than one step's
    capacity are admitted chunk by chunk across steps, accumulating their
    output segments in ``parts``.
    """

    fks: List[np.ndarray]
    n: int
    lane: str
    future: Future
    t_submit: float
    served: int = 0
    parts: List[np.ndarray] = dataclasses.field(default_factory=list)


class _PlanQueue:
    """Per-plan admission state: two bounded lanes + mid-flight work."""

    def __init__(self, name: str, runtime: ServingRuntime,
                 max_queued_rows: int, batch_reserve: int):
        self.name = name
        self.runtime = runtime
        self.max_queued_rows = max_queued_rows
        self.batch_reserve = batch_reserve
        self.lanes: Dict[str, Deque[_Pending]] = {
            lane: collections.deque() for lane in LANES}
        self.inflight: Dict[str, Deque[_Pending]] = {
            lane: collections.deque() for lane in LANES}
        # Unadmitted rows per lane (backpressure accounting): decremented
        # as rows are admitted, wherever the request currently lives.
        self.queued_rows: Dict[str, int] = {lane: 0 for lane in LANES}
        self.lat: Dict[str, Deque[float]] = {
            lane: collections.deque(maxlen=STATS_WINDOW) for lane in LANES}
        self.steps = 0
        self.admitted_rows = 0
        self.padded_rows = 0
        self.rejected = 0

    def has_inflight(self) -> bool:
        return any(self.inflight[lane] for lane in LANES)

    def has_work(self) -> bool:
        return self.has_inflight() or any(self.lanes[la] for la in LANES)

    def flush_state(self, now: float, *, fenced: bool, slo_s: float,
                    closed: bool) -> Tuple[bool, Optional[float]]:
        """``(ready, seconds_until_deadline)`` for the drain loop's poll.

        Mid-flight work is always ready (its next chunk never waits);
        queued work is ready when it fills the top bucket, when the oldest
        request hits the SLO deadline, or when the scheduler is closing
        (final drain).  During a fence only mid-flight work is admissible.
        """
        if self.has_inflight():
            return True, None
        if fenced:
            return False, None
        rows = sum(self.queued_rows.values())
        if rows == 0:
            return False, None
        if closed or rows >= self.runtime.buckets[-1]:
            return True, None
        oldest = min(q[0].t_submit for q in self.lanes.values() if q)
        if now >= oldest + slo_s:
            return True, None
        return False, oldest + slo_s - now


@dataclasses.dataclass(frozen=True)
class ScheduledPlan:
    """A registered plan's handle: submit requests, read its stats."""

    scheduler: "AdmissionScheduler"
    name: str
    runtime: ServingRuntime

    def submit(self, requests, *, lane: str = "interactive") -> Future:
        """Enqueue one request batch; see :meth:`AdmissionScheduler.submit`."""
        return self.scheduler.submit(self.name, requests, lane=lane)

    def stats(self) -> Dict:
        """This plan's admission/latency stats (see scheduler ``stats``)."""
        return self.scheduler.stats()[self.name]


class AdmissionScheduler:
    """Request queues + one drain loop over any number of serving plans.

    ``slo_ms`` is the coalescing flush deadline (0 serves immediately);
    ``max_queued_rows`` bounds each lane's queue in rows (backpressure);
    ``batch_reserve_rows`` is the batch lane's guaranteed per-step row
    share while it has work (default: a quarter of the plan's top bucket),
    the starvation-freedom knob in both directions.  ``auto_start=False``
    skips the drain thread — tests and steppers then drive admission
    deterministically via :meth:`step`.

    Thread contract: ``submit`` is safe from any thread; execution happens
    on the single drain thread, so the underlying runtimes are never
    entered concurrently.  Do not call ``runtime.serve``/``refresh``
    directly while a scheduler owns the runtime — route refreshes through
    :meth:`refresh`, which fences in-flight work first.
    """

    def __init__(self, *, slo_ms: float = DEFAULT_SLO_MS,
                 max_queued_rows: int = DEFAULT_MAX_QUEUED_ROWS,
                 batch_reserve_rows: Optional[int] = None,
                 auto_start: bool = True):
        if slo_ms < 0:
            raise ValueError(f"slo_ms must be >= 0, got {slo_ms}")
        if max_queued_rows < 1:
            raise ValueError(
                f"max_queued_rows must be >= 1, got {max_queued_rows}")
        self.slo_ms = float(slo_ms)
        self._slo_s = float(slo_ms) / 1e3
        self._max_queued_rows = int(max_queued_rows)
        self._batch_reserve_rows = batch_reserve_rows
        self._plans: Dict[str, _PlanQueue] = {}
        self._cv = threading.Condition()
        self._closed = False
        self._fences = 0
        self._refresh_trail: Deque[str] = collections.deque(maxlen=32)
        self._drained = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if auto_start:
            self._thread = threading.Thread(
                target=self._drain_loop, name="admission-drain", daemon=True)
            self._thread.start()

    # -- lifecycle -----------------------------------------------------------
    def __enter__(self) -> "AdmissionScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close(cancel=exc[0] is not None)

    def close(self, *, cancel: bool = False) -> None:
        """Stop the scheduler; drains queued work first unless ``cancel``.

        With ``cancel=True`` every unresolved future fails with
        :class:`SchedulerClosedError` instead (mid-flight requests
        included — their partial output is dropped).
        """
        with self._cv:
            if self._closed:
                return
            self._closed = True
            if cancel:
                for plan in self._plans.values():
                    for store in (plan.inflight, plan.lanes):
                        for lane in LANES:
                            while store[lane]:
                                p = store[lane].popleft()
                                plan.queued_rows[lane] -= p.n - p.served
                                self._fail(p, SchedulerClosedError(
                                    "scheduler closed before the request "
                                    "was served"))
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join()
        else:
            while self._step() > 0:   # manual mode: drain inline
                pass

    @property
    def closed(self) -> bool:
        return self._closed

    # -- registration --------------------------------------------------------
    def register(self, runtime: ServingRuntime, name: Optional[str] = None,
                 *, max_queued_rows: Optional[int] = None,
                 batch_reserve_rows: Optional[int] = None) -> ScheduledPlan:
        """Add a compiled plan to the drain loop; idempotent per runtime.

        Returns the plan's :class:`ScheduledPlan` handle.  ``name``
        defaults to ``plan<N>``; per-plan ``max_queued_rows`` /
        ``batch_reserve_rows`` override the scheduler defaults.
        """
        with self._cv:
            if self._closed:
                raise SchedulerClosedError("cannot register on a closed "
                                           "scheduler")
            for existing in self._plans.values():
                if existing.runtime is runtime:
                    return ScheduledPlan(self, existing.name, runtime)
            if name is None:
                name = f"plan{len(self._plans)}"
            if name in self._plans:
                raise ValueError(f"plan name {name!r} already registered "
                                 f"(names: {sorted(self._plans)})")
            reserve = batch_reserve_rows
            if reserve is None:
                reserve = self._batch_reserve_rows
            if reserve is None:
                reserve = max(1, runtime.buckets[-1] // 4)
            self._plans[name] = _PlanQueue(
                name, runtime,
                max_queued_rows or self._max_queued_rows,
                min(int(reserve), runtime.buckets[-1]))
            self._cv.notify_all()
        return ScheduledPlan(self, name, runtime)

    def is_registered(self, runtime: ServingRuntime) -> bool:
        with self._cv:
            return any(p.runtime is runtime for p in self._plans.values())

    @property
    def plan_names(self) -> Tuple[str, ...]:
        with self._cv:
            return tuple(self._plans)

    # -- submission ----------------------------------------------------------
    def submit(self, plan: str, requests, *,
               lane: str = "interactive") -> Future:
        """Enqueue one request batch; returns a Future of the predictions.

        ``requests`` takes every form ``ServingRuntime.serve`` accepts and
        is validated synchronously (missing/ragged/sentinel-key errors
        raise here, in the caller).  ``lane`` is ``"interactive"`` (point
        lookups, admitted first) or ``"batch"`` (analytical scans, chunked
        through the reserved share).  Raises
        :class:`SchedulerBackpressureError` when the lane's row bound is
        hit and :class:`SchedulerClosedError` after :meth:`close`.
        """
        if lane not in LANES:
            raise ValueError(f"unknown lane {lane!r}; lanes are {LANES}")
        with self._cv:
            if plan not in self._plans:
                raise KeyError(f"unknown plan {plan!r}; registered: "
                               f"{sorted(self._plans)}")
            pq = self._plans[plan]
        fks = pq.runtime._normalize(requests)
        n = int(fks[0].shape[0])
        future: Future = Future()
        if n == 0:
            future.set_result(
                jnp.zeros((0, pq.runtime.out_width), jnp.float32))
            return future
        with self._cv:
            if self._closed:
                raise SchedulerClosedError(
                    "scheduler is closed; no further submissions")
            queued = pq.queued_rows[lane]
            if queued + n > pq.max_queued_rows:
                pq.rejected += 1
                raise SchedulerBackpressureError(
                    f"plan {plan!r} lane {lane!r} is at capacity: {queued} "
                    f"rows queued + {n} submitted > bound "
                    f"{pq.max_queued_rows}; shed load or retry later")
            pq.lanes[lane].append(_Pending(
                fks=fks, n=n, lane=lane, future=future,
                t_submit=time.perf_counter()))
            pq.queued_rows[lane] += n
            self._cv.notify_all()
        return future

    # -- refresh fencing -----------------------------------------------------
    def refresh(self, runtime: Optional[ServingRuntime] = None
                ) -> Dict[str, str]:
        """Drain-then-swap: fence in-flight work, then refresh runtimes.

        New admissions pause; requests already started (admission cursor
        past zero) run to completion so no request ever spans two data
        generations; then each registered runtime's ``refresh()`` applies
        pending catalog deltas on a quiesced device (``runtime`` narrows
        the swap to one plan — the fence is still global).  Queued-but-
        unstarted requests are served entirely post-swap.  Returns the
        per-plan refresh decision lines.
        """
        with self._cv:
            self._fences += 1
            self._drained.clear()
            self._cv.notify_all()
        try:
            if self._thread is None:
                while any(p.has_inflight() for p in self._plans.values()):
                    self._step()
            else:
                self._drained.wait()
            with self._cv:
                targets = [p for p in self._plans.values()
                           if runtime is None or p.runtime is runtime]
            out = {p.name: p.runtime.refresh() for p in targets}
            with self._cv:
                for name, line in out.items():
                    self._refresh_trail.append(f"{name}: {line}")
            return out
        finally:
            with self._cv:
                self._fences -= 1
                self._cv.notify_all()

    def explain(self) -> ExplainReport:
        """Structured scheduler report, unified with plan/runtime explains.

        ``trail`` carries the most recent fenced-refresh decision lines
        (``"<plan>: <runtime refresh line>"``); ``extras`` summarize the
        fleet (plan count, admission counters, backpressure rejections).
        """
        with self._cv:
            extras = (
                ("plans", tuple(sorted(self._plans))),
                ("steps", sum(p.steps for p in self._plans.values())),
                ("admitted_rows",
                 sum(p.admitted_rows for p in self._plans.values())),
                ("rejected",
                 sum(p.rejected for p in self._plans.values())),
                ("closed", self._closed),
            )
            return ExplainReport(kind="scheduler",
                                 trail=tuple(self._refresh_trail),
                                 extras=extras)

    # -- stats ---------------------------------------------------------------
    def stats(self) -> Dict[str, Dict]:
        """Per-plan admission/latency report.

        For each plan: ``steps`` (admission steps executed),
        ``admitted_rows`` / ``padded_rows`` (bucket-shape overhead),
        ``rejected`` (backpressure count), current ``queued_rows``, and
        per-lane completed-request latency percentiles in ms — measured
        submit→result per *request*, which is what an open-loop client
        sees, unlike the runtime's per-dispatch bucket windows.
        """
        with self._cv:
            out: Dict[str, Dict] = {}
            for name, plan in self._plans.items():
                lanes = {}
                for lane in LANES:
                    ts = plan.lat[lane]
                    entry: Dict[str, float] = {"count": len(ts)}
                    if ts:
                        ms = np.asarray(ts) * 1e3
                        entry.update(
                            p50=float(np.percentile(ms, 50)),
                            p95=float(np.percentile(ms, 95)),
                            p99=float(np.percentile(ms, 99)))
                    lanes[lane] = entry
                out[name] = {
                    "steps": plan.steps,
                    "admitted_rows": plan.admitted_rows,
                    "padded_rows": plan.padded_rows,
                    "rejected": plan.rejected,
                    "queued_rows": dict(plan.queued_rows),
                    "lanes": lanes,
                }
            return out

    # -- the drain loop ------------------------------------------------------
    def _drain_loop(self) -> None:
        while True:
            with self._cv:
                while True:
                    now = time.perf_counter()
                    ready, wait = self._poll_locked(now)
                    if ready:
                        break
                    if self._closed:
                        return
                    if self._fences and not any(
                            p.has_inflight() for p in self._plans.values()):
                        self._drained.set()
                    self._cv.wait(timeout=wait)
                steps = []
                for plan in ready:
                    take, total = self._form_step_locked(plan)
                    if total:
                        steps.append((plan, take, total))
            for plan, take, total in steps:
                self._exec_step(plan, take, total)

    def _poll_locked(self, now: float
                     ) -> Tuple[List[_PlanQueue], Optional[float]]:
        ready: List[_PlanQueue] = []
        wait: Optional[float] = None
        for plan in self._plans.values():
            r, w = plan.flush_state(now, fenced=self._fences > 0,
                                    slo_s=self._slo_s, closed=self._closed)
            if r:
                ready.append(plan)
            elif w is not None:
                wait = w if wait is None else min(wait, w)
        return ready, wait

    def _form_step_locked(self, plan: _PlanQueue
                          ) -> Tuple[List[Tuple[_Pending, int, int]], int]:
        """One admission step: which rows of which requests run next.

        Capacity is the top bucket.  Order: mid-flight interactive, queued
        interactive (up to capacity minus the batch reservation while the
        batch lane has work), then mid-flight batch and queued batch into
        everything left.  Under a fence only mid-flight work is admitted.
        Mutates cursors/queues; execution happens outside the lock.
        """
        cap = plan.runtime.buckets[-1]
        left = cap
        take: List[Tuple[_Pending, int, int]] = []

        def drain(src: Deque[_Pending], budget: int,
                  to_inflight: bool) -> int:
            taken = 0
            while src and budget > 0:
                p = src[0]
                if p.future.cancelled():
                    src.popleft()
                    plan.queued_rows[p.lane] -= p.n - p.served
                    continue
                c = min(p.n - p.served, budget)
                take.append((p, p.served, c))
                p.served += c
                plan.queued_rows[p.lane] -= c
                taken += c
                budget -= c
                if p.served == p.n:
                    src.popleft()
                elif to_inflight:
                    src.popleft()
                    plan.inflight[p.lane].append(p)
            return taken

        if self._fences:
            for lane in LANES:
                left -= drain(plan.inflight[lane], left, False)
        else:
            batch_work = (plan.inflight["batch"] or plan.lanes["batch"])
            reserve = min(plan.batch_reserve, left) if batch_work else 0
            budget = left - reserve
            taken = drain(plan.inflight["interactive"], budget, False)
            taken += drain(plan.lanes["interactive"], budget - taken, True)
            left -= taken
            left -= drain(plan.inflight["batch"], left, False)
            left -= drain(plan.lanes["batch"], left, True)
        return take, cap - left

    def _exec_step(self, plan: _PlanQueue,
                   take: List[Tuple[_Pending, int, int]], total: int) -> None:
        runtime = plan.runtime
        try:
            num_arms = len(runtime.request_keys)
            if len(take) == 1:
                p0, s0, c0 = take[0]
                cols = [p0.fks[i][s0:s0 + c0] for i in range(num_arms)]
            else:
                cols = [np.concatenate([p.fks[i][s:s + c]
                                        for p, s, c in take])
                        for i in range(num_arms)]
            bucket, padded = runtime._admit(cols)
            body = runtime._execute(padded, bucket)[:total]
            done = time.perf_counter()
            offset = 0
            for p, s, c in take:
                seg = body[offset:offset + c]
                offset += c
                if s == 0 and c == p.n:
                    self._resolve(plan, p, seg, done)
                else:
                    # Chunked request: segments assemble on host (matches
                    # the oversized path of ``serve``, incl. the sharded
                    # eager-concat miscompile workaround).
                    p.parts.append(np.asarray(seg))
                    if p.served == p.n:
                        self._resolve(
                            plan, p,
                            jnp.asarray(np.concatenate(p.parts, axis=0)),
                            done)
            plan.steps += 1
            plan.admitted_rows += total
            plan.padded_rows += bucket - total
        except Exception as exc:   # noqa: BLE001 — futures carry the error
            for p, _, _ in take:
                self._fail(p, exc)

    def _resolve(self, plan: _PlanQueue, p: _Pending, result,
                 done: float) -> None:
        try:
            p.future.set_result(result)
        except InvalidStateError:
            return    # cancelled between admission and completion
        plan.lat[p.lane].append(done - p.t_submit)

    @staticmethod
    def _fail(p: _Pending, exc: BaseException) -> None:
        try:
            p.future.set_exception(exc)
        except InvalidStateError:
            pass

    # -- manual stepping (deterministic tests / external drivers) ------------
    def _step(self) -> int:
        """Form + execute one admission step per plan with work, now.

        Ignores the SLO wait (anything queued is admitted immediately,
        subject to fence/lane rules) — the deterministic drive used when
        ``auto_start=False``.  Returns total rows admitted this call.
        """
        with self._cv:
            steps = []
            for plan in self._plans.values():
                if not (plan.has_inflight()
                        or (not self._fences and plan.has_work())):
                    continue
                take, total = self._form_step_locked(plan)
                if total:
                    steps.append((plan, take, total))
        served = 0
        for plan, take, total in steps:
            self._exec_step(plan, take, total)
            served += total
        return served

    def step(self) -> int:
        """Public manual drive (only without the drain thread)."""
        if self._thread is not None:
            raise RuntimeError(
                "step() is for auto_start=False schedulers; the drain "
                "thread owns admission here")
        return self._step()
