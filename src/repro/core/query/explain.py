"""One structured ``explain()`` surface for every executable artifact.

``CompiledQuery``, ``ServingRuntime`` and ``AdmissionScheduler`` each keep a
plan-decision string (``plan.reason``) plus a bounded refresh/fallback trail;
before this module each surfaced them differently (a raw string attribute, a
string return value, nothing at all).  :class:`ExplainReport` unifies them:

* ``plan_reason`` — the *base* planner decision line (cost-model choices,
  backend picks), without accumulated refresh notes;
* ``trail`` — the bounded refresh/fallback decision trail, newest last;
* ``shared_artifacts`` — the :class:`~.multiquery.ArtifactPool` keys this
  artifact references (empty when compiled without a pool);
* ``as_dict()`` — a stable, JSON-friendly mapping for tooling;
* ``str(report)`` — the legacy one-line string form (``plan_reason`` plus
  the trail, ``"; "``-joined), so ``print(q.explain())`` reads exactly like
  the old ``plan.reason``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ExplainReport:
    """Structured plan/refresh introspection shared by every artifact kind.

    ``kind`` is ``"compiled"`` / ``"serving"`` / ``"scheduler"``; backend
    fields are ``None`` where the artifact has no such choice (a scheduler
    has no backends; a serving runtime has no join/agg backend).
    """

    kind: str
    backend: Optional[str] = None
    join_backend: Optional[str] = None
    agg_backend: Optional[str] = None
    serve_backend: Optional[str] = None
    plan_reason: str = ""
    trail: Tuple[str, ...] = ()
    shared_artifacts: Tuple[tuple, ...] = ()
    extras: Tuple[Tuple[str, object], ...] = ()

    def as_dict(self) -> dict:
        """A stable JSON-friendly form (tuples become lists).

        The key set is fixed across artifact kinds so tooling can consume
        reports uniformly; absent choices are ``None``/empty rather than
        missing keys.
        """
        return {
            "kind": self.kind,
            "backend": self.backend,
            "join_backend": self.join_backend,
            "agg_backend": self.agg_backend,
            "serve_backend": self.serve_backend,
            "plan_reason": self.plan_reason,
            "trail": list(self.trail),
            "shared_artifacts": [list(k) for k in self.shared_artifacts],
            "extras": {k: v for k, v in self.extras},
        }

    def __str__(self) -> str:
        return "; ".join(p for p in (self.plan_reason, *self.trail) if p)
