"""Randomized snowflake workloads fuzzing the compiler against numpy.

The compiler's correctness story leans on algebraic identities — factored
joins compose associatively, predicates fold into validity vectors, Eq. 1
prefusion distributes over arms — and hand-written tests only exercise the
schemas their authors thought of.  This module generates *random* snowflake
schemas (chain depth ≤ 3, fanout ≤ 3 per node), random predicates (up to
two per column, mixing strict and non-strict bounds so the rewrite
engine's interval merging is exercised), models, prediction filters
(``model_preds``) and aggregate sets, runs them
end-to-end through :func:`compile_query` across fused/nonfused ×
segment/matmul, and checks the results **bit-exact** against an independent
float64 numpy oracle.  Sampled cases additionally run with ``rewrite="off"``
(the IR rewrite engine's escape hatch — on/off must agree bit-for-bit),
stream the fact axis out-of-core (``stream_chunk_rows=16``), append rows
and re-check the delta-refresh path against a cold rebuild, and serve FK
request batches through :func:`compile_serving`.

Bit-exactness is by construction, not tolerance: every generated column is
integer-valued in a small range, model weights and tree thresholds are small
integers, and row counts are bounded, so each float32 sum/product the engine
computes is exactly representable and equals the float64 oracle value
(``div`` value expressions are excluded for the same reason; ``mean`` is
checked via float32 division of the exact sum/count pair, mirroring the
engine's lowering).  Any mismatch is therefore a real compiler bug, never
numerical noise.

Every case derives from a single integer seed (``generate_case(seed)`` is
deterministic), so a CI failure replays locally with one command::

    python scripts/fuzz_repro.py --seed 12345

Table capacities are drawn from a small canonical set so jit traces reuse
across cases where shapes collide.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..fusion.operators import LinearOperator, tree_from_arrays
from ..laq.catalog import Catalog
from ..laq.selection import Pred
from ..laq.table import PAD_KEY, Table
from .compile import compile_query
from .ir import (COUNT_STAR, PREDICTION, Aggregate, ArmSpec, ChainLink,
                 GroupKey, PredictionFilter, PredictiveQuery)
from .serving import compile_serving, requests_from_rows
from .session import Session

#: Chain shape bounds (per the snowflake subsystem contract).
MAX_DEPTH = 3        # head + up to 2 further hops
MAX_FANOUT = 3       # children per chain node
MAX_LINKS = 4        # total sub-dimensions per arm

#: Canonical capacities: shapes collide across cases → jit trace reuse.
_FACT_CAPS = (64, 128)
_DIM_CAPS = (16, 32)

_BACKENDS = ("fused", "nonfused")
_AGG_BACKENDS = ("segment", "matmul")


# --------------------------------------------------------------------------
# Schema + data generation
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FuzzCase:
    """One generated workload: tables + query, fully derived from ``seed``."""

    seed: int
    tables: Dict[str, Table]
    query: PredictiveQuery

    def catalog(self) -> Catalog:
        """A fresh mutable catalog over (copies of) the case tables."""
        return Catalog(dict(self.tables))


def _make_table(rng: np.random.Generator, name: str, n: int, cap: int,
                key_data: Dict[str, np.ndarray],
                val_cols: Sequence[str]) -> Table:
    """An integer-valued Table: key columns + small feature/measure cols.

    Key columns are mirrored into the matrix (repo convention), padded with
    ``PAD_KEY`` beyond the live rows; value columns draw from [-4, 4].
    """
    data = dict(key_data)
    for c in val_cols:
        data[c] = rng.integers(-4, 5, n)
    cols = tuple(data)
    matrix = np.zeros((cap, len(cols)), np.float32)
    for j, c in enumerate(cols):
        matrix[:n, j] = data[c]
    keys = {}
    for c in key_data:
        a = np.full(cap, PAD_KEY, np.int32)
        a[:n] = np.asarray(key_data[c], np.int32)
        keys[c] = jnp.asarray(a)
    return Table(name, cols, jnp.asarray(matrix), keys, n)


def _rand_pred(rng: np.random.Generator, col: str) -> Pred:
    op = rng.choice(["==", ">", ">=", "<", "<=", "between", "in"])
    if op == "between":
        lo = int(rng.integers(-4, 2))
        return Pred(col, "between", (lo, lo + int(rng.integers(1, 5))))
    if op == "in":
        vals = sorted(int(v) for v in rng.choice(
            np.arange(-4, 5), size=int(rng.integers(2, 5)), replace=False))
        return Pred(col, "in", tuple(vals))
    return Pred(col, str(op), int(rng.integers(-3, 4)))


def _rand_preds(rng: np.random.Generator, col: str) -> Tuple[Pred, ...]:
    """1–2 predicates on the *same* column: stacked strict/non-strict
    bounds exercise the rewrite engine's interval analysis (``_col_bounds``
    strictness merging) that single-pred columns never reach."""
    preds = [_rand_pred(rng, col)]
    if rng.random() < 0.4:
        preds.append(_rand_pred(rng, col))
    return tuple(preds)


def _gen_dim_tree(rng: np.random.Generator, arm_id: int
                  ) -> Tuple[List[dict], List[ChainLink]]:
    """One arm's dimension tree: head spec + ChainLinks (depth/fanout caps).

    Each spec dict carries ``name / n / cap / nfeat / children`` — tables
    are built afterwards so parents can carry FK columns to every child.
    """
    counter = [0]

    def new_spec(depth: int) -> dict:
        counter[0] += 1
        name = f"a{arm_id}d{counter[0]}"
        spec = {"name": name, "n": int(rng.integers(4, 17)),
                "cap": int(rng.choice(_DIM_CAPS)),
                "nfeat": int(rng.integers(0, 3)), "children": []}
        if depth < MAX_DEPTH:
            for _ in range(int(rng.integers(0, MAX_FANOUT + 1))):
                if counter[0] > MAX_LINKS:
                    break
                if rng.random() < 0.45:
                    spec["children"].append(new_spec(depth + 1))
        return spec

    head = new_spec(1)
    links: List[ChainLink] = []

    def flatten(spec: dict, is_head: bool):
        for i, child in enumerate(spec["children"]):
            # parent=None exercises the previous-hop default, but only
            # where declaration order makes the previous hop THE parent:
            # the first child declared immediately after its parent.
            explicit = not (i == 0 and (is_head or rng.random() < 0.5))
            preds = ()
            if rng.random() < 0.35 and child["nfeat"]:
                preds = _rand_preds(rng, f"{child['name']}_f0")
            links.append(ChainLink(
                table=child["name"],
                fk_col=f"{spec['name']}_to_{child['name']}",
                pk_col=f"{child['name']}_pk",
                feature_cols=tuple(f"{child['name']}_f{k}"
                                   for k in range(child["nfeat"])),
                preds=preds,
                parent=spec["name"] if explicit else None))
            flatten(child, False)

    flatten(head, True)
    return [head], links


def _collect_specs(spec: dict) -> List[dict]:
    out = [spec]
    for c in spec["children"]:
        out.extend(_collect_specs(c))
    return out


def generate_case(seed: int) -> FuzzCase:
    """Deterministically generate one random snowflake workload."""
    rng = np.random.default_rng(seed)
    n_fact = int(rng.integers(16, 49))
    fact_cap = int(rng.choice(_FACT_CAPS))
    n_arms = int(rng.integers(1, 3))

    arms: List[ArmSpec] = []
    tables: Dict[str, Table] = {}
    group_candidates: List[Tuple[str, str]] = [("fact", "f_g")]
    fact_keys: Dict[str, np.ndarray] = {}

    for a in range(n_arms):
        (head,), links = _gen_dim_tree(rng, a)
        specs = {s["name"]: s for s in _collect_specs(head)}
        # Build child-first so parents can reference child sizes for FKs.
        order = list(reversed(_collect_specs(head)))
        for s in order:
            name, n = s["name"], s["n"]
            key_data = {f"{name}_pk": np.arange(n),
                        f"{name}_g": rng.integers(0, 3, n)}
            for child in s["children"]:
                # Child FKs miss sometimes (values past the child's PKs).
                key_data[f"{name}_to_{child['name']}"] = rng.integers(
                    0, child["n"] + 2, n)
            feats = [f"{name}_f{k}" for k in range(s["nfeat"])]
            tables[name] = _make_table(rng, name, n, s["cap"], key_data,
                                       feats)
            group_candidates.append((name, f"{name}_g"))
        head_preds = ()
        if rng.random() < 0.3 and head["nfeat"]:
            head_preds = _rand_preds(rng, f"{head['name']}_f0")
        arms.append(ArmSpec(
            head["name"], f"fk{a}", f"{head['name']}_pk",
            tuple(f"{head['name']}_f{k}" for k in range(head["nfeat"])),
            head_preds, tuple(links)))
        fact_keys[f"fk{a}"] = rng.integers(0, head["n"] + 2, n_fact)
        del specs

    fact_keys["f_g"] = rng.integers(0, 3, n_fact)
    measures = ["m0", "m1"]
    tables["fact"] = _make_table(rng, "fact", n_fact, fact_cap, fact_keys,
                                 measures)

    # Model: none (pure relational) / linear / GEMM decision tree — over
    # however many features the arms contribute.
    width = sum(a.feature_width for a in arms)
    model = None
    roll = rng.random()
    if width and roll < 0.45:
        out = int(rng.integers(1, 3))
        model = LinearOperator(jnp.asarray(
            rng.integers(-2, 3, (width, out)), jnp.float32))
    elif width and roll < 0.7:
        depth = int(rng.integers(1, 3))
        p = 2 ** depth - 1
        model = tree_from_arrays(rng.integers(0, width, p),
                                 rng.integers(-3, 4, p).astype(np.float32),
                                 width)

    fact_preds = ()
    if rng.random() < 0.4:
        fact_preds = _rand_preds(rng, str(rng.choice(measures)))

    # Prediction filters: exercise the model_preds validity fold and (for
    # trees selecting a single leaf) the distillation rewrite.  Integer
    # weights × integer features keep linear predictions exactly
    # representable, so the threshold comparisons are noise-free.
    model_preds: Tuple[PredictionFilter, ...] = ()
    if model is not None and rng.random() < 0.4:
        out_dim = int(model.l)
        o = int(rng.integers(0, out_dim))
        if hasattr(model, "F"):  # tree: one-hot leaf indicator outputs
            model_preds = (PredictionFilter(o, "==", 1.0),)
        else:
            op = str(rng.choice([">", ">=", "<", "<="]))
            model_preds = (PredictionFilter(o, op,
                                            float(rng.integers(-6, 7))),)

    group_keys: Tuple[GroupKey, ...] = ()
    num_groups: int = 8
    if rng.random() < 0.6:
        picks = rng.choice(len(group_candidates),
                           size=int(rng.integers(1, 3)), replace=False)
        group_keys = tuple(GroupKey(*group_candidates[int(i)], 3, 0)
                           for i in picks)
        num_groups = 3 ** len(group_keys)

    aggs: List[Aggregate] = []
    n_aggs = int(rng.integers(1, 4))
    values: List[object] = ["m0", "m1", ("mul", "m0", "m1"),
                            ("sub", "m0", "m1"), ("add", "m0", "m1")]
    if model is not None:
        values.append(PREDICTION)
    for i in range(n_aggs):
        op = str(rng.choice(["sum", "count", "mean", "min", "max"]))
        value = (COUNT_STAR if op == "count"
                 else values[int(rng.integers(0, len(values)))])
        aggs.append(Aggregate(value, op, f"agg{i}"))

    q = PredictiveQuery("fact", tuple(arms), fact_preds, model,
                        group_keys, tuple(aggs), num_groups,
                        model_preds=model_preds)
    return FuzzCase(seed, tables, q)


# --------------------------------------------------------------------------
# Float64 numpy oracle (chain-aware)
# --------------------------------------------------------------------------
def _np_views(t: Table) -> Tuple[Dict[str, np.ndarray],
                                 Dict[str, np.ndarray]]:
    n = int(t.nvalid)
    m = np.asarray(t.matrix)
    cols = {c: m[:n, i].astype(np.float64)
            for i, c in enumerate(t.columns)}
    keys = {c: np.asarray(v)[:n] for c, v in t.keys.items()}
    return cols, keys


def _np_pred(p: Pred, cols, keys) -> np.ndarray:
    src = keys[p.col] if p.col in keys else cols[p.col]
    if p.op == "between":
        lo, hi = p.value
        return (src >= lo) & (src <= hi)
    if p.op == "in":
        return np.isin(src, np.asarray(list(p.value)))
    import operator
    ops = {"==": operator.eq, "!=": operator.ne, "<": operator.lt,
           "<=": operator.le, ">": operator.gt, ">=": operator.ge}
    return ops[p.op](src, p.value)


def _np_value(cols, expr) -> np.ndarray:
    if isinstance(expr, str):
        return cols[expr]
    op, *args = expr
    if op == "col":
        return _np_value(cols, args[0])
    a, b = (_np_value(cols, x) for x in args)
    return {"add": a.__add__, "sub": a.__sub__, "mul": a.__mul__}[op](b)


def _np_model(model, x: np.ndarray) -> np.ndarray:
    if hasattr(model, "L"):
        return x @ np.asarray(model.L, np.float64)
    b = (x @ np.asarray(model.F, np.float64)
         > np.asarray(model.v, np.float64)[None, :]).astype(np.float64)
    score = b @ np.asarray(model.H, np.float64)
    return (score == np.asarray(model.h, np.float64)[None, :]
            ).astype(np.float64)


def _np_resolve(tables: Dict[str, Table], q: PredictiveQuery):
    """Per-fact-row chain resolution: validity, features, per-table ptrs.

    The oracle resolves every hop with a dict lookup per row — no factored
    joins, no composition — so agreement with the engine genuinely
    cross-checks the algebra.  Returns ``(valid, feats, ptrs, keymaps)``:
    ``ptrs[name]`` is the fact-granularity row pointer into table ``name``
    (clipped to 0 on misses; misses are already folded into ``valid``).
    """
    fcols, fkeys = _np_views(tables[q.fact])
    n = len(fkeys[next(iter(fkeys))]) if fkeys else int(
        tables[q.fact].nvalid)
    valid = np.ones(n, bool)
    for p in q.fact_preds:
        valid &= _np_pred(p, fcols, fkeys)
    feats: List[np.ndarray] = []
    ptrs: Dict[str, np.ndarray] = {}
    keymaps: Dict[str, Dict[str, np.ndarray]] = {}

    for arm in q.arms:
        chain = [(arm.table, None, arm.fk_col, arm.pk_col, arm.feature_cols,
                  arm.preds)]
        prev = arm.table
        for lk in arm.links:
            chain.append((lk.table,
                          lk.parent if lk.parent is not None else prev,
                          lk.fk_col, lk.pk_col, lk.feature_cols, lk.preds))
            prev = lk.table
        for name, parent, fk_col, pk_col, fcols_t, preds in chain:
            dcols, dkeys = _np_views(tables[name])
            pkmap = {int(k): i for i, k in enumerate(dkeys[pk_col])}
            if parent is None:
                fk = fkeys[fk_col]
                ptr = np.asarray([pkmap.get(int(k), -1) for k in fk])
            else:
                pfk = keymaps[parent][fk_col]
                pptr = ptrs[parent]
                ptr = np.asarray([pkmap.get(int(pfk[j]), -1)
                                  for j in np.clip(pptr, 0, None)])
                ptr = np.where(pptr < 0, -1, ptr)
            ok = ptr >= 0
            if preds:
                dmask = np.ones(len(dkeys[pk_col]), bool)
                for p in preds:
                    dmask &= _np_pred(p, dcols, dkeys)
                ok = ok & dmask[np.clip(ptr, 0, None)]
            valid &= ok
            ptrs[name] = ptr
            keymaps[name] = dkeys
            for c in fcols_t:
                feats.append(dcols[c][np.clip(ptr, 0, None)])
    return valid, feats, ptrs, keymaps


def np_oracle(tables: Dict[str, Table], q: PredictiveQuery) -> dict:
    """Brute-force float64 reference for a (possibly snowflake) query.

    Returns ``{"rows": int, "scalars": {name: (w,) float64} | None,
    "groups": {code: {name: (w,) float64}} | None}``.  ``mean`` divides
    the exact sum/count pair in float32, matching the engine's lowering
    bit-for-bit on integer-valued data.
    """
    fcols, fkeys = _np_views(tables[q.fact])
    valid, feats, ptrs, keymaps = _np_resolve(tables, q)
    n = valid.shape[0]
    pred = None
    if q.model is not None:
        x = (np.stack(feats, axis=1) if feats
             else np.zeros((n, 0), np.float64))
        pred = _np_model(q.model, x)

    if q.model_preds:
        # AND semantics make miss-row feature garbage irrelevant: those
        # rows are already invalid, and on valid rows the float32 engine
        # predictions are exact, so the comparisons agree bit-for-bit.
        import operator
        ops = {"==": operator.eq, "!=": operator.ne, "<": operator.lt,
               "<=": operator.le, ">": operator.gt, ">=": operator.ge}
        for f in q.model_preds:
            valid = valid & ops[f.op](pred[:, f.output], f.value)

    codes = None
    if q.group_keys:
        codes = np.zeros(n, np.int64)
        for gk in q.group_keys:
            col = (fkeys[gk.col] if gk.table == "fact" or gk.table == q.fact
                   else keymaps[gk.table][gk.col][
                       np.clip(ptrs[gk.table], 0, None)])
            codes = codes * int(gk.bound) + (col.astype(np.int64)
                                             - gk.offset)

    group_rows: Optional[Dict[int, np.ndarray]] = None
    if q.group_keys:
        group_rows = {}
        for i in np.nonzero(valid)[0]:
            group_rows.setdefault(int(codes[i]), []).append(int(i))

    def reduce(arr: np.ndarray, op: str) -> np.ndarray:
        if op == "count":
            return np.asarray([np.float64(arr.shape[0])])
        if op == "mean":
            # Engine lowers mean as fused f32 sum / f32 count; both are
            # exact here, so f32 division reproduces it bit-for-bit.
            s = arr.sum(axis=0).astype(np.float32)
            return (s / np.float32(arr.shape[0])).astype(np.float64)
        if op == "min":
            return arr.min(axis=0)
        if op == "max":
            return arr.max(axis=0)
        return arr.sum(axis=0)

    groups = {} if q.group_keys else None
    scalars = None if q.group_keys else {}
    for agg in q.aggregates:
        if agg.op == "count":
            v2 = np.ones((n, 1), np.float64)
        else:
            vals = (pred if agg.value == PREDICTION
                    else _np_value(fcols, agg.value))
            v2 = vals if vals.ndim > 1 else vals[:, None]
        if q.group_keys:
            for code, idx in group_rows.items():
                groups.setdefault(code, {})[agg.name] = reduce(v2[idx],
                                                               agg.op)
        elif valid.any():
            scalars[agg.name] = reduce(v2[valid], agg.op)
        else:
            # min/max/mean over zero rows have no identity; _compare only
            # checks sum/count (== 0) for empty scalar results.
            scalars[agg.name] = None
    return {"rows": int(valid.sum()), "scalars": scalars, "groups": groups}


def np_serving_oracle(tables: Dict[str, Table], q: PredictiveQuery
                      ) -> np.ndarray:
    """Per-fact-row serving reference: model(features) × arm validity.

    Serving ignores fact-side predicates (requests are FK tuples), so only
    the join/chain/dimension-predicate validity gates each row.
    """
    q_nofact = dataclasses.replace(q, fact_preds=())
    valid, feats, _, _ = _np_resolve(tables, q_nofact)
    n = valid.shape[0]
    x = np.stack(feats, axis=1) if feats else np.zeros((n, 0), np.float64)
    out = _np_model(q.model, x)
    return out * valid[:, None]


# --------------------------------------------------------------------------
# The checker
# --------------------------------------------------------------------------
PAD_GROUP = np.int64(2**31 - 1)  # matches laq.aggregation.PAD_GROUP


def _engine_maps(res, names) -> Dict[str, Dict[int, np.ndarray]]:
    groups = np.asarray(res["groups"])
    live = groups != PAD_GROUP
    out = {}
    for name in names:
        vals = np.asarray(res[name], np.float64)
        v2 = vals if vals.ndim > 1 else vals[:, None]
        out[name] = {int(g): v2[i] for i, g in enumerate(groups)
                     if live[i]}
    return out


def _compare(res, want, q: PredictiveQuery, label: str) -> List[str]:
    """Bit-exact engine-vs-oracle comparison; returns mismatch strings."""
    bad = []
    if int(res["rows"]) != want["rows"]:
        bad.append(f"{label}: rows {int(res['rows'])} != {want['rows']}")
        return bad
    names = [a.name for a in q.aggregates]
    if want["groups"] is None:
        if want["rows"] == 0:
            # min/max/mean over zero rows are unspecified; sum/count must
            # still be exactly zero.
            for a in q.aggregates:
                if a.op in ("sum", "count"):
                    got = np.asarray(res[a.name], np.float64)
                    if np.any(got != 0):
                        bad.append(f"{label}: {a.name} nonzero on empty")
            return bad
        for a in q.aggregates:
            got = np.atleast_1d(np.asarray(res[a.name], np.float64)).ravel()
            exp = np.atleast_1d(want["scalars"][a.name]).ravel()
            if not np.array_equal(got, exp):
                bad.append(f"{label}: {a.name} {got} != {exp}")
        return bad
    got_maps = _engine_maps(res, names)
    for a in q.aggregates:
        exp_g = {c: v[a.name] for c, v in want["groups"].items()}
        got_g = got_maps[a.name]
        if set(got_g) != set(exp_g):
            bad.append(f"{label}: {a.name} group codes "
                       f"{sorted(got_g)} != {sorted(exp_g)}")
            continue
        for c, exp in exp_g.items():
            if not np.array_equal(got_g[c].ravel(),
                                  np.asarray(exp).ravel()):
                bad.append(f"{label}: {a.name}[{c}] "
                           f"{got_g[c].ravel()} != "
                           f"{np.asarray(exp).ravel()}")
    return bad


def _append_rows(rng: np.random.Generator, cat: Catalog,
                 tables: Dict[str, Table], name: str) -> bool:
    """Append 1-2 integer-valued rows to ``name`` (inside capacity).

    Fresh PKs continue the arange; FK/value columns draw from the same
    integer ranges as generation.  Returns False when the table is full.
    """
    t = cat[name]
    n = int(t.nvalid)
    k = min(int(rng.integers(1, 3)), t.capacity - n)
    if k <= 0:
        return False
    rows = {}
    for c in t.columns:
        if c.endswith("_pk"):
            rows[c] = np.arange(n, n + k)
        elif c in t.keys:
            # FK or group col: stay in the generated integer range (child
            # sizes are ≤ 16+2; group cols < 3) — misses are fine.
            hi = 3 if c.endswith("_g") else 18
            rows[c] = rng.integers(0, hi, k)
        else:
            rows[c] = rng.integers(-4, 5, k)
    cat.append(name, rows)
    tables[name] = cat[name]
    return True


def check_case(seed: int, *, full: bool = True) -> List[str]:
    """Run one generated case end-to-end; returns mismatch descriptions.

    ``full`` runs the whole matrix — fused/nonfused × segment/matmul,
    plus the append→refresh-vs-cold-rebuild and serving checks; quick mode
    (``full=False``) runs fused+nonfused against the oracle only, for
    high-case-count smoke budgets.
    """
    case = generate_case(seed)
    q = case.query
    tables = dict(case.tables)
    want = np_oracle(tables, q)
    bad: List[str] = []

    combos = [(b, ab) for b in _BACKENDS for ab in
              (_AGG_BACKENDS if full else _AGG_BACKENDS[:1])]
    for backend, agg_backend in combos:
        res = compile_query(Catalog(dict(tables)), q, backend=backend,
                            agg_backend=agg_backend).run()
        bad += _compare(res, want, q,
                        f"seed={seed} {backend}/{agg_backend}")

    if full:
        # Rewrite escape hatch: the unrewritten plan must agree with the
        # (default, rewritten) plans above — both sides check against the
        # same oracle, so on/off bit-exactness is transitive.
        res_off = compile_query(Catalog(dict(tables)), q,
                                rewrite="off").run()
        bad += _compare(res_off, want, q, f"seed={seed} rewrite=off")

        # Out-of-core: stream the fact axis in small chunks and fold —
        # chunked f32 sums of integer-valued data stay exact.
        res_st = compile_query(Catalog(dict(tables)), q,
                               stream_chunk_rows=16).run()
        bad += _compare(res_st, want, q, f"seed={seed} stream[16]")

    if full:
        # Append to a random participating table → session refresh must
        # equal a cold compile of the new catalog.
        rng = np.random.default_rng(seed + 1)
        cat = Catalog(dict(tables))
        sess = Session(cat)
        sess.compile(q).run()
        names = sorted({t for a in q.arms
                        for t in (a.table, *(lk.table for lk in a.links))}
                       | {q.fact})
        target = names[int(rng.integers(0, len(names)))]
        if _append_rows(rng, cat, tables, target):
            res = sess.compile(q).run()
            want2 = np_oracle(tables, q)
            bad += _compare(res, want2, q,
                            f"seed={seed} refresh[{target}]")
            cold = compile_query(Catalog(dict(tables)), q).run()
            bad += _compare(cold, want2, q, f"seed={seed} cold[{target}]")
        want = want2 = None

    if full and q.model is not None and q.arms:
        # Serving returns raw predictions per request row — prediction
        # filters live in the aggregate path only (compile_serving rejects
        # them), so serve the unfiltered query.
        qs = dataclasses.replace(q, model_preds=())
        rt = compile_serving(Catalog(dict(tables)), qs)
        n = int(tables[q.fact].nvalid)
        reqs = requests_from_rows(tables[q.fact], qs, np.arange(n))
        got = np.asarray(rt.serve(reqs), np.float64)
        exp = np_serving_oracle(tables, qs)
        if not np.array_equal(got, exp):
            i = int(np.argmax(np.any(got != exp, axis=1)))
            bad.append(f"seed={seed} serving: row {i} "
                       f"{got[i]} != {exp[i]}")
    return bad


@dataclasses.dataclass(frozen=True)
class FuzzReport:
    """Outcome of a fuzz run: seeds exercised + surviving mismatches."""

    cases: int
    seeds: Tuple[int, ...]
    failures: Tuple[str, ...]

    @property
    def ok(self) -> bool:
        return not self.failures

    def summary(self) -> str:
        if self.ok:
            return f"fuzz: {self.cases} cases, 0 mismatches"
        return (f"fuzz: {len(self.failures)} mismatches in {self.cases} "
                f"cases; replay: python scripts/fuzz_repro.py --seed "
                f"{self.failures[0].split()[0].split('=')[1]}")


def run_fuzz(cases: int, *, seed: int = 0, full_every: int = 4
             ) -> FuzzReport:
    """Fuzz ``cases`` randomized workloads derived from base ``seed``.

    Case seeds are ``seed*10_000 + i`` (stable, disjoint between bases).
    Every ``full_every``-th case runs the full matrix (all four
    backend combos + refresh + serving); the rest run the quick oracle
    check, keeping large case counts affordable in CI.
    """
    seeds = tuple(seed * 10_000 + i for i in range(cases))
    failures: List[str] = []
    for i, s in enumerate(seeds):
        failures.extend(check_case(s, full=(i % full_every == 0)))
    return FuzzReport(cases, seeds, tuple(failures))
