"""Predictive-query compiler: selection ⋈ star join ⋈ model ⋈ group-by,
lowered to one jitted linear-algebra program.

The paper's thesis (§3) is that relational operators and ML predictions share
a linear-algebra substrate, so a *whole* predictive query can be planned and
fused as one program.  This package is that planner/compiler, fronted by one
declarative surface: the :class:`Session` query builder.

Session API (the single entry point)
------------------------------------
A ``Session`` binds a catalog (+ optional device mesh) once; a fluent,
immutable builder then describes the pipeline and drives all three
execution modes::

    sess = Session(catalog, mesh=None)
    q = (sess.query("lineorder")
         .join("date", on=("lo_orderdate", "datekey"),
               features=["d_month"], where=[("d_year", "==", 1993)])
         .where(("lo_discount", "between", (1, 3)))
         .predict(model)
         .group_by(("date", "d_year", 8, 1992), num_groups="auto")
         .agg(revenue="sum(lo_revenue)", preds=("mean", PREDICTION),
              n="count"))

    q.run()                    # whole-query aggregates — one fused program
    q.rows(row_ids)            # row predictions for a fact-row batch
    q.serve(buckets=(8, 64))   # bucketed dynamic-batch ServingRuntime

One compiled program computes *all* named aggregates over the shared
join/model work: ``sum``/``count``/``mean``/``min``/``max``, with mean
lowered as a fused sum/count and min/max through segment ops on both
aggregation backends.  Mesh placement, sharding thresholds, interpret mode
and plan-cache keys live on the session; plans are cached structurally
(:func:`~repro.core.query.session.query_key`), so equivalent pipelines —
fluent, hand-built IR, or registry rebuilds — never re-trace.

Multi-query optimization (the shared-artifact pool + batched execution)
-----------------------------------------------------------------------
A session is a *multi-query* optimizer, not just a plan cache.  Every plan
and serving runtime compiled through it acquires its physical artifacts —
PK indices, factored join pointers, predicate dim-masks, pre-fused model
partials — from one reference-counted :class:`ArtifactPool`
(``sess.pool``) keyed by arm-level content hashes.  N plans sharing a join
arm hold ONE pkindex/pointer array; N plans pre-fusing the same model over
the same dimension hold ONE partial.  The payoffs::

    sess.pool.stats()        # entries/hits/misses/bytes, per artifact kind
    catalog.append(...)      # a refresh touches each shared artifact ONCE
    sess.run_all([q1, ...])  # structurally compatible plans stack into one
                             # jitted program (leading query axis, vmapped)
                             # — one dispatch per class, bit-exact vs run()
    sess.evict(q)            # release a query's pool references; the last
                             # holder of an artifact frees it

``plan_query`` hears about sharing too: a join arm already resident in the
pool amortizes its maintenance cost over all holders, which the planner
folds into the fusion decision (``sharing=…x`` in the plan reason).
``compiled.explain()`` / ``runtime.explain()`` / ``scheduler.explain()``
all return a unified :class:`ExplainReport` whose ``shared_artifacts``
lists the pool keys a plan holds; ``str(report)`` is the legacy one-line
trail, ``report.as_dict()`` the machine-readable form.

Migration from the deprecated pre-Session entry points (thin shims that now
raise ``DeprecationWarning`` — the ``PredictiveQuery`` IR itself is still
the stable compiler contract):

=============================================  =============================
Old call                                       Session call
=============================================  =============================
``compile_query(catalog, q, **kw)``            ``sess.compile(q, **kw)`` or
                                               ``sess.bind(q).compile(**kw)``
``compile_query(catalog, q).run()``            ``sess.bind(q).run()``
``[compile_query(c, q).run() for q in qs]``    ``sess.run_all(qs)`` (pooled
                                               artifacts + one stacked
                                               program per class)
``CompiledQuery.predict_rows(ids)``            ``builder.rows(ids)``
``compile_serving(catalog, q, buckets=b)``     ``builder.serve(buckets=b)``
``compile_query(..., mesh=m, shard_...=...)``  ``Session(catalog, mesh=m,
                                               shard_...)`` once, per-call
                                               plumbing gone
``compiled_plan(name, data)`` (SSB registry)   ``ssb_session(data).compile(
                                               QUERY_IR[name]())``
``compile_query({'t': table, ...}, q)``        ``Session(Catalog({...}))``
(plain-dict catalog, auto-wrapped read-only;   — versioned, appendable,
deprecated)                                    pool-shared
hand-built ``PredictiveQuery(...)``            ``sess.query(fact).join(...)
                                               .where(...).predict(...)
                                               .group_by(...).agg(...)``
frozen ``{name: Table}`` dict + full           ``Session(Catalog({...}))``;
rebuild after data changes                     ``catalog.append(...)`` /
                                               ``.update_column(...)``,
                                               cached plans/runtimes
                                               refresh *in place* (delta
                                               path, zero retraces while
                                               shapes hold)
=============================================  =============================

Data lifecycle
--------------
``Session(catalog)`` accepts a :class:`~repro.core.laq.Catalog` — the
versioned data surface.  Every table carries a monotone version counter;
``catalog.append(table, rows)`` / ``catalog.update_column(...)`` bump it
transactionally and log the delta.  Plan/runtime cache keys include the
participating tables' versions, so a stale artifact is impossible to serve:
the next lookup refreshes it in place — ``PKIndex.extend`` sorted merges,
``prefuse_rows`` over only the new dimension rows, mask scatters — with
zero retraces while shapes hold (appends within a table's padded capacity).
Capacity growth recompiles, with the reason on ``explain()``.  Plain dicts
auto-wrap read-only (the old frozen contract, unchanged).

The full lifecycle surface and what each mutation costs a cached plan:

========================================  ===================================
Catalog call                              Cached-plan consequence
========================================  ===================================
``append(t, rows)`` within capacity       delta refresh in place: sorted
                                          ``PKIndex.extend`` merges, block
                                          join probes, ``prefuse_rows`` over
                                          only the new rows — zero retraces
``append(t, rows)`` beyond capacity       recompile/rebuild (shapes changed),
                                          reason on ``explain()``
``update_column(t, col, ids, vals)``      delta refresh of just the dirty
                                          rows (masks/partials rescattered)
``delete_rows(t, ids)``                   tombstone: shapes, keys and row
                                          placement all kept, so the delta
                                          path applies — deleted rows drop
                                          out through the validity fold,
                                          zero retraces
``compact(t)`` (tombstone GC, fires       row ids are rewritten, so every
past ``tombstone_fraction`` threshold)    referencing plan recompiles with
                                          ``compaction:<t> rewrote row ids``
========================================  ===================================

Snowflake chains (multi-hop dimensions)
---------------------------------------
An arm generalizes past a star: :class:`ChainLink` hangs sub-dimensions off
a dimension (or off an earlier link), TPC-DS-style, to depth 3 with fanout
up to 3 per node.  Factored PK–FK joins compose associatively —
``ptr_chain = take(link_ptr, head_ptr)`` — so the compiler collapses each
chain *inner-out* into one head-granularity virtual dimension before
prefusing it into the Eq. 1 partial form; the result is bit-exact with
materializing the chain as a flat pre-joined dimension
(:func:`~repro.core.query.snowflake.materialize_chains` is the executable
statement of that identity).  Sub-dimension predicates fold into the
chain's validity vector exactly like flat dimension predicates.  The
planner costs prefuse-through vs materialize-at-hop-k per chain
(``chain[head->hop->…]: …`` in the plan reason); pooled sessions share one
collapsed chain per content key and refresh it once per sub-dimension
append; serving prefuses chains offline so the request shape is unchanged.
Build chains fluently — ``.join(..., via=[("nation", "c_nationkey",
"n_pk", ["n_gdp"])])``, or just chain ``.join`` calls whose FK lives on an
already-joined dimension — or hand ``ArmSpec(links=(...))`` to the IR.

The subsystem is fuzzed: ``core.query.workload`` generates random
snowflake schemas/queries/models/prediction filters and checks every
lowering bit-exact against a float64 numpy oracle (``python
scripts/fuzz_repro.py --seed N`` replays any failure deterministically;
``--rewrite-matrix`` re-runs a seed with the rewrite engine on and off).

Query/model co-optimization (the rewrite engine)
------------------------------------------------
Because the paper expresses query *and* model as one linear-algebra
program, optimizations can cross the boundary between them.
:mod:`~repro.core.query.rewrite` runs a deterministic rule engine over the
IR before planning (``compile_query(rewrite="on")``, the default; ``"off"``
is the escape hatch):

``distill_tree_filter``
    ``.predict(tree, where=[(leaf, "==", 1.0)])`` filters rows on a tree
    prediction (:class:`PredictionFilter`).  When the filters select
    exactly one leaf, its root-to-leaf path conditions compile into
    ordinary dimension/link predicates and the model drops out of the
    online phase entirely — predict-then-filter becomes a pure relational
    query.
``fold_constant_inputs``
    An equality predicate pinning a feature column folds ``u · L[row]``
    into a model bias (carried by arm 0's prefused partial) and removes
    the input.
``project_zero_weights``
    Features with all-zero model rows leave the arms and the model.
``prune_tree_branches``
    Range predicates that decide a tree-node comparison for every
    surviving row fold that node into the compare vector ``h``.

Every rule is exact — the rewritten plan's ``run()`` is bit-identical to
the unrewritten plan's on all lowerings (the fuzzer checks on vs off per
case) — and data-independent, so rewritten plans refresh through the same
delta paths.  The planner costs the rewritten query against the original
(:func:`~repro.core.query.planner.estimate_query_cost`) and keeps the
winner; the fired-rule trail surfaces in ``plan.reason``
(``rewrite=[...]``) and ``explain()`` extras.

Out-of-core execution (fact streaming)
--------------------------------------
When the fact table's working set exceeds device memory — or the caller
pins a chunk size — the compiled program streams: the fact axis is
block-partitioned, per-chunk partial aggregates are folded through a
carried segment accumulator (sum/count exactly; min/max as masked segment
folds), and host→device transfer of chunk *i+1* overlaps compute of chunk
*i* (double buffering with donated chunk buffers).  Results are bit-exact
vs the in-core fused/gather/segment program — same adds, same order, the
chunk boundary never splits a segment update.  Enabled per call
(``compile(q, stream_chunk_rows=..., memory_budget_bytes=...)``) or
session-wide (``Session(catalog, memory_budget_bytes=...)``); the planner
explains its in-core-vs-streaming choice in ``plan_reason`` and
``explain().extras["stream"]`` describes the chunking.  Dimension-side
artifacts (partials, pointers, masks) are built once and shared across all
chunks — streaming composes with the artifact pool unchanged.

IR node → paper construct
-------------------------
======================  =====================================================
IR node                 Paper construct
======================  =====================================================
``Pred`` (via arms /    §2.2 selection as a binary filter vector s ∈ {0,1}ⁿ —
``fact_preds``)         folded into the matching matrix's validity instead of
                        multiplied through the data (mask_select)
``ArmSpec``             §2.3/§3.1 MM-Join arm: Iⱼ = MAT_fact · MAT_dimᵀ
                        (Alg. 1), kept factored as FK pointers for PK–FK
``PredictiveQuery``     §3 predictive pipeline  γ ∘ model ∘ ⋈ ∘ σ
``model=Linear…``       Eq. 1: T·L = Σⱼ Iⱼ(Bⱼ Mⱼ L) — the linear prefix is
                        *pre-fused* into each dimension table
``model=DecisionTree…`` Eq. 3 / Fig. 5: ((T F > v) H) == h with per-dimension
                        node-ownership masks Wⱼ
``GroupKey``            §2.4.2 composite group codes (sort-unique); the radix
                        ``bound`` is one digit of the code
``Aggregate``           §2.4/Fig. 4 group-by: one-hot matmul (faithful) or
                        segment ops (optimized) — compiler-chosen per the
                        whole aggregate set (``plan_aggregation``)
======================  =====================================================

``plan_query`` extends the paper's Eq. 2/4 fusion boundary with selection
selectivity, the Fig. 4 aggregation-backend choice costed over the combined
aggregate set, and the serving-kernel choice (``plan_serving_backend``);
its thresholds are keyed by ``jax.default_backend()``
(``planner_threshold`` / ``PLANNER_THRESHOLDS``) with CPU-seeded defaults,
so TPU calibration is a table entry.  ``num_groups="auto"`` sizes the group
dimension from the measured code domain on the offline concrete-array path.

Serving
-------
``builder.serve(buckets=...)`` (→ :func:`compile_serving`) compiles the
*online phase alone* over a ``(batch, fk...)`` request pytree and returns a
:class:`ServingRuntime` — the production entry point when requests are
arbitrary incoming key tuples rather than fact rows.  Each batch is
PAD_KEY-padded up to the smallest configured bucket and dispatched through
that bucket's jitted program (one trace per bucket, ever); a session mesh
shards the quasi-static partials per ``plan_partition_spec``; the Pallas
kernels (``fused_star_gather`` / ``tree_predict``) lower the gather-sum when
shapes fit.  Request keys equal to the padding sentinel are rejected with
:class:`SentinelKeyError` — they would be indistinguishable from padding.

Async serving (the admission scheduler)
---------------------------------------
``ServingRuntime.serve`` is synchronous: one caller, one batch at a time —
right for batch scoring, wrong for concurrent open-loop traffic.
``builder.serve(async_=True)`` (or ``sess.scheduler().register(runtime)``)
puts the runtime behind an :class:`AdmissionScheduler`: a per-plan request
queue whose single drain loop coalesces arriving FK requests into
bucket-shaped batches under a latency SLO (``slo_ms``), serves oversized
analytical batches chunk-by-chunk so point lookups interleave instead of
queueing behind them (per-step admission capped at the top bucket), keeps
two priority lanes (``"interactive"`` first, ``"batch"`` with a reserved
per-step row share — starvation-free both ways), and sheds load at a
bounded row queue with :class:`SchedulerBackpressureError`.  ``submit``
returns a Future; results are bit-exact vs synchronous ``serve``.  Data
refreshes on a scheduled runtime fence first (drain-then-swap): the
session's refresh paths route through ``scheduler.refresh()`` so no request
ever spans two catalog versions.  Use the scheduler when many concurrent
callers share compiled plans; call ``serve`` directly when one caller owns
the runtime.
"""
from ..laq.catalog import (Catalog, CatalogHistoryError,
                           CatalogReadOnlyError, TableDelta, changed_spans)
from .ir import (AGG_OPS, COUNT_STAR, FILTER_OPS, PREDICTION, Aggregate,
                 ArmSpec, ChainLink, GroupKey, PredictionFilter,
                 PredictiveQuery, eval_value, query_signature)
from .compile import CompiledQuery, compile_query, query_from_star
from .rewrite import RewriteResult, rewrite_query
from .explain import ExplainReport
from .snowflake import (CollapsedChain, chain_tables, materialize_chains,
                        resolve_chain, virtual_name)
from .workload import FuzzCase, FuzzReport, generate_case, np_oracle, run_fuzz
from .multiquery import (ArtifactPool, arm_keys, artifact_bytes,
                         make_stacked_runner, stack_key, stack_states)
from .planner import (AggDecision, QueryPlan, plan_aggregation,
                      plan_partition_spec, plan_placements, plan_query,
                      plan_serving_backend, plan_streaming, planner_threshold,
                      DENSE_JOIN_ELEMS, MXU_SEGMENT_ADVANTAGE,
                      PLANNER_THRESHOLDS, SERVE_KERNEL_MAX_NODES,
                      SERVE_KERNEL_MAX_WIDTH, SHARD_PARTIAL_BYTES)
from .scheduler import (DEFAULT_MAX_QUEUED_ROWS, DEFAULT_SLO_MS, LANES,
                        AdmissionScheduler, ScheduledPlan,
                        SchedulerBackpressureError, SchedulerClosedError)
from .serving import (DEFAULT_BUCKETS, SentinelKeyError, ServingRuntime,
                      compile_serving, requests_from_rows)
from .session import QueryBuilder, Session, query, query_key
from .streaming import DEFAULT_CHUNK_ROWS, StreamExecutor, plan_chunk_rows
from .sharding import (ShardedArm, ShardedPrefusedPartials,
                       shard_prefused_partials)

__all__ = [
    "AGG_OPS", "COUNT_STAR", "FILTER_OPS", "PREDICTION", "Aggregate",
    "ArmSpec", "ChainLink", "GroupKey", "PredictionFilter",
    "PredictiveQuery", "query_signature",
    "RewriteResult", "rewrite_query",
    "CollapsedChain", "chain_tables", "materialize_chains", "resolve_chain",
    "virtual_name",
    "FuzzCase", "FuzzReport", "generate_case", "np_oracle", "run_fuzz",
    "Catalog", "CatalogHistoryError", "CatalogReadOnlyError", "TableDelta",
    "changed_spans",
    "eval_value", "CompiledQuery", "compile_query", "query_from_star",
    "ExplainReport",
    "ArtifactPool", "arm_keys", "artifact_bytes", "make_stacked_runner",
    "stack_key", "stack_states",
    "AggDecision", "QueryPlan", "plan_aggregation", "plan_partition_spec",
    "plan_placements", "plan_query", "plan_serving_backend",
    "plan_streaming", "planner_threshold", "PLANNER_THRESHOLDS",
    "DEFAULT_CHUNK_ROWS", "StreamExecutor", "plan_chunk_rows",
    "DENSE_JOIN_ELEMS",
    "MXU_SEGMENT_ADVANTAGE", "SERVE_KERNEL_MAX_NODES",
    "SERVE_KERNEL_MAX_WIDTH", "SHARD_PARTIAL_BYTES",
    "DEFAULT_BUCKETS", "SentinelKeyError", "ServingRuntime",
    "compile_serving", "requests_from_rows",
    "AdmissionScheduler", "ScheduledPlan", "SchedulerBackpressureError",
    "SchedulerClosedError", "DEFAULT_MAX_QUEUED_ROWS", "DEFAULT_SLO_MS",
    "LANES",
    "QueryBuilder", "Session", "query", "query_key",
    "ShardedArm", "ShardedPrefusedPartials", "shard_prefused_partials",
]
