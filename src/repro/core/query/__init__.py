"""Predictive-query compiler: selection ⋈ star join ⋈ model ⋈ group-by,
lowered to one jitted linear-algebra program.

The paper's thesis (§3) is that relational operators and ML predictions share
a linear-algebra substrate, so a *whole* predictive query can be planned and
fused as one program.  This package is that planner/compiler.  IR node →
paper equation map:

======================  =====================================================
IR node                 Paper construct
======================  =====================================================
``Pred`` (via arms /    §2.2 selection as a binary filter vector s ∈ {0,1}ⁿ —
``fact_preds``)         folded into the matching matrix's validity instead of
                        multiplied through the data (mask_select)
``ArmSpec``             §2.3/§3.1 MM-Join arm: Iⱼ = MAT_fact · MAT_dimᵀ
                        (Alg. 1), kept factored as FK pointers for PK–FK
``PredictiveQuery``     §3 predictive pipeline  γ ∘ model ∘ ⋈ ∘ σ
``model=Linear…``       Eq. 1: T·L = Σⱼ Iⱼ(Bⱼ Mⱼ L) — the linear prefix is
                        *pre-fused* into each dimension table
``model=DecisionTree…`` Eq. 3 / Fig. 5: ((T F > v) H) == h with per-dimension
                        node-ownership masks Wⱼ
``GroupKey``            §2.4.2 composite group codes (sort-unique); the radix
                        ``bound`` is one digit of the code
``Aggregate``           §2.4/Fig. 4 group-by-sum: one-hot matmul (faithful)
                        or segment_sum (optimized) — compiler-chosen
======================  =====================================================

``plan_query`` extends the paper's Eq. 2/4 fusion boundary with selection
selectivity, the Fig. 4 aggregation-backend choice, and the serving-kernel
choice (``plan_serving_backend``); ``compile_query`` lowers the winning plan
into a single jitted XLA program and exposes a row-batched serving entry
point (``CompiledQuery.predict_rows``).

Serving API
-----------
``compile_serving(catalog, q, buckets=...)`` compiles the *online phase
alone* over a ``(batch, fk...)`` request pytree and returns a
:class:`ServingRuntime` — the production entry point when requests are
arbitrary incoming key tuples rather than fact rows:

    runtime = compile_serving(catalog, query, buckets=(8, 64, 512))
    preds = runtime.serve({"lo_partkey": ..., "lo_suppkey": ..., ...})

Bucket policy: each batch is PAD_KEY-padded up to the smallest configured
bucket and dispatched through that bucket's jitted program (one trace per
bucket, ever — ``runtime.num_compiles`` proves it); batches above the top
bucket are served in top-bucket chunks.  Buckets are the latency/memory
knob: more buckets → tighter padding waste, fewer buckets → fewer compiled
programs.  ``runtime.latency_stats()`` reports per-bucket percentiles.
``serve_backend`` lowers the gather-sum onto the Pallas kernels
(``fused_star_gather`` / ``tree_predict``) when shapes fit; the jnp gather
path stays the bit-exact fp32 reference.
"""
from .ir import (PREDICTION, Aggregate, ArmSpec, GroupKey, PredictiveQuery,
                 eval_value)
from .compile import CompiledQuery, compile_query, query_from_star
from .planner import (AggDecision, QueryPlan, plan_aggregation,
                      plan_partition_spec, plan_placements, plan_query,
                      plan_serving_backend, DENSE_JOIN_ELEMS,
                      MXU_SEGMENT_ADVANTAGE, SERVE_KERNEL_MAX_NODES,
                      SERVE_KERNEL_MAX_WIDTH, SHARD_PARTIAL_BYTES)
from .serving import (DEFAULT_BUCKETS, ServingRuntime, compile_serving,
                      requests_from_rows)
from .sharding import (ShardedArm, ShardedPrefusedPartials,
                       shard_prefused_partials)

__all__ = [
    "PREDICTION", "Aggregate", "ArmSpec", "GroupKey", "PredictiveQuery",
    "eval_value", "CompiledQuery", "compile_query", "query_from_star",
    "AggDecision", "QueryPlan", "plan_aggregation", "plan_partition_spec",
    "plan_placements", "plan_query", "plan_serving_backend",
    "DENSE_JOIN_ELEMS",
    "MXU_SEGMENT_ADVANTAGE", "SERVE_KERNEL_MAX_NODES",
    "SERVE_KERNEL_MAX_WIDTH", "SHARD_PARTIAL_BYTES",
    "DEFAULT_BUCKETS", "ServingRuntime", "compile_serving",
    "requests_from_rows",
    "ShardedArm", "ShardedPrefusedPartials", "shard_prefused_partials",
]
