"""Snowflake chains: multi-hop arms collapsed to head-granularity virtual dims.

The paper's factored-join form (Eq. 1) composes associatively: if the fact
resolves into a dimension ``D`` through ``FactoredJoin(ptr_f, found_f)`` and
``D`` resolves into a sub-dimension ``S`` through ``FactoredJoin(ptr_d,
found_d)``, then ``ptr_f→S = ptr_d[ptr_f]`` with ``found = found_f ∧
found_d[ptr_f]`` is exactly the pointer array of the flat ``fact ⋈ S`` join.
This module exploits that to *collapse* a multi-hop chain (``ArmSpec.links``)
into one head-granularity virtual dimension offline:

- every hop is probed once at the **parent's** granularity (dimension-sized,
  never fact-sized), then composed top-down to head granularity;
- sub-dimension feature columns are gathered through the composed pointers
  into one virtual feature matrix (qualified ``table.col`` column names);
- sub-dimension predicates and row liveness fold into a single
  head-granularity validity vector — exactly how the compiler folds flat
  dimension predicates into the join's validity (§2.2).

The compiler then lowers the chained arm as an ordinary flat arm over the
virtual table: same Eq. 1 prefusion, same online program, bit-exact with
materializing the chain as one flat pre-joined dimension
(:func:`materialize_chains` builds that baseline for tests/benches).

Where along the chain to *materialize* is a planner decision
(:func:`~.planner.plan_chain_materialization`): caching the first ``k`` hop
probes (``CollapsedChain.hops``) costs dimension-sized memory but lets
:func:`refresh_chain` recompose the chain after an append without re-probing
unchanged hops.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Mapping, Optional, Set, Tuple

import jax.numpy as jnp
import numpy as np

from ..laq.join import FactoredJoin, join_factored
from ..laq.table import Table
from .ir import ArmSpec, PredictiveQuery


def virtual_name(arm: ArmSpec) -> str:
    """The collapsed chain's catalog-overlay name: ``head->link->...``."""
    return "->".join([arm.table, *(lk.table for lk in arm.links)])


def qualified_cols(arm: ArmSpec) -> Tuple[str, ...]:
    """Virtual feature columns, ``table.col``-qualified.

    Qualification keeps the names unique across hops (the IR rejects
    duplicate table aliases) and self-describing in explain output.
    """
    cols = [f"{arm.table}.{c}" for c in arm.feature_cols]
    for lk in arm.links:
        cols.extend(f"{lk.table}.{c}" for c in lk.feature_cols)
    return tuple(cols)


def flat_arm(arm: ArmSpec) -> ArmSpec:
    """The flat arm the compiler lowers in place of a chained one.

    Predicates are dropped deliberately: head *and* link predicates are
    already folded into the collapsed chain's validity vector, which the
    compiler threads in as the arm's dmask.
    """
    if not arm.links:
        return arm
    return ArmSpec(virtual_name(arm), arm.fk_col, arm.pk_col,
                   qualified_cols(arm))


def link_parents(arm: ArmSpec) -> Tuple[str, ...]:
    """Each link's resolved parent table (``parent=None`` → previous hop)."""
    parents, prev = [], arm.table
    for lk in arm.links:
        parents.append(lk.parent if lk.parent is not None else prev)
        prev = lk.table
    return tuple(parents)


def chain_tables(arm: ArmSpec) -> Tuple[str, ...]:
    """Real catalog tables a (possibly chained) arm reads: head + links."""
    return (arm.table, *(lk.table for lk in arm.links))


def participating_tables(q: PredictiveQuery) -> Tuple[str, ...]:
    """Every real table the query reads: fact, heads, and chain links."""
    names = {q.fact}
    for a in q.arms:
        names.update(chain_tables(a))
    return tuple(sorted(names))


def chain_key(arm: ArmSpec) -> tuple:
    """Content key for pooled collapsed chains.

    Everything the collapsed value depends on: head table/PK, the gathered
    feature columns, head predicates and the full link tuple (tables, hop
    keys, link features, link predicates, parents).  The fact-side
    ``fk_col`` is deliberately excluded — two queries joining the same
    chain through different fact FKs share one collapse.
    """
    return ("chain", arm.table, arm.pk_col, arm.feature_cols, arm.preds,
            arm.links)


@dataclasses.dataclass(frozen=True)
class CollapsedChain:
    """One chain, collapsed offline to head granularity.

    ``table`` is the virtual dimension (qualified feature columns, the
    head's PK); ``dmask`` is the head-granularity validity vector with
    every hop's ``found``, liveness and predicates folded in;
    ``link_ptrs`` maps each link table to its head-granularity composed
    pointers (group-by keys on sub-dimension columns gather through
    these); ``hops`` caches the first ``k`` parent-granularity probes for
    :func:`refresh_chain` (``None`` entries are re-probed on refresh —
    the planner's prefuse-through side of the materialization decision).
    """

    arm: ArmSpec
    table: Table
    dmask: jnp.ndarray
    link_ptrs: Tuple[Tuple[str, jnp.ndarray, jnp.ndarray], ...]
    hops: Tuple[Optional[FactoredJoin], ...]

    @property
    def cached_hops(self) -> int:
        return sum(1 for h in self.hops if h is not None)


def resolve_chain(catalog: Mapping[str, Table], arm: ArmSpec, *,
                  keep_hops: int = 0,
                  reuse: Optional[CollapsedChain] = None,
                  stale: Iterable[str] = (),
                  hop_source=None) -> CollapsedChain:
    """Collapse one chained arm to a head-granularity virtual dimension.

    ``keep_hops`` caches the first ``k`` parent-granularity probes on the
    result (the planner's materialize-at-hop-k decision).  ``reuse`` +
    ``stale`` is the refresh path: hops cached on the previous collapse
    whose parent *and* link tables are not stale are reused instead of
    re-probed — the composition and feature gathers always rerun (they
    are cheap dimension-sized gathers), so the result is bit-identical
    to a cold collapse.

    ``hop_source(parent, link) -> FactoredJoin | None`` supplies individual
    hop probes from outside — the :class:`~.multiquery.ArtifactPool` passes
    one so two chains threading the *same* sub-dimension hop share one
    parent-granularity probe instead of each collapsing it.  A None return
    falls through to ``reuse``/``join_factored``; a supplied probe must be
    ``join_factored(catalog[parent].key(link.fk_col),
    catalog[link.table].key(link.pk_col))`` (which the pool's join artifact
    is, by construction).
    """
    head = catalog[arm.table]
    stale = set(stale)
    # Identity composition for the head itself: link hops hanging directly
    # off the head use their probe unchanged.
    to_head: Dict[str, Optional[Tuple[jnp.ndarray, jnp.ndarray]]]
    to_head = {arm.table: None}
    dmask = head.valid_mask()
    for p in arm.preds:
        dmask = dmask & p.mask(head)
    feats = [head.col(c) for c in arm.feature_cols]
    link_ptrs = []
    hops = []
    for i, (lk, parent) in enumerate(zip(arm.links, link_parents(arm))):
        fj = None
        if hop_source is not None:
            fj = hop_source(parent, lk)
        if (fj is None and reuse is not None and i < len(reuse.hops)
                and reuse.hops[i] is not None
                and parent not in stale and lk.table not in stale):
            fj = reuse.hops[i]
        if fj is None:
            fj = join_factored(catalog[parent].key(lk.fk_col),
                               catalog[lk.table].key(lk.pk_col))
        hops.append(fj if i < keep_hops else None)
        comp = to_head[parent]
        if comp is None:
            ptr_h, found_h = fj.ptr, fj.found
        else:
            p_ptr, p_found = comp
            # Associative composition: head→parent pointers chase into the
            # parent→link probe; a miss anywhere along the path is a miss.
            ptr_h = jnp.take(fj.ptr, p_ptr)
            found_h = p_found & jnp.take(fj.found, p_ptr)
        to_head[lk.table] = (ptr_h, found_h)
        link = catalog[lk.table]
        ok = link.valid_mask()
        for p in lk.preds:
            ok = ok & p.mask(link)
        dmask = dmask & found_h & jnp.take(ok, ptr_h)
        # Gathered sub-dimension features are zeroed where the hop missed:
        # the row is invalid either way (dmask is False there), but the
        # virtual matrix stays deterministic for delta comparisons.
        zero = found_h.astype(jnp.float32)
        for c in lk.feature_cols:
            feats.append(jnp.take(link.col(c), ptr_h) * zero)
        link_ptrs.append((lk.table, ptr_h, found_h))
    cols = qualified_cols(arm)
    matrix = (jnp.stack(feats, axis=1).astype(jnp.float32) if feats
              else jnp.zeros((head.capacity, 0), jnp.float32))
    virtual = Table(virtual_name(arm), cols, matrix,
                    {arm.pk_col: head.key(arm.pk_col)}, head.nvalid)
    return CollapsedChain(arm, virtual, dmask, tuple(link_ptrs), tuple(hops))


def refresh_chain(catalog: Mapping[str, Table], old: CollapsedChain,
                  stale: Iterable[str]) -> CollapsedChain:
    """Re-collapse after catalog deltas, reusing unchanged cached hops."""
    return resolve_chain(catalog, old.arm, keep_hops=old.cached_hops,
                         reuse=old, stale=stale)


def chain_dirty_heads(cc: CollapsedChain,
                      touched: Mapping[str, np.ndarray]
                      ) -> Optional[np.ndarray]:
    """Head rows whose virtual matrix rows may differ after the deltas.

    ``touched`` maps real table names to appended/updated row ids; ``cc``
    must be the *new* (re-collapsed) chain so freshly-found hops resolve
    into the appended link rows and land in the dirty set.  Returns
    sorted int32 ids, or None when nothing in the chain was touched.
    """
    ids: Set[int] = {int(i) for i in touched.get(cc.arm.table, ())}
    for name, ptr, found in cc.link_ptrs:
        t = np.asarray(touched.get(name, ()), np.int64)
        if t.size:
            hit = np.isin(np.asarray(ptr), t) & np.asarray(found)
            ids.update(np.nonzero(hit)[0].tolist())
    if not ids:
        return None
    return np.asarray(sorted(ids), np.int32)


def materialize_chains(catalog: Mapping[str, Table], q: PredictiveQuery
                       ) -> Tuple[Dict[str, Table], PredictiveQuery]:
    """The flat-star baseline: each chain as one real pre-joined dimension.

    Returns ``(tables, flat_q)`` where ``tables`` holds one materialized
    dimension per chained arm and ``flat_q`` joins them as ordinary flat
    arms.  Rows the chain's validity vector excludes are re-keyed to
    unique negative sentinels, so the flat probe misses them exactly
    where the collapsed path's ``found ∧ dmask[ptr]`` fold is False —
    the two lowerings are bit-exact (assumes non-negative PKs, which
    :func:`Table.from_columns` key columns and the workload generator
    both guarantee).

    Group keys on chain tables survive: every group-key column of the head
    or a link is gathered through the composed pointers into a qualified
    ``table.col`` *key* column on the flat dimension, and ``flat_q``'s
    group keys are rewritten to reference it — so a group-by on a
    sub-dimension column can be checked against this baseline.
    """
    tables: Dict[str, Table] = {}
    arms = []
    group_keys = list(q.group_keys)
    for arm in q.arms:
        if not arm.links:
            arms.append(arm)
            continue
        cc = resolve_chain(catalog, arm)
        pk = np.asarray(catalog[arm.table].key(arm.pk_col))
        dm = np.asarray(cc.dmask)
        if np.any(pk[dm] < 0):
            raise ValueError(
                f"materialize_chains on arm {arm.table!r} requires "
                "non-negative PKs (negative ids are the re-key sentinels)")
        ids = np.arange(pk.shape[0], dtype=np.int64)
        newpk = np.where(dm, pk, (-(ids + 2)).astype(pk.dtype))
        keys = {arm.pk_col: jnp.asarray(newpk)}
        # Head granularity is identity; links gather through the chain's
        # composed head→link pointers.  Misses gather garbage rows, but
        # those head rows are re-keyed sentinels the flat probe can never
        # match (dmask folds every hop's found).
        ptr_to = {arm.table: None}
        ptr_to.update((name, ptr_h) for name, ptr_h, _f in cc.link_ptrs)
        for gi, gk in enumerate(group_keys):
            if gk.table not in ptr_to:
                continue
            src = catalog[gk.table].key(gk.col)
            ptr_h = ptr_to[gk.table]
            qname = f"{gk.table}.{gk.col}"
            keys[qname] = src if ptr_h is None else jnp.take(src, ptr_h)
            group_keys[gi] = dataclasses.replace(
                gk, table=virtual_name(arm), col=qname)
        flat = Table(cc.table.name, cc.table.columns, cc.table.matrix,
                     keys, cc.table.nvalid)
        tables[flat.name] = flat
        arms.append(flat_arm(arm))
    return tables, dataclasses.replace(q, arms=tuple(arms),
                                       group_keys=tuple(group_keys))
