"""Whole-query cost model: fusion × join backend × aggregation backend.

Extends the paper's Eq. 2/4 fusion boundary (``repro.core.fusion.plan_fusion``)
to the full predictive query:

* **Selection selectivity** shrinks every online term — selection is folded
  into the factored-join validity before prediction, so only surviving rows
  flow through the model and the aggregation (§2.2 composed with §3).
* **Join backend** — factored gathers by default; the paper-faithful dense
  one-hot matmul (Alg. 1) only ever wins on tiny inputs where the MXU matmul
  amortizes gather latency, mirroring the paper's MM-Join-vs-hash-join
  crossover (§4.2).
* **Aggregation backend** — Fig. 4's one-hot matmul costs ~2·i·G·l FLOPs vs
  the segment-sum scatter's ~i·l; the matmul only pays when the group count G
  is small enough that MXU throughput covers the extra work.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

from ...launch.sharding import safe_spec
from ..fusion.operators import DecisionTreeGEMM
from ..fusion.planner import FusionDecision, plan_fusion
from .ir import Model

# Cost-model thresholds, keyed by ``jax.default_backend()`` with the
# CPU-bench-seeded values as the default row — making TPU calibration a
# table entry ("tpu": {...}) rather than a refactor:
#
# * DENSE_JOIN_ELEMS — dense one-hot row-matching matrices are only viable
#   when the (fact × dim) matrix is small (paper §4.2: MM-Join loses to
#   pointer joins at scale).
# * MXU_SEGMENT_ADVANTAGE — MXU matmul throughput advantage over
#   scatter-based segment_sum: the matmul aggregation is picked when its
#   FLOP overcount (≈2·G) stays under this.  Calibrated on
#   bench_predictive_queries (G=8,l=4 matmul 4× faster; G=8192 matmul 300×
#   slower — any value in [13, ~1000) separates the two regimes).
# * SHARD_PARTIAL_BYTES — below this size a prefused partial is replicated
#   rather than row-sharded: the partial fits every device comfortably and
#   replication keeps the online gather collective-free.  CPU-bench
#   calibrated (bench_sharded_serving: the psum overhead only amortizes once
#   per-device slices clear the cache-resident regime).
PLANNER_THRESHOLDS = {
    "default": {
        "DENSE_JOIN_ELEMS": 1 << 14,
        "MXU_SEGMENT_ADVANTAGE": 16.0,
        "SHARD_PARTIAL_BYTES": 1 << 20,
        # Snowflake chains: total bytes of cached hop probes (int32 ptr +
        # bool found per parent row) a chain may pin to speed refresh.
        # Hops are cached parent-first until the budget runs out —
        # materialize-at-hop-k; a zero/overflowing budget prefuses through.
        "CHAIN_CACHE_BYTES": 1 << 22,
    },
    # "tpu": {...}  ← ROADMAP "Planner calibration": re-measure there and
    # fill this row in; every decision point below reads through
    # planner_threshold(), so no other code changes.
}

# Backward-compatible module-level aliases for the CPU-seeded defaults.
DENSE_JOIN_ELEMS = PLANNER_THRESHOLDS["default"]["DENSE_JOIN_ELEMS"]
MXU_SEGMENT_ADVANTAGE = PLANNER_THRESHOLDS["default"]["MXU_SEGMENT_ADVANTAGE"]
SHARD_PARTIAL_BYTES = PLANNER_THRESHOLDS["default"]["SHARD_PARTIAL_BYTES"]


def planner_threshold(name: str, platform: Optional[str] = None):
    """The calibrated threshold ``name`` for ``platform``.

    ``platform`` defaults to ``jax.default_backend()``; platforms without a
    calibration row fall back to the CPU-seeded ``"default"`` values.
    """
    defaults = PLANNER_THRESHOLDS["default"]
    if name not in defaults:
        raise KeyError(f"unknown planner threshold {name!r}; expected one "
                       f"of {sorted(defaults)}")
    if platform is None:
        platform = jax.default_backend()
    return PLANNER_THRESHOLDS.get(platform, defaults).get(
        name, defaults[name])


# fused_star_gather holds (J+1) lane-padded (1, l) row blocks in VMEM per
# grid step; tree_predict additionally keeps the (k, p) feature-selection
# block resident.  Both are far below VMEM at these bounds, which exist to
# refuse pathological widths rather than to pack VMEM tightly.
SERVE_KERNEL_MAX_WIDTH = 8192
SERVE_KERNEL_MAX_NODES = 16384


@dataclasses.dataclass(frozen=True)
class AggDecision:
    backend: str            # "segment" | "matmul"
    matmul_flops: float
    segment_flops: float
    reason: str


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    backend: str            # "fused" | "nonfused"
    join_backend: str       # "gather" | "matmul"
    agg: Optional[AggDecision]
    fusion: Optional[FusionDecision]
    selectivity: float
    reason: str
    serve_backend: str = "jnp"   # "jnp" | "pallas" — online gather-sum kernel
    # Per-arm placement of the quasi-static row tables (prefused partials /
    # projected features) over the serving mesh; None when planned meshless.
    partition_specs: Optional[Tuple[P, ...]] = None
    # Out-of-core: rows per fact chunk when the plan streams the fact axis
    # (None = in-core).  Decided by plan_streaming from the fact working-set
    # bytes vs the device-memory budget, or pinned by the caller.
    stream_chunk_rows: Optional[int] = None


def plan_partition_spec(mesh, shape: Sequence[int], *, itemsize: int = 4,
                        axis: str = "model",
                        threshold: Optional[int] = None
                        ) -> Tuple[P, str]:
    """Placement for one quasi-static row table: replicate or row-shard.

    Small tables replicate (the online gather stays collective-free); tables
    past ``threshold`` bytes (default: the backend-keyed
    ``SHARD_PARTIAL_BYTES``) row-shard over the mesh's ``axis`` — through
    ``safe_spec``, so a row count that doesn't divide the axis degrades to
    replication instead of failing (the 15-heads-on-16-way rule, applied to
    prefused partials).  Returns ``(spec, reason)``.
    """
    if threshold is None:
        threshold = planner_threshold("SHARD_PARTIAL_BYTES")
    replicated = P(*([None] * len(shape)))
    if mesh is None:
        return replicated, "no mesh: replicate"
    nbytes = itemsize
    for d in shape:
        nbytes *= int(d)
    if nbytes < threshold:
        return replicated, (f"{nbytes}B < {threshold}B: replicate small "
                            "partial")
    spec = safe_spec(mesh, shape, axis, *([None] * (len(shape) - 1)))
    if spec[0] is None:
        return spec, (f"rows={shape[0]} does not divide mesh[{axis!r}]: "
                      "replicate (safe_spec fallback)")
    return spec, f"row-shard {shape[0]} rows over {axis}={mesh.shape[axis]}"


def plan_placements(mesh, shapes: Sequence[Sequence[int]], *,
                    itemsize: int = 4, axis: str = "model",
                    threshold: Optional[int] = None
                    ) -> Tuple[Tuple[P, ...], str]:
    """Per-arm placement over the arms' row-table shapes.

    The single implementation behind ``plan_query(mesh=...)`` and the
    compile/serving paths (which re-derive from *actual* table shapes) —
    returns ``(specs, reason)`` with the reason in the plan's
    ``place=[...]`` format.
    """
    specs, whys = [], []
    for shape in shapes:
        spec, why = plan_partition_spec(mesh, shape, itemsize=itemsize,
                                        axis=axis, threshold=threshold)
        specs.append(spec)
        whys.append(why)
    return tuple(specs), "place=[" + "; ".join(whys) + "]"


def place_tables(mesh, tables, plan: "QueryPlan", *, axis: str = "model",
                 threshold_bytes: Optional[int] = None
                 ) -> Tuple[Tuple[P, ...], "QueryPlan"]:
    """Placement for *actual* arm row tables, recorded on the plan.

    The one mesh-path setup shared by ``compile_query(mesh=)`` and
    ``compile_serving(mesh=)``: fused partial widths differ from non-fused
    feature widths, so placement is re-derived from the real table shapes
    and the plan's ``partition_specs``/reason updated to match what
    executes.
    """
    specs, place = plan_placements(
        mesh, [t.shape for t in tables], itemsize=tables[0].dtype.itemsize,
        axis=axis, threshold=threshold_bytes)
    plan = dataclasses.replace(plan, partition_specs=specs,
                               reason=plan.reason + "; " + place)
    return specs, plan


def resolve_mesh_serve_backend(serve_backend: str, mesh) -> str:
    """Clamp the serve backend for mesh serving (jnp-only today).

    The Pallas kernels are not composed with ``shard_map`` yet (the sharded
    block kernels are the TPU calibration follow-up), so an explicit
    ``"pallas"`` request alongside a mesh is an error rather than a silent
    downgrade; "auto"/"jnp" resolve to the jnp gathers.
    """
    if mesh is None:
        return serve_backend
    if serve_backend == "pallas":
        raise ValueError(
            "serve_backend='pallas' does not compose with mesh serving "
            "yet (sharded block kernels are the TPU follow-up); use "
            "serve_backend='jnp' or 'auto'")
    return "jnp"


def plan_serving_backend(model: Optional[Model], num_arms: int, *,
                         backend: str = "fused",
                         platform: Optional[str] = None) -> Tuple[str, str]:
    """Physical backend for the online gather-sum: Pallas kernel or jnp.

    Returns ``(backend, reason)``.  The Pallas lowering only pays off when
    the shapes fit the kernels' block specs (SystemML's fusion-plan lesson:
    a fused operator is only a win on the right physical kernel); everything
    else falls back to the pure-jnp gathers, which XLA lowers well on every
    platform.  Pallas TPU kernels also run on CPU in interpret mode — tests
    and the CI kernels-interpret job force ``serve_backend="pallas"`` with
    ``interpret=True`` there, so the choice here is only the *default*.
    """
    if platform is None:
        platform = jax.default_backend()
    if model is None:
        return "jnp", "no model head: nothing to lower onto a kernel"
    if platform != "tpu":
        return "jnp", f"platform {platform!r}: Pallas TPU kernels need a TPU"
    if backend == "fused":
        if num_arms < 1:
            return "jnp", "no arms: no gather-sum to lower"
        if model.l > SERVE_KERNEL_MAX_WIDTH:
            return "jnp", (f"l={model.l} exceeds fused_star_gather width "
                           f"bound {SERVE_KERNEL_MAX_WIDTH}")
        return "pallas", (f"fused_star_gather fits: J={num_arms}, "
                          f"l={model.l}")
    if isinstance(model, DecisionTreeGEMM):
        if (model.p <= SERVE_KERNEL_MAX_NODES
                and model.l <= SERVE_KERNEL_MAX_WIDTH):
            return "pallas", (f"tree_predict fits: p={model.p}, l={model.l}")
        return "jnp", (f"tree p={model.p}/l={model.l} exceeds tree_predict "
                       "block bounds")
    return "jnp", "nonfused linear head: XLA matmul already optimal"


def resolve_serve_backend(serve_backend: str, backend: str, model) -> str:
    """Clamp a requested serve backend to one that actually has a kernel.

    A non-fused *linear* head has no Pallas lowering (its online step is a
    plain matmul), so a "pallas" request degrades to "jnp" there — keeping
    the recorded serve_backend an honest statement of what executes.
    """
    if serve_backend != "pallas" or backend == "fused":
        return serve_backend
    return "pallas" if isinstance(model, DecisionTreeGEMM) else "jnp"


def effective_serve_backend(plan: "QueryPlan", serve_backend: str,
                            backend: str, model, num_arms: int) -> str:
    """The serve backend that will actually execute.

    "auto" must be re-planned against the *resolved* execution backend —
    the plan's own choice was made for the planner's backend, and e.g. an
    oversized tree that fits the fused kernel's width bound does not fit
    ``tree_predict``'s node bound.  Explicit choices are clamped only where
    no kernel lowering exists (non-fused linear heads).
    """
    if serve_backend == "auto":
        if backend == plan.backend:
            return plan.serve_backend
        return plan_serving_backend(model, num_arms, backend=backend)[0]
    return resolve_serve_backend(serve_backend, backend, model)


def plan_streaming(requested, fact_rows: int, fact_row_bytes: int,
                   memory_budget_bytes: Optional[int]
                   ) -> Tuple[Optional[int], str]:
    """In-core vs out-of-core for the fact axis; returns ``(chunk, reason)``.

    The working set of the online program is ~``fact_rows × fact_row_bytes``
    (matrix columns, join pointers, validity, group ids, plus the fact-sized
    intermediates the program materializes).  When a caller pins
    ``stream_chunk_rows`` to an int the decision is theirs; ``"auto"``
    streams with budget-sized chunks; ``None`` streams only when a
    ``memory_budget_bytes`` is given and the working set exceeds it — the
    common case stays in-core with zero overhead.
    """
    from .streaming import plan_chunk_rows
    est = int(fact_rows) * max(int(fact_row_bytes), 1)
    chunk = plan_chunk_rows(requested, int(fact_rows), int(fact_row_bytes),
                            memory_budget_bytes)
    if chunk is None:
        if memory_budget_bytes is not None:
            return None, (f"stream=off (working set ~{est / 1e6:.1f}MB fits "
                          f"budget {memory_budget_bytes / 1e6:.1f}MB)")
        return None, ""
    if isinstance(requested, int) and requested > 0:
        why = "caller pinned"
    elif memory_budget_bytes is not None:
        why = (f"working set ~{est / 1e6:.1f}MB vs budget "
               f"{memory_budget_bytes / 1e6:.1f}MB")
    else:
        why = "stream_chunk_rows='auto', no budget: default chunk"
    n_chunks = -(-int(fact_rows) // chunk) if fact_rows else 1
    return chunk, (f"stream={chunk} rows/chunk x {n_chunks} ({why}; fused "
                   "segment fold, dimension-side artifacts shared)")


def plan_chain_materialization(chain_name: str, parent_rows: Sequence[int],
                               *, strategy: str = "auto",
                               platform: Optional[str] = None
                               ) -> Tuple[int, str]:
    """Where along a snowflake chain to materialize; ``(k, reason)``.

    Collapsing a chain probes each hop at its parent's granularity.  The
    probes can be *cached* on the collapsed chain (materialize-at-hop-k:
    the first ``k`` hops keep their ``FactoredJoin``), so a refresh after
    an append re-probes only hops whose tables changed — at the cost of
    ``parent_rows[i] × 5`` resident bytes per cached hop (int32 ptr +
    bool found).  Hops are admitted parent-first while the cumulative
    cost fits ``CHAIN_CACHE_BYTES``; ``strategy`` overrides: ``"through"``
    caches nothing (prefuse-through), ``"materialize"`` caches every hop.
    """
    n = len(parent_rows)
    costs = [int(r) * 5 for r in parent_rows]
    if strategy == "through":
        return 0, f"chain[{chain_name}]: prefuse-through (caller pinned)"
    if strategy == "materialize":
        return n, (f"chain[{chain_name}]: materialize@{n}/{n} "
                   f"(caller pinned; hop cache {sum(costs)}B)")
    if strategy != "auto":
        raise ValueError(f"chain_strategy {strategy!r} not one of "
                         "('auto', 'through', 'materialize')")
    budget = planner_threshold("CHAIN_CACHE_BYTES", platform)
    k, spent = 0, 0
    for c in costs:
        if spent + c > budget:
            break
        spent += c
        k += 1
    if k == 0:
        return 0, (f"chain[{chain_name}]: prefuse-through (hop cache "
                   f"{costs[0] if costs else 0}B exceeds budget {budget}B)")
    return k, (f"chain[{chain_name}]: materialize@{k}/{n} (hop cache "
               f"{spent}B fits budget {budget}B; refresh reuses unchanged "
               "hops)")


def plan_aggregation(online_rows: float, num_groups: int, out_width: int,
                     ops: Sequence[str] = ("sum",),
                     platform: Optional[str] = None) -> AggDecision:
    """Fig. 4 matmul vs segment-sum, costed over the whole aggregate set.

    Multi-aggregate queries share work: every ``mean``/``count`` aggregate
    reuses one count reduction (a width-1 one-hot matmul or ones
    segment-sum), and each ``sum``/``mean`` needs one value reduction of
    ``out_width``.  ``min``/``max`` have no one-hot matmul form (Fig. 4 is
    additive) and lower through segment ops on *both* backends, so their
    cost is shared and only the matmul-able reductions decide the backend.
    """
    i = max(online_rows, 1.0)
    g = max(num_groups, 1)
    l = max(out_width, 1)
    ops = tuple(ops) or ("sum",)
    n_sums = sum(1 for op in ops if op in ("sum", "mean"))
    needs_count = any(op in ("count", "mean") for op in ops)
    n_minmax = sum(1 for op in ops if op in ("min", "max"))
    # onehot(gid)ᵀ @ values per sum-like reduction (+ a width-1 count).
    matmul = 2.0 * i * g * l * n_sums + (2.0 * i * g if needs_count else 0.0)
    # scatter-add + id gather per reduction.
    segment = (i * l + i) * n_sums + (2.0 * i if needs_count else 0.0)
    shared = (i * l + i) * n_minmax            # segment min/max either way
    advantage = planner_threshold("MXU_SEGMENT_ADVANTAGE", platform)
    if matmul > 0 and matmul <= segment * advantage:
        return AggDecision("matmul", matmul + shared, segment + shared,
                           f"G={g} small: MXU matmul beats scatter")
    return AggDecision("segment", matmul + shared, segment + shared,
                       f"G={g}: segment ops ({segment + shared:.0f} flops) "
                       f"beat one-hot matmul ({matmul + shared:.0f} flops)")


def estimate_query_cost(model: Optional[Model], fact_rows: int,
                        dim_rows: Sequence[int], *, num_groups: int = 0,
                        out_width: int = 1, agg_ops: Sequence[str] = ("sum",),
                        batches_per_update: float = 1000.0,
                        platform: Optional[str] = None) -> float:
    """Scalar per-batch work estimate for rewrite-vs-original comparison.

    One number covering the online phase (per-arm gathers + the model's
    fused contribution + aggregation) plus the offline prefuse build
    amortized over ``batches_per_update`` — so it moves in the right
    direction for every rewrite rule: dropping the model removes the
    dominant online term (distillation), while shrinking features (k),
    tree nodes (p) or model width shrinks the amortized offline term.
    It deliberately reuses :func:`plan_aggregation`'s FLOP counts rather
    than re-deriving them.
    """
    n = float(max(fact_rows, 1))
    j = max(len(dim_rows), 1)
    r = float(sum(dim_rows)) if dim_rows else 0.0
    cost = 2.0 * n * j                         # probes + validity fold
    if model is not None:
        l = max(model.l, 1)
        cost += n * (j + 1) * l                # Σⱼ Iⱼ Pⱼ gathers + adds
        offline = 2.0 * r * max(model.k, 1) * l        # B (M L) / B (M F)
        if isinstance(model, DecisionTreeGEMM):
            # compares + ownership mask + preds @ H per dimension row
            offline += r * model.p * (l + 2.0)
            cost += n * l                      # the == h compare
        cost += offline / max(batches_per_update, 1.0)
    if num_groups > 0:
        agg = plan_aggregation(n, num_groups, out_width, ops=agg_ops,
                               platform=platform)
        cost += min(agg.matmul_flops, agg.segment_flops)
    return cost


def plan_query(model: Optional[Model], fact_rows: int,
               dim_rows: Sequence[int], *, selectivity: float = 1.0,
               num_groups: int = 0, out_width: int = 1,
               agg_ops: Sequence[str] = ("sum",),
               batches_per_update: float = 1000.0,
               memory_budget_bytes: Optional[int] = None,
               platform: Optional[str] = None, mesh=None,
               shard_axis: str = "model",
               shard_threshold_bytes: Optional[int] = None,
               sharing: float = 1.0) -> QueryPlan:
    """Pick fused/nonfused + join/agg/serving backends for one query.

    ``agg_ops`` is the query's combined aggregate set (one op per
    aggregate); the aggregation backend is costed over all of them at once
    (:func:`plan_aggregation`).  With a ``mesh``, the plan also decides
    per-arm *placement* of the quasi-static row tables
    (``partition_specs``): each arm's prefused partial is sized as
    (dim rows × out_width) fp32 and either replicated or row-sharded over
    ``shard_axis`` (see :func:`plan_partition_spec`).

    ``sharing`` (≥ 1) is the multi-query pool's hint: how many plans share
    this query's prefused partials/join artifacts.  A partial referenced by
    N plans amortizes its one-time prefuse cost over N × the batches, which
    moves the fused/nonfused break-even — modeled by scaling
    ``batches_per_update`` in the fusion decision.
    """
    sel = min(max(float(selectivity), 0.0), 1.0)
    online_rows = float(fact_rows) * sel
    sharing = max(float(sharing), 1.0)

    fusion = None
    backend = "fused"
    if model is not None:
        fusion = plan_fusion(model, fact_rows, dim_rows,
                             batches_per_update=batches_per_update * sharing,
                             memory_budget_bytes=memory_budget_bytes,
                             selectivity=sel)
        backend = "fused" if fusion.fuse else "nonfused"

    dense_elems = float(fact_rows) * float(max(dim_rows, default=1))
    join_backend = ("matmul" if dense_elems <= planner_threshold(
        "DENSE_JOIN_ELEMS", platform) else "gather")

    agg = None
    if num_groups > 0:
        agg = plan_aggregation(online_rows, num_groups, out_width,
                               ops=agg_ops, platform=platform)

    serve_backend, serve_reason = plan_serving_backend(
        model, len(dim_rows), backend=backend, platform=platform)

    partition_specs = place_reason = None
    if mesh is not None:
        partition_specs, place_reason = plan_placements(
            mesh, [(int(r), out_width) for r in dim_rows], axis=shard_axis,
            threshold=shard_threshold_bytes)

    parts = [f"sel={sel:.3f}", f"join={join_backend}"]
    if sharing > 1.0:
        parts.append(f"sharing={sharing:g}x")
    if fusion is not None:
        parts.append(f"{backend} ({fusion.reason})")
    if agg is not None:
        parts.append(f"agg={agg.backend}")
    parts.append(f"serve={serve_backend} ({serve_reason})")
    if place_reason is not None:
        parts.append(place_reason)
    return QueryPlan(backend=backend, join_backend=join_backend, agg=agg,
                     fusion=fusion, selectivity=sel,
                     reason="; ".join(parts), serve_backend=serve_backend,
                     partition_specs=partition_specs)
