"""Whole-query cost model: fusion × join backend × aggregation backend.

Extends the paper's Eq. 2/4 fusion boundary (``repro.core.fusion.plan_fusion``)
to the full predictive query:

* **Selection selectivity** shrinks every online term — selection is folded
  into the factored-join validity before prediction, so only surviving rows
  flow through the model and the aggregation (§2.2 composed with §3).
* **Join backend** — factored gathers by default; the paper-faithful dense
  one-hot matmul (Alg. 1) only ever wins on tiny inputs where the MXU matmul
  amortizes gather latency, mirroring the paper's MM-Join-vs-hash-join
  crossover (§4.2).
* **Aggregation backend** — Fig. 4's one-hot matmul costs ~2·i·G·l FLOPs vs
  the segment-sum scatter's ~i·l; the matmul only pays when the group count G
  is small enough that MXU throughput covers the extra work.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from ..fusion.planner import FusionDecision, plan_fusion
from .ir import Model

# Dense one-hot row-matching matrices are only viable when the (fact × dim)
# matrix is small (paper §4.2: MM-Join loses to pointer joins at scale).
DENSE_JOIN_ELEMS = 1 << 14

# MXU matmul throughput advantage over scatter-based segment_sum: the matmul
# aggregation is picked when its FLOP overcount (≈2·G) stays under this.
# Calibrated on bench_predictive_queries (G=8,l=4 matmul 4× faster; G=8192
# matmul 300× slower — any value in [13, ~1000) separates the two regimes).
MXU_SEGMENT_ADVANTAGE = 16.0


@dataclasses.dataclass(frozen=True)
class AggDecision:
    backend: str            # "segment" | "matmul"
    matmul_flops: float
    segment_flops: float
    reason: str


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    backend: str            # "fused" | "nonfused"
    join_backend: str       # "gather" | "matmul"
    agg: Optional[AggDecision]
    fusion: Optional[FusionDecision]
    selectivity: float
    reason: str


def plan_aggregation(online_rows: float, num_groups: int,
                     out_width: int) -> AggDecision:
    """Fig. 4 matmul vs segment-sum for Σ values per group."""
    i = max(online_rows, 1.0)
    g = max(num_groups, 1)
    l = max(out_width, 1)
    matmul = 2.0 * i * g * l          # onehot(gid)ᵀ @ values
    segment = i * l + i               # scatter-add + id gather
    if matmul <= segment * MXU_SEGMENT_ADVANTAGE:
        return AggDecision("matmul", matmul, segment,
                           f"G={g} small: MXU matmul beats scatter")
    return AggDecision("segment", matmul, segment,
                       f"G={g}: segment_sum ({segment:.0f} flops) beats "
                       f"one-hot matmul ({matmul:.0f} flops)")


def plan_query(model: Optional[Model], fact_rows: int,
               dim_rows: Sequence[int], *, selectivity: float = 1.0,
               num_groups: int = 0, out_width: int = 1,
               batches_per_update: float = 1000.0,
               memory_budget_bytes: Optional[int] = None) -> QueryPlan:
    """Pick fused/nonfused + join/aggregation backends for one query."""
    sel = min(max(float(selectivity), 0.0), 1.0)
    online_rows = float(fact_rows) * sel

    fusion = None
    backend = "fused"
    if model is not None:
        fusion = plan_fusion(model, fact_rows, dim_rows,
                             batches_per_update=batches_per_update,
                             memory_budget_bytes=memory_budget_bytes,
                             selectivity=sel)
        backend = "fused" if fusion.fuse else "nonfused"

    dense_elems = float(fact_rows) * float(max(dim_rows, default=1))
    join_backend = "matmul" if dense_elems <= DENSE_JOIN_ELEMS else "gather"

    agg = None
    if num_groups > 0:
        agg = plan_aggregation(online_rows, num_groups, out_width)

    parts = [f"sel={sel:.3f}", f"join={join_backend}"]
    if fusion is not None:
        parts.append(f"{backend} ({fusion.reason})")
    if agg is not None:
        parts.append(f"agg={agg.backend}")
    return QueryPlan(backend=backend, join_backend=join_backend, agg=agg,
                     fusion=fusion, selectivity=sel,
                     reason="; ".join(parts))
