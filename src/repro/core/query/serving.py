"""Dynamic-batch serving: compile the fused online phase once, serve any
request batch.

``compile_query`` binds a static fact table, so its serving entry point
(``CompiledQuery.predict_rows``) can only score *fact rows*.  This module
traces the fused online phase over a ``(batch, fk...)`` request pytree
instead: a request is one foreign key per star arm, and the compiled program
is exactly the paper's Eq. 1 online phase — per-arm PK lookups into the
quasi-static sorted key index, then Σⱼ Pⱼ[ptrⱼ] gathers into the pre-fused
partials (+ ``== h`` for trees).  One compiled plan therefore serves
arbitrary incoming batches, not just rows the fact table happened to
contain.

Bucketed padding policy
-----------------------
XLA needs static shapes, so each incoming batch is padded (with ``PAD_KEY``,
which never matches a live PK) up to the smallest configured *bucket* size
and dispatched through one jitted program per bucket.  The jit cache is
keyed on the padded shape, so after at most ``len(buckets)`` traces no
request ever recompiles; batches larger than the top bucket are served in
top-bucket chunks.  Request buffers are donated on accelerators so the
padded int32 staging arrays are recycled across calls.

Physical lowering
-----------------
The gather-sum is lowered onto the Pallas kernels when the planner says the
shapes fit their block specs (``plan_serving_backend``): the fused path onto
``kernels/fused_star_gather`` (scalar-prefetched FK pointers, one DMA pass),
the non-fused decision-tree path onto ``kernels/tree_predict``.  Everything
else uses the pure-jnp gathers, which remain the reference semantics — the
kernel backends match them bit-exactly in fp32.

Sharded serving
---------------
``compile_serving(..., mesh=...)`` partitions the quasi-static state across
a device mesh (``core.query.sharding``): large partials row-shard over the
mesh's model axis with per-shard ``PKIndex`` slices, small ones replicate
(``plan_partition_spec``), and the padded FK batch shards over the DP axes.
Each bucket's program becomes one ``shard_map``-jitted device-local
probe + gather + psum, bit-exact vs the single-device jnp path.  Buckets
are rounded up to multiples of the DP size so every padded batch divides
the mesh.  The Pallas lowering is mutually exclusive with ``mesh`` (the
sharded block kernels are the TPU calibration follow-up).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...launch.mesh import dp_size
from ..fusion.operators import DecisionTreeGEMM
from ..fusion.pipeline import prefuse_dims
from ..laq.join import PKIndex, pk_index
from ..laq.projection import mapping_matrix
from ..laq.star import DimSpec
from ..laq.table import PAD_KEY, Table
from .ir import PredictiveQuery
from .planner import (QueryPlan, effective_serve_backend, place_tables,
                      plan_query, resolve_mesh_serve_backend)
from .sharding import (ShardedPrefusedPartials, make_serving_forward,
                       shard_prefused_partials)

#: Default padding buckets: small interactive batches, mid-size batches, and
#: a bulk bucket that also serves as the chunk size for oversized requests.
DEFAULT_BUCKETS = (8, 64, 512)

#: Per-bucket latency samples kept for the percentile report (a bounded
#: window, so a long-lived runtime's bookkeeping stays O(1) per bucket).
LATENCY_WINDOW = 2048


@dataclasses.dataclass(frozen=True)
class _ArmIndex:
    """Quasi-static per-arm lookup state (paper's offline phase, per arm).

    ``index`` factors the PK side of ``join_factored`` out of the online
    program: the sort runs once at compile time, the online lookup is the
    shared ``PKIndex.probe`` (searchsorted + two gathers) — the *same*
    probe the compiled-query join uses, which is what keeps serving
    bit-identical to ``predict_rows``.  ``dmask`` carries the
    dimension-side predicates and row liveness, folded into the lookup's
    validity exactly like the compiler folds them into the join (§2.2).
    """

    fk_col: str
    index: Optional[PKIndex]  # None on the mesh path (per-shard slices rule)
    dmask: jnp.ndarray        # (r,) bool, in dimension-row order
    table: Optional[jnp.ndarray]  # (r, w) partial; None on the mesh path


def _lookup(arm: _ArmIndex, fk: jnp.ndarray
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """PK–FK pointer lookup for a request column, with dim preds folded."""
    fj = arm.index.probe(fk)
    hit = fj.found & jnp.take(arm.dmask, fj.ptr)
    return fj.ptr, hit


class ServingRuntime:
    """One compiled predictive pipeline serving arbitrary request batches.

    Built by :func:`compile_serving`; hold one instance per (query, catalog)
    and call :meth:`serve` with request batches of any size.  Thread-compat:
    serving is functional over quasi-static arrays; only the latency/trace
    bookkeeping is unsynchronized.
    """

    def __init__(self, query: PredictiveQuery, plan: QueryPlan, backend: str,
                 serve_backend: str, buckets: Tuple[int, ...],
                 arms: Tuple[_ArmIndex, ...], model, h: Optional[jnp.ndarray],
                 interpret: bool, donate: bool, sync_stats: bool = True,
                 sharded: Optional[ShardedPrefusedPartials] = None):
        self.query = query
        self.plan = plan
        self.backend = backend                # "fused" | "nonfused"
        self.serve_backend = serve_backend    # "jnp" | "pallas"
        self.buckets = buckets
        self._arms = arms
        self._model = model
        self._h = h
        self._interpret = interpret
        self._sync_stats = sync_stats
        self._trace_count = 0
        self._lat: Dict[int, Deque[float]] = {}
        self._compile_s: Dict[int, float] = {}
        self.sharded = sharded
        self._forward_impl = (
            make_serving_forward(sharded, model, backend)
            if sharded is not None else None)
        donate_argnums = (0,) if donate else ()
        self._jit = jax.jit(self._forward, donate_argnums=donate_argnums)

    # -- sharding introspection ----------------------------------------------
    @property
    def mesh(self):
        """The serving mesh, or None on the single-device path."""
        return self.sharded.mesh if self.sharded is not None else None

    # -- introspection -------------------------------------------------------
    @property
    def request_keys(self) -> Tuple[str, ...]:
        """FK column names a request must provide, in arm order."""
        return tuple(a.fk_col for a in self._arms)

    @property
    def out_width(self) -> int:
        return self._model.l

    @property
    def num_compiles(self) -> int:
        """Traces taken so far — bounded by ``len(buckets)`` for life."""
        return self._trace_count

    def jit_cache_size(self) -> Optional[int]:
        """The jit executable cache size (None if jax hides it)."""
        try:
            return self._jit._cache_size()
        except AttributeError:
            return None

    def latency_stats(self) -> Dict[int, Dict[str, float]]:
        """Per-bucket steady-state serve latency percentiles (ms).

        Each bucket's one-time trace+compile call is kept out of the
        percentiles and reported separately as ``compile_ms``; a bucket
        that has only ever compiled still appears, with ``count == 0`` and
        no percentile keys.  Percentiles measure wall time only when the
        runtime synchronizes per call (``sync_stats``, the default).
        """
        out = {}
        for bucket in sorted(set(self._lat) | set(self._compile_s)):
            ts = self._lat.get(bucket, ())
            out[bucket] = {"count": len(ts)}
            if ts:
                ms = np.asarray(ts) * 1e3
                out[bucket].update(
                    p50=float(np.percentile(ms, 50)),
                    p95=float(np.percentile(ms, 95)),
                    p99=float(np.percentile(ms, 99)),
                )
            if bucket in self._compile_s:
                out[bucket]["compile_ms"] = self._compile_s[bucket] * 1e3
        return out

    # -- the compiled program ------------------------------------------------
    def _forward(self, fks: Tuple[jnp.ndarray, ...]) -> jnp.ndarray:
        # Python side effect: runs once per trace (i.e. once per bucket).
        self._trace_count += 1
        if self._forward_impl is not None:   # sharded shard_map program
            return self._forward_impl(fks)
        ptrs, hits = [], []
        for arm, fk in zip(self._arms, fks):
            ptr, hit = _lookup(arm, fk)
            ptrs.append(ptr)
            hits.append(hit)
        valid = hits[0]
        for hit in hits[1:]:
            valid = valid & hit
        if self.backend == "fused":
            out = self._online_fused(ptrs, hits, valid)
        else:
            out = self._online_nonfused(ptrs, hits, valid)
        return out * valid[:, None].astype(out.dtype)

    def _online_fused(self, ptrs, hits, valid) -> jnp.ndarray:
        tables = [a.table for a in self._arms]
        if self.serve_backend == "pallas":
            from repro.kernels import fused_star_gather
            return fused_star_gather(
                jnp.stack(ptrs), jnp.stack(hits).astype(jnp.int32),
                tables, self._h, interpret=self._interpret)
        acc = None
        for ptr, hit, tbl in zip(ptrs, hits, tables):
            part = jnp.take(tbl, ptr, axis=0) * hit[:, None].astype(tbl.dtype)
            acc = part if acc is None else acc + part
        if self._h is None:
            return acc
        acc = acc * valid[:, None].astype(acc.dtype)
        return (acc == self._h[None, :].astype(acc.dtype)).astype(acc.dtype)

    def _online_nonfused(self, ptrs, hits, valid) -> jnp.ndarray:
        parts = []
        for arm, ptr, hit in zip(self._arms, ptrs, hits):
            rows = jnp.take(arm.table, ptr, axis=0)
            parts.append(rows * hit[:, None].astype(rows.dtype))
        t = jnp.concatenate(parts, axis=1) * valid[:, None].astype(jnp.float32)
        if (self.serve_backend == "pallas"
                and isinstance(self._model, DecisionTreeGEMM)):
            from repro.kernels import tree_predict
            m = self._model
            return tree_predict(t, m.F, m.v, m.H, m.h,
                                interpret=self._interpret)
        return self._model.apply(t)

    # -- request entry points ------------------------------------------------
    def serve(self, requests) -> jnp.ndarray:
        """Predictions for a request batch — any size, no recompilation.

        ``requests`` is a mapping ``{fk_col: (n,) ints}`` covering
        :attr:`request_keys`, a sequence of per-arm key arrays in arm order,
        or a stacked ``(num_arms, n)`` array.  Returns ``(n, l)`` fp32
        predictions; requests whose keys miss a live (predicate-passing)
        dimension row score zero, matching inner-join semantics.
        """
        fks = self._normalize(requests)
        n = int(fks[0].shape[0])
        if n == 0:
            return jnp.zeros((0, self.out_width), jnp.float32)
        top = self.buckets[-1]
        if n > top:
            chunks = [self._serve_bucketed([f[i:i + top] for f in fks])
                      for i in range(0, n, top)]
            if self.sharded is not None:
                # Eagerly concatenating mesh-sharded chunks miscompiles on
                # some jax versions (observed: values scaled by the model
                # axis size) — assemble oversized batches on host instead.
                return jnp.asarray(np.concatenate(
                    [np.asarray(c) for c in chunks], axis=0))
            return jnp.concatenate(chunks, axis=0)
        return self._serve_bucketed(fks)

    def _serve_bucketed(self, fks: List[np.ndarray]) -> jnp.ndarray:
        n = int(fks[0].shape[0])
        bucket = next(b for b in self.buckets if b >= n)
        padded = tuple(
            jnp.asarray(np.pad(f, (0, bucket - n), constant_values=PAD_KEY))
            for f in fks)
        traces_before = self._trace_count
        t0 = time.perf_counter()
        out = self._jit(padded)
        if self._sync_stats:
            # Wall-clock percentiles need a device fence; latency-sensitive
            # callers pass sync_stats=False to keep async dispatch (stats
            # then record dispatch time only).
            jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        if self._trace_count > traces_before:
            # First call into this bucket: dominated by trace + XLA compile,
            # which would otherwise masquerade as a p99 outlier.
            self._compile_s[bucket] = dt
        else:
            self._lat.setdefault(
                bucket, collections.deque(maxlen=LATENCY_WINDOW)).append(dt)
        return out[:n]

    def _normalize(self, requests) -> List[np.ndarray]:
        keys = self.request_keys
        if isinstance(requests, Mapping):
            missing = [k for k in keys if k not in requests]
            if missing:
                raise KeyError(f"request batch missing fk columns {missing}")
            cols = [requests[k] for k in keys]
        else:
            arr = requests
            if isinstance(arr, (np.ndarray, jnp.ndarray)) and arr.ndim == 1:
                cols = [arr]
            else:
                cols = list(arr)
        if len(cols) != len(keys):
            raise ValueError(
                f"expected {len(keys)} fk columns {keys}, got {len(cols)}")
        out = [np.asarray(c, np.int32).reshape(-1) for c in cols]
        n = out[0].shape[0]
        if any(c.shape[0] != n for c in out):
            raise ValueError("ragged fk columns in one request batch")
        return out


def requests_from_rows(fact: Table, q: PredictiveQuery, row_ids
                       ) -> Dict[str, np.ndarray]:
    """Lift fact-row ids into the equivalent FK request batch.

    Bridges the old serving interface (``predict_rows`` on fact rows) onto
    the dynamic runtime: the request carries exactly the fact rows' foreign
    keys, so serving it reproduces ``predict_rows`` for rows that pass the
    fact-side predicates.
    """
    ids = np.asarray(row_ids, np.int64)
    return {a.fk_col: np.asarray(fact.key(a.fk_col))[ids].astype(np.int32)
            for a in q.arms}


def compile_serving(catalog: Mapping[str, Table], q: PredictiveQuery, *,
                    backend: str = "auto", serve_backend: str = "auto",
                    buckets: Sequence[int] = DEFAULT_BUCKETS,
                    interpret: bool = False, donate: Optional[bool] = None,
                    sync_stats: bool = True,
                    batches_per_update: float = 1000.0,
                    memory_budget_bytes: Optional[int] = None,
                    mesh=None, shard_axis: str = "model",
                    shard_threshold_bytes: Optional[int] = None
                    ) -> ServingRuntime:
    """Compile ``q``'s online phase over a (batch, fk...) request pytree.

    The quasi-static phase (PK sort, predicate masks, Eq. 1 pre-fusion) runs
    here, once; the returned :class:`ServingRuntime` then serves arbitrary
    request batches through a fixed set of shape buckets with no
    recompilation beyond one trace per bucket.

    ``backend`` picks fused/nonfused execution ("auto" → cost model, sized
    at the top bucket); ``serve_backend`` picks the jnp gathers or the
    Pallas kernel lowering ("auto" → :func:`plan_serving_backend`; pass
    ``"pallas"`` with ``interpret=True`` to exercise the kernels on CPU).
    ``donate`` donates the padded request buffers to the compiled program
    (default: only on accelerators, where donation is supported).
    ``sync_stats=False`` drops the per-call device fence used for wall-clock
    latency percentiles, preserving async dispatch on the hot path (stats
    then record dispatch time only).

    Fact-side state is deliberately absent: requests are *not* fact rows, so
    ``q.fact_preds`` (predicates over fact measures) cannot apply and are
    ignored; dimension-side predicates are folded into the lookup validity.

    ``mesh`` switches on sharded serving: per-arm placement is decided by
    :func:`plan_partition_spec` (replicate below ``shard_threshold_bytes``,
    row-shard over ``shard_axis`` with the ``safe_spec`` divisibility
    fallback above it), buckets round up to multiples of the mesh's DP size
    and each bucket's program runs as one ``shard_map`` of device-local
    probes + gathers.  ``mesh`` is incompatible with
    ``serve_backend="pallas"``.
    """
    if q.model is None:
        raise ValueError("compile_serving requires a model head")
    if not q.arms:
        raise ValueError("compile_serving requires at least one star arm")
    for arg, allowed in ((backend, ("auto", "fused", "nonfused")),
                         (serve_backend, ("auto", "jnp", "pallas"))):
        if arg not in allowed:
            raise ValueError(f"backend {arg!r} not one of {allowed}")
    serve_backend = resolve_mesh_serve_backend(serve_backend, mesh)
    buckets = tuple(sorted({int(b) for b in buckets}))
    if not buckets or buckets[0] < 1:
        raise ValueError(f"buckets must be positive ints, got {buckets!r}")
    if mesh is not None:
        dp = dp_size(mesh)
        buckets = tuple(sorted({-(-b // dp) * dp for b in buckets}))

    dims = [DimSpec(catalog[a.table], a.fk_col, a.pk_col, a.feature_cols)
            for a in q.arms]
    dim_rows = []
    for d in dims:
        try:
            dim_rows.append(int(d.dim.nvalid))
        except jax.errors.ConcretizationTypeError:
            dim_rows.append(d.dim.capacity)
    plan = plan_query(q.model, buckets[-1], dim_rows,
                      selectivity=1.0, num_groups=0, out_width=q.model.l,
                      batches_per_update=batches_per_update,
                      memory_budget_bytes=memory_budget_bytes)
    backend = plan.backend if backend == "auto" else backend
    serve_backend = effective_serve_backend(plan, serve_backend, backend,
                                            q.model, len(dims))
    if serve_backend != plan.serve_backend:
        plan = dataclasses.replace(
            plan, serve_backend=serve_backend,
            reason=f"{plan.reason}; serve={serve_backend} (caller override)")

    if backend == "fused":
        pre = prefuse_dims(dims, q.model)
        tables = pre.partials
        h = pre.h
    else:
        tables = tuple(
            d.dim.matrix @ mapping_matrix(d.dim.columns, d.feature_cols)
            for d in dims)
        h = None

    arms = []
    masks = []
    for arm, d, tbl in zip(q.arms, dims, tables):
        dmask = d.dim.valid_mask()
        for p in arm.preds:
            dmask = dmask & p.mask(d.dim)
        masks.append(dmask)
        # On the mesh path the global index/table are dead weight: the
        # shard_map forward probes the per-shard slices instead.
        arms.append(_ArmIndex(
            fk_col=arm.fk_col,
            index=None if mesh is not None
            else pk_index(d.dim.key(arm.pk_col)),
            dmask=dmask,
            table=None if mesh is not None else tbl))

    sharded = None
    if mesh is not None:
        specs, plan = place_tables(mesh, tables, plan, axis=shard_axis,
                                   threshold_bytes=shard_threshold_bytes)
        sharded = shard_prefused_partials(
            mesh,
            [(arm.fk_col, d.dim.key(arm.pk_col), dmask, tbl)
             for arm, d, dmask, tbl in zip(q.arms, dims, masks, tables)],
            h, specs, shard_axis=shard_axis)
        if h is not None:
            h = sharded.h

    if donate is None:
        donate = (mesh is None
                  and jax.default_backend() in ("tpu", "gpu"))
    return ServingRuntime(query=q, plan=plan, backend=backend,
                          serve_backend=serve_backend, buckets=buckets,
                          arms=tuple(arms), model=q.model, h=h,
                          interpret=interpret, donate=donate,
                          sync_stats=sync_stats, sharded=sharded)
