"""Dynamic-batch serving: compile the fused online phase once, serve any
request batch.

``compile_query`` binds a static fact table, so its serving entry point
(``CompiledQuery.predict_rows``) can only score *fact rows*.  This module
traces the fused online phase over a ``(batch, fk...)`` request pytree
instead: a request is one foreign key per star arm, and the compiled program
is exactly the paper's Eq. 1 online phase — per-arm PK lookups into the
quasi-static sorted key index, then Σⱼ Pⱼ[ptrⱼ] gathers into the pre-fused
partials (+ ``== h`` for trees).  One compiled plan therefore serves
arbitrary incoming batches, not just rows the fact table happened to
contain.

Bucketed padding policy
-----------------------
XLA needs static shapes, so each incoming batch is padded (with ``PAD_KEY``,
which never matches a live PK) up to the smallest configured *bucket* size
and dispatched through one jitted program per bucket.  The jit cache is
keyed on the padded shape, so after at most ``len(buckets)`` traces no
request ever recompiles; batches larger than the top bucket are served in
top-bucket chunks.  Request buffers are donated on accelerators so the
padded int32 staging arrays are recycled across calls.

Physical lowering
-----------------
The gather-sum is lowered onto the Pallas kernels when the planner says the
shapes fit their block specs (``plan_serving_backend``): the fused path onto
``kernels/fused_star_gather`` (scalar-prefetched FK pointers, one DMA pass),
the non-fused decision-tree path onto ``kernels/tree_predict``.  Everything
else uses the pure-jnp gathers, which remain the reference semantics — the
kernel backends match them bit-exactly in fp32.

Sharded serving
---------------
``compile_serving(..., mesh=...)`` partitions the quasi-static state across
a device mesh (``core.query.sharding``): large partials row-shard over the
mesh's model axis with per-shard ``PKIndex`` slices, small ones replicate
(``plan_partition_spec``), and the padded FK batch shards over the DP axes.
Each bucket's program becomes one ``shard_map``-jitted device-local
probe + gather + psum, bit-exact vs the single-device jnp path.  Buckets
are rounded up to multiples of the DP size so every padded batch divides
the mesh.  The Pallas lowering is mutually exclusive with ``mesh`` (the
sharded block kernels are the TPU calibration follow-up).

Incremental maintenance
-----------------------
The quasi-static state (PK indices, predicate masks, prefused partials) is
a *call-time pytree argument* of the bucket programs, not a closure
constant, and the runtime records the :class:`~repro.core.laq.Catalog`
versions it was built against.  :meth:`ServingRuntime.refresh` applies
pending dimension appends/updates by delta — sorted-merge
``PKIndex.extend``, ``prefuse_rows`` over only the new rows, in-place mask
scatters, and (sharded) re-indexing of only the shard blocks that own the
appended tail — so the already-traced bucket programs keep serving with
zero recompiles.  Capacity growth changes shapes and falls back to a full
rebuild + replan (divisibility boundaries re-checked), with the decision
recorded on ``plan.reason``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Deque, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...launch.mesh import dp_size
from ..fusion.operators import DecisionTreeGEMM
from ..fusion.pipeline import prefuse_dims, prefuse_rows
from ..laq.catalog import Catalog, CatalogHistoryError, changed_spans
from ..laq.join import PKIndex, pk_index
from ..laq.projection import mapping_matrix
from ..laq.star import DimSpec
from ..laq.table import PAD_KEY, Table
from .explain import ExplainReport
from .ir import PredictiveQuery
from .multiquery import holds_tracers
from .snowflake import CollapsedChain, chain_tables, resolve_chain
from .planner import (QueryPlan, effective_serve_backend, place_tables,
                      plan_query, resolve_mesh_serve_backend)
from .sharding import (ShardedPrefusedPartials, extend_sharded_arm,
                       make_serving_forward, serving_arm_state,
                       shard_prefused_partials)

#: Default padding buckets: small interactive batches, mid-size batches, and
#: a bulk bucket that also serves as the chunk size for oversized requests.
DEFAULT_BUCKETS = (8, 64, 512)

#: Per-bucket latency samples kept for the percentile report (a bounded
#: window, so a long-lived runtime's bookkeeping stays O(1) per bucket).
LATENCY_WINDOW = 2048


class SentinelKeyError(ValueError):
    """A request carried a key equal to the padding sentinel ``PAD_KEY``.

    Padded slots are recognized *by value* — ``PAD_KEY`` never matches a
    live PK — so a real request key equal to the sentinel would be
    indistinguishable from padding: it would silently score zero with no
    indication anything was wrong.  ``ServingRuntime._normalize`` rejects
    such keys loudly instead; re-key the dimension if ``2**31 - 1`` must be
    a servable key.
    """


@dataclasses.dataclass(frozen=True)
class _ArmIndex:
    """Quasi-static per-arm lookup state (paper's offline phase, per arm).

    ``index`` factors the PK side of ``join_factored`` out of the online
    program: the sort runs once at compile time, the online lookup is the
    shared ``PKIndex.probe`` (searchsorted + two gathers) — the *same*
    probe the compiled-query join uses, which is what keeps serving
    bit-identical to ``predict_rows``.  ``dmask`` carries the
    dimension-side predicates and row liveness, folded into the lookup's
    validity exactly like the compiler folds them into the join (§2.2).
    """

    fk_col: str
    index: Optional[PKIndex]  # None on the mesh path (per-shard slices rule)
    dmask: jnp.ndarray        # (r,) bool, in dimension-row order
    table: Optional[jnp.ndarray]  # (r, w) partial; None on the mesh path


def _serving_tables(q: PredictiveQuery) -> Tuple[str, ...]:
    """Real catalog tables whose versions gate a runtime: heads + links.

    The fact table is deliberately absent — requests are FK tuples, never
    fact rows — but every table along a snowflake chain participates: a
    sub-dimension append changes the collapsed virtual dimension.
    """
    return tuple(sorted({t for a in q.arms for t in chain_tables(a)}))


def _serving_dims(catalog: Mapping[str, Table], q: PredictiveQuery,
                  pool=None) -> Tuple[List[DimSpec],
                                      Tuple[Optional[CollapsedChain], ...],
                                      Tuple[Optional[tuple], ...]]:
    """Per-arm DimSpecs with snowflake chains collapsed offline.

    Flat arms resolve against the catalog directly; chained arms collapse
    (through the shared pool when available — the same entry compiled
    plans use) to their head-granularity virtual dimension, whose columns
    become the arm's served feature set.  Returns ``(dims, chains,
    chain_keys)`` with ``None`` chain slots for flat arms.
    """
    dims, chains, chain_keys = [], [], []
    for a in q.arms:
        if a.links:
            if pool is not None:
                cc, ckey = pool.acquire_chain(a)
            else:
                cc, ckey = resolve_chain(catalog, a), None
            dims.append(DimSpec(cc.table, a.fk_col, a.pk_col,
                                tuple(cc.table.columns)))
            chains.append(cc)
            chain_keys.append(ckey)
        else:
            dims.append(DimSpec(catalog[a.table], a.fk_col, a.pk_col,
                                a.feature_cols))
            chains.append(None)
            chain_keys.append(None)
    return dims, tuple(chains), tuple(chain_keys)


def _mask_rows(dim: Table, preds, ids: np.ndarray) -> jnp.ndarray:
    """The dim-predicate mask evaluated on just the (live) rows ``ids``."""
    sub = Table(dim.name, dim.columns,
                jnp.take(dim.matrix, jnp.asarray(ids), axis=0),
                {c: jnp.take(v, jnp.asarray(ids))
                 for c, v in dim.keys.items()},
                int(ids.shape[0]))
    # The sub-table is all-live by construction (nvalid = len(ids), no
    # tombstones), so fold the *parent's* liveness at these rows explicitly
    # — a tombstoned row must come back False no matter what the predicates
    # say, exactly as the cold build's ``valid_mask() & preds`` fold does.
    m = jnp.take(dim.valid_mask(), jnp.asarray(ids))
    for p in preds:
        m = m & p.mask(sub)
    return m


class ServingRuntime:
    """One compiled predictive pipeline serving arbitrary request batches.

    Built by :func:`compile_serving`; hold one instance per (query, catalog)
    and call :meth:`serve` with request batches of any size.  Thread-compat:
    serving is functional over quasi-static arrays; only the latency/trace
    bookkeeping is unsynchronized.
    """

    def __init__(self, query: PredictiveQuery, plan: QueryPlan, backend: str,
                 serve_backend: str, buckets: Tuple[int, ...],
                 arms: Tuple[_ArmIndex, ...], model, h: Optional[jnp.ndarray],
                 interpret: bool, donate: bool, sync_stats: bool = True,
                 sharded: Optional[ShardedPrefusedPartials] = None,
                 catalog: Optional[Catalog] = None,
                 mesh=None, shard_axis: str = "model",
                 shard_threshold_bytes: Optional[int] = None,
                 pool=None, pool_refs: Optional[Dict] = None):
        self.query = query
        self.plan = plan
        self.backend = backend                # "fused" | "nonfused"
        self.serve_backend = serve_backend    # "jnp" | "pallas"
        self.buckets = buckets
        self._model = model
        self._interpret = interpret
        self._sync_stats = sync_stats
        self._trace_count = 0
        self._lat: Dict[int, Deque[float]] = {}
        self._lat_chunked: Deque[float] = collections.deque(
            maxlen=LATENCY_WINDOW)
        # One compile record per jit-cache generation: ``_compile_s`` is the
        # live generation's {bucket: seconds}, appended to ``_compile_log``
        # by ``_install`` so a rebuild archives instead of overwriting.
        self._compile_log: List[Dict[int, float]] = []
        self._donate = donate
        self.catalog = catalog
        self.versions: Dict[str, int] = (
            {t: catalog.version(t) for t in _serving_tables(query)}
            if catalog is not None else {})
        self._mesh = mesh
        self._shard_axis = shard_axis
        self._shard_threshold_bytes = shard_threshold_bytes
        # Session-owned ArtifactPool sharing (None when compiled
        # standalone): the keys this runtime holds references to —
        # {"arms": ((pkindex, dmask, features|None) per arm),
        #  "partials": (keys,)} — released by close().
        self._pool = pool
        self._pool_refs: Dict = pool_refs or {}
        self._install(arms, h, sharded)

    def _install(self, arms: Tuple[_ArmIndex, ...],
                 h: Optional[jnp.ndarray],
                 sharded: Optional[ShardedPrefusedPartials]):
        """Bind quasi-static state + a fresh jit cache (build and rebuild).

        The per-arm state is passed into the traced program as an argument
        (see ``_forward``), so a same-shape refresh swaps ``_state`` and
        re-dispatches into the existing executables; ``_install`` itself is
        only called when the program *must* be rebuilt (first build, or a
        shape-changing refresh), which is why it resets the trace count.
        """
        self._arms = arms
        self._h = h
        self.sharded = sharded
        self._forward_impl = (
            make_serving_forward(sharded, self._model, self.backend)
            if sharded is not None else None)
        self._state = {"arms": self._arm_state(), "h": self._h}
        self._trace_count = 0
        # A fresh cache generation starts a fresh compile record; earlier
        # generations stay archived in ``_compile_log`` (compile_history).
        self._compile_s: Dict[int, float] = {}
        self._compile_log.append(self._compile_s)
        donate_argnums = (0,) if self._donate else ()
        self._jit = jax.jit(self._forward, donate_argnums=donate_argnums)

    def _arm_state(self) -> Tuple:
        if self.sharded is not None:
            return serving_arm_state(self.sharded)
        return tuple((a.index.sorted_pk, a.index.order,
                      a.dmask.astype(jnp.bool_), a.table)
                     for a in self._arms)

    # -- sharding introspection ----------------------------------------------
    @property
    def mesh(self):
        """The serving mesh, or None on the single-device path."""
        return self.sharded.mesh if self.sharded is not None else None

    # -- introspection -------------------------------------------------------
    @property
    def request_keys(self) -> Tuple[str, ...]:
        """FK column names a request must provide, in arm order."""
        return tuple(a.fk_col for a in self._arms)

    @property
    def out_width(self) -> int:
        return self._model.l

    @property
    def num_compiles(self) -> int:
        """Traces taken since the jit cache was (re)built.

        Bounded by ``len(buckets)`` per cache generation: a delta
        ``refresh`` swaps same-shape state and never adds a trace; only a
        shape-changing rebuild starts a fresh cache (count restarts at 0).
        """
        return self._trace_count

    @property
    def generation(self) -> int:
        """The jit-cache generation (0-based; rebuilds increment it)."""
        return len(self._compile_log) - 1

    def compile_history(self) -> List[Dict[int, float]]:
        """Per-generation ``{bucket: compile_ms}`` records, oldest first.

        Consistent with the ``num_compiles`` generation semantics: a delta
        refresh keeps the live generation's record (no retrace happened), a
        shape-changing rebuild archives it and starts a new one — the
        first-generation compile times survive every later retrace instead
        of being overwritten.
        """
        return [{b: s * 1e3 for b, s in gen.items()}
                for gen in self._compile_log]

    def jit_cache_size(self) -> Optional[int]:
        """The jit executable cache size (None if jax hides it)."""
        try:
            return self._jit._cache_size()
        except AttributeError:
            return None

    def latency_stats(self) -> Dict[object, Dict[str, float]]:
        """Per-bucket steady-state serve latency percentiles (ms).

        Each bucket's one-time trace+compile call is kept out of the
        percentiles and reported separately as ``compile_ms`` (the *live*
        cache generation's record — earlier generations survive in
        :meth:`compile_history`); a bucket that has only ever compiled
        still appears, with ``count == 0`` and no percentile keys.

        Oversized batches (``n > buckets[-1]``) are served in top-bucket
        chunks, and their wall time is attributed **per request** under the
        ``"chunked"`` key — one sample for the whole oversized call — not
        per chunk, so one analytical batch cannot skew the top bucket's
        point-lookup percentiles.  Percentiles measure wall time only when
        the runtime synchronizes per call (``sync_stats``, the default).
        """
        out: Dict[object, Dict[str, float]] = {}
        for bucket in sorted(set(self._lat) | set(self._compile_s)):
            ts = self._lat.get(bucket, ())
            out[bucket] = {"count": len(ts)}
            if ts:
                out[bucket].update(self._percentiles(ts))
            if bucket in self._compile_s:
                out[bucket]["compile_ms"] = self._compile_s[bucket] * 1e3
        if self._lat_chunked:
            out["chunked"] = {"count": len(self._lat_chunked),
                              **self._percentiles(self._lat_chunked)}
        return out

    @staticmethod
    def _percentiles(ts) -> Dict[str, float]:
        ms = np.asarray(ts) * 1e3
        return {"p50": float(np.percentile(ms, 50)),
                "p95": float(np.percentile(ms, 95)),
                "p99": float(np.percentile(ms, 99))}

    # -- the compiled program ------------------------------------------------
    def _forward(self, fks: Tuple[jnp.ndarray, ...], state) -> jnp.ndarray:
        # Python side effect: runs once per trace (i.e. once per bucket;
        # the quasi-static state is an argument, so a same-shape refresh
        # never re-enters here).
        self._trace_count += 1
        if self._forward_impl is not None:   # sharded shard_map program
            return self._forward_impl(fks, state["arms"])
        ptrs, hits = [], []
        for (sorted_pk, order, dmask, _), fk in zip(state["arms"], fks):
            fj = PKIndex(sorted_pk, order).probe(fk)
            ptrs.append(fj.ptr)
            hits.append(fj.found & jnp.take(dmask, fj.ptr))
        valid = hits[0]
        for hit in hits[1:]:
            valid = valid & hit
        tables = [t for (_, _, _, t) in state["arms"]]
        if self.backend == "fused":
            out = self._online_fused(ptrs, hits, valid, tables, state["h"])
        else:
            out = self._online_nonfused(ptrs, hits, valid, tables)
        return out * valid[:, None].astype(out.dtype)

    def _online_fused(self, ptrs, hits, valid, tables, h) -> jnp.ndarray:
        if self.serve_backend == "pallas":
            from repro.kernels import fused_star_gather
            return fused_star_gather(
                jnp.stack(ptrs), jnp.stack(hits).astype(jnp.int32),
                tables, h, interpret=self._interpret)
        acc = None
        for ptr, hit, tbl in zip(ptrs, hits, tables):
            part = jnp.take(tbl, ptr, axis=0) * hit[:, None].astype(tbl.dtype)
            acc = part if acc is None else acc + part
        if h is None:
            return acc
        acc = acc * valid[:, None].astype(acc.dtype)
        return (acc == h[None, :].astype(acc.dtype)).astype(acc.dtype)

    def _online_nonfused(self, ptrs, hits, valid, tables) -> jnp.ndarray:
        parts = []
        for tbl, ptr, hit in zip(tables, ptrs, hits):
            rows = jnp.take(tbl, ptr, axis=0)
            parts.append(rows * hit[:, None].astype(rows.dtype))
        t = jnp.concatenate(parts, axis=1) * valid[:, None].astype(jnp.float32)
        if (self.serve_backend == "pallas"
                and isinstance(self._model, DecisionTreeGEMM)):
            from repro.kernels import tree_predict
            m = self._model
            return tree_predict(t, m.F, m.v, m.H, m.h,
                                interpret=self._interpret)
        return self._model.apply(t)

    # -- introspection / lifecycle -------------------------------------------
    def _pool_keys(self) -> list:
        """Every pool key this runtime references (with multiplicity)."""
        keys = [k for ref in self._pool_refs.get("arms", ()) for k in ref
                if k is not None]
        keys.extend(self._pool_refs.get("partials", ()))
        return keys

    def explain(self) -> ExplainReport:
        """Structured plan/refresh report (``str()`` gives the legacy line)."""
        return ExplainReport(
            kind="serving", backend=self.backend,
            serve_backend=self.serve_backend,
            plan_reason=getattr(self, "_base_reason", self.plan.reason),
            trail=tuple(getattr(self, "_refresh_notes", ())),
            shared_artifacts=tuple(self._pool_keys()),
            extras=(("buckets", self.buckets),
                    ("generation", self.generation)))

    def close(self) -> None:
        """Release this runtime's shared-artifact references (idempotent)."""
        if self._pool is not None and self._pool_refs:
            self._pool.release(self._pool_keys())
        self._pool_refs = {}

    # -- incremental maintenance --------------------------------------------
    def refresh(self) -> str:
        """Apply pending catalog deltas to the serving state, in place.

        Same-shape appends/updates take the delta path: per-arm
        ``PKIndex.extend`` sorted merges (sharded arms re-index only the
        shard blocks owning the appended tail), ``prefuse_rows`` over just
        the changed dimension rows, and predicate-mask scatters — the state
        pytree is swapped and the already-traced bucket programs keep
        serving with **zero new compiles** (``num_compiles`` unchanged).
        Capacity growth falls back to a full rebuild + replan (placement
        divisibility re-checked) with a fresh jit cache, so
        ``num_compiles`` restarts from 0.  Either way the latency windows
        reset: post-refresh ``latency_stats`` never mix pre-refresh
        samples.  Compile records follow the cache generation instead: the
        delta path keeps the live record, a rebuild archives it into
        :meth:`compile_history` and starts generation ``g+1``.  Returns
        the decision line (also appended to ``plan.reason``).

        Concurrency: refresh swaps the state pytree out from under the
        bucket programs and is **not** fenced against concurrent
        :meth:`serve` calls from other threads.  Serve through an
        :class:`~repro.core.query.scheduler.AdmissionScheduler` (or its
        ``refresh()``) when requests are in flight — it drains admitted
        work before swapping.
        """
        if self.catalog is None:
            return self._note("refresh=no-op(detached: no catalog)")
        cat = self.catalog
        try:
            changed = {
                t: cat.deltas_since(t, self.versions.get(t, 0))
                for t in _serving_tables(self.query)}
        except CatalogHistoryError:
            return self._rebuild("history-compacted: runtime staler than "
                                 "the delta log")
        changed = {n: d for n, d in changed.items() if d}
        if not changed:
            return self._note("refresh=no-op(versions unchanged)")
        if any(changed_spans(d)[2] for d in changed.values()):
            compacted = sorted(n for n, d in changed.items()
                               if any(t.kind == "compact" for t in d))
            if compacted:
                return self._rebuild(
                    f"compaction:{','.join(compacted)} rewrote row ids")
            grown = sorted(n for n, d in changed.items()
                           if changed_spans(d)[2])
            return self._rebuild(f"capacity-growth:{','.join(grown)}")
        chained = {t for a in self.query.arms if a.links
                   for t in chain_tables(a)}
        if chained & set(changed):
            # A delta anywhere along a chain changes the collapsed virtual
            # dimension (composed pointers, gathered features, folded
            # validity) — re-collapse and rebind through the full rebuild
            # path rather than teaching the delta scatters chain
            # composition.  Bit-exact by construction; the flat-arm delta
            # path below stays zero-recompile for non-chain appends.
            touched = ",".join(sorted(chained & set(changed)))
            return self._rebuild(
                f"chain tables changed: {touched} re-collapsed")
        line = self._refresh_delta(changed)
        self._reset_stats()
        return line

    def _note(self, line: str) -> str:
        # Bounded decision trail: base plan reason + the last few refresh
        # lines — a runtime refreshed per streaming batch must not grow
        # its explain() string (and memory) without limit.
        if not hasattr(self, "_refresh_notes"):
            self._refresh_notes = collections.deque(maxlen=8)
        if not self._refresh_notes:
            self._base_reason = self.plan.reason
        self._refresh_notes.append(line)
        self.plan = dataclasses.replace(
            self.plan, reason="; ".join([self._base_reason,
                                         *self._refresh_notes]))
        return line

    def _reset_stats(self):
        """Latency percentiles restart at a refresh boundary (pre-refresh
        samples would pollute the post-refresh distribution).  Compile
        records are *not* cleared here: they are per cache generation
        (``num_compiles`` semantics) — a delta refresh keeps the live
        generation's record, and a rebuild already archived it via
        ``_install``."""
        self._lat.clear()
        self._lat_chunked.clear()

    def _rebuild(self, why: str) -> str:
        q = self.query
        dims, chains, chain_keys = _serving_dims(self.catalog, q,
                                                 pool=self._pool)
        # Re-plan from the *base* reason (accumulated refresh notes would
        # otherwise be baked into the new plan's base and grow unbounded).
        base_plan = (dataclasses.replace(self.plan,
                                         reason=self._base_reason)
                     if getattr(self, "_refresh_notes", None)
                     else self.plan)
        # Re-acquire from the pool FIRST (fresh references keep shared
        # refcounts above zero), then release the references of the state
        # being replaced.
        old_keys = self._pool_keys()
        arms, h, sharded, plan, refs = _serving_artifacts(
            self.catalog, q, dims, self._model, self.backend, base_plan,
            mesh=self._mesh, shard_axis=self._shard_axis,
            shard_threshold_bytes=self._shard_threshold_bytes,
            pool=self._pool, chains=chains, chain_keys=chain_keys)
        self._pool_refs = refs
        if self._pool is not None and old_keys:
            self._pool.release(old_keys)
        self.plan = plan
        if hasattr(self, "_refresh_notes"):
            self._refresh_notes.clear()   # replanned: fresh decision trail
        self._install(arms, h, sharded)
        self._reset_stats()
        self.versions = {t: self.catalog.version(t)
                         for t in _serving_tables(q)}
        return self._note(f"refresh=rebuild({why}; replanned, jit cache "
                          "reset)")

    def _refresh_delta_pooled(self, changed) -> str:
        """Pool-backed delta refresh: O(distinct artifacts), not O(plans).

        Each ``pool.get`` delta-updates the shared entry at most once per
        catalog version change regardless of how many runtimes/plans
        reference it; rebinding the refreshed arrays into ``_state`` is
        all that remains per runtime.
        """
        q = self.query
        cat = self.catalog
        pool = self._pool
        pkeys = self._pool_refs.get("partials", ())
        parts = tuple(pool.get(k) for k in pkeys) if pkeys else None
        new_arms = []
        for j, (old, ref) in enumerate(
                zip(self._arms, self._pool_refs["arms"])):
            # Serving refs are (ikey, mkey, tkey[, ckey]); a chained arm
            # carries its dmask/features on the pooled chain entry.
            ikey, mkey, tkey, ckey = (tuple(ref) + (None,) * 4)[:4]
            if ckey is not None:
                cc = pool.get(ckey)
                dmask = cc.dmask
                tbl = parts[j] if parts is not None else cc.table.matrix
            else:
                dmask = pool.get(mkey)
                tbl = parts[j] if parts is not None else pool.get(tkey)
            new_arms.append(dataclasses.replace(
                old, index=pool.get(ikey), dmask=dmask, table=tbl))
        self._arms = tuple(new_arms)
        self._state = {"arms": self._arm_state(), "h": self._h}
        self.versions = {t: cat.version(t) for t in _serving_tables(q)}
        touched = ",".join(f"{n}+{len(changed[n])}" for n in sorted(changed))
        return self._note(f"refresh=delta({touched}; pooled artifacts, "
                          "0 new compiles)")

    def _refresh_delta(self, changed) -> str:
        if self._pool is not None and self._pool_refs.get("arms"):
            return self._refresh_delta_pooled(changed)
        q = self.query
        cat = self.catalog
        # Chain tables never reach this path (refresh() routes any chain
        # delta to _rebuild), but chained arms still shape the prefuse
        # feature slices — resolve them so arm j's slice offsets match the
        # build.
        dims, _, _ = _serving_dims(cat, q)
        new_arms = list(self._arms)
        new_sharded_arms = (list(self.sharded.arms)
                            if self.sharded is not None else None)
        for j, arm in enumerate(q.arms):
            if arm.table not in changed:
                continue
            dim = cat[arm.table]
            span, dirty, _, deleted = changed_spans(changed[arm.table])
            ids = set(dirty)
            if span is not None:
                ids.update(range(span[0], span[1]))
            # Tombstoned rows need only the validity scatter below: their
            # partial rows, keys and slots are untouched (deletion is a
            # pure validity fold), so they join the mask ids but not the
            # prefuse recompute.
            touched = sorted(ids | set(deleted))
            if not touched:    # e.g. history contains only no-op deltas
                continue
            old = self._arms[j]
            table = (old.table if old.table is not None
                     else new_sharded_arms[j].table)
            if ids:
                # Partial (fused) / projected-feature (nonfused) rows: only
                # the changed dimension rows are recomputed, then scattered
                # — the delta half of Eq. 1 maintenance, bit-exact vs a
                # cold prefuse.
                upd = np.asarray(sorted(ids), np.int32)
                if self.backend == "fused":
                    rows = prefuse_rows(dims, self._model, j,
                                        jnp.asarray(upd))
                else:
                    m = mapping_matrix(dim.columns, arm.feature_cols)
                    rows = jnp.take(dim.matrix, jnp.asarray(upd),
                                    axis=0) @ m
                table = table.at[jnp.asarray(upd)].set(rows)
            ids = np.asarray(touched, np.int32)
            lo, hi = int(ids.min()), int(ids.max()) + 1
            dmask = old.dmask.at[jnp.asarray(ids)].set(
                _mask_rows(dim, arm.preds, ids))
            if new_sharded_arms is not None:
                new_sharded_arms[j] = extend_sharded_arm(
                    self.sharded, j, table, dim.key(arm.pk_col), dmask,
                    lo, hi)
                new_arms[j] = dataclasses.replace(old, dmask=dmask)
            else:
                index = old.index
                if span is not None:
                    index = index.extend(
                        dim.key(arm.pk_col)[span[0]:span[1]],
                        np.arange(span[0], span[1]))
                new_arms[j] = dataclasses.replace(
                    old, index=index, dmask=dmask, table=table)
        self._arms = tuple(new_arms)
        if new_sharded_arms is not None:
            self.sharded = dataclasses.replace(
                self.sharded, arms=tuple(new_sharded_arms))
        self._state = {"arms": self._arm_state(), "h": self._h}
        self.versions = {t: cat.version(t) for t in _serving_tables(q)}
        touched = ",".join(f"{n}+{len(changed[n])}" for n in sorted(changed))
        return self._note(f"refresh=delta({touched}; shapes kept, "
                          "0 new compiles)")

    # -- request entry points ------------------------------------------------
    def serve(self, requests) -> jnp.ndarray:
        """Predictions for a request batch — any size, no recompilation.

        ``requests`` is a mapping ``{fk_col: (n,) ints}`` covering
        :attr:`request_keys`, a sequence of per-arm key arrays in arm order,
        or a stacked ``(num_arms, n)`` array.  Returns ``(n, l)`` fp32
        predictions; requests whose keys miss a live (predicate-passing)
        dimension row score zero, matching inner-join semantics.
        """
        fks = self._normalize(requests)
        n = int(fks[0].shape[0])
        if n == 0:
            return jnp.zeros((0, self.out_width), jnp.float32)
        top = self.buckets[-1]
        if n > top:
            # Oversized analytical batch: top-bucket chunks, but the wall
            # time is attributed to the *request* (one "chunked" sample),
            # never per chunk into the top bucket's percentile window —
            # one big batch must not skew point-lookup p99.
            t0 = time.perf_counter()
            chunks = [self._serve_bucketed([f[i:i + top] for f in fks],
                                           record=False)
                      for i in range(0, n, top)]
            if self.sharded is not None:
                # Eagerly concatenating mesh-sharded chunks miscompiles on
                # some jax versions (observed: values scaled by the model
                # axis size) — assemble oversized batches on host instead.
                out = jnp.asarray(np.concatenate(
                    [np.asarray(c) for c in chunks], axis=0))
            else:
                out = jnp.concatenate(chunks, axis=0)
                if self._sync_stats:
                    jax.block_until_ready(out)
            self._lat_chunked.append(time.perf_counter() - t0)
            return out
        return self._serve_bucketed(fks)

    def _serve_bucketed(self, fks: List[np.ndarray], *,
                        record: bool = True) -> jnp.ndarray:
        n = int(fks[0].shape[0])
        bucket, padded = self._admit(fks)
        return self._execute(padded, bucket, record=record)[:n]

    # Admission/execution split: the async scheduler composes padded
    # sub-batches itself (coalescing several queued requests into one
    # bucket-shaped step), so padding and dispatch are separate entry
    # points rather than one opaque serve call.
    def _admit(self, fks: List[np.ndarray],
               bucket: Optional[int] = None
               ) -> Tuple[int, Tuple[jnp.ndarray, ...]]:
        """Pad normalized request columns into a bucket-shaped batch.

        Returns ``(bucket, padded)``; ``bucket`` defaults to the smallest
        configured bucket that fits the rows (callers chunk batches larger
        than ``buckets[-1]`` before admitting).
        """
        n = int(fks[0].shape[0])
        if bucket is None:
            if n > self.buckets[-1]:
                raise ValueError(
                    f"cannot admit {n} rows in one step: top bucket is "
                    f"{self.buckets[-1]} (chunk the batch first)")
            bucket = next(b for b in self.buckets if b >= n)
        elif bucket < n or bucket not in self.buckets:
            raise ValueError(f"bucket {bucket} cannot hold {n} rows "
                             f"(buckets: {self.buckets})")
        return bucket, tuple(
            jnp.asarray(np.pad(f, (0, bucket - n), constant_values=PAD_KEY))
            for f in fks)

    def _execute(self, padded: Tuple[jnp.ndarray, ...], bucket: int, *,
                 record: bool = True) -> jnp.ndarray:
        """Dispatch one bucket program; returns the full padded output.

        Owns the latency/trace bookkeeping: a first call into a bucket is
        dominated by trace + XLA compile and lands in the generation's
        compile record instead of the percentile window (where it would
        masquerade as a p99 outlier); ``record=False`` additionally keeps
        the steady-state wall time out of the bucket window — chunk
        executions of an oversized request are attributed to the whole
        request by the caller, not per chunk.
        """
        traces_before = self._trace_count
        t0 = time.perf_counter()
        out = self._jit(padded, self._state)
        if self._sync_stats:
            # Wall-clock percentiles need a device fence; latency-sensitive
            # callers pass sync_stats=False to keep async dispatch (stats
            # then record dispatch time only).
            jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        if self._trace_count > traces_before:
            self._compile_s[bucket] = dt
        elif record:
            self._lat.setdefault(
                bucket, collections.deque(maxlen=LATENCY_WINDOW)).append(dt)
        return out

    def _normalize(self, requests) -> List[np.ndarray]:
        keys = self.request_keys
        if isinstance(requests, Mapping):
            missing = [k for k in keys if k not in requests]
            if missing:
                raise KeyError(f"request batch missing fk columns {missing}")
            cols = [requests[k] for k in keys]
        else:
            arr = requests
            if isinstance(arr, (np.ndarray, jnp.ndarray)) and arr.ndim == 1:
                cols = [arr]
            else:
                cols = list(arr)
        if len(cols) != len(keys):
            raise ValueError(
                f"expected {len(keys)} fk columns {keys}, got {len(cols)}")
        out = [np.asarray(c, np.int32).reshape(-1) for c in cols]
        n = out[0].shape[0]
        if any(c.shape[0] != n for c in out):
            raise ValueError("ragged fk columns in one request batch")
        for key, c in zip(keys, out):
            if np.any(c == PAD_KEY):
                raise SentinelKeyError(
                    f"request column {key!r} contains the padding sentinel "
                    f"{int(PAD_KEY)} (PAD_KEY): sentinel-valued keys are "
                    "indistinguishable from padded slots and would "
                    "silently score zero")
        return out


def requests_from_rows(fact: Table, q: PredictiveQuery, row_ids
                       ) -> Dict[str, np.ndarray]:
    """Lift fact-row ids into the equivalent FK request batch.

    Bridges the old serving interface (``predict_rows`` on fact rows) onto
    the dynamic runtime: the request carries exactly the fact rows' foreign
    keys, so serving it reproduces ``predict_rows`` for rows that pass the
    fact-side predicates.
    """
    ids = np.asarray(row_ids, np.int64)
    return {a.fk_col: np.asarray(fact.key(a.fk_col))[ids].astype(np.int32)
            for a in q.arms}


def _serving_artifacts(catalog: Mapping[str, Table], q: PredictiveQuery,
                       dims: Sequence[DimSpec], model, backend: str,
                       plan: QueryPlan, *, mesh=None,
                       shard_axis: str = "model",
                       shard_threshold_bytes: Optional[int] = None,
                       pool=None, chains: Sequence[
                           Optional[CollapsedChain]] = (),
                       chain_keys: Sequence[Optional[tuple]] = ()):
    """The quasi-static serving state: prefused/projected tables, per-arm
    PK indices + predicate masks, and (mesh) the placed shards.

    Shared by the cold ``compile_serving`` build and the runtime's
    shape-changing ``refresh`` rebuild, so both paths place and index the
    state identically (placement replanned from the *current* table
    shapes — the divisibility boundary is re-checked on every rebuild).
    Returns ``(arms, h, sharded, plan, pool_refs)``.

    With a ``pool`` (single-device path only), the partials / projected
    feature tables / masks / PK indices are acquired from the shared
    :class:`~.multiquery.ArtifactPool` — the same entries compiled plans
    use, so a serving runtime and a fused compiled query over the same arm
    reference one physical partial.

    ``chains``/``chain_keys`` come from :func:`_serving_dims`: a chained
    arm's dmask is the collapsed chain's validity vector (head liveness,
    hop misses and every predicate along the chain already folded in),
    its nonfused feature table is the virtual matrix, and its PK index is
    built on the *real head table's* name — the virtual PK column is the
    head's, so the entry is shared with compiled plans over the head.
    """
    chains = tuple(chains) + (None,) * (len(dims) - len(chains))
    chain_keys = (tuple(chain_keys)
                  + (None,) * (len(dims) - len(chain_keys)))
    partial_keys: Tuple = ()
    if backend == "fused":
        if pool is not None:
            tables, h, partial_keys = pool.acquire_partials(
                dims, model, chains=chains)
        else:
            pre = prefuse_dims(dims, model)
            tables = pre.partials
            h = pre.h
    else:
        feat_keys = []
        if pool is not None:
            tables = []
            for d, cc in zip(dims, chains):
                if cc is not None:
                    # The virtual matrix IS the projected feature table
                    # (columns == the arm's served features); it lives in
                    # the pool under the chain key, not a features entry.
                    tables.append(cc.table.matrix)
                    feat_keys.append(None)
                    continue
                tbl, tkey = pool.acquire_features(d.dim.name,
                                                  d.feature_cols)
                tables.append(tbl)
                feat_keys.append(tkey)
            tables = tuple(tables)
        else:
            tables = tuple(
                d.dim.matrix @ mapping_matrix(d.dim.columns, d.feature_cols)
                for d in dims)
        h = None

    arms = []
    masks = []
    arm_refs = []
    for j, (arm, d, tbl, cc) in enumerate(zip(q.arms, dims, tables,
                                              chains)):
        if pool is not None:
            if cc is not None:
                dmask, mkey = cc.dmask, None
            else:
                dmask, mkey = pool.acquire_dmask(arm.table, arm.preds)
            index, ikey = pool.acquire_pkindex(arm.table, arm.pk_col)
            arm_refs.append((ikey, mkey,
                             feat_keys[j] if backend != "fused" else None,
                             chain_keys[j]))
        else:
            if cc is not None:
                dmask = cc.dmask
            else:
                dmask = d.dim.valid_mask()
                for p in arm.preds:
                    dmask = dmask & p.mask(d.dim)
            index = (None if mesh is not None
                     else pk_index(d.dim.key(arm.pk_col)))
        masks.append(dmask)
        # On the mesh path the global index/table are dead weight: the
        # shard_map forward probes the per-shard slices instead.
        arms.append(_ArmIndex(
            fk_col=arm.fk_col,
            index=index,
            dmask=dmask,
            table=None if mesh is not None else tbl))
    pool_refs = ({"arms": tuple(arm_refs), "partials": tuple(partial_keys)}
                 if pool is not None else {})

    sharded = None
    if mesh is not None:
        specs, plan = place_tables(mesh, tables, plan, axis=shard_axis,
                                   threshold_bytes=shard_threshold_bytes)
        sharded = shard_prefused_partials(
            mesh,
            [(arm.fk_col, d.dim.key(arm.pk_col), dmask, tbl)
             for arm, d, dmask, tbl in zip(q.arms, dims, masks, tables)],
            h, specs, shard_axis=shard_axis)
        if h is not None:
            h = sharded.h
    return tuple(arms), h, sharded, plan, pool_refs


def compile_serving(catalog: Mapping[str, Table], q: PredictiveQuery, *,
                    backend: str = "auto", serve_backend: str = "auto",
                    buckets: Sequence[int] = DEFAULT_BUCKETS,
                    interpret: bool = False, donate: Optional[bool] = None,
                    sync_stats: bool = True,
                    batches_per_update: float = 1000.0,
                    memory_budget_bytes: Optional[int] = None,
                    mesh=None, shard_axis: str = "model",
                    shard_threshold_bytes: Optional[int] = None,
                    pool=None) -> ServingRuntime:
    """Compile ``q``'s online phase over a (batch, fk...) request pytree.

    The quasi-static phase (PK sort, predicate masks, Eq. 1 pre-fusion) runs
    here, once; the returned :class:`ServingRuntime` then serves arbitrary
    request batches through a fixed set of shape buckets with no
    recompilation beyond one trace per bucket.

    ``backend`` picks fused/nonfused execution ("auto" → cost model, sized
    at the top bucket); ``serve_backend`` picks the jnp gathers or the
    Pallas kernel lowering ("auto" → :func:`plan_serving_backend`; pass
    ``"pallas"`` with ``interpret=True`` to exercise the kernels on CPU).
    ``donate`` donates the padded request buffers to the compiled program
    (default: only on accelerators, where donation is supported).
    ``sync_stats=False`` drops the per-call device fence used for wall-clock
    latency percentiles, preserving async dispatch on the hot path (stats
    then record dispatch time only).

    Fact-side state is deliberately absent: requests are *not* fact rows, so
    ``q.fact_preds`` (predicates over fact measures) cannot apply and are
    ignored; dimension-side predicates are folded into the lookup validity.

    ``mesh`` switches on sharded serving: per-arm placement is decided by
    :func:`plan_partition_spec` (replicate below ``shard_threshold_bytes``,
    row-shard over ``shard_axis`` with the ``safe_spec`` divisibility
    fallback above it), buckets round up to multiples of the mesh's DP size
    and each bucket's program runs as one ``shard_map`` of device-local
    probes + gathers.  ``mesh`` is incompatible with
    ``serve_backend="pallas"``.

    ``catalog`` may be a :class:`~repro.core.laq.Catalog`, whose appends
    and column updates the runtime absorbs in place via
    :meth:`ServingRuntime.refresh`; plain mappings are auto-wrapped into a
    read-only Catalog (the pre-Catalog frozen contract — such runtimes
    never have pending deltas and refresh is a no-op).
    """
    if q.model is None:
        raise ValueError("compile_serving requires a model head")
    if q.model_preds:
        raise ValueError(
            "compile_serving does not take prediction filters "
            "(model_preds): serving returns raw predictions per request "
            "row — filter in the aggregate path (compile_query) instead")
    if not q.arms:
        raise ValueError("compile_serving requires at least one star arm")
    for arg, allowed in ((backend, ("auto", "fused", "nonfused")),
                         (serve_backend, ("auto", "jnp", "pallas"))):
        if arg not in allowed:
            raise ValueError(f"backend {arg!r} not one of {allowed}")
    serve_backend = resolve_mesh_serve_backend(serve_backend, mesh)
    if not isinstance(catalog, Catalog):
        warnings.warn(
            "passing a plain mapping to compile_serving is deprecated and "
            "will require an explicit wrap in a future release; construct "
            "a repro.core.laq.Catalog (or go through Session) — see the "
            "migration table in repro.core.query",
            DeprecationWarning, stacklevel=2)
    catalog = Catalog.wrap(catalog)
    for arm in q.arms:   # teach the catalog the join contract (PK columns)
        catalog.note_unique(arm.table, arm.pk_col)
        for lk in arm.links:
            catalog.note_unique(lk.table, lk.pk_col)
    # Pool sharing engages only on the plain single-device path against
    # the pool's own catalog (mesh placement commits arrays to devices;
    # tracer-holding tables must never leak into a cross-plan cache).
    if not (pool is not None and mesh is None and pool.catalog is catalog
            and not holds_tracers(catalog, q)):
        pool = None
    buckets = tuple(sorted({int(b) for b in buckets}))
    if not buckets or buckets[0] < 1:
        raise ValueError(f"buckets must be positive ints, got {buckets!r}")
    if mesh is not None:
        dp = dp_size(mesh)
        buckets = tuple(sorted({-(-b // dp) * dp for b in buckets}))

    dims, chains, chain_keys = _serving_dims(catalog, q, pool=pool)
    dim_rows = []
    for d in dims:
        try:
            dim_rows.append(int(d.dim.nvalid))
        except jax.errors.ConcretizationTypeError:
            dim_rows.append(d.dim.capacity)
    plan = plan_query(q.model, buckets[-1], dim_rows,
                      selectivity=1.0, num_groups=0, out_width=q.model.l,
                      batches_per_update=batches_per_update,
                      memory_budget_bytes=memory_budget_bytes)
    backend = plan.backend if backend == "auto" else backend
    serve_backend = effective_serve_backend(plan, serve_backend, backend,
                                            q.model, len(dims))
    if serve_backend != plan.serve_backend:
        plan = dataclasses.replace(
            plan, serve_backend=serve_backend,
            reason=f"{plan.reason}; serve={serve_backend} (caller override)")

    arms, h, sharded, plan, pool_refs = _serving_artifacts(
        catalog, q, dims, q.model, backend, plan, mesh=mesh,
        shard_axis=shard_axis, shard_threshold_bytes=shard_threshold_bytes,
        pool=pool, chains=chains, chain_keys=chain_keys)

    if donate is None:
        donate = (mesh is None
                  and jax.default_backend() in ("tpu", "gpu"))
    return ServingRuntime(query=q, plan=plan, backend=backend,
                          serve_backend=serve_backend, buckets=buckets,
                          arms=arms, model=q.model, h=h,
                          interpret=interpret, donate=donate,
                          sync_stats=sync_stats, sharded=sharded,
                          catalog=catalog, mesh=mesh, shard_axis=shard_axis,
                          shard_threshold_bytes=shard_threshold_bytes,
                          pool=pool, pool_refs=pool_refs)
