"""Declarative IR for predictive queries (selection ⋈ star ⋈ model ⋈ γ).

A ``PredictiveQuery`` is the logical plan the compiler lowers; every node is
data (frozen dataclasses + tuples) so plans are cheap to build, inspect and
cache.  Value expressions over fact columns are tiny s-expressions::

    "lo_revenue"                          # a column
    ("mul", "lo_extendedprice", "lo_discount")
    ("sub", "lo_revenue", "lo_supplycost")

and the sentinel ``PREDICTION`` aggregates the model's output matrix instead
of a fact column.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax.numpy as jnp

from ..fusion.operators import DecisionTreeGEMM, LinearOperator
from ..laq.selection import Pred
from ..laq.table import Table

Model = Union[LinearOperator, DecisionTreeGEMM]

#: Aggregate.value sentinel: aggregate the (n, l) model prediction matrix.
PREDICTION = "@prediction"

_BINOPS = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
}


@dataclasses.dataclass(frozen=True)
class ArmSpec:
    """One arm of the star: ``fact.fk_col = <table>.pk_col`` (paper §3.1).

    ``preds`` are dimension-side predicates, pushed below the join: they are
    evaluated once on the dimension table and folded into the factored
    matching matrix's validity (selection-as-filter-vector, §2.2, composed
    with the join instead of multiplied through).
    """

    table: str                            # catalog name of the dimension
    fk_col: str
    pk_col: str
    feature_cols: Tuple[str, ...] = ()
    preds: Tuple[Pred, ...] = ()


@dataclasses.dataclass(frozen=True)
class GroupKey:
    """One GROUP BY key column, drawn from the fact table or a joined arm.

    ``bound`` is an exclusive upper bound on ``col - offset`` — the radix of
    this digit in the composite group code (§2.4.2).
    """

    table: str                            # "fact" or an ArmSpec.table name
    col: str
    bound: int
    offset: int = 0


@dataclasses.dataclass(frozen=True)
class Aggregate:
    """SUM(value) [GROUP BY ...]; ``value`` is an expr or ``PREDICTION``."""

    value: Union[str, tuple]
    op: str = "sum"
    name: str = "agg"


@dataclasses.dataclass(frozen=True, eq=False)
class PredictiveQuery:
    """The whole predictive pipeline as one logical plan.

    σ(fact preds) ∧ ⋈(arms, with dim preds) → model → γ(group_keys, aggs).
    ``model=None`` gives a pure relational query (the 13 SSB queries);
    ``group_keys=()`` gives a scalar aggregate (SSB QG1).
    """

    fact: str                             # catalog name of the fact table
    arms: Tuple[ArmSpec, ...]
    fact_preds: Tuple[Pred, ...] = ()
    model: Optional[Model] = None
    group_keys: Tuple[GroupKey, ...] = ()
    aggregates: Tuple[Aggregate, ...] = (Aggregate("lo_revenue"),)
    num_groups: int = 8192

    @property
    def feature_width(self) -> int:
        return sum(len(a.feature_cols) for a in self.arms)


def eval_value(fact: Table, expr) -> jnp.ndarray:
    """Evaluate a fact-column value expression to a (capacity,) float array."""
    if isinstance(expr, str):
        return fact.col(expr)
    op, *args = expr
    if op == "col":
        return fact.col(args[0])
    vals = [eval_value(fact, a) for a in args]
    if op not in _BINOPS or len(vals) != 2:
        raise ValueError(f"bad value expression {expr!r}")
    return _BINOPS[op](vals[0], vals[1])
