"""Declarative IR for predictive queries (selection ⋈ model ⋈ γ).

A ``PredictiveQuery`` is the logical plan the compiler lowers; every node is
data (frozen dataclasses + tuples) so plans are cheap to build, inspect and
cache.  Value expressions over fact columns are tiny s-expressions::

    "lo_revenue"                          # a column
    ("mul", "lo_extendedprice", "lo_discount")
    ("sub", "lo_revenue", "lo_supplycost")

and the sentinel ``PREDICTION`` aggregates the model's output matrix instead
of a fact column.  ``COUNT_STAR`` is the value placeholder for ``count``
aggregates, which count surviving rows and never evaluate their value.

The fluent way to build this IR is :mod:`repro.core.query.session`
(``Session`` / ``query``); the dataclasses below stay the stable compiler
contract either way.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from ..fusion.operators import DecisionTreeGEMM, LinearOperator
from ..laq.selection import Pred
from ..laq.table import Table

Model = Union[LinearOperator, DecisionTreeGEMM]

#: Comparison ops a PredictionFilter may use (scalar compares only — the
#: set/range forms belong to relational Pred, which filters *columns*).
FILTER_OPS = ("==", "!=", "<", "<=", ">", ">=")

#: Aggregate.value sentinel: aggregate the (n, l) model prediction matrix.
PREDICTION = "@prediction"

#: Aggregate.value placeholder for ``count`` (COUNT(*) — value is ignored).
COUNT_STAR = "*"

#: Aggregate ops the compiler lowers (mean = fused sum/count; min/max via
#: segment ops on both aggregation backends).
AGG_OPS = ("sum", "count", "mean", "min", "max")

_BINOPS = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
}


@dataclasses.dataclass(frozen=True)
class ChainLink:
    """One snowflake hop: ``<parent>.fk_col = <table>.pk_col``.

    A link hangs a sub-dimension off an arm's dimension (or off an earlier
    link), TPC-DS-style.  ``fk_col`` is a key column on the *parent* table;
    ``parent`` names that table explicitly (tree-shaped snowflakes) or is
    ``None``, meaning the previous hop in declaration order (the arm's head
    dimension for the first link).  ``preds`` are sub-dimension predicates:
    they fold into the chain's validity vector exactly like flat dimension
    predicates — evaluated once offline, composed with the factored join.
    """

    table: str                            # catalog name of the sub-dimension
    fk_col: str                           # FK column on the parent table
    pk_col: str                           # PK column on this table
    feature_cols: Tuple[str, ...] = ()
    preds: Tuple[Pred, ...] = ()
    parent: Optional[str] = None          # None → previous hop / head dim


@dataclasses.dataclass(frozen=True)
class ArmSpec:
    """One arm of the star: ``fact.fk_col = <table>.pk_col`` (paper §3.1).

    ``preds`` are dimension-side predicates, pushed below the join: they are
    evaluated once on the dimension table and folded into the factored
    matching matrix's validity (selection-as-filter-vector, §2.2, composed
    with the join instead of multiplied through).

    ``links`` generalizes the arm to a multi-hop snowflake chain: factored
    joins compose associatively, so the compiler collapses the chain to one
    head-granularity virtual dimension (bit-exact with materializing the
    chain as a flat join) before prefusing it into the Eq. 1 partial form.
    """

    table: str                            # catalog name of the dimension
    fk_col: str
    pk_col: str
    feature_cols: Tuple[str, ...] = ()
    preds: Tuple[Pred, ...] = ()
    links: Tuple[ChainLink, ...] = ()

    @property
    def feature_width(self) -> int:
        return (len(self.feature_cols)
                + sum(len(lk.feature_cols) for lk in self.links))


@dataclasses.dataclass(frozen=True)
class GroupKey:
    """One GROUP BY key column, drawn from the fact table or a joined arm.

    ``bound`` is an exclusive upper bound on ``col - offset`` — the radix of
    this digit in the composite group code (§2.4.2).
    """

    table: str                            # "fact" or an ArmSpec.table name
    col: str
    bound: int
    offset: int = 0


@dataclasses.dataclass(frozen=True)
class PredictionFilter:
    """A predicate over the *model's prediction*: ``op(P[:, output], value)``.

    The model-side analogue of :class:`~repro.core.laq.selection.Pred`: a
    fact row survives iff the comparison holds for its prediction — e.g.
    ``PredictionFilter(3, "==", 1.0)`` keeps rows a tree classifies into
    leaf 3.  Predictions are quasi-static (they depend only on join
    pointers and dimension features, never fact measures), so the compiler
    folds these filters into the offline validity vector; the rewrite
    engine (:mod:`repro.core.query.rewrite`) goes further and *distills* a
    tree-model filter into ordinary dimension predicates, dropping the
    model from the online phase entirely.
    """

    output: int                           # prediction column, in [0, l)
    op: str                               # one of FILTER_OPS
    value: float


@dataclasses.dataclass(frozen=True)
class Aggregate:
    """``op(value) [GROUP BY ...]``; ``value`` is an expr or ``PREDICTION``.

    ``op`` is one of :data:`AGG_OPS`.  ``count`` ignores its value
    (conventionally :data:`COUNT_STAR`) and counts surviving rows; ``mean``
    is lowered as a fused sum/count sharing one count reduction across every
    mean/count aggregate of the query.
    """

    value: Union[str, tuple]
    op: str = "sum"
    name: str = "agg"


@dataclasses.dataclass(frozen=True, eq=False)
class PredictiveQuery:
    """The whole predictive pipeline as one logical plan.

    σ(fact preds) ∧ ⋈(arms, with dim preds) → model → γ(group_keys, aggs).
    ``model=None`` gives a pure relational query (the 13 SSB queries);
    ``group_keys=()`` gives a scalar aggregate (SSB QG1).  ``num_groups``
    may be ``"auto"``: the compiler then sizes it from the measured code
    domain on the offline concrete-array path (traced callers must pass an
    explicit int — the domain is abstract under a trace).
    """

    fact: str                             # catalog name of the fact table
    arms: Tuple[ArmSpec, ...]
    fact_preds: Tuple[Pred, ...] = ()
    model: Optional[Model] = None
    group_keys: Tuple[GroupKey, ...] = ()
    aggregates: Tuple[Aggregate, ...] = (Aggregate("lo_revenue"),)
    num_groups: Union[int, str] = 8192
    #: Predicates over the model's prediction matrix, ANDed into validity.
    model_preds: Tuple[PredictionFilter, ...] = ()

    def __post_init__(self):
        if self.model_preds:
            if self.model is None:
                raise ValueError(
                    "model_preds filter the model's predictions, but the "
                    "query has no model head")
            l = self.model.l
            for f in self.model_preds:
                if f.op not in FILTER_OPS:
                    raise ValueError(
                        f"prediction filter op {f.op!r} not one of "
                        f"{FILTER_OPS}")
                if not 0 <= int(f.output) < l:
                    raise ValueError(
                        f"prediction filter output {f.output} out of range "
                        f"for a model with l={l} outputs")
        # A duplicate table alias would silently shadow in every
        # name-keyed structure downstream (catalog overlays, group-key
        # pointer maps, serving version maps) — reject it here, once.
        seen = set()
        for a in self.arms:
            names = [a.table] + [lk.table for lk in a.links]
            for n in names:
                if n in seen:
                    raise ValueError(
                        f"duplicate table alias {n!r} across the arms/chains "
                        f"of query on fact {self.fact!r}: each dimension or "
                        "sub-dimension table may join at most once")
                seen.add(n)
            known = {a.table}
            for lk in a.links:
                parent = lk.parent
                if parent is not None and parent not in known:
                    raise ValueError(
                        f"chain link {lk.table!r} on arm {a.table!r} names "
                        f"parent {parent!r}, which is not the arm's head "
                        "dimension or an earlier link (links must be "
                        "declared parent-first; self-referential chains are "
                        "invalid)")
                known.add(lk.table)

    @property
    def feature_width(self) -> int:
        return sum(a.feature_width for a in self.arms)

    # Content-based ("rewrite-safe") equality: a rewritten query must
    # compare unequal to its source even when the object graphs alias, and
    # two independently built but structurally identical queries must
    # compare equal — model weight arrays are compared by value (digest),
    # not identity.  The dataclass is eq=False, so these are the only
    # equality semantics.
    def __eq__(self, other):
        if self is other:
            return True
        if not isinstance(other, PredictiveQuery):
            return NotImplemented
        return query_signature(self) == query_signature(other)

    def __ne__(self, other):
        eq = self.__eq__(other)
        return eq if eq is NotImplemented else not eq

    def __hash__(self):
        return hash(query_signature(self))


def _content_token(obj):
    """A hashable, by-value token for any IR node (arrays by digest).

    Tracer-stage arrays cannot be read; they token by identity, which
    degrades equality to identity for in-trace queries — exactly the old
    (eq=False) behaviour, so nothing under a trace changes semantics.
    """
    if obj is None or isinstance(obj, (str, int, float, bool, bytes)):
        return obj
    if isinstance(obj, (tuple, list)):
        return tuple(_content_token(o) for o in obj)
    if isinstance(obj, (set, frozenset)):
        return ("set",) + tuple(sorted(repr(o) for o in obj))
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return ((type(obj).__name__,)
                + tuple(_content_token(getattr(obj, f.name))
                        for f in dataclasses.fields(obj)))
    if hasattr(obj, "shape") and hasattr(obj, "dtype"):
        try:
            arr = np.asarray(obj)
        except Exception:   # tracer / abstract value: identity token
            return ("tracer", tuple(obj.shape), str(obj.dtype), id(obj))
        return ("array", str(arr.dtype), arr.shape,
                hashlib.sha1(arr.tobytes()).hexdigest())
    return (type(obj).__name__, repr(obj))


def query_signature(q: PredictiveQuery) -> tuple:
    """The query's content signature (cached; arrays digested by value)."""
    sig = q.__dict__.get("_signature")
    if sig is None:
        sig = _content_token(q)
        object.__setattr__(q, "_signature", sig)
    return sig


def eval_value(fact: Table, expr, *, query: Optional[str] = None
               ) -> jnp.ndarray:
    """Evaluate a fact-column value expression to a (capacity,) float array.

    Unknown columns and malformed s-expressions raise a ``ValueError``
    naming the offending expression (and the query, when the caller passes
    a ``query`` descriptor) instead of leaking a bare KeyError/IndexError
    from ``Table.col``.
    """
    where = f" of query {query}" if query else ""
    if isinstance(expr, str):
        if expr in (PREDICTION, COUNT_STAR):
            raise ValueError(
                f"sentinel {expr!r} is not a fact column{where}: "
                "PREDICTION/COUNT_STAR are handled by the compiler, not "
                "eval_value")
        try:
            return fact.col(expr)
        except (KeyError, ValueError, IndexError) as e:
            raise ValueError(
                f"unknown column {expr!r} on table {fact.name!r} in value "
                f"expression{where}; available columns: "
                f"{list(fact.columns)}") from e
    if not isinstance(expr, tuple) or not expr or not isinstance(expr[0],
                                                                 str):
        raise ValueError(
            f"malformed value expression {expr!r}{where}: expected a column "
            "name or an ('op', ...) s-expression tuple")
    op, *args = expr
    if op == "col":
        if len(args) != 1 or not isinstance(args[0], str):
            raise ValueError(
                f"malformed value expression {expr!r}{where}: "
                "('col', name) takes exactly one column name")
        return eval_value(fact, args[0], query=query)
    if op not in _BINOPS:
        raise ValueError(
            f"unknown op {op!r} in value expression {expr!r}{where}; "
            f"expected one of {sorted(_BINOPS)} or 'col'")
    if len(args) != 2:
        raise ValueError(
            f"malformed value expression {expr!r}{where}: op {op!r} takes "
            f"2 arguments, got {len(args)}")
    vals = [eval_value(fact, a, query=query) for a in args]
    return _BINOPS[op](vals[0], vals[1])
