"""Query/model co-optimization: exact rewrite rules over the IR.

The paper treats data processing and model prediction as one algebraic
program; this module rewrites *across* that boundary before planning, in
the spirit of Park et al.'s end-to-end prediction-query optimizer
(model-to-query transformations) and SystemML's fusion-plan rule engine
(deterministic rules + a cost model, not ad-hoc lowering).

Every rule is **exact**: the rewritten query computes bit-identical
``run()`` results to the original on every execution path the compiler
lowers (fused/nonfused × segment/matmul, streaming, pooled).  Two rules
are exact on any float data (their transforms only move *comparisons*,
never re-associate sums); two move a term between f32 summation orders and
are exact under the repo's established exact-arithmetic convention
(integer-valued data — the same convention that makes fused == nonfused
bit-exact, see ``core.query.workload``):

``distill_tree_filter`` (any data)
    A query that thresholds/classifies on a *tree* model's prediction
    (``model_preds``) selects a set of leaves.  When exactly one leaf
    satisfies the filters, its root-to-leaf path conditions
    (``feature > v`` / ``feature <= v``) compile into ordinary dimension /
    link predicates, and the model drops out of the online phase entirely
    — the paper's join+predict program degenerates to a pure relational
    one.  When every leaf satisfies, the filters are vacuous and are
    dropped.

``prune_tree_branches`` (any data)
    Range predicates already on the query imply some tree-node
    comparisons are constant for every surviving row; those nodes are
    removed from F/v/H and their contribution folded into the compare
    vector ``h`` — the score sums lose only terms that were provably
    constant, so the leaf one-hot is unchanged.

``fold_constant_inputs`` (exact-arithmetic data)
    An equality predicate pinning a dimension feature to ``u`` makes that
    model input constant: the feature leaves the arm, its row leaves
    ``L``, and ``u · L[row]`` folds into the model bias (carried in arm
    0's Eq. 1 prefused partial).

``project_zero_weights`` (exact-arithmetic data; ±0 folded)
    Features with an all-zero ``L`` row (linear) or feeding no tree node
    (all-zero ``F`` row) contribute nothing; they leave the arms and the
    model, shrinking the prefused partial build and the nonfused
    materialize width.

:func:`rewrite_query` runs the rules to a bounded fixpoint and returns
the rewritten IR plus a per-rule trail; ``compile_query(rewrite="on")``
costs the rewritten query against the original
(:func:`~.planner.estimate_query_cost`) and surfaces the trail in
``plan.reason`` and ``explain()``.  All rules are data-*independent*
(they read query structure, model weights and catalog schema — never row
values), so a rewritten plan refreshes through the same delta paths as an
unrewritten one.
"""
from __future__ import annotations

import dataclasses
from typing import List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..fusion.operators import DecisionTreeGEMM, LinearOperator
from ..laq.selection import Pred
from ..laq.table import Table
from .ir import PREDICTION, PredictiveQuery

#: Fixpoint bound: each pass can only shrink the query (fewer features,
#: nodes, filters), so a handful of passes always converges; the bound is
#: a guard against a buggy rule oscillating, not a tuning knob.
MAX_PASSES = 4

_FILTER_FNS = {
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
}


@dataclasses.dataclass(frozen=True)
class FeatureSite:
    """Where one model input column lives: an arm's head or one of its
    links, in the model's global feature order (arms in order; within an
    arm the head's ``feature_cols`` first, then each link's in declaration
    order — the order ``qualified_cols``/``_feature_slices`` use)."""

    arm: int                    # index into q.arms
    link: Optional[int]         # index into arm.links, None for the head
    table: str                  # real catalog table owning the column
    col: str


@dataclasses.dataclass(frozen=True)
class RewriteResult:
    """The rewritten IR plus the per-rule trail (empty = nothing fired)."""

    query: PredictiveQuery
    trail: Tuple[str, ...]

    @property
    def changed(self) -> bool:
        return bool(self.trail)


def feature_sites(q: PredictiveQuery) -> List[FeatureSite]:
    """Every model input column, in global (model-row) feature order."""
    sites: List[FeatureSite] = []
    for i, a in enumerate(q.arms):
        sites.extend(FeatureSite(i, None, a.table, c)
                     for c in a.feature_cols)
        for li, lk in enumerate(a.links):
            sites.extend(FeatureSite(i, li, lk.table, c)
                         for c in lk.feature_cols)
    return sites


def _site_preds(q: PredictiveQuery, s: FeatureSite) -> Tuple[Pred, ...]:
    a = q.arms[s.arm]
    return a.preds if s.link is None else a.links[s.link].preds


def _rewritable_col(catalog: Mapping[str, Table], s: FeatureSite) -> bool:
    """Only plain float matrix columns are analyzable: ``Pred.mask``
    prefers the int *key* array when the name is also a key column, whose
    integer compare does not match the f32 feature compare."""
    t = catalog.get(s.table) if hasattr(catalog, "get") else catalog[s.table]
    return t is not None and s.col not in t.keys


# -- predicate interval analysis (all comparisons in float32) ---------------
@dataclasses.dataclass
class _Bounds:
    lo: float = -np.inf
    lo_strict: bool = False
    hi: float = np.inf
    hi_strict: bool = False
    values: Optional[frozenset] = None    # finite domain, when known

    def _values_in_bounds(self):
        out = []
        for w in self.values:
            if w < self.lo or (self.lo_strict and w == self.lo):
                continue
            if w > self.hi or (self.hi_strict and w == self.hi):
                continue
            out.append(w)
        return out

    def forced(self, v: np.float32) -> Optional[bool]:
        """Is ``x > v`` decided for every x satisfying the bounds?"""
        if self.values is not None:
            vals = self._values_in_bounds()
            if not vals:
                return None        # empty domain: leave the node alone
            if all(w > v for w in vals):
                return True
            if all(w <= v for w in vals):
                return False
            return None
        if self.lo > v or (self.lo_strict and self.lo >= v):
            return True
        if self.hi <= v:
            return False
        return None

    def pinned(self) -> Optional[np.float32]:
        """The single value x must take, if the bounds pin one."""
        if self.values is not None:
            vals = self._values_in_bounds()
            return np.float32(vals[0]) if len(vals) == 1 else None
        if (self.lo == self.hi and not self.lo_strict
                and not self.hi_strict and np.isfinite(self.lo)):
            return np.float32(self.lo)
        return None


def _col_bounds(preds: Sequence[Pred], col: str) -> _Bounds:
    """Fold every predicate on ``col`` into one f32 bound set."""
    b = _Bounds()
    for p in preds:
        if p.col != col:
            continue
        if p.op == "between":
            lo, hi = (float(np.float32(p.value[0])),
                      float(np.float32(p.value[1])))
            # A non-strict bound that strictly tightens must also clear the
            # strict flag an earlier '>'/'<' left behind; at equality the
            # existing (strict) bound is already at least as tight.
            if lo > b.lo:
                b.lo, b.lo_strict = lo, False
            if hi < b.hi:
                b.hi, b.hi_strict = hi, False
        elif p.op == "==":
            vals = frozenset([float(np.float32(p.value))])
            b.values = vals if b.values is None else (b.values & vals)
        elif p.op == "in":
            vals = frozenset(float(np.float32(v)) for v in p.value)
            b.values = vals if b.values is None else (b.values & vals)
        elif p.op == ">":
            v = float(np.float32(p.value))
            if v > b.lo or (v == b.lo and not b.lo_strict):
                b.lo, b.lo_strict = v, True
        elif p.op == ">=":
            if float(np.float32(p.value)) > b.lo:
                b.lo, b.lo_strict = float(np.float32(p.value)), False
        elif p.op == "<":
            v = float(np.float32(p.value))
            if v < b.hi or (v == b.hi and not b.hi_strict):
                b.hi, b.hi_strict = v, True
        elif p.op == "<=":
            if float(np.float32(p.value)) < b.hi:
                b.hi, b.hi_strict = float(np.float32(p.value)), False
        # "!=" carries no interval information — ignored.
    return b


# -- shared feature-dropping machinery --------------------------------------
def _drop_features(q: PredictiveQuery, drop: Sequence[int]
                   ) -> Tuple[PredictiveQuery, List[str]]:
    """Remove the given global feature indices from every arm/link.

    Returns the new query (model untouched — callers shrink it) and the
    dropped ``table.col`` names for the trail.
    """
    sites = feature_sites(q)
    dropset = set(drop)
    names = [f"{sites[i].table}.{sites[i].col}" for i in sorted(dropset)]
    gi = 0
    arms = []
    for a in q.arms:
        keep_head = []
        for c in a.feature_cols:
            if gi not in dropset:
                keep_head.append(c)
            gi += 1
        links = []
        for lk in a.links:
            keep_lk = []
            for c in lk.feature_cols:
                if gi not in dropset:
                    keep_lk.append(c)
                gi += 1
            links.append(dataclasses.replace(
                lk, feature_cols=tuple(keep_lk)))
        arms.append(dataclasses.replace(
            a, feature_cols=tuple(keep_head), links=tuple(links)))
    return dataclasses.replace(q, arms=tuple(arms)), names


# -- the rules ---------------------------------------------------------------
def _rule_distill(catalog, q: PredictiveQuery):
    """tree→predicate distillation: compile the satisfying leaf's path
    into dimension/link predicates and drop the model entirely."""
    if not isinstance(q.model, DecisionTreeGEMM) or not q.model_preds:
        return None
    m = q.model
    l = m.l
    # The prediction of a (valid) row is a one-hot leaf indicator, so the
    # filters select a leaf subset — evaluate them on each unit vector,
    # with the same f32 casts the folded validity path applies.
    leaves = []
    for leaf in range(l):
        ok = True
        for f in q.model_preds:
            e = np.float32(1.0 if int(f.output) == leaf else 0.0)
            if not bool(_FILTER_FNS[f.op](e, np.float32(f.value))):
                ok = False
                break
        if ok:
            leaves.append(leaf)
    if len(leaves) == l:
        # Vacuous filters: every leaf passes — drop the filters, keep the
        # model (nothing else changes, so this is trivially exact).
        return (dataclasses.replace(q, model_preds=()),
                "vacuous filter dropped")
    if any(a.value == PREDICTION for a in q.aggregates):
        return None             # predictions still feed an aggregate
    if len(leaves) != 1:
        return None             # OR-of-paths / empty: not expressible yet
    leaf = leaves[0]
    sites = feature_sites(q)
    F = np.asarray(m.F)
    H = np.asarray(m.H)
    v = np.asarray(m.v, np.float32)
    if F.shape[0] != len(sites):
        return None             # inconsistent IR; refuse to touch it
    # Per-site path constraints: +1 → feature > v_p, −1 → feature <= v_p.
    gt: dict = {}
    le: dict = {}
    for p in range(F.shape[1]):
        d = H[p, leaf]
        if d == 0:
            continue            # node not on this leaf's path
        if np.count_nonzero(F[:, p]) != 1 or F[:, p].max() != 1.0:
            return None         # not a single-feature node: refuse
        si = int(np.argmax(F[:, p]))
        if not _rewritable_col(catalog, sites[si]):
            return None
        vp = float(v[p])
        if d > 0:
            gt[si] = max(gt.get(si, -np.inf), vp)
        else:
            le[si] = min(le.get(si, np.inf), vp)
    for si in set(gt) & set(le):
        if le[si] <= gt[si]:
            return None         # path self-contradictory: leaf unreachable
    # Attach the distilled predicates to the owning arm/link.
    arms = list(q.arms)
    for si in sorted(set(gt) | set(le)):
        s = sites[si]
        new: List[Pred] = []
        if si in gt:
            new.append(Pred(s.col, ">", gt[si]))
        if si in le:
            new.append(Pred(s.col, "<=", le[si]))
        a = arms[s.arm]
        if s.link is None:
            arms[s.arm] = dataclasses.replace(a, preds=a.preds + tuple(new))
        else:
            links = list(a.links)
            links[s.link] = dataclasses.replace(
                links[s.link], preds=links[s.link].preds + tuple(new))
            arms[s.arm] = dataclasses.replace(a, links=tuple(links))
    q = dataclasses.replace(q, arms=tuple(arms), model=None, model_preds=())
    # The features fed only the (now dropped) model.
    q, _ = _drop_features(q, range(len(sites)))
    npreds = sum(1 for d in (gt, le) for _ in d)
    return q, f"leaf {leaf} -> {npreds} predicates, model dropped"


def _rule_fold_constants(catalog, q: PredictiveQuery):
    """constant-input folding: equality predicates pin features, whose
    ``L`` rows fold into the model bias."""
    if not isinstance(q.model, LinearOperator):
        return None
    sites = feature_sites(q)
    L = np.asarray(q.model.L)
    if L.shape[0] != len(sites):
        return None
    pinned: List[Tuple[int, np.float32]] = []
    for i, s in enumerate(sites):
        if not _rewritable_col(catalog, s):
            continue
        u = _col_bounds(_site_preds(q, s), s.col).pinned()
        if u is not None:
            pinned.append((i, u))
    if not pinned or len(pinned) >= len(sites):
        return None             # nothing pinned, or no feature would remain
    drop = [i for i, _ in pinned]
    delta = np.zeros((L.shape[1],), np.float32)
    for i, u in pinned:
        delta = delta + np.float32(u) * L[i].astype(np.float32)
    bias = delta if q.model.bias is None else (
        np.asarray(q.model.bias, np.float32) + delta)
    import jax.numpy as jnp
    model = LinearOperator(jnp.asarray(np.delete(L, drop, axis=0)),
                           jnp.asarray(bias))
    q, names = _drop_features(q, drop)
    return (dataclasses.replace(q, model=model),
            f"pinned {','.join(names)} into bias")


def _rule_zero_weight(catalog, q: PredictiveQuery):
    """zero-weight feature projection: inputs with an all-zero model row
    (``L`` row / ``F`` row) leave the arms and the model."""
    if q.model is None:
        return None
    sites = feature_sites(q)
    if isinstance(q.model, LinearOperator):
        W = np.asarray(q.model.L)
    else:
        W = np.asarray(q.model.F)
    if W.shape[0] != len(sites):
        return None
    dead = [i for i in range(W.shape[0]) if not W[i].any()]
    if not dead or len(dead) >= len(sites):
        return None
    import jax.numpy as jnp
    if isinstance(q.model, LinearOperator):
        model = dataclasses.replace(
            q.model, L=jnp.asarray(np.delete(W, dead, axis=0)))
    else:
        model = dataclasses.replace(
            q.model, F=jnp.asarray(np.delete(W, dead, axis=0)))
    q, names = _drop_features(q, dead)
    return (dataclasses.replace(q, model=model),
            f"projected {','.join(names)}")


def _rule_prune_tree(catalog, q: PredictiveQuery):
    """predicate-implied tree pruning: nodes whose comparison the query's
    range predicates decide are folded into ``h`` and removed."""
    if not isinstance(q.model, DecisionTreeGEMM):
        return None
    m = q.model
    sites = feature_sites(q)
    F = np.asarray(m.F)
    if F.shape[0] != len(sites):
        return None
    v = np.asarray(m.v, np.float32)
    H = np.asarray(m.H, np.float32)
    h = np.asarray(m.h, np.float32)
    bounds: dict = {}
    decided: dict = {}
    for p in range(F.shape[1]):
        if np.count_nonzero(F[:, p]) != 1 or F[:, p].max() != 1.0:
            continue            # not a single-feature node: leave it alone
        si = int(np.argmax(F[:, p]))
        s = sites[si]
        if not _rewritable_col(catalog, s):
            continue
        if si not in bounds:
            bounds[si] = _col_bounds(_site_preds(q, s), s.col)
        c = bounds[si].forced(np.float32(v[p]))
        if c is not None:
            decided[p] = c
    if not decided or len(decided) >= F.shape[1]:
        return None             # nothing decided, or no node would remain
    keep = [p for p in range(F.shape[1]) if p not in decided]
    # score == h  ⟺  score_kept == h − Σ_decided c_p · H[p, :]: the decided
    # terms are constant over every surviving row, so moving them into the
    # compare vector preserves the leaf one-hot exactly (±1 integer sums).
    h2 = h.copy()
    for p, c in decided.items():
        if c:
            h2 = h2 - H[p]
    import jax.numpy as jnp
    model = DecisionTreeGEMM(jnp.asarray(F[:, keep]),
                             jnp.asarray(v[keep]),
                             jnp.asarray(H[keep]), jnp.asarray(h2))
    return (dataclasses.replace(q, model=model),
            f"{F.shape[1]}->{len(keep)} nodes")


#: Deterministic rule order.  Distillation first (it may drop the model,
#: making the model-shrinking rules no-ops); pruning last so it sees any
#: predicates the other rules introduced.
RULES: Tuple[Tuple[str, object], ...] = (
    ("distill_tree_filter", _rule_distill),
    ("fold_constant_inputs", _rule_fold_constants),
    ("project_zero_weights", _rule_zero_weight),
    ("prune_tree_branches", _rule_prune_tree),
)


def rewrite_query(catalog: Mapping[str, Table], q: PredictiveQuery, *,
                  max_passes: int = MAX_PASSES) -> RewriteResult:
    """Run every rewrite rule to a bounded fixpoint.

    Deterministic: rules run in :data:`RULES` order within a pass, and a
    pass that fires nothing ends the loop.  The trail records one
    ``rule(note)`` entry per firing, in order.
    """
    trail: List[str] = []
    for _ in range(max_passes):
        fired = False
        for name, rule in RULES:
            out = rule(catalog, q)
            if out is None:
                continue
            q, note = out
            trail.append(f"{name}({note})")
            fired = True
        if not fired:
            break
    return RewriteResult(q, tuple(trail))
