"""Sharded prefused partials: Eq. 1's quasi-static state over a device mesh.

The paper's serving speedup rests on prefusing each dimension's partial
``P_j = B_j M_j L`` offline and serving queries as pure gathers over those
partials.  At production scale the partials (and the fact FK batches)
outgrow one device, so this module partitions the quasi-static state across
a mesh and rebuilds the online phase as one ``shard_map``-jitted program:

* **Partials row-shard** over the mesh's ``model`` axis in contiguous
  blocks, each block paired with its own ``ShardedPKIndex`` slice and
  dimension-predicate mask, so a probe + gather touches only device-local
  rows.  A key owned by another shard misses locally; one ``psum`` over the
  model axis merges the per-shard contributions (at most one shard hits per
  key — live PKs are globally unique), reconstructing the global gather.
* **Request FK batches shard** over the data-parallel axes; the model tail
  (the tree compare vector ``h``, the non-fused model head) replicates.
* **Placement is planned, not fixed** (`plan_partition_spec`): partials
  below a byte threshold replicate, larger ones shard row-wise via
  ``launch.sharding.safe_spec`` — a row count that doesn't divide the mesh
  axis degrades to replication instead of failing.

Bit-exactness: the owning shard contributes the identical fp32 row the
single-device gather would read and every other shard contributes zeros, so
the psum, followed by the same arm-order accumulation the unsharded runtime
uses, reproduces the single-device jnp reference bitwise (the multi-device
CI job asserts this across mesh shapes).

The Pallas kernel lowerings are deliberately not composed with ``shard_map``
here — sharded serving always uses the jnp gathers (the bit-exact reference
semantics); fusing ``fused_star_gather`` into the per-shard block program is
the TPU calibration follow-up tracked in ROADMAP.md.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

try:  # moved to the jax namespace in newer releases
    from jax import shard_map
except ImportError:  # jax <= 0.4/0.5 keeps it under experimental
    from jax.experimental.shard_map import shard_map

from ...launch.mesh import dp_axes
from ..fusion.operators import DecisionTreeGEMM, LinearOperator
from ..laq.join import PKIndex, pk_index, shard_pk_index


def _shard_map(f, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions (the rep-check kwarg was renamed).

    The replication check is disabled explicitly: the forward programs end
    in a ``psum`` over the shard axis, which guarantees the out-spec's
    replication but which older checkers cannot always prove through the
    mixed replicated/sharded arm state.
    """
    try:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def _rep_spec(x) -> P:
    return P(*([None] * x.ndim))


@dataclasses.dataclass(frozen=True)
class ShardedArm:
    """One star arm's quasi-static serving state, placed on the mesh.

    ``table`` is the arm's prefused partial (fused backend) or projected
    feature block (non-fused backend).  When ``spec`` row-shards it, the
    probe state is sharded to match: ``sorted_pk``/``order`` hold the
    flattened per-shard ``ShardedPKIndex`` slices (shard-local row offsets)
    and ``dmask`` the per-shard dimension-predicate mask, all laid out in
    the same contiguous row blocks so ``in_specs=P(axis)`` hands each device
    exactly its slice.  Probe state is ``None`` on the global-pointer path
    (``CompiledQuery.predict_rows``), where the FK→row resolution already
    happened offline.
    """

    fk_col: str
    spec: P
    table: jnp.ndarray                    # (r, w)
    sorted_pk: Optional[jnp.ndarray]      # (r,) per-shard-sorted | None
    order: Optional[jnp.ndarray]          # (r,) shard-local offsets | None
    dmask: Optional[jnp.ndarray]          # (r,) bool | None

    @property
    def is_sharded(self) -> bool:
        return len(self.spec) > 0 and self.spec[0] is not None


@dataclasses.dataclass(frozen=True)
class ShardedPrefusedPartials:
    """All arms' prefused partials placed across ``mesh``.

    Built once per (query, catalog, mesh) by :func:`shard_prefused_partials`
    — the sharded analogue of :class:`..fusion.pipeline.PrefusedStar` plus
    the per-arm lookup state, ready for :func:`make_serving_forward` /
    :func:`make_predict_rows_forward` to close over.
    """

    mesh: object                          # jax.sharding.Mesh
    shard_axis: str
    arms: Tuple[ShardedArm, ...]
    h: Optional[jnp.ndarray]              # tree compare vector, replicated

    @property
    def placement(self) -> Tuple[P, ...]:
        return tuple(a.spec for a in self.arms)

    @property
    def num_sharded(self) -> int:
        return sum(1 for a in self.arms if a.is_sharded)

    def nbytes_per_device(self) -> int:
        """Quasi-static bytes resident per device under this placement.

        Counts the partials *and* the per-arm probe state (PK-index slices,
        predicate masks) — for narrow partials the int32 probe arrays are a
        material fraction of the footprint.
        """
        total = 0
        for a in self.arms:
            arrs = [x for x in (a.table, a.sorted_pk, a.order, a.dmask)
                    if x is not None]
            n = sum(int(x.size) * x.dtype.itemsize for x in arrs)
            if a.is_sharded:
                n //= int(self.mesh.shape[self.shard_axis])
            total += n
        if self.h is not None:
            total += int(self.h.size) * self.h.dtype.itemsize
        return total


def shard_prefused_partials(
        mesh, arms: Sequence[Tuple[str, Optional[jnp.ndarray],
                                   Optional[jnp.ndarray], jnp.ndarray]],
        h: Optional[jnp.ndarray], specs: Sequence[P], *,
        shard_axis: str = "model") -> ShardedPrefusedPartials:
    """Place each arm's ``(fk_col, pk, dmask, table)`` per its spec.

    Arms whose spec row-shards get per-shard ``ShardedPKIndex`` slices and
    contiguous-block layouts; replicated arms keep the global ``PKIndex``.
    Every array is ``device_put`` with its ``NamedSharding`` here, so the
    per-bucket jitted programs see committed inputs and never reshard the
    quasi-static state on the serving hot path.  ``pk``/``dmask`` may be
    ``None`` for the global-pointer (``predict_rows``) path.
    """
    if shard_axis in mesh.axis_names:
        num_shards = int(mesh.shape[shard_axis])
    else:
        num_shards = 1
    placed = []
    for (fk_col, pk, dmask, table), spec in zip(arms, specs):
        sharded = len(spec) > 0 and spec[0] is not None
        if pk is None:
            sorted_pk = order = None
        elif sharded:
            sidx = shard_pk_index(pk, num_shards)
            sorted_pk = sidx.sorted_pk.reshape(-1)
            order = sidx.order.reshape(-1)
        else:
            gidx = pk_index(pk)
            sorted_pk, order = gidx.sorted_pk, gidx.order
        vec_spec = P(shard_axis) if sharded else P(None)

        def put(x, s):
            return (None if x is None
                    else jax.device_put(x, NamedSharding(mesh, s)))

        placed.append(ShardedArm(
            fk_col=fk_col, spec=spec,
            table=put(table, spec),
            sorted_pk=put(sorted_pk, vec_spec),
            order=put(order, vec_spec),
            dmask=put(dmask, vec_spec)))
    if h is not None:
        h = jax.device_put(h, NamedSharding(mesh, P(None)))
    return ShardedPrefusedPartials(mesh=mesh, shard_axis=shard_axis,
                                   arms=tuple(placed), h=h)


def _model_leaves(model) -> Tuple[Tuple[jnp.ndarray, ...], str]:
    """The replicated model tail as explicit shard_map operands."""
    if isinstance(model, LinearOperator):
        return (model.L,), "linear"
    if isinstance(model, DecisionTreeGEMM):
        return (model.F, model.v, model.H, model.h), "tree"
    raise TypeError(f"no sharded lowering for model {type(model).__name__}")


def _rebuild_model(kind: str, leaves):
    return (LinearOperator(*leaves) if kind == "linear"
            else DecisionTreeGEMM(*leaves))


def _merge_sharded(parts, hits, contribs, shard_axis):
    """psum the row-sharded arm contributions back to global values.

    One collective for all sharded arms (a pytree psum); at most one shard
    hit per request key, so the summed hit counts are exactly the global
    ``found & dmask`` bits and the summed partial rows are bitwise the
    single-device gather results (zeros are exact fp32 identities).
    """
    if not contribs:
        return parts, hits
    red = jax.lax.psum(contribs, shard_axis)
    for j, (part, hit_count) in red.items():
        parts[j] = part
        hits[j] = hit_count > 0
    return parts, hits


def _accumulate(parts, hits, valid, h, model, backend):
    """The online tail, in the exact arm/op order of the unsharded runtime
    (``ServingRuntime._online_fused`` / ``_online_nonfused``) so fp32
    results stay bitwise identical."""
    if backend == "fused":
        acc = parts[0]
        for part in parts[1:]:
            acc = acc + part
        if h is not None:
            acc = acc * valid[:, None].astype(acc.dtype)
            acc = (acc == h[None, :].astype(acc.dtype)).astype(acc.dtype)
        out = acc
    else:
        t = jnp.concatenate(parts, axis=1) * valid[:, None].astype(
            jnp.float32)
        out = model.apply(t)
    return out * valid[:, None].astype(out.dtype)


def serving_arm_state(sp: ShardedPrefusedPartials) -> Tuple:
    """The placed per-arm serving state as a swappable pytree.

    One tuple per arm — ``(table, sorted_pk, order, dmask)`` — passed into
    the ``shard_map`` program at call time rather than closed over, so the
    serving runtime's ``refresh`` can swap in extended arrays (same shapes,
    same shardings) and re-dispatch into the already-compiled executables.
    """
    return tuple((a.table, a.sorted_pk, a.order,
                  a.dmask.astype(jnp.bool_)) for a in sp.arms)


def extend_sharded_arm(sp: ShardedPrefusedPartials, j: int,
                       table: jnp.ndarray, pk: jnp.ndarray,
                       dmask: jnp.ndarray, lo: int, hi: int) -> ShardedArm:
    """Re-place arm ``j`` after rows ``[lo, hi)`` changed, touching only the
    shard blocks that own them.

    The contiguous-block layout means appended rows land in the tail
    block(s): only those shards' ``ShardedPKIndex`` slices are re-argsorted
    (rows_per_shard elements each) — every untouched block's index, order
    and mask bytes are reused as-is.  Replicated arms just re-place the
    whole (small) table.  Shapes and specs are unchanged, so the swapped
    arm state dispatches into the compiled ``shard_map`` program.
    """
    arm = sp.arms[j]
    mesh = sp.mesh
    num_shards = (int(mesh.shape[sp.shard_axis])
                  if sp.shard_axis in mesh.axis_names else 1)

    def put(x, s):
        return (None if x is None
                else jax.device_put(x, NamedSharding(mesh, s)))

    if not arm.is_sharded:
        idx = pk_index(pk) if pk is not None else None
        return dataclasses.replace(
            arm, table=put(table, arm.spec),
            sorted_pk=put(idx.sorted_pk if idx else None, P(None)),
            order=put(idx.order if idx else None, P(None)),
            dmask=put(dmask, P(None)))
    r = int(table.shape[0])
    rps = r // num_shards
    s_lo, s_hi = lo // rps, -(-hi // rps)   # shard blocks owning [lo, hi)
    vec_spec = P(sp.shard_axis)
    sorted_pk = order = None
    if pk is not None:
        sorted_pk = np.array(np.asarray(sp.arms[j].sorted_pk))
        order = np.array(np.asarray(sp.arms[j].order))
        blocks = np.asarray(pk).reshape(num_shards, rps)
        for s in range(s_lo, s_hi):
            o = np.argsort(blocks[s], kind="stable").astype(np.int32)
            sorted_pk[s * rps:(s + 1) * rps] = blocks[s][o]
            order[s * rps:(s + 1) * rps] = o
        sorted_pk = jnp.asarray(sorted_pk)
        order = jnp.asarray(order)
    return dataclasses.replace(
        arm, table=put(table, arm.spec), sorted_pk=put(sorted_pk, vec_spec),
        order=put(order, vec_spec),
        dmask=put(dmask.astype(jnp.bool_) if dmask is not None else None,
                  vec_spec))


def make_serving_forward(sp: ShardedPrefusedPartials, model, backend: str):
    """The sharded online phase for ``ServingRuntime``: fks → predictions.

    One ``shard_map``-wrapped program (jitted per padding bucket by the
    runtime): the FK batch shards over the DP axes, each arm probes its
    device-local ``PKIndex`` slice and gathers its local partial rows, and
    a single psum over the shard axis merges the row-sharded arms.  The
    per-arm placed state (:func:`serving_arm_state`) is a call-time
    argument: ``forward(fks, arms)``.
    """
    mesh, axis = sp.mesh, sp.shard_axis
    dp = dp_axes(mesh)
    batch_spec = P(dp) if dp else P(None)
    extras, kind = ((), None) if backend == "fused" else _model_leaves(model)
    if backend == "fused" and sp.h is not None:
        extras = (sp.h,)
    arm_specs = tuple(
        ((P(axis, None), P(axis), P(axis), P(axis)) if a.is_sharded
         else (P(None, None), P(None), P(None), P(None)))
        for a in sp.arms)
    in_specs = (tuple(batch_spec for _ in sp.arms), arm_specs,
                tuple(_rep_spec(e) for e in extras))
    out_spec = P(dp if dp else None, None)

    def body(fks, arms, extras):
        h = extras[0] if (backend == "fused" and sp.h is not None) else None
        mdl = _rebuild_model(kind, extras) if backend != "fused" else None
        parts, hits, contribs = [], [], {}
        for j, (table, sorted_pk, order, dmask) in enumerate(arms):
            fj = PKIndex(sorted_pk, order).probe(fks[j])
            hit = fj.found & jnp.take(dmask, fj.ptr)
            rows = jnp.take(table, fj.ptr, axis=0)
            part = rows * hit[:, None].astype(rows.dtype)
            if sp.arms[j].is_sharded:
                contribs[j] = (part, hit.astype(jnp.int32))
            parts.append(part)
            hits.append(hit)
        parts, hits = _merge_sharded(parts, hits, contribs, axis)
        valid = hits[0]
        for hit in hits[1:]:
            valid = valid & hit
        return _accumulate(parts, hits, valid, h, mdl, backend)

    smapped = _shard_map(body, mesh, in_specs, out_spec)

    def forward(fks, arms):
        return smapped(tuple(fks), tuple(arms), extras)

    return forward


def predict_rows_state(sp: ShardedPrefusedPartials,
                       tables: Sequence[jnp.ndarray],
                       ptrs: Sequence[jnp.ndarray],
                       founds: Sequence[jnp.ndarray],
                       row_valid: jnp.ndarray) -> dict:
    """Placed call-time state for :func:`make_predict_rows_forward`.

    Pointers/validity replicate; each arm table keeps its planned spec.
    Rebuilt wholesale on refresh (the arrays are re-``device_put`` with the
    same shardings, so the compiled program re-dispatches without retrace).
    """
    mesh = sp.mesh
    rep = NamedSharding(mesh, P(None))
    return {
        "ptrs": tuple(jax.device_put(p, rep) for p in ptrs),
        "founds": tuple(jax.device_put(f.astype(jnp.bool_), rep)
                        for f in founds),
        "valid": jax.device_put(row_valid.astype(jnp.bool_), rep),
        "tables": tuple(
            jax.device_put(t, NamedSharding(mesh, a.spec))
            for t, a in zip(tables, sp.arms)),
    }


def make_predict_rows_forward(sp: ShardedPrefusedPartials, model,
                              backend: str):
    """Sharded ``CompiledQuery.predict_rows``: fact row ids → predictions.

    Here the FK→row resolution already ran offline (``join_factored``), so
    the per-arm pointers are *global* row numbers; each shard serves the
    pointers that land in its contiguous block (``axis_index`` arithmetic)
    and the psum merges, matching the unsharded gather bitwise.  The placed
    pointer/table state (:func:`predict_rows_state`) is a call-time
    argument: ``forward(row_ids, state)``.
    """
    mesh, axis = sp.mesh, sp.shard_axis
    extras, kind = ((), None) if backend == "fused" else _model_leaves(model)
    if backend == "fused" and sp.h is not None:
        extras = (sp.h,)
    table_specs = tuple(P(axis, None) if a.is_sharded else P(None, None)
                        for a in sp.arms)
    in_specs = (P(None), tuple(P(None) for _ in sp.arms),
                tuple(P(None) for _ in sp.arms), P(None), table_specs,
                tuple(_rep_spec(e) for e in extras))

    def body(row_ids, ptrs, founds, valid_full, tables, extras):
        h = extras[0] if (backend == "fused" and sp.h is not None) else None
        mdl = _rebuild_model(kind, extras) if backend != "fused" else None
        v = jnp.take(valid_full, row_ids)
        # Out-of-range row ids follow the unsharded ``jnp.take`` fill
        # semantics (NaN rows).  The sharded gather clips pointers into the
        # local block, which would silently turn the NaN fill into 0.0, so
        # the fill is reproduced explicitly: a float gather over the fact
        # capacity is 0 in range (negative ids wrap) and NaN out of range.
        poison = jnp.take(jnp.zeros((valid_full.shape[0],), jnp.float32),
                          row_ids)
        parts, hits, contribs = [], [], {}
        for j, table in enumerate(tables):
            gptr = jnp.take(ptrs[j], row_ids)
            hit = jnp.take(founds[j], row_ids)
            if sp.arms[j].is_sharded:
                rps = table.shape[0]
                lo = jax.lax.axis_index(axis) * rps
                own = (gptr >= lo) & (gptr < lo + rps) & hit
                local = jnp.clip(gptr - lo, 0, rps - 1)
                part = (jnp.take(table, local, axis=0)
                        * own[:, None].astype(table.dtype))
                contribs[j] = (part, own.astype(jnp.int32))
            else:
                part = (jnp.take(table, gptr, axis=0)
                        * hit[:, None].astype(table.dtype))
            parts.append(part)
            hits.append(hit)
        parts, _ = _merge_sharded(parts, hits, contribs, axis)
        # predict_rows applies the *combined* offline validity (fact preds
        # folded in), not the per-arm hit conjunction — mirror it exactly.
        if backend == "fused":
            acc = parts[0]
            for part in parts[1:]:
                acc = acc + part
            acc = acc * v[:, None].astype(acc.dtype)
            if h is None:
                out = acc
            else:
                eq = (acc == h[None, :].astype(acc.dtype)).astype(acc.dtype)
                out = eq * v[:, None].astype(acc.dtype)
        else:
            t = jnp.concatenate(parts, axis=1) * v[:, None].astype(
                jnp.float32)
            out = mdl.apply(t) * v[:, None].astype(jnp.float32)
        bad = jnp.isnan(poison)[:, None]
        return jnp.where(bad, poison[:, None].astype(out.dtype), out)

    smapped = _shard_map(body, mesh, in_specs, P(None, None))

    def forward(row_ids, state):
        return smapped(row_ids, state["ptrs"], state["founds"],
                       state["valid"], state["tables"], extras)

    return forward
