"""Out-of-core execution for the fact axis: chunked streaming aggregation.

MatFast-style block partitioning (PAPERS.md, arxiv 2110.01767): the fact
table is split along the row axis into fixed-size chunks, each chunk is
shipped host→device (``jax.device_put`` of chunk *i+1* issued right after
the — asynchronously dispatched — compute on chunk *i*, so transfer and
compute overlap; chunk and accumulator buffers are donated off-CPU), and the
same fused online program the in-core ``run()`` executes is applied per
chunk.  Dimension-side artifacts (prefused partials, the tree compare
vector) are device-resident once and shared by every chunk unchanged —
only fact-axis leaves (matrix rows, validity, join pointers, group ids)
stream.

Bit-exactness contract
----------------------
The per-chunk partial aggregates are **not** combined by re-reducing chunk
results (floating-point addition is non-associative, so per-chunk
``segment_sum`` partials added across chunks drift in the last ulp).
Instead the executor carries one accumulator of ``num_groups + 1`` segments
across chunks and *continues the same row-order fold* the in-core segment
reduction performs: ``acc.at[gid].add(vals)`` (``.min``/``.max`` for those
ops) applies scatter updates row-sequentially, so after the last chunk the
accumulator holds bitwise the same values as one full-table
``segment_sum``/``segment_min``/``segment_max`` — for every chunk size,
including 1, non-divisors of the row count, and sizes past the fact length.
Grouped aggregates and ungrouped ``count``/``min``/``max`` are therefore
bit-exact vs the in-core ``run()``.  Ungrouped ``sum``/``mean`` reduce the
whole fact axis with no segment structure to preserve the fold order
through; they are exact up to float summation order (tests use allclose
there, and bitwise everywhere else).

The fused online program is chunk-stable by construction — per-row gathers
into dimension-side partials plus elementwise adds, no cross-row matmul —
which is why streaming pins ``backend="fused"``, ``join_backend="gather"``
and ``agg_backend="segment"`` (``compile_query`` rejects explicit conflicting
overrides).  The chunk program is one jitted function keyed on the chunk
shape: the last chunk is padded to the uniform size (padded rows are
invalid and carry the overflow group id, so they only ever touch the
dropped ``num_groups`` segment), and ``rebind`` swaps refreshed state in
without changing shapes — a refresh that keeps the chunk count re-dispatches
with **zero retraces**.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..fusion.pipeline import PrefusedStar, predict_fused
from ..laq.join import FactoredJoin
from ..laq.star import StarJoin
from .ir import PREDICTION, eval_value

#: Default rows per chunk when streaming is requested without a size.
DEFAULT_CHUNK_ROWS = 65536


def plan_chunk_rows(requested, capacity: int, row_bytes: int,
                    budget_bytes: Optional[int]) -> Optional[int]:
    """Resolve a ``stream_chunk_rows`` request to a concrete chunk size.

    ``requested`` may be a positive int (use it), ``"auto"`` (size chunks to
    the budget, default chunk when none), or ``None`` (stream only when a
    budget is given and the fact working set exceeds it).  Returns ``None``
    for the in-core path.
    """
    if requested is None or requested == 0:
        if budget_bytes is None:
            return None
        if capacity * max(row_bytes, 1) <= budget_bytes:
            return None
        requested = "auto"
    if requested == "auto":
        if budget_bytes is None:
            return min(DEFAULT_CHUNK_ROWS, max(capacity, 1))
        rows = budget_bytes // max(row_bytes, 1)
        return int(min(max(rows, 1), max(capacity, 1)))
    rows = int(requested)
    if rows < 1:
        raise ValueError(f"stream_chunk_rows must be >= 1, got {rows}")
    return rows


def assert_pool_dimension_side(pool, refs: Dict, state: Dict,
                               star: StarJoin) -> None:
    """Assert pooled artifacts compose with streaming exactly as designed.

    Pooled *dimension-side* artifacts — prefused partials (and the dmasks /
    PK indices behind the validity fold) — must be the very arrays every
    chunk shares unchanged: partial values identical (by object) to the
    plan state's and sized by the *dimension* capacity, never the fact's.
    Pooled *fact-axis* join pointers are the arrays the executor slices per
    chunk — shared with the state by object too, and never mutated by
    streaming.  A violation means a copy slipped in between the pool and
    the chunk program, silently breaking O(distinct artifacts) refresh.
    """
    parts = state.get("partials") or ()
    part_ids = {id(p) for p in parts}
    for k in refs.get("partials", ()):
        if id(pool.get(k)) not in part_ids:
            raise AssertionError(
                f"pooled partial {k} is not the array the streamed plan "
                "shares across chunks — dimension-side artifacts must flow "
                "from the pool to every chunk unchanged")
    for p, d in zip(parts, star.dims):
        if int(p.shape[0]) != d.dim.capacity:
            raise AssertionError(
                f"prefused partial for {d.dim.name!r} is "
                f"{int(p.shape[0])}-row, expected the dimension capacity "
                f"{d.dim.capacity}: partials must stay dimension-side "
                "(fact-sized partials would have to stream)")
    ptr_ids = {id(p) for p in state["ptrs"]}
    found_ids = {id(f) for f in state["founds"]}
    for (_ikey, jkey, _mkey) in refs.get("arms", ()):
        ptr, found = pool.get(jkey)
        if id(ptr) not in ptr_ids or id(found) not in found_ids:
            raise AssertionError(
                f"pooled join {jkey} diverged from the streamed plan's "
                "pointers — chunking must slice the shared arrays, not "
                "copies")


class StreamExecutor:
    """Chunked driver for one compiled query's online aggregate program.

    Built by ``compile_query`` when a plan streams; holds host-side views of
    the fact-axis state leaves, the shared dimension-side leaves, and one
    jitted chunk-fold program.  ``run()`` produces the same aggregate dict
    as the in-core jitted ``_online`` (see the module docstring for the
    exactness contract); ``rebind(state)`` swaps refreshed state in without
    retracing while the chunk count is unchanged.
    """

    #: fact-axis state leaves (sliced per chunk); everything else is shared.
    _FACT_AXIS = ("fact_matrix", "valid", "ptrs", "founds", "gid")

    def __init__(self, *, star: StarJoin, state: Dict, aggregates,
                 model, num_groups: int, fact_desc: str, chunk_rows: int,
                 out_shapes: Dict):
        self._star0 = star
        self._fact0 = star.fact
        self._aggregates = tuple(aggregates)
        self._model = model
        self._num_groups = int(num_groups)
        self._fact_desc = fact_desc
        self._grouped = state["gid"] is not None
        self._capacity = int(state["fact_matrix"].shape[0])
        self.chunk_rows = int(min(max(chunk_rows, 1), max(self._capacity, 1)))
        self.n_chunks = max(
            1, math.ceil(self._capacity / self.chunk_rows))
        # Result widths per aggregate, from the in-core program's abstract
        # output shapes (jax.eval_shape — no FLOPs spent).
        self._widths = {}
        for agg in self._aggregates:
            sh = tuple(out_shapes[agg.name].shape)
            self._widths[agg.name] = (sh[-1] if len(sh) > (
                1 if self._grouped else 0) else None)
        self._needs_count = any(a.op in ("count", "mean")
                                for a in self._aggregates)
        self.traces = 0
        platform = jax.default_backend()
        # Donating the accumulator and the chunk buffers lets XLA write the
        # folded accumulator (and scratch) into the arriving chunk's memory;
        # CPU jit does not honor donation and warns, so gate it.
        donate = (0, 1) if platform != "cpu" else ()
        self._step = jax.jit(self._chunk_step, donate_argnums=donate)
        self._finalize = jax.jit(self._finalize_fn)
        self.rebind(state)

    # -- state binding -------------------------------------------------------
    def rebind(self, state: Dict) -> None:
        """Swap in refreshed state.  Shapes (and so the chunk program's jit
        cache) are preserved — same capacity ⇒ same chunk count ⇒ zero
        retraces; a capacity change recompiles the owning plan instead."""
        if int(state["fact_matrix"].shape[0]) != self._capacity:
            raise ValueError(
                "stream rebind with a different fact capacity "
                f"({int(state['fact_matrix'].shape[0])} vs "
                f"{self._capacity}): capacity growth recompiles")
        if (state["gid"] is not None) != self._grouped:
            raise ValueError("stream rebind changed group-by structure")
        # Host views of the fact-axis leaves (numpy slicing below is
        # zero-copy; the per-chunk device_put materializes only chunk-sized
        # buffers on device).
        self._h_matrix = np.asarray(state["fact_matrix"])
        self._h_valid = np.asarray(state["valid"])
        self._h_ptrs = tuple(np.asarray(p) for p in state["ptrs"])
        self._h_founds = tuple(np.asarray(f) for f in state["founds"])
        self._h_gid = (np.asarray(state["gid"]) if self._grouped else None)
        self._shared = {"partials": state["partials"], "h": state["h"]}

    # -- chunk construction --------------------------------------------------
    def _host_chunk(self, i: int) -> Dict:
        lo = i * self.chunk_rows
        hi = min(lo + self.chunk_rows, self._capacity)
        pad = self.chunk_rows - (hi - lo)

        def pad1(a, fill):
            if pad == 0:
                return a[lo:hi]
            out = np.full((self.chunk_rows,) + a.shape[1:], fill, a.dtype)
            out[:hi - lo] = a[lo:hi]
            return out

        chunk = {
            "fact_matrix": pad1(self._h_matrix, 0),
            # Padded rows are invalid and land in the dropped overflow
            # segment — they can only ever touch acc[num_groups].
            "valid": pad1(self._h_valid, False),
            "ptrs": tuple(pad1(p, 0) for p in self._h_ptrs),
            "founds": tuple(pad1(f, False) for f in self._h_founds),
            "gid": (pad1(self._h_gid, self._num_groups)
                    if self._grouped else None),
        }
        return chunk

    def _put(self, i: int):
        return jax.device_put(self._host_chunk(i))

    # -- the jitted chunk fold ----------------------------------------------
    def _acc_shape(self, width):
        lead = (self._num_groups + 1,) if self._grouped else ()
        return lead + ((width,) if width is not None else ())

    def _init_acc(self) -> Dict:
        acc = {}
        if self._needs_count:
            acc["count"] = jnp.zeros(self._acc_shape(None), jnp.float32)
        for agg in self._aggregates:
            if agg.op == "count":
                continue
            shape = self._acc_shape(self._widths[agg.name])
            if agg.op == "min":
                acc[agg.name] = jnp.full(shape, jnp.inf, jnp.float32)
            elif agg.op == "max":
                acc[agg.name] = jnp.full(shape, -jnp.inf, jnp.float32)
            else:
                acc[agg.name] = jnp.zeros(shape, jnp.float32)
        return acc

    def _chunk_predictions(self, chunk: Dict, shared: Dict) -> jnp.ndarray:
        """``predict_fused`` on the chunk view: per-row gathers into the
        shared dimension-side partials — bitwise independent of chunking."""
        fact_v = dataclasses.replace(self._fact0,
                                     matrix=chunk["fact_matrix"])
        joins = tuple(FactoredJoin(p, f)
                      for p, f in zip(chunk["ptrs"], chunk["founds"]))
        star_v = dataclasses.replace(self._star0, fact=fact_v, joins=joins,
                                     row_valid=chunk["valid"])
        return predict_fused(star_v,
                             PrefusedStar(tuple(shared["partials"]),
                                          shared["h"]))

    def _chunk_values(self, agg, pred, chunk):
        """Mirror of the compiler's ``_agg_values`` on a chunk view."""
        if agg.value == PREDICTION:
            return pred                          # already validity-masked
        fact_v = dataclasses.replace(self._fact0,
                                     matrix=chunk["fact_matrix"])
        vals = eval_value(fact_v, agg.value,
                          query=f"{agg.name!r} on {self._fact_desc!r}")
        if agg.op in ("min", "max"):
            return vals       # invalid rows are masked by gid / ±inf below
        return jnp.where(chunk["valid"], vals, 0.0)

    def _chunk_step(self, acc: Dict, chunk: Dict, shared: Dict) -> Dict:
        self.traces += 1       # python side effect: counts (re)traces only
        valid = chunk["valid"]
        gid = chunk["gid"]
        pred = (self._chunk_predictions(chunk, shared)
                if self._model is not None else None)
        out = {}
        if self._needs_count:
            ones = valid.astype(jnp.float32)
            out["count"] = (acc["count"].at[gid].add(ones) if self._grouped
                            else acc["count"] + jnp.sum(ones))
        for agg in self._aggregates:
            if agg.op == "count":
                continue
            vals = self._chunk_values(agg, pred, chunk)
            a = acc[agg.name]
            if self._grouped:
                # Scatter into the carried (num_groups+1)-segment
                # accumulator: updates apply row-sequentially, continuing
                # the full-table segment fold bit-exactly.
                if agg.op == "min":
                    out[agg.name] = a.at[gid].min(vals)
                elif agg.op == "max":
                    out[agg.name] = a.at[gid].max(vals)
                else:
                    out[agg.name] = a.at[gid].add(vals)
            elif agg.op in ("min", "max"):
                fill = jnp.inf if agg.op == "min" else -jnp.inf
                mask = valid[:, None] if vals.ndim > 1 else valid
                r = (jnp.min if agg.op == "min" else jnp.max)(
                    jnp.where(mask, vals, fill), axis=0)
                out[agg.name] = (jnp.minimum if agg.op == "min"
                                 else jnp.maximum)(a, r)
            else:
                out[agg.name] = a + jnp.sum(vals, axis=0)
        return out

    def _finalize_fn(self, acc: Dict) -> Dict:
        """Slice off the overflow segment and apply the same final forms the
        in-core program uses (isfinite-zero for min/max, sum/count for
        mean) — bit-identical inputs ⇒ bit-identical outputs."""
        g = self._num_groups
        count = acc.get("count")
        if count is not None and self._grouped:
            count = count[:g]
        out = {}
        for agg in self._aggregates:
            if agg.op == "count":
                out[agg.name] = count
                continue
            a = acc[agg.name]
            if self._grouped:
                a = a[:g]
            if agg.op in ("min", "max"):
                out[agg.name] = jnp.where(jnp.isfinite(a), a, 0.0)
            elif agg.op == "mean":
                c = jnp.maximum(count, 1.0)
                out[agg.name] = a / (c[:, None] if a.ndim > 1 else c)
            else:
                out[agg.name] = a
        return out

    # -- driver --------------------------------------------------------------
    def run(self) -> Dict[str, jnp.ndarray]:
        """Stream every chunk through the fold and finalize.

        Double-buffered: compute on chunk *i* is dispatched (async) before
        chunk *i+1*'s host→device transfer is issued, overlapping transfer
        with compute.  Peak device residency is the shared dimension-side
        state plus two chunks plus the accumulator.
        """
        acc = self._init_acc()
        cur = self._put(0)
        for i in range(self.n_chunks):
            acc = self._step(acc, cur, self._shared)
            cur = self._put(i + 1) if i + 1 < self.n_chunks else None
        return dict(self._finalize(acc))

    # -- introspection -------------------------------------------------------
    def chunk_bytes(self) -> int:
        """Approximate device bytes one chunk occupies."""
        per_row = self._h_matrix.shape[1] * 4 + 1 + len(self._h_ptrs) * 5
        if self._grouped:
            per_row += 4
        return int(self.chunk_rows * per_row)

    def describe(self) -> str:
        return (f"stream: {self.n_chunks} chunk(s) x {self.chunk_rows} rows "
                f"(~{self.chunk_bytes() / 1e6:.1f} MB/chunk)")
