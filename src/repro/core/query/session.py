"""``Session``: the single fluent entry point for predictive queries.

The paper's thesis is that the *whole* pipeline — σ ⋈ model γ — is one
linear-algebra program; this module makes it one API.  A :class:`Session`
binds a catalog (and optionally a device mesh) once, and a fluent immutable
:class:`QueryBuilder` describes the pipeline declaratively::

    from repro.core.query import Session, PREDICTION

    sess = Session(catalog, mesh=None)
    q = (sess.query("lineorder")
         .join("date", on=("lo_orderdate", "datekey"),
               features=["d_month"], where=[("d_year", "==", 1993)])
         .where(("lo_discount", "between", (1, 3)))
         .predict(model)
         .group_by(("date", "d_year", 8, 1992))
         .agg(revenue="sum(lo_revenue)", preds=("mean", PREDICTION),
              n="count"))

    q.run()                      # whole-query aggregates (one fused program)
    q.rows(batch)                # row predictions (CompiledQuery.predict_rows)
    q.serve(buckets=(8, 64))     # bucketed ServingRuntime (compile_serving)

Every builder step returns a *new* builder (frozen dataclass), so partial
pipelines are shareable and cacheable.  The builder lowers to the existing
:class:`~repro.core.query.ir.PredictiveQuery` IR — the stable compiler
contract — via :meth:`QueryBuilder.build`; mesh placement, sharding
thresholds, kernel interpret mode, and plan-cache keys are handled by the
session instead of being threaded through every call site.

Plan caching is *structural*: :func:`query_key` hashes the IR by content
(models by array bytes), so a builder-constructed query and an equivalent
hand-built ``PredictiveQuery`` — or two builds of the same registry entry —
share one compiled plan and never re-trace.  Plans compiled under an outer
``jit`` hold tracers and are never cached (same rule as the old per-dataset
caches).

Module-level :func:`query` starts a *detached* builder (no session) for
data-independent IR registries: ``.build()`` works, the execution verbs
require a session.
"""
from __future__ import annotations

import dataclasses
import inspect
import re
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from ..laq.catalog import Catalog
from ..laq.selection import Pred
from ..laq.table import Table
from .compile import CompiledQuery, _program_state, compile_query
from .explain import ExplainReport
from .ir import (AGG_OPS, COUNT_STAR, PREDICTION, Aggregate, ArmSpec,
                 ChainLink, GroupKey, Model, PredictionFilter,
                 PredictiveQuery)
# _array_key/model_key moved to multiquery (the arm-level hashing layer);
# re-exported here because they are part of this module's public surface.
from .multiquery import (ArtifactPool, _array_key, make_stacked_runner,
                         model_key, stack_key, stack_states)
from .scheduler import AdmissionScheduler, ScheduledPlan
from .serving import DEFAULT_BUCKETS, ServingRuntime, compile_serving
from .snowflake import chain_tables

_SEXPR_OPS = ("col", "add", "sub", "mul", "div")
_AGG_CALL = re.compile(r"^(sum|count|mean|min|max)\s*\(\s*(.*?)\s*\)$")


# --------------------------------------------------------------------------
# Structural plan-cache keys
# --------------------------------------------------------------------------
def query_key(q: PredictiveQuery) -> tuple:
    """Structural hash key of a ``PredictiveQuery``.

    Two structurally identical queries share one key even when they are
    distinct objects holding distinct (but value-equal) model arrays — the
    property the session's plan cache relies on so registry builders that
    reconstruct their IR per call still hit the cache.
    """
    return ("pq", q.fact, q.arms, q.fact_preds, model_key(q.model),
            q.group_keys, q.aggregates, q.num_groups, q.model_preds)


def _signature_defaults(fn) -> Dict:
    return {k: p.default for k, p in inspect.signature(fn).parameters.items()
            if p.default is not inspect.Parameter.empty}


#: Option defaults per entry point — the normalization tables behind
#: ``_opts_key``: an option spelled out at its default value must produce
#: the same cache key as the option omitted.
_COMPILE_DEFAULTS = _signature_defaults(compile_query)
_SERVING_DEFAULTS = _signature_defaults(compile_serving)
_MISSING = object()


def _normalize_buckets(v) -> tuple:
    return tuple(sorted({int(b) for b in v}))


def _opts_key(opts: Mapping, *, defaults: Optional[Mapping] = None) -> tuple:
    """Hashable cache key for compile options, normalized.

    Equivalent spellings collapse to one key: options equal to the entry
    point's defaults are dropped (``backend="auto"`` ≡ omitted), bucket
    sequences are sorted/deduplicated/int-coerced, the shared pool never
    participates (it is session plumbing, not a plan choice), and meshes
    key by identity (unhashable, and distinct meshes genuinely are
    distinct compilation targets).
    """
    defaults = _COMPILE_DEFAULTS if defaults is None else defaults
    items = []
    for k in sorted(opts):
        if k == "pool":
            continue
        v = opts[k]
        if k == "buckets":
            v = _normalize_buckets(v)
        d = defaults.get(k, _MISSING)
        if d is not _MISSING:
            if k == "buckets":
                d = _normalize_buckets(d)
            if v is d or v == d:   # e.g. 1000 ≡ 1000.0: same compile
                continue
        items.append((k, id(v) if k == "mesh" else v))
    return tuple(items)


# --------------------------------------------------------------------------
# Spec parsing: preds / group keys / aggregates
# --------------------------------------------------------------------------
def _as_pred(spec) -> Pred:
    if isinstance(spec, Pred):
        return spec
    if isinstance(spec, tuple) and len(spec) == 3:
        return Pred(*spec)
    raise ValueError(f"unparseable predicate {spec!r}: expected a Pred or a "
                     "(col, op, value) tuple")


def _as_link(spec) -> ChainLink:
    """One ``.join(via=[...])`` entry → a :class:`ChainLink`.

    Accepted specs::

        ChainLink(...)                              # passthrough
        ("nation", "c_nationkey", "n_nationkey")    # (table, fk, pk
        (..., ["n_gdp"], [("n_region","==",1)],     #  [, features [, where
         "customer")                                #  [, parent]]])
        {"table": ..., "fk_col": ..., "pk_col": ...,
         "features": [...], "where": [...], "parent": ...}
    """
    if isinstance(spec, ChainLink):
        return spec
    if isinstance(spec, Mapping):
        d = dict(spec)
        preds = d.pop("where", d.pop("preds", ()))
        feats = d.pop("features", d.pop("feature_cols", ()))
        try:
            link = ChainLink(d.pop("table"), d.pop("fk_col"),
                             d.pop("pk_col"), tuple(feats),
                             tuple(_as_pred(p) for p in preds),
                             d.pop("parent", None))
        except KeyError as e:
            raise ValueError(
                f"unparseable chain link {spec!r}: missing key {e}") from e
        if d:
            raise ValueError(
                f"unparseable chain link {spec!r}: unknown keys {sorted(d)}")
        return link
    if isinstance(spec, tuple) and 3 <= len(spec) <= 6:
        table, fk, pk, *rest = spec
        feats = tuple(rest[0]) if len(rest) >= 1 else ()
        preds = tuple(_as_pred(p) for p in (rest[1] if len(rest) >= 2
                                            else ()))
        parent = rest[2] if len(rest) >= 3 else None
        return ChainLink(table, fk, pk, feats, preds, parent)
    raise ValueError(
        f"unparseable chain link {spec!r}: expected a ChainLink, a "
        "(table, fk_col, pk_col[, features[, where[, parent]]]) tuple, or "
        "a dict with those keys")


def _as_prediction_filter(spec) -> PredictionFilter:
    if isinstance(spec, PredictionFilter):
        return spec
    if isinstance(spec, tuple) and len(spec) == 3:
        return PredictionFilter(*spec)
    raise ValueError(
        f"unparseable prediction filter {spec!r}: expected a "
        "PredictionFilter or an (output, op, value) tuple")


def _as_group_key(spec) -> GroupKey:
    if isinstance(spec, GroupKey):
        return spec
    if isinstance(spec, tuple) and len(spec) in (3, 4):
        return GroupKey(*spec)
    raise ValueError(
        f"unparseable group key {spec!r}: expected a GroupKey or a "
        "(table, col, bound[, offset]) tuple ('fact' names the fact table)")


def _as_aggregate(name: str, spec) -> Aggregate:
    """One ``.agg(name=spec)`` entry → an :class:`Aggregate`.

    Accepted specs::

        "count"                      # COUNT(*) of surviving rows
        "sum(lo_revenue)"            # op(column) call syntax
        "mean(lo_quantity)"
        "lo_revenue"                 # bare column → sum
        ("mean", PREDICTION)         # (op, value) — value may be a column,
        ("sum", ("mul", "a", "b"))   #   PREDICTION, or an s-expression
        ("sub", "a", "b")            # bare s-expression value → sum
        Aggregate(...)               # passthrough, renamed to the kwarg
    """
    if isinstance(spec, Aggregate):
        return dataclasses.replace(spec, name=name)
    if isinstance(spec, tuple):
        if len(spec) == 2 and spec[0] in AGG_OPS:
            op, value = spec
            if op == "count":
                value = COUNT_STAR
            return Aggregate(value, op, name)
        if spec and spec[0] in _SEXPR_OPS:
            return Aggregate(spec, "sum", name)
        raise ValueError(
            f"unparseable aggregate {name}={spec!r}: tuple specs are "
            f"(op, value) with op in {list(AGG_OPS)} or an s-expression "
            f"starting with one of {list(_SEXPR_OPS)}")
    if isinstance(spec, str):
        s = spec.strip()
        if s in ("count", "count(*)", "count()"):
            return Aggregate(COUNT_STAR, "count", name)
        m = _AGG_CALL.match(s)
        if m:
            op, col = m.groups()
            if op == "count":
                return Aggregate(COUNT_STAR, "count", name)
            if not col:
                raise ValueError(
                    f"aggregate {name}={spec!r}: {op}() needs a column")
            return Aggregate(col, op, name)
        return Aggregate(s, "sum", name)
    raise ValueError(f"unparseable aggregate {name}={spec!r}")


# --------------------------------------------------------------------------
# The fluent builder
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class QueryBuilder:
    """An immutable, fluent description of one predictive pipeline.

    Every method returns a new builder; :meth:`build` lowers to the
    ``PredictiveQuery`` IR.  The execution verbs (:meth:`run`,
    :meth:`rows`, :meth:`serve`, :meth:`compile`) go through the bound
    session's plan cache; a detached builder (module-level :func:`query`)
    only supports :meth:`build`.
    """

    session: Optional["Session"]
    fact: str
    arms: Tuple[ArmSpec, ...] = ()
    fact_preds: Tuple[Pred, ...] = ()
    model: Optional[Model] = None
    group_keys: Tuple[GroupKey, ...] = ()
    aggregates: Tuple[Aggregate, ...] = ()
    num_groups: Union[int, str] = 8192
    model_preds: Tuple[PredictionFilter, ...] = ()

    # -- pipeline steps ------------------------------------------------------
    def join(self, table: str, *, on: Tuple[str, str],
             features: Sequence[str] = (),
             where: Sequence = (),
             via: Sequence = ()) -> "QueryBuilder":
        """Add one star arm: ``fact.<fk> = <table>.<pk>``.

        ``on=(fk_col, pk_col)``; ``features`` are dimension columns fed to
        the model (in join order); ``where`` holds dimension-side predicates
        (``Pred`` or ``(col, op, value)``), pushed below the join into the
        matching matrix's validity.

        ``via`` extends the arm into a snowflake chain: each entry (see
        :func:`_as_link`) hangs a sub-dimension off the head (or an earlier
        link), TPC-DS-style.  A bound builder also recognizes a *chained*
        join — when ``on``'s FK column is a key of an already-joined
        dimension or link table rather than the fact, the new table is
        attached as a :class:`ChainLink` of the owning arm instead of a
        star arm::

            (sess.query("sales")
             .join("customer", on=("s_custkey", "c_custkey"))
             .join("nation", on=("c_nationkey", "n_nationkey"),
                   features=["n_gdp"]))        # chains off customer

        Either way the compiler collapses the chain offline to one
        head-granularity virtual dimension (see ``core.query.snowflake``).
        """
        if not (isinstance(on, tuple) and len(on) == 2):
            raise ValueError(f"join on={on!r}: expected (fk_col, pk_col)")
        fk, pk = on
        preds = tuple(_as_pred(p) for p in where)
        links = tuple(_as_link(lk) for lk in via)
        if not links:
            owner = self._link_parent(fk)
            if owner is not None:
                i, parent = owner
                link = ChainLink(table, fk, pk, tuple(features), preds,
                                 parent=parent)
                arm = dataclasses.replace(
                    self.arms[i], links=self.arms[i].links + (link,))
                if self.session is not None:
                    self.session._check_arm(self.fact, arm)
                return dataclasses.replace(
                    self,
                    arms=self.arms[:i] + (arm,) + self.arms[i + 1:])
        arm = ArmSpec(table, fk, pk, tuple(features), preds, links)
        if self.session is not None:
            self.session._check_arm(self.fact, arm)
        return dataclasses.replace(self, arms=self.arms + (arm,))

    def _link_parent(self, fk: str) -> Optional[Tuple[int, str]]:
        """``(arm_index, parent_table)`` when ``fk`` belongs to a joined
        dimension/link table (a chained join), None when it is a fact FK.

        Detached builders always return None — chains there go through
        ``via=`` explicitly (no catalog to resolve column ownership).
        """
        if self.session is None:
            return None
        cat = self.session.catalog
        fact_t = cat.get(self.fact)
        if fact_t is not None and fk in fact_t.keys:
            return None
        matches = [(i, t) for i, a in enumerate(self.arms)
                   for t in chain_tables(a)
                   if t in cat and fk in cat[t].keys]
        if len(matches) > 1:
            raise ValueError(
                f"ambiguous chained join: FK column {fk!r} is a key of "
                f"multiple joined tables {sorted(t for _, t in matches)}; "
                "spell the chain out with via=[...]")
        return matches[0] if matches else None

    def where(self, *preds) -> "QueryBuilder":
        """AND fact-side predicates (``Pred`` or ``(col, op, value)``)."""
        new = tuple(_as_pred(p) for p in preds)
        return dataclasses.replace(self,
                                   fact_preds=self.fact_preds + new)

    def predict(self, model: Model, *, where: Sequence = ()
                ) -> "QueryBuilder":
        """Attach the model head (LinearOperator / DecisionTreeGEMM).

        ``where`` filters rows on the *prediction*: each entry is a
        :class:`~repro.core.query.ir.PredictionFilter` or an
        ``(output, op, value)`` tuple — a row survives only when
        ``op(prediction[output], value)`` holds.  For tree models, a filter
        selecting exactly one leaf is distilled back into ordinary
        dimension predicates by the rewrite engine
        (``core.query.rewrite``), dropping the model from the online phase
        entirely.
        """
        filters = self.model_preds + tuple(
            _as_prediction_filter(f) for f in where)
        return dataclasses.replace(self, model=model, model_preds=filters)

    def group_by(self, *keys,
                 num_groups: Optional[Union[int, str]] = None
                 ) -> "QueryBuilder":
        """Add GROUP BY keys (``GroupKey`` or ``(table, col, bound[, offset])``).

        ``num_groups`` sizes the dense group dimension; ``"auto"`` defers to
        the compiler, which measures the live code domain offline.
        """
        new = tuple(_as_group_key(k) for k in keys)
        kw: Dict = {"group_keys": self.group_keys + new}
        if num_groups is not None:
            kw["num_groups"] = num_groups
        return dataclasses.replace(self, **kw)

    def agg(self, **named) -> "QueryBuilder":
        """Add named aggregates; each kwarg is one result column.

        See :func:`_as_aggregate` for the spec grammar — e.g.
        ``.agg(revenue="sum(lo_revenue)", preds=("mean", PREDICTION),
        n="count")``.  One compiled program computes all of them over the
        shared join/model work.
        """
        new = tuple(_as_aggregate(n, s) for n, s in named.items())
        return dataclasses.replace(self,
                                   aggregates=self.aggregates + new)

    # -- lowering ------------------------------------------------------------
    def build(self) -> PredictiveQuery:
        """Lower to the ``PredictiveQuery`` IR (the compiler contract)."""
        kw = dict(fact=self.fact, arms=self.arms,
                  fact_preds=self.fact_preds, model=self.model,
                  group_keys=self.group_keys, num_groups=self.num_groups,
                  model_preds=self.model_preds)
        if self.aggregates:
            kw["aggregates"] = self.aggregates
        elif self.model is not None:
            # No explicit aggregates on a model query: aggregate the
            # prediction matrix (matches query_from_star).
            kw["aggregates"] = (Aggregate(PREDICTION, "sum", "prediction"),)
        return PredictiveQuery(**kw)

    # -- execution (through the session) -------------------------------------
    def _bound(self) -> "Session":
        if self.session is None:
            raise ValueError(
                "detached builder: module-level query() only builds IR — "
                "use Session.query()/Session.bind() for run/rows/serve")
        return self.session

    def compile(self, **overrides) -> CompiledQuery:
        """The (cached) compiled plan; overrides are compile_query kwargs."""
        return self._bound().compile(self.build(), **overrides)

    def run(self, **overrides) -> Dict[str, jnp.ndarray]:
        """Execute the whole-query aggregate program.

        Returns the named aggregates (+ ``"groups"``/``"rows"``).
        """
        return self.compile(**overrides).run()

    def rows(self, batch, **overrides) -> jnp.ndarray:
        """Row predictions for a batch of fact row ids (serving-by-row)."""
        return self.compile(**overrides).predict_rows(
            jnp.asarray(batch, jnp.int32))

    def serve(self, *, buckets: Sequence[int] = DEFAULT_BUCKETS,
              async_: bool = False,
              **overrides) -> "ServingRuntime | ScheduledPlan":
        """The (cached) bucketed dynamic-batch serving runtime.

        With ``async_=True`` the runtime is registered on the session's
        :meth:`Session.scheduler` and the returned :class:`ScheduledPlan`
        handle serves through the admission scheduler (``.submit(...)`` →
        Future) instead of the synchronous ``serve`` call — use it when
        many concurrent callers share the plan; stay synchronous for
        single-caller batch scoring.
        """
        runtime = self._bound().serving(self.build(), buckets=buckets,
                                        **overrides)
        if async_:
            return self._bound().scheduler().register(runtime)
        return runtime

    def explain(self, **overrides) -> ExplainReport:
        """Structured report for the compiled plan.

        Returns an :class:`ExplainReport`; ``str()`` of it is the legacy
        one-line decision trail, ``as_dict()`` the machine-readable form.
        """
        return self.compile(**overrides).explain()


# --------------------------------------------------------------------------
# The session
# --------------------------------------------------------------------------
class Session:
    """A catalog + execution context with one structural plan cache.

    Holds everything the three execution modes share — the catalog, the
    (optional) device mesh with its shard axis/threshold, kernel interpret
    mode — so call sites describe *queries*, not plumbing.  Compiled plans
    and serving runtimes are cached by :func:`query_key` + options **and
    the participating tables' catalog versions**; identical pipelines never
    re-trace, whether they were built fluently, by hand, or re-built from a
    registry, and a stale entry can never be served: after a
    ``catalog.append``, the next lookup sees the version mismatch and
    brings the cached artifact up to date *in place* via its ``refresh()``
    (the delta path — no retrace while shapes hold) before returning it.

    ``catalog`` may be a mutable :class:`~repro.core.laq.Catalog` (the
    versioned data surface — appends/updates flow through to every cached
    plan) or any plain ``Mapping[str, Table]``, which auto-wraps read-only
    for back-compat with the pre-Catalog frozen-dict Sessions.
    """

    def __init__(self, catalog: "Mapping[str, Table] | Catalog", *,
                 mesh=None, shard_axis: str = "model",
                 shard_threshold_bytes: Optional[int] = None,
                 interpret: bool = False,
                 memory_budget_bytes: Optional[int] = None,
                 stream_chunk_rows: Optional[Union[int, str]] = None):
        self.catalog: Catalog = Catalog.wrap(catalog)
        self.mesh = mesh
        self.shard_axis = shard_axis
        self.shard_threshold_bytes = shard_threshold_bytes
        self.interpret = interpret
        # Out-of-core defaults: a device-memory budget and/or a fact chunk
        # size applied to every compile through this session (per-call
        # overrides win).  See core.query.streaming for the execution model.
        self.memory_budget_bytes = memory_budget_bytes
        self.stream_chunk_rows = stream_chunk_rows
        # key → (versions-at-build, artifact); versions are re-checked (and
        # the artifact refreshed) on every hit.
        self._plans: Dict[tuple, Tuple[tuple, CompiledQuery]] = {}
        self._runtimes: Dict[tuple, Tuple[tuple, ServingRuntime]] = {}
        self._scheduler: Optional[AdmissionScheduler] = None
        # The multi-query optimizer's shared-artifact pool: every plan and
        # serving runtime compiled through this session acquires its PK
        # indices / join pointers / predicate masks / prefused partials
        # here, so N plans sharing an arm reference ONE physical artifact
        # and a refresh updates it once (see core.query.multiquery).
        self.pool = ArtifactPool(self.catalog)
        # stack_key → (online_fn identity, stacked runner) for run_all.
        self._stacked: Dict[tuple, Tuple[object, callable]] = {}

    # -- builders ------------------------------------------------------------
    def query(self, fact: str) -> QueryBuilder:
        """Start a fluent pipeline over catalog table ``fact``."""
        if fact not in self.catalog:
            raise KeyError(f"unknown fact table {fact!r}; catalog has "
                           f"{sorted(self.catalog)}")
        return QueryBuilder(session=self, fact=fact)

    def bind(self, q: PredictiveQuery) -> QueryBuilder:
        """Wrap an existing IR in a builder bound to this session."""
        return QueryBuilder(session=self, fact=q.fact, arms=q.arms,
                            fact_preds=q.fact_preds, model=q.model,
                            group_keys=q.group_keys,
                            aggregates=q.aggregates,
                            num_groups=q.num_groups,
                            model_preds=q.model_preds)

    def _check_arm(self, fact: str, arm: ArmSpec):
        """Early, named errors for a new join arm (builder ergonomics)."""
        if arm.table not in self.catalog:
            raise KeyError(f"unknown dimension table {arm.table!r}; "
                           f"catalog has {sorted(self.catalog)}")
        dim = self.catalog[arm.table]
        if arm.pk_col not in dim.keys:
            raise ValueError(
                f"join on {arm.table!r}: {arm.pk_col!r} is not a key column "
                f"(keys: {sorted(dim.keys)})")
        fact_t = self.catalog.get(fact)
        if fact_t is not None and arm.fk_col not in fact_t.keys:
            raise ValueError(
                f"join on {arm.table!r}: {arm.fk_col!r} is not a key column "
                f"of {fact!r} (keys: {sorted(fact_t.keys)})")
        missing = [c for c in arm.feature_cols if c not in dim.columns]
        if missing:
            raise ValueError(
                f"join on {arm.table!r}: unknown feature columns {missing} "
                f"(columns: {list(dim.columns)})")
        known = {arm.table: dim}
        prev = arm.table
        for lk in arm.links:
            parent_name = lk.parent if lk.parent is not None else prev
            parent_t = known.get(parent_name)
            if parent_t is None:
                raise ValueError(
                    f"chain link {lk.table!r} on arm {arm.table!r}: parent "
                    f"{parent_name!r} is not the head dimension or an "
                    f"earlier link (have: {sorted(known)})")
            if lk.fk_col not in parent_t.keys:
                raise ValueError(
                    f"chain link {lk.table!r}: {lk.fk_col!r} is not a key "
                    f"column of parent {parent_name!r} "
                    f"(keys: {sorted(parent_t.keys)})")
            if lk.table not in self.catalog:
                raise KeyError(
                    f"unknown sub-dimension table {lk.table!r}; catalog "
                    f"has {sorted(self.catalog)}")
            link_t = self.catalog[lk.table]
            if lk.pk_col not in link_t.keys:
                raise ValueError(
                    f"chain link {lk.table!r}: {lk.pk_col!r} is not a key "
                    f"column (keys: {sorted(link_t.keys)})")
            missing = [c for c in lk.feature_cols
                       if c not in link_t.columns]
            if missing:
                raise ValueError(
                    f"chain link {lk.table!r}: unknown feature columns "
                    f"{missing} (columns: {list(link_t.columns)})")
            known[lk.table] = link_t
            prev = lk.table

    # -- cached compilation --------------------------------------------------
    def _mesh_kwargs(self) -> Dict:
        if self.mesh is None:
            return {}
        return dict(mesh=self.mesh, shard_axis=self.shard_axis,
                    shard_threshold_bytes=self.shard_threshold_bytes)

    def _stream_kwargs(self, *, serving: bool = False) -> Dict:
        """Session-level out-of-core defaults, omitted when unset so the
        plan-cache keys of sessions without them are unchanged.  Serving
        runtimes batch by request rows, not fact scans, so only the memory
        budget (a planner input) applies there."""
        kw: Dict = {}
        if self.memory_budget_bytes is not None:
            kw["memory_budget_bytes"] = self.memory_budget_bytes
        if not serving and self.stream_chunk_rows is not None:
            kw["stream_chunk_rows"] = self.stream_chunk_rows
        return kw

    def _tables_of(self, q: PredictiveQuery, *, serving: bool = False
                   ) -> Tuple[str, ...]:
        """The catalog tables whose versions gate ``q``'s cached artifacts.

        Serving runtimes never touch the fact table (requests are FK
        tuples), so fact appends leave them valid.  Chained arms gate on
        every table along the chain — a sub-dimension append invalidates
        the collapsed chain just like a head append.
        """
        names = {t for a in q.arms for t in chain_tables(a)}
        if not serving:
            names.add(q.fact)
        return tuple(sorted(names))

    def compile(self, q: PredictiveQuery, **overrides) -> CompiledQuery:
        """The compiled plan for ``q`` (structurally + version cached).

        ``overrides`` are :func:`compile_query` keyword arguments
        (``backend``, ``agg_backend``, ...) and participate in the cache
        key, so requesting a different backend compiles a sibling plan
        instead of returning the first one.  A cached plan built against
        older catalog versions is refreshed in place before it is returned
        — the cache can never hand out pre-append state.
        """
        opts = {"interpret": self.interpret, "pool": self.pool,
                **self._mesh_kwargs(), **self._stream_kwargs(), **overrides}
        key = (query_key(q), _opts_key(opts))
        versions = self.catalog.versions(self._tables_of(q))
        hit = self._plans.get(key)
        if hit is not None:
            built_at, compiled = hit
            if built_at != versions:
                compiled.refresh()
                self._plans[key] = (versions, compiled)
            return compiled
        compiled = compile_query(self.catalog, q, **opts)
        if not compiled.is_traced:
            self._plans[key] = (versions, compiled)  # traced plans hold
        else:                                        # tracers: never cached
            compiled.close()   # nor may they pin shared artifacts
        return compiled

    def serving(self, q: PredictiveQuery, *,
                buckets: Sequence[int] = DEFAULT_BUCKETS,
                **overrides) -> ServingRuntime:
        """The dynamic-batch serving runtime for ``q`` (cached).

        Version-gated like :meth:`compile`: pending dimension appends are
        applied via ``ServingRuntime.refresh`` before the runtime is
        returned, so cached runtimes never serve pre-append partials.
        """
        opts = {"interpret": self.interpret, "pool": self.pool,
                **self._mesh_kwargs(), **self._stream_kwargs(serving=True),
                **overrides}
        key = ("serve", query_key(q),
               _opts_key({**opts, "buckets": tuple(buckets)},
                         defaults=_SERVING_DEFAULTS))
        versions = self.catalog.versions(self._tables_of(q, serving=True))
        hit = self._runtimes.get(key)
        if hit is not None:
            built_at, runtime = hit
            if built_at != versions:
                self._refresh_runtime(runtime)
                self._runtimes[key] = (versions, runtime)
            return runtime
        runtime = compile_serving(self.catalog, q, buckets=buckets, **opts)
        self._runtimes[key] = (versions, runtime)
        return runtime

    def _refresh_runtime(self, runtime: ServingRuntime) -> str:
        """Refresh one runtime, fencing through the scheduler if it owns it.

        A runtime registered on the session scheduler may have batches in
        flight on the drain thread — swapping state under them would mix
        data generations, so the refresh is routed through the scheduler's
        drain-then-swap fence instead of calling ``runtime.refresh()``
        directly.
        """
        if self._scheduler is not None and not self._scheduler.closed \
                and self._scheduler.is_registered(runtime):
            return next(iter(
                self._scheduler.refresh(runtime).values()))
        return runtime.refresh()

    def refresh(self) -> Dict[str, str]:
        """Bring every cached plan/runtime up to the catalog's versions.

        Eager maintenance for serving fleets: one call after a batch of
        appends applies the delta path everywhere, instead of each artifact
        paying it lazily on its next lookup.  Returns the per-entry
        decision lines (keyed by a short artifact descriptor).
        """
        out = {}
        for store, gate in ((self._plans, {}), (self._runtimes,
                                                {"serving": True})):
            for i, (key, (built_at, art)) in enumerate(list(store.items())):
                versions = self.catalog.versions(
                    self._tables_of(art.query, **gate))
                if built_at != versions:
                    desc = f"{art.__class__.__name__}[{art.query.fact}#{i}]"
                    if isinstance(art, ServingRuntime):
                        out[desc] = self._refresh_runtime(art)
                    else:
                        out[desc] = art.refresh()
                    store[key] = (versions, art)
        return out

    # -- batched multi-query execution ---------------------------------------
    def run_all(self, queries: Sequence, **overrides) -> List[Dict]:
        """Execute many queries, batching compatible plans into one program.

        ``queries`` is a sequence of :class:`PredictiveQuery` IRs and/or
        bound :class:`QueryBuilder` pipelines.  Each is compiled through the
        session cache (sharing pooled artifacts), then plans whose stacked
        signature matches (same star shape, aggregates, model class and
        state structure — see :func:`multiquery.stack_key`) are stacked
        along a leading query axis and executed as ONE jitted, vmapped
        program: one dispatch instead of N.  Plans that cannot stack
        (sharded, traced, compacted) fall back to per-plan ``run()``.

        Results come back in input order and are bit-exact with what each
        ``compile(q).run()`` would return.  The stacked runners are cached
        on the session keyed by signature, so repeated ``run_all`` calls
        re-dispatch without re-tracing.
        """
        plans = []
        for q in queries:
            if isinstance(q, QueryBuilder):
                q = q.build()
            plans.append(self.compile(q, **overrides))
        results: List[Optional[Dict]] = [None] * len(plans)
        groups: Dict[tuple, List[int]] = {}
        for i, p in enumerate(plans):
            sk = stack_key(p)
            if sk is None:
                results[i] = p.run()
            else:
                groups.setdefault(sk, []).append(i)
        for sk, idxs in groups.items():
            if len(idxs) == 1:           # nothing to batch with
                i = idxs[0]
                results[i] = plans[i].run()
                continue
            rep = plans[idxs[0]]
            cached = self._stacked.get(sk)
            if cached is None or cached[0] is not rep._online_fn:
                # (re)build: the representative's online closure is pure in
                # its program-state pytree, so vmapping it over stacked
                # states runs every member in one program.
                runner = make_stacked_runner(rep._online_fn)
                self._stacked[sk] = (rep._online_fn, runner)
            else:
                runner = cached[1]
            stacked = stack_states(
                [_program_state(plans[i]._state) for i in idxs])
            out = runner(stacked)
            for slot, i in enumerate(idxs):
                p = plans[i]
                r = {name: v[slot] for name, v in out.items()}
                if p.group_codes is not None:
                    r["groups"] = p.group_codes
                r["rows"] = p._rows
                results[i] = r
        return results

    def evict(self, q: Optional[PredictiveQuery] = None) -> int:
        """Drop cached plans/runtimes (all, or just those for ``q``).

        Closing each artifact releases its shared-pool references, so the
        last plan using an artifact frees it from the session pool.
        Returns the number of cache entries removed.
        """
        qk = None if q is None else query_key(q)
        removed = 0
        for store in (self._plans, self._runtimes):
            for key in list(store):
                this_qk = key[1] if key[0] == "serve" else key[0]
                if qk is not None and this_qk != qk:
                    continue
                _, art = store.pop(key)
                art.close()
                removed += 1
        if q is None:
            self._stacked.clear()
        return removed

    def scheduler(self, **opts) -> AdmissionScheduler:
        """The session's admission scheduler (lazy singleton).

        Created on first call; ``opts`` (``slo_ms``, ``max_queued_rows``,
        ``batch_reserve_rows``, ``auto_start``) only apply then — later
        calls with options on a live scheduler raise rather than silently
        ignoring them.  ``QueryBuilder.serve(async_=True)`` registers its
        runtime here, and session-driven refreshes of registered runtimes
        fence through it automatically.
        """
        if self._scheduler is None or self._scheduler.closed:
            self._scheduler = AdmissionScheduler(**opts)
        elif opts:
            raise ValueError(
                "session scheduler already running; close() it before "
                f"re-creating with new options {sorted(opts)}")
        return self._scheduler

    # -- introspection -------------------------------------------------------
    @property
    def num_plans(self) -> int:
        """Distinct compiled aggregate plans held by the cache."""
        return len(self._plans)

    @property
    def num_runtimes(self) -> int:
        """Distinct serving runtimes held by the cache."""
        return len(self._runtimes)


def query(fact: str) -> QueryBuilder:
    """A detached fluent builder (IR construction only, no session).

    For data-independent registries: ``query("lineorder").join(...).build()``
    produces the same IR the equivalent ``Session.query`` chain would, and
    any session later compiles it with full cache sharing.
    """
    return QueryBuilder(session=None, fact=fact)
