"""Lower a ``PredictiveQuery`` to one jitted XLA program.

Offline (quasi-static, runs once per (query, catalog version set)):
  1. selection masks on the fact table and each dimension (``Pred``, §2.2),
  2. factored matching matrices per arm (``join_factored``, Alg. 1 / §3.1),
     with dimension-side predicate masks gathered through the FK pointers —
     the selection vector *folded into* the join validity instead of being
     multiplied through the data,
  3. the model's linear prefix pushed into the dimension tables
     (``prefuse``, Eq. 1/3),
  4. composite group codes + dense group ids (§2.4.2),
  5. the whole-query cost model (``plan_query``) choosing fused/nonfused and
     gather/matmul backends from the measured selectivity.

Online (the single jitted program): Σⱼ Iⱼ Pⱼ gathers (+ ``== h`` for trees),
value expressions, and the group-by reduction composed directly on the fused
prediction output — no intermediate table ever materializes on the fused
path.

Incremental maintenance: every quasi-static array the online programs read
(matrices, pointers, masks, partials, group ids) is threaded through the
jitted functions as one *state pytree argument* rather than closed over —
closure capture would bake the arrays into the jaxpr as constants and force
a retrace on every append.  :meth:`CompiledQuery.refresh` applies pending
:class:`~repro.core.laq.catalog.Catalog` deltas to that state (sorted-merge
``PKIndex.extend``, delta ``prefuse_rows``, mask scatters): same shapes ⇒
the swapped state hits the same jit cache, no retrace; capacity growth (or
select-compaction / group overflow) falls back to a recompile with a named
``explain()`` reason.
"""
from __future__ import annotations

import collections
import dataclasses
import warnings
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..fusion.operators import DecisionTreeGEMM
from ..fusion.pipeline import (PrefusedStar, extend_prefused, predict_fused,
                               predict_fused_kernel, predict_fused_matmul,
                               predict_nonfused, predict_nonfused_kernel,
                               predict_nonfused_matmul, prefuse)
from ..laq.aggregation import (auto_num_groups, composite_code,
                               groupby_codes, matmul_aggregate,
                               segment_aggregate, segment_reduce)
from ..laq.catalog import Catalog, CatalogHistoryError, changed_spans
from ..laq.join import FactoredJoin, PKIndex, pk_index
from ..laq.projection import mapping_matrix
from ..laq.selection import select
from ..laq.star import DimSpec, StarJoin
from ..laq.table import PAD_KEY, Table
from .explain import ExplainReport
from .ir import (AGG_OPS, PREDICTION, Aggregate, ArmSpec, PredictiveQuery,
                 eval_value)
from .multiquery import holds_tracers
from .planner import (QueryPlan, effective_serve_backend,
                      estimate_query_cost, place_tables,
                      plan_chain_materialization, plan_query, plan_streaming,
                      resolve_mesh_serve_backend)
from .rewrite import _FILTER_FNS, rewrite_query
from .snowflake import (CollapsedChain, chain_dirty_heads, chain_tables,
                        flat_arm, link_parents, participating_tables,
                        refresh_chain, resolve_chain, virtual_name)
from .sharding import (make_predict_rows_forward, predict_rows_state,
                       shard_prefused_partials)
from .streaming import StreamExecutor, assert_pool_dimension_side


@dataclasses.dataclass
class CompiledQuery:
    """An executable plan: one jitted program + its quasi-static artifacts.

    The artifacts live in ``_state`` (a pytree the jitted programs take as
    an argument); ``catalog``/``versions`` record the data they were built
    against, and :meth:`refresh` brings them up to the catalog's current
    versions in place — by delta when shapes allow, by recompile otherwise.
    """

    query: PredictiveQuery
    plan: QueryPlan
    backend: str                    # "fused" | "nonfused"
    join_backend: str               # "gather" | "matmul"
    agg_backend: str                # "segment" | "matmul"
    serve_backend: str              # "jnp" | "pallas"
    star: StarJoin
    prefused: Optional[PrefusedStar]
    selectivity: float              # measured fraction of surviving fact rows
    group_codes: Optional[jnp.ndarray]   # sorted unique composite codes
    _gid: Optional[jnp.ndarray]
    _rows: jnp.ndarray                   # surviving-row count
    _run: callable
    _predict: Optional[callable]
    _predict_rows: Optional[callable]
    _state: Dict = dataclasses.field(default_factory=dict)
    catalog: Optional[Catalog] = None
    versions: Dict[str, int] = dataclasses.field(default_factory=dict)
    _indices: Tuple[PKIndex, ...] = ()   # per-arm PK indices (extendable)
    _source: Optional[PredictiveQuery] = None  # q as originally passed
    # Per-arm collapsed snowflake chains (None for flat arms; empty tuple
    # for all-flat queries).  ``query`` holds the *flattened* arms — the
    # chains carry the real head/link tables and the composed pointers the
    # refresh and group-by paths need.
    _chains: Tuple[Optional[CollapsedChain], ...] = ()
    _opts: Dict = dataclasses.field(default_factory=dict)
    _sp: Optional[object] = None         # ShardedPrefusedPartials (mesh path)
    # Bounded refresh-decision trail appended to plan.reason: a long-lived
    # streaming plan must not grow its explain() string without limit.
    _refresh_notes: "collections.deque" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=8))
    # Session-owned ArtifactPool sharing: the pool this plan acquired from
    # (None when compiled standalone) and the keys it holds references to —
    # {"arms": ((pkindex, join, dmask|None) per arm), "partials": (keys,)}.
    # ``close()`` releases them; eviction is an optimization, so a compile
    # that raises mid-way leaking a reference is benign retention, never a
    # correctness hazard.
    _pool: Optional[object] = None
    _pool_refs: Dict = dataclasses.field(default_factory=dict)
    # The raw (un-jitted) online closure, kept so Session.run_all can vmap
    # structurally compatible plans into one stacked program.
    _online_fn: Optional[callable] = None
    # Out-of-core driver (streaming.StreamExecutor) when the plan streams
    # the fact axis; ``run()`` dispatches through it instead of the
    # in-core jitted program.  None on the in-core path.
    _stream: Optional[object] = None
    # Per-rule trail from core.query.rewrite ("" entries never occur; empty
    # tuple = no rule fired or rewrite="off").  ``query`` holds the
    # *rewritten* IR the plan executes; ``_source`` the query as written.
    _rewrites: Tuple[str, ...] = ()

    @property
    def is_traced(self) -> bool:
        """True when compiled under an outer trace — such a plan holds
        tracers and must not be cached/reused outside that trace."""
        return isinstance(self._rows, jax.core.Tracer)

    def run(self) -> Dict[str, jnp.ndarray]:
        """Execute the query; returns aggregates (+ "groups", "rows").

        Streaming plans (``stream_chunk_rows``) fold the fact axis chunk by
        chunk through the same fused program — grouped aggregates and
        ungrouped count/min/max come back bit-exact vs the in-core path
        (see :mod:`repro.core.query.streaming`).
        """
        if self._stream is not None:
            out = dict(self._stream.run())
        else:
            out = dict(self._run(self._state))
        if self.group_codes is not None:
            out["groups"] = self.group_codes
        out["rows"] = self._rows
        return out

    def predictions(self) -> jnp.ndarray:
        """The (fact_capacity, l) prediction matrix (model queries only)."""
        if self._predict is None:
            raise ValueError("query has no model")
        return self._predict(self._state)

    def predict_rows(self, row_ids: jnp.ndarray) -> jnp.ndarray:
        """Batched serving: predictions for a batch of fact row ids.

        On the fused backend this is |arms| gathers into the prefused
        partials + adds — the paper's online phase, at request batch size.
        Out-of-range ids follow ``jnp.take`` fill semantics (NaN rows);
        negative ids wrap like numpy.
        """
        if self._predict_rows is None:
            raise ValueError("query has no model")
        return self._predict_rows(row_ids, self._state)

    # -- introspection / lifecycle ------------------------------------------
    def _pool_keys(self) -> list:
        """Every pool key this plan holds a reference to (with multiplicity)."""
        keys = [k for ref in self._pool_refs.get("arms", ()) for k in ref
                if k is not None]
        keys.extend(self._pool_refs.get("partials", ()))
        return keys

    def explain(self) -> ExplainReport:
        """Structured plan/refresh report (``str()`` gives the legacy line)."""
        return ExplainReport(
            kind="compiled", backend=self.backend,
            join_backend=self.join_backend, agg_backend=self.agg_backend,
            serve_backend=self.serve_backend,
            plan_reason=getattr(self, "_base_reason", self.plan.reason),
            trail=tuple(self._refresh_notes),
            shared_artifacts=tuple(self._pool_keys()),
            extras=(("selectivity", self.selectivity),
                    ("rewrites", self._rewrites),
                    ("stream", self._stream.describe()
                     if self._stream is not None else None)))

    def close(self) -> None:
        """Release this plan's shared-artifact references (idempotent).

        ``Session.evict`` calls this when dropping a cached plan; the pool
        evicts an artifact only when its *last* referencing plan closes.
        """
        if self._pool is not None and self._pool_refs:
            self._pool.release(self._pool_keys())
        self._pool_refs = {}

    # -- incremental maintenance --------------------------------------------
    def _participating(self) -> Tuple[str, ...]:
        return participating_tables(self._source or self.query)

    def refresh(self) -> str:
        """Apply pending catalog deltas to the compiled artifacts, in place.

        Appends that fit the tables' existing capacity (and non-key column
        updates) take the delta path: per-arm ``PKIndex.extend`` sorted
        merges, probes of only the appended keys/rows, ``prefuse_rows``
        over only the new dimension rows, and in-place mask/group-id
        rebuilds — all shape-preserving, so the already-compiled programs
        keep serving from the jit cache with zero retraces.  Capacity
        growth, select-compaction, or group-code overflow fall back to a
        full recompile; either way the decision is appended to
        ``plan.reason`` (visible via ``explain``) and returned.
        """
        if self.catalog is None:
            return self._note("refresh=no-op(detached: no catalog)")
        if self.is_traced:
            raise ValueError("cannot refresh a traced plan: it holds "
                             "tracers from an outer jit")
        cat = self.catalog
        try:
            changed = {n: cat.deltas_since(n, self.versions.get(n, 0))
                       for n in self._participating()}
        except CatalogHistoryError:
            return self._recompile("history-compacted: plan staler than "
                                   "the delta log")
        changed = {n: d for n, d in changed.items() if d}
        if not changed:
            return self._note("refresh=no-op(versions unchanged)")
        if self._opts.get("select_capacity") is not None:
            return self._recompile("select-compaction rebinds the fact")
        if any(changed_spans(d)[2] for d in changed.values()):
            # Compaction reuses the capacity-growth contract (row ids
            # changed shape-compatibly ⇒ every pointer artifact rebuilds),
            # but the explain() reason names it distinctly.
            compacted = sorted(n for n, d in changed.items()
                               if any(t.kind == "compact" for t in d))
            if compacted:
                return self._recompile(
                    f"compaction:{','.join(compacted)} rewrote row ids")
            grown = sorted(n for n, d in changed.items()
                           if changed_spans(d)[2])
            return self._recompile(f"capacity-growth:{','.join(grown)}")
        try:
            return self._refresh_delta(changed)
        except _GroupOverflow:
            return self._recompile("group-overflow: live codes exceed the "
                                   "compiled num_groups")

    def _note(self, line: str) -> str:
        if not self._refresh_notes:
            self._base_reason = self.plan.reason
        self._refresh_notes.append(line)
        self.plan = dataclasses.replace(
            self.plan, reason="; ".join([self._base_reason,
                                         *self._refresh_notes]))
        return line

    def _recompile(self, why: str) -> str:
        # Recompile FIRST (the fresh plan re-acquires shared artifacts,
        # keeping their refcounts above zero), then release the old
        # references — releasing first would evict artifacts the fresh
        # compile is about to rebuild.
        old_pool, old_keys = self._pool, self._pool_keys()
        fresh = compile_query(self.catalog, self._source, **self._opts)
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(fresh, f.name))
        if old_pool is not None:
            old_pool.release(old_keys)
        return self._note(f"refresh=recompile({why})")

    def _refresh_delta(self, changed) -> str:
        if self._pool is not None and self._pool_refs.get("arms"):
            return self._refresh_delta_pooled(changed)
        q = self.query
        cat = self.catalog
        fact = cat[q.fact]
        fspan, _, _, _ = (changed_spans(changed[q.fact])
                          if q.fact in changed else (None, (), False, ()))

        # Re-collapse chains whose real tables changed (cached hops on
        # unchanged tables are reused); the per-arm pointer work below then
        # runs against the *head* table — the fact joins the head's PK, at
        # head granularity, chain or no chain.
        chains = (list(self._chains) if self._chains
                  else [None] * len(q.arms))
        stale = set(changed)
        for j, ch in enumerate(chains):
            if ch is not None and stale & set(chain_tables(ch.arm)):
                chains[j] = refresh_chain(cat, ch, stale)
        overlay = cat
        if any(c is not None for c in chains):
            overlay = {**cat, **{c.table.name: c.table
                                 for c in chains if c is not None}}

        ptrs = [np.array(p) for p in self._state["ptrs"]]
        founds = [np.array(f) for f in self._state["founds"]]
        indices = list(self._indices)
        dirty_rows = []
        for j, arm in enumerate(q.arms):
            ch = chains[j]
            head = ch.arm.table if ch is not None else arm.table
            dim = cat[head]
            # Deleted ids need no pointer/index/prefuse work: a tombstone
            # keeps the row's slot, key and data, so only the validity fold
            # (recomputed below by _assemble_star) changes.
            span, dirty, _, _ = (
                changed_spans(changed[head])
                if head in changed else (None, (), False, ()))
            ids = set(dirty)
            if span is not None:
                lo, hi = span
                ids.update(range(lo, hi))
                indices[j] = indices[j].extend(
                    dim.key(arm.pk_col)[lo:hi], np.arange(lo, hi))
                # Fact rows whose FK now hits an appended PK: probe only the
                # appended key block (O(n log m)), scatter into ptr/found.
                nk = np.asarray(dim.key(arm.pk_col))[lo:hi]
                order = np.argsort(nk, kind="stable")
                snk, srow = nk[order], (lo + order).astype(np.int32)
                fk = np.asarray(fact.key(arm.fk_col))
                pos = np.searchsorted(snk, fk)
                posc = np.clip(pos, 0, len(snk) - 1)
                hit = (snk[posc] == fk) & (fk != PAD_KEY)
                ptrs[j] = np.where(hit, srow[posc], ptrs[j]).astype(np.int32)
                founds[j] = founds[j] | hit
            if fspan is not None:
                # Appended fact rows: probe their FKs against the (already
                # extended) full index, scatter into the new row span.
                flo, fhi = fspan
                fj = indices[j].probe(fact.key(arm.fk_col)[flo:fhi])
                ptrs[j][flo:fhi] = np.asarray(fj.ptr)
                founds[j][flo:fhi] = np.asarray(fj.found)
            if ch is not None:
                # Sub-dimension deltas dirty the head rows whose composed
                # pointers resolve into the touched link rows — those
                # virtual-matrix rows (and only those) differ from the old
                # collapse, so the partial scatter stays bit-exact vs cold.
                touched = {}
                for t in chain_tables(ch.arm):
                    if t in changed:
                        tspan, tdirty, _, _ = changed_spans(changed[t])
                        tids = set(tdirty)
                        if tspan is not None:
                            tids.update(range(tspan[0], tspan[1]))
                        if tids:
                            touched[t] = np.asarray(sorted(tids), np.int64)
                dh = chain_dirty_heads(ch, touched)
                if dh is not None:
                    ids.update(int(i) for i in dh)
            dirty_rows.append(
                np.asarray(sorted(ids), np.int32) if ids else None)

        # Validity, prefuse partials and group ids rebuild from the updated
        # pointers — eager element-wise work, never a retrace.  The mask
        # fold is the same _assemble_star the cold compile runs, so the
        # refreshed validity is bitwise the cold rebuild's by construction.
        joins = tuple(FactoredJoin(jnp.asarray(p), jnp.asarray(f))
                      for p, f in zip(ptrs, founds))
        dmasks = (tuple(c.dmask if c is not None else None for c in chains)
                  if any(c is not None for c in chains) else None)
        star, valid = _assemble_star(overlay, q, joins, dmasks=dmasks)

        prefused = self.prefused
        if prefused is not None:
            prefused = extend_prefused(prefused, star.dims, q.model,
                                       dirty_rows)
        self._indices = tuple(indices)
        self._chains = tuple(chains) if any(
            c is not None for c in chains) else ()
        return self._rebind(changed, star, valid, prefused,
                            "shapes kept, jit cache reused")

    def _refresh_delta_pooled(self, changed) -> str:
        """Delta refresh for pool-backed plans.

        The shared quasi-static artifacts (PK indices, join pointers,
        predicate masks, prefused partials) come from the pool, which
        delta-updates each stale entry *exactly once* no matter how many
        plans reference it — so N plans over one registry pay O(distinct
        artifacts), not O(plans), for the probe/prefuse work.  Only the
        per-plan residue — the validity fold, group codes and state-pytree
        rebuild — runs here.
        """
        q = self.query
        cat = self.catalog
        pool = self._pool
        chains = (list(self._chains) if self._chains
                  else [None] * len(q.arms))
        indices, joins, dmasks = [], [], []
        for j, (ikey, jkey, mkey) in enumerate(self._pool_refs["arms"]):
            indices.append(pool.get(ikey))
            ptr, found = pool.get(jkey)
            joins.append(FactoredJoin(ptr, found))
            mval = pool.get(mkey) if mkey is not None else None
            if isinstance(mval, CollapsedChain):
                # Chained arm: the mask slot holds the pooled collapsed
                # chain — the pool re-collapsed it at most once for every
                # plan sharing it; the dmask and virtual table fall out.
                chains[j] = mval
                mval = mval.dmask
            dmasks.append(mval)
        overlay = cat
        if any(c is not None for c in chains):
            overlay = {**cat, **{c.table.name: c.table
                                 for c in chains if c is not None}}
        self._chains = tuple(chains) if any(
            c is not None for c in chains) else ()
        star, valid = _assemble_star(overlay, q, tuple(joins),
                                     dmasks=tuple(dmasks))
        prefused = self.prefused
        pkeys = self._pool_refs.get("partials", ())
        if pkeys:
            prefused = PrefusedStar(tuple(pool.get(k) for k in pkeys),
                                    prefused.h)
        self._indices = tuple(indices)
        return self._rebind(changed, star, valid, prefused,
                            "pooled artifacts, jit cache reused")

    def _rebind(self, changed, star, valid, prefused, how: str) -> str:
        """Shared delta-refresh tail: group codes, counts, state pytree."""
        q = self.query
        cat = self.catalog
        codes = uniq = gid = None
        if q.group_keys:
            cols, bounds = _group_columns(cat, q, star, self._chains)
            codes = composite_code(cols, bounds, valid)
            try:
                uniq, gid = groupby_codes(codes, q.num_groups)
            except ValueError as e:
                raise _GroupOverflow(str(e)) from e

        rows = jnp.sum(valid.astype(jnp.int32))
        n_fact = _static_int(star.fact.nvalid, star.fact.capacity)
        self.star = star
        self.prefused = prefused
        self.group_codes = uniq
        self._gid = gid
        self._rows = rows
        self.selectivity = float(rows) / max(n_fact, 1)
        state = _query_state(star, prefused, gid)
        if self._sp is not None:
            tables = (list(prefused.partials) if self.backend == "fused"
                      else [d.dim.matrix
                            @ mapping_matrix(d.dim.columns, d.feature_cols)
                            for d in star.dims])
            state["sharded"] = predict_rows_state(
                self._sp, tables, [fj.ptr for fj in star.joins],
                [fj.found for fj in star.joins], valid)
        self._state = state
        if self._stream is not None:
            # Same capacity ⇒ same chunk shapes ⇒ the executor's jit cache
            # keeps serving: a streamed refresh is zero-retrace too.
            self._stream.rebind(state)
        self.versions = {n: cat.version(n) for n in self._participating()}
        touched = ",".join(f"{n}+{len(changed[n])}"
                           for n in sorted(changed))
        return self._note(f"refresh=delta({touched}; {how})")


class _GroupOverflow(ValueError):
    """Internal: live group codes outgrew the compiled num_groups."""


def _static_int(x, default: int) -> int:
    """``int(x)`` when concrete, ``default`` when ``x`` is a tracer."""
    try:
        return int(x)
    except jax.errors.ConcretizationTypeError:
        return default


def _assemble_star(catalog: Mapping[str, Table], q: PredictiveQuery,
                   joins: Tuple[FactoredJoin, ...],
                   dmasks: Optional[Tuple] = None
                   ) -> Tuple[StarJoin, jnp.ndarray]:
    """Fold every selection mask into the combined validity, given resolved
    per-arm joins.

    The single definition of predicate semantics (fact preds AND-fold, dim
    preds gathered through the FK pointers) shared by the cold compile and
    the delta refresh — the two must agree bitwise or refresh loses its
    ≡-cold-rebuild contract.  ``dmasks`` optionally supplies precomputed
    per-arm dimension masks (pool-shared); ``Pred.mask`` folds the table's
    validity itself, so a pooled ``valid ∧ preds`` mask is boolean-equal to
    the AND-fold done here.
    """
    fact = catalog[q.fact]
    valid = fact.valid_mask()
    for p in q.fact_preds:
        valid = valid & p.mask(fact)
    dims = []
    for j, (arm, fj) in enumerate(zip(q.arms, joins)):
        dim = catalog[arm.table]
        dims.append(DimSpec(dim, arm.fk_col, arm.pk_col, arm.feature_cols))
        ok = fj.found
        dmask = dmasks[j] if dmasks is not None else None
        if dmask is None and arm.preds:
            dmask = arm.preds[0].mask(dim)
            for p in arm.preds[1:]:
                dmask = dmask & p.mask(dim)
        if dmask is None and dim.deleted is not None:
            # ``Pred.mask`` folds the dimension's validity (tombstones
            # included), but an arm with no predicates has no mask to fold
            # through — gather the live mask explicitly so fact rows joined
            # to a tombstoned dimension row drop out.
            dmask = dim.valid_mask()
        if dmask is not None:
            ok = ok & jnp.take(dmask, fj.ptr)
        valid = valid & ok
    star = StarJoin(fact=fact, dims=tuple(dims), joins=tuple(joins),
                    row_valid=valid)
    if q.model_preds:
        # Prediction filters fold into the validity like any predicate: the
        # predictions are quasi-static (functions of the joined dimension
        # rows), so the mask is offline work and both delta-refresh paths
        # inherit it by re-running this fold.  Invalid rows may see a
        # different (zeroed-features) prediction than they would if valid —
        # irrelevant under the AND: they stay invalid either way.
        preds = q.model.apply(star.materialize())
        for f in q.model_preds:
            valid = valid & _FILTER_FNS[f.op](preds[:, f.output],
                                              jnp.float32(f.value))
        star = dataclasses.replace(star, row_valid=valid)
    return star, valid


def _resolve_star(catalog: Mapping[str, Table], q: PredictiveQuery,
                  pool=None, chains: Tuple = (), chain_keys: Tuple = ()
                  ) -> Tuple[StarJoin, jnp.ndarray, Tuple[PKIndex, ...],
                             Tuple[tuple, ...]]:
    """Joins + combined validity with every selection mask folded in.

    Also returns the per-arm ``PKIndex`` — the quasi-static half of each
    join, kept for ``refresh`` to extend instead of re-sorting.  With a
    ``pool``, indices/pointers/masks are acquired from the shared
    :class:`~.multiquery.ArtifactPool` (computed once per distinct arm
    across all plans) and the per-arm reference keys are returned as the
    fourth element (empty tuple when unpooled).

    Chained arms (``chains[j]`` not None) index and probe against the
    *real* head table name, so two queries joining the same head through
    different chains still share one PK index and fact probe; their dmask
    is the chain's folded validity (the pool reference in the mask slot
    is the chain entry's key).
    """
    fact = catalog[q.fact]
    joins, indices, arm_refs, dmasks = [], [], [], []
    any_chain = any(c is not None for c in chains)
    for j, arm in enumerate(q.arms):
        ch = chains[j] if j < len(chains) else None
        head = ch.arm.table if ch is not None else arm.table
        if pool is not None:
            idx, ikey = pool.acquire_pkindex(head, arm.pk_col)
            (ptr, found), jkey = pool.acquire_join(
                q.fact, arm.fk_col, head, arm.pk_col)
            fj = FactoredJoin(ptr, found)
            if ch is not None:
                dmask, mkey = ch.dmask, chain_keys[j]
            elif arm.preds:
                dmask, mkey = pool.acquire_dmask(arm.table, arm.preds)
            else:
                dmask = mkey = None
            arm_refs.append((ikey, jkey, mkey))
            dmasks.append(dmask)
        else:
            idx = pk_index(catalog[head].key(arm.pk_col))
            fj = idx.probe(fact.key(arm.fk_col))
            dmasks.append(ch.dmask if ch is not None else None)
        joins.append(fj)
        indices.append(idx)
    star, valid = _assemble_star(
        catalog, q, tuple(joins),
        dmasks=(tuple(dmasks) if pool is not None or any_chain else None))
    return star, valid, tuple(indices), tuple(arm_refs)


def _group_columns(catalog: Mapping[str, Table], q: PredictiveQuery,
                   star: StarJoin, chains: Tuple = ()):
    """Exact int32 group-key columns, gathered through the arm pointers.

    Chained arms register their *real* head name plus every link table:
    a sub-dimension group key composes the fact→head pointers with the
    chain's head→link pointers (associativity again — the composition is
    the flat fact→link join's pointer array).  Misses gather row 0, which
    is masked by ``composite_code``'s validity fold like any flat miss.
    """
    arm_ptr = {}
    for j, (a, fj) in enumerate(zip(q.arms, star.joins)):
        ch = chains[j] if j < len(chains) else None
        if ch is None:
            arm_ptr[a.table] = fj.ptr
        else:
            arm_ptr[ch.arm.table] = fj.ptr
            for name, lptr, _found in ch.link_ptrs:
                arm_ptr[name] = jnp.take(lptr, fj.ptr)
    cols, bounds = [], []
    for gk in q.group_keys:
        if gk.table == "fact":
            c = star.fact.key(gk.col)
        else:
            c = jnp.take(catalog[gk.table].key(gk.col), arm_ptr[gk.table])
        cols.append(c - jnp.int32(gk.offset))
        bounds.append(gk.bound)
    return cols, bounds


def _fact_row_bytes(fact: Table, q: PredictiveQuery, n_arms: int,
                    out_width: int) -> int:
    """Per-fact-row working-set bytes of the online program.

    State leaves (matrix columns, exact keys, per-arm pointer+found,
    validity, group id) plus the fact-sized intermediates the program
    materializes (prediction rows, per-aggregate masked value temps) — the
    quantity the streaming planner compares against the device budget.
    """
    base = fact.ncols * 4 + len(fact.keys) * 4 + n_arms * 5 + 1 + 4
    inter = ((out_width * 4 if q.model is not None else 0)
             + 4 * max(len(q.aggregates), 1))
    return base + inter


def _check_aggregates(q: PredictiveQuery):
    if not q.aggregates:
        raise ValueError("query has no aggregates")
    names = [a.name for a in q.aggregates]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate aggregate names {names}: each "
                         "aggregate needs a distinct result column name")
    reserved = {"rows", "groups"} & set(names)
    if reserved:
        raise ValueError(f"aggregate names {sorted(reserved)} collide with "
                         "the reserved result keys 'rows'/'groups'")
    for agg in q.aggregates:
        if agg.op not in AGG_OPS:
            raise ValueError(
                f"aggregate op {agg.op!r} (aggregate {agg.name!r}) not one "
                f"of {list(AGG_OPS)}")
        if agg.value == PREDICTION and q.model is None:
            raise ValueError("PREDICTION aggregate requires a model")


# --------------------------------------------------------------------------
# Quasi-static state as a pytree (the jitted programs' data argument)
# --------------------------------------------------------------------------
def _query_state(star: StarJoin, prefused: Optional[PrefusedStar],
                 gid: Optional[jnp.ndarray]) -> Dict:
    """Every array the online programs read, as one swappable pytree.

    ``refresh`` replaces leaves with same-shape updates; because these are
    jit *arguments* (not closure constants), the swapped state re-dispatches
    into the already-compiled executables.
    """
    return {
        "fact_matrix": star.fact.matrix,
        "valid": star.row_valid,
        "ptrs": tuple(fj.ptr for fj in star.joins),
        "founds": tuple(fj.found for fj in star.joins),
        "dim_mats": tuple(d.dim.matrix for d in star.dims),
        "partials": (tuple(prefused.partials)
                     if prefused is not None else None),
        "h": prefused.h if prefused is not None else None,
        "gid": gid,
        "sharded": None,
    }


def _star_view(star0: StarJoin, state: Dict) -> StarJoin:
    """The StarJoin skeleton rebound onto the state pytree's arrays."""
    fact = dataclasses.replace(star0.fact, matrix=state["fact_matrix"])
    dims = tuple(
        dataclasses.replace(d, dim=dataclasses.replace(d.dim, matrix=m))
        for d, m in zip(star0.dims, state["dim_mats"]))
    joins = tuple(FactoredJoin(p, f)
                  for p, f in zip(state["ptrs"], state["founds"]))
    return StarJoin(fact=fact, dims=dims, joins=joins,
                    row_valid=state["valid"])


def _prefused_view(state: Dict) -> Optional[PrefusedStar]:
    if state["partials"] is None:
        return None
    return PrefusedStar(tuple(state["partials"]), state["h"])


def _program_state(state: Dict) -> Dict:
    """The state subtree the single-device programs take.

    The ``"sharded"`` subtree holds mesh-committed arrays; feeding those
    into a single-device jit alongside host arrays would raise a device
    mismatch, so each program crosses the jit boundary with exactly the
    arrays it reads.
    """
    return {k: v for k, v in state.items() if k != "sharded"}


def compile_query(catalog: Mapping[str, Table], q: PredictiveQuery, *,
                  backend: str = "auto", join_backend: str = "auto",
                  agg_backend: str = "auto", serve_backend: str = "auto",
                  select_capacity: Optional[int] = None,
                  batches_per_update: float = 1000.0,
                  memory_budget_bytes: Optional[int] = None,
                  stream_chunk_rows=None,
                  chain_strategy: str = "auto",
                  rewrite: str = "on",
                  interpret: bool = False, mesh=None,
                  shard_axis: str = "model",
                  shard_threshold_bytes: Optional[int] = None,
                  pool=None) -> CompiledQuery:
    """Plan + lower ``q`` against ``catalog`` into one jitted program.

    ``catalog`` may be a :class:`~repro.core.laq.Catalog` — the versioned
    data surface whose appends the compiled plan can absorb via
    :meth:`CompiledQuery.refresh` — or any plain ``Mapping[str, Table]``,
    which is auto-wrapped into a *read-only* Catalog for back-compat (the
    pre-Catalog frozen-dict contract; such plans never have pending deltas).

    All of ``q.aggregates`` lower into that one program over the shared
    join/model work: ``sum``/``count``/``mean``/``min``/``max``, with mean
    as a fused sum/count (one count reduction shared across every
    count/mean aggregate) and min/max through segment ops on either
    aggregation backend.  ``q.num_groups == "auto"`` sizes the group
    dimension from the measured live code domain (offline concrete path
    only — see :func:`~repro.core.laq.aggregation.auto_num_groups`).

    ``backend`` / ``join_backend`` / ``agg_backend`` override the planner
    ("auto" defers to the cost model); explicit "matmul" backends give the
    paper-faithful reference lowering used by tests and benchmarks.
    ``serve_backend`` picks the physical kernel for the *serving* paths —
    ``predict_rows`` always, and ``predictions`` when the join backend is
    "gather" (the dense "matmul" join is its own paper-faithful lowering):
    "pallas" lowers the fused gather-sum onto ``fused_star_gather`` and
    non-fused trees onto ``tree_predict`` ("auto" picks it on TPU when the
    shapes fit the block specs); ``interpret=True`` runs the kernels in
    interpret mode so the lowering is testable on CPU.

    ``stream_chunk_rows`` turns ``run()`` out-of-core: the fact axis streams
    host→device in chunks of that many rows (``"auto"`` sizes chunks to
    ``memory_budget_bytes``; the default ``None`` streams only when the
    budget is set and the fact working set exceeds it) through the fused
    online program, folding per-chunk partial aggregates bit-exactly for
    grouped aggregates and ungrouped count/min/max — see
    :mod:`repro.core.query.streaming`.  The serving paths
    (``predict_rows``) are request-batched and unaffected.

    ``select_capacity`` applies the fact predicates by ``mask_select``
    compaction (§2.2) *before* the joins: surviving rows are packed into a
    fixed buffer of that many rows, shrinking every online shape — the right
    call for very selective queries.  Row ids seen by ``predict_rows`` then
    index the compacted table.

    ``mesh`` shards the *serving* path: each arm's quasi-static row table
    (prefused partial / projected features) is placed per
    ``plan_partition_spec`` and ``predict_rows`` becomes one ``shard_map``
    of device-local gathers + a psum (``core.query.sharding``), bit-exact
    vs the single-device program.  The whole-query aggregate program
    (``run``/``predictions``) stays single-device — it is fact-sized, not
    partial-sized.  ``mesh`` is incompatible with ``serve_backend="pallas"``.
    """
    for name, arg, allowed in (
            ("backend", backend, ("auto", "fused", "nonfused")),
            ("join_backend", join_backend, ("auto", "gather", "matmul")),
            ("agg_backend", agg_backend, ("auto", "segment", "matmul")),
            ("serve_backend", serve_backend, ("auto", "jnp", "pallas")),
            ("chain_strategy", chain_strategy,
             ("auto", "through", "materialize")),
            ("rewrite", rewrite, ("on", "off"))):
        if arg not in allowed:
            raise ValueError(f"{name} {arg!r} not one of {allowed}")
    serve_backend = resolve_mesh_serve_backend(serve_backend, mesh)
    _check_aggregates(q)
    if not isinstance(catalog, Catalog):
        warnings.warn(
            "passing a plain mapping to compile_query is deprecated and "
            "will require an explicit wrap in a future release; construct "
            "a repro.core.laq.Catalog (or go through Session) — see the "
            "migration table in repro.core.query",
            DeprecationWarning, stacklevel=2)
    cat0 = Catalog.wrap(catalog)
    for arm in q.arms:   # teach the catalog the join contract (PK columns)
        cat0.note_unique(arm.table, arm.pk_col)
        for lk in arm.links:
            cat0.note_unique(lk.table, lk.pk_col)
    source_q = q
    opts = dict(backend=backend, join_backend=join_backend,
                agg_backend=agg_backend, serve_backend=serve_backend,
                select_capacity=select_capacity,
                batches_per_update=batches_per_update,
                memory_budget_bytes=memory_budget_bytes,
                stream_chunk_rows=stream_chunk_rows,
                chain_strategy=chain_strategy, rewrite=rewrite,
                interpret=interpret, mesh=mesh, shard_axis=shard_axis,
                shard_threshold_bytes=shard_threshold_bytes, pool=pool)
    # Query/model co-optimization (core.query.rewrite): run the exact
    # rewrite rules over the IR, then keep whichever of (original,
    # rewritten) the cost model scores cheaper.  The rules read arrays, so
    # they are skipped under an outer trace; ``_source`` stays the original
    # query, so refresh-by-recompile re-runs the rewrite from scratch.
    rewrite_trail: Tuple[str, ...] = ()
    if rewrite == "on" and not holds_tracers(cat0, q):
        rw = rewrite_query(cat0, q)
        if rw.changed:
            def _cost(qq):
                return estimate_query_cost(
                    qq.model, cat0[qq.fact].capacity,
                    [cat0[a.table].capacity for a in qq.arms],
                    out_width=qq.model.l if qq.model is not None else 1,
                    batches_per_update=batches_per_update)
            cost_orig, cost_rw = _cost(q), _cost(rw.query)
            if cost_rw <= cost_orig:
                q = rw.query
                rewrite_trail = rw.trail
            else:
                rewrite_trail = (
                    f"rejected: cost {cost_rw:.3g} > {cost_orig:.3g}",)
    # Pool sharing engages only on the plain single-device path against the
    # pool's own catalog: select-compaction rebinds the fact to a local
    # table, mesh placement commits arrays to devices, and tracer-holding
    # tables must never leak into a cross-plan cache.
    use_pool = (pool is not None and select_capacity is None
                and mesh is None and pool.catalog is cat0
                and not holds_tracers(cat0, q))
    # How many plans already share these join artifacts — measured BEFORE
    # this plan acquires (its own reference must not inflate the hint).
    sharing = pool.sharing_hint(q.fact, q.arms) if use_pool else 1.0
    catalog = cat0
    if select_capacity is not None:
        fact = select(catalog[q.fact], q.fact_preds,
                      capacity=select_capacity)
        catalog = {**catalog, q.fact: fact}
        q = dataclasses.replace(q, fact_preds=())
    # Snowflake chains collapse offline to head-granularity virtual
    # dimensions (factored joins compose associatively — see
    # core.query.snowflake), overlaid on the catalog like the
    # select-compacted fact; the flattened query then lowers through the
    # unchanged star pipeline, bit-exact with materializing each chain.
    chains: Tuple = ()
    chain_keys: Tuple = ()
    chain_notes = []
    if any(a.links for a in q.arms):
        ccs, ckeys = [], []
        for arm in q.arms:
            if not arm.links:
                ccs.append(None)
                ckeys.append(None)
                continue
            k, note = plan_chain_materialization(
                virtual_name(arm),
                [catalog[p].capacity for p in link_parents(arm)],
                strategy=chain_strategy)
            chain_notes.append(note)
            if use_pool:
                cc, ckey = pool.acquire_chain(arm, keep_hops=k)
            else:
                cc, ckey = resolve_chain(catalog, arm, keep_hops=k), None
            ccs.append(cc)
            ckeys.append(ckey)
        chains, chain_keys = tuple(ccs), tuple(ckeys)
        catalog = {**catalog, **{c.table.name: c.table
                                 for c in chains if c is not None}}
        q = dataclasses.replace(q, arms=tuple(flat_arm(a) for a in q.arms))
    star, valid, indices, arm_refs = _resolve_star(
        catalog, q, pool=pool if use_pool else None, chains=chains,
        chain_keys=chain_keys)
    fact = star.fact
    rows = jnp.sum(valid.astype(jnp.int32))
    # Offline compilation measures selectivity from the data; when a caller
    # traces compile_query itself (whole pipeline under one outer jit), the
    # counts are abstract — plan with static shapes and selectivity 1.
    n_fact = _static_int(fact.nvalid, fact.capacity)
    try:
        sel = float(rows) / max(n_fact, 1)
    except jax.errors.ConcretizationTypeError:
        sel = 1.0

    # Group codes resolve before planning so ``num_groups="auto"`` can size
    # the group dimension from the measured code domain (the codes are
    # concrete on the offline path) and feed the planner the real G.
    codes = None
    n_live = None
    if q.group_keys:
        cols, bounds = _group_columns(catalog, q, star, chains)
        codes = composite_code(cols, bounds, valid)
        if q.num_groups == "auto":
            n_live = auto_num_groups(codes)
            q = dataclasses.replace(q, num_groups=n_live)
    elif q.num_groups == "auto":
        q = dataclasses.replace(
            q, num_groups=PredictiveQuery.__dataclass_fields__[
                "num_groups"].default)

    out_width = q.model.l if q.model is not None else 1
    # The planner's selectivity term models mask_select compaction (§2.2):
    # online shapes only actually shrink when ``select_capacity`` compacted
    # the fact table (already reflected in n_fact).  The default lowering
    # masks without compacting, so its online cost stays at full capacity —
    # feeding the measured selectivity in would optimize a plan shape that
    # is not the one being executed.
    plan = plan_query(q.model, n_fact,
                      [_static_int(d.dim.nvalid, d.dim.capacity)
                       for d in star.dims],
                      selectivity=1.0,
                      num_groups=q.num_groups if q.group_keys else 0,
                      out_width=out_width,
                      agg_ops=tuple(a.op for a in q.aggregates),
                      batches_per_update=batches_per_update,
                      memory_budget_bytes=memory_budget_bytes,
                      sharing=sharing)
    if rewrite_trail:
        chain_notes.insert(0, "rewrite=[" + "; ".join(rewrite_trail) + "]")
    if chain_notes:
        plan = dataclasses.replace(
            plan, reason="; ".join([plan.reason, *chain_notes]))
    backend = plan.backend if backend == "auto" else backend
    join_backend = plan.join_backend if join_backend == "auto" else join_backend
    agg_backend = ((plan.agg.backend if plan.agg else "segment")
                   if agg_backend == "auto" else agg_backend)

    # Out-of-core decision: fact working-set bytes vs the device budget
    # (planner), or a caller-pinned chunk size.  Streaming runs the fused
    # gather/segment program per chunk — the one lowering whose per-row
    # bits are independent of chunking — so explicit conflicting backend
    # overrides are rejected rather than silently un-streamed.
    stream_rows = None
    if stream_chunk_rows is not None or memory_budget_bytes is not None:
        row_bytes = _fact_row_bytes(fact, q, len(star.dims), out_width)
        stream_rows, stream_reason = plan_streaming(
            stream_chunk_rows, fact.capacity, row_bytes,
            memory_budget_bytes)
        if (stream_rows is not None and stream_chunk_rows is None
                and q.model is not None and backend == "nonfused"
                and plan.fusion is not None
                and memory_budget_bytes is not None
                and plan.fusion.prefused_bytes > memory_budget_bytes):
            # The budget already ruled out resident prefused partials
            # (plan_fusion's older contract) — chunking the fact cannot
            # shrink the dimension side, so the budget-driven path defers
            # to that choice.  A merely amortization-driven nonfused pick
            # does NOT defer: out-of-core has no nonfused lowering, and
            # prefusing is the price of exceeding memory.  An explicit
            # chunk size always streams.
            stream_rows = None
            stream_reason = "stream=off (budget forces nonfused prefuse)"
        if stream_reason:
            plan = dataclasses.replace(
                plan, stream_chunk_rows=stream_rows,
                reason=f"{plan.reason}; {stream_reason}")
    if stream_rows is not None:
        for name, val, bad in (("backend", opts["backend"], "nonfused"),
                               ("join_backend", opts["join_backend"],
                                "matmul"),
                               ("agg_backend", opts["agg_backend"],
                                "matmul")):
            if val == bad:
                raise ValueError(
                    f"stream_chunk_rows is incompatible with {name}="
                    f"{bad!r}: chunked execution folds partial aggregates "
                    "through the fused gather/segment program (matmul "
                    "lowerings are not bitwise chunk-stable)")
        if isinstance(rows, jax.core.Tracer) or holds_tracers(cat0,
                                                              source_q):
            raise ValueError(
                "streaming is an offline host-side driver: it cannot run "
                "under an outer trace (compile without stream_chunk_rows "
                "there)")
        if q.model is not None:
            backend = "fused"
        join_backend = "gather"
        agg_backend = "segment"
    serve_backend = effective_serve_backend(plan, serve_backend, backend,
                                            q.model, len(star.dims))
    if serve_backend != plan.serve_backend:
        plan = dataclasses.replace(
            plan, serve_backend=serve_backend,
            reason=f"{plan.reason}; serve={serve_backend} (caller override)")

    prefused = None
    partial_keys = ()
    if q.model is not None and backend == "fused":
        if use_pool:
            parts, h, partial_keys = pool.acquire_partials(
                star.dims, q.model, chains=chains)
            prefused = PrefusedStar(parts, h)
        else:
            prefused = prefuse(star, q.model)

    uniq = gid = None
    if q.group_keys:
        uniq, gid = groupby_codes(codes, q.num_groups, n_live=n_live)

    reduce_fn = (matmul_aggregate if agg_backend == "matmul"
                 else segment_aggregate)
    model = q.model
    num_groups = q.num_groups
    aggregates = q.aggregates
    fact_desc = q.fact

    def _predictions(state):
        star_v = _star_view(star, state)
        pre_v = _prefused_view(state)
        if backend == "fused":
            if join_backend != "gather":
                return predict_fused_matmul(star_v, pre_v)
            if serve_backend == "pallas":
                return predict_fused_kernel(star_v, pre_v,
                                            interpret=interpret)
            return predict_fused(star_v, pre_v)
        if join_backend != "gather":
            return predict_nonfused_matmul(star_v, model)
        if serve_backend == "pallas":   # resolve_ guarantees a tree model
            return predict_nonfused_kernel(star_v, model,
                                           interpret=interpret)
        return predict_nonfused(star_v, model)

    def _agg_values(agg, pred, fact_v, valid_v):
        """Per-row values for one aggregate (sum-masked for additive ops)."""
        if agg.value == PREDICTION:
            return pred                          # already validity-masked
        vals = eval_value(fact_v, agg.value,
                          query=f"{agg.name!r} on {fact_desc!r}")
        if agg.op in ("min", "max"):
            return vals       # invalid rows are masked by gid / ±inf below
        return jnp.where(valid_v, vals, 0.0)

    def _online(state):
        fact_v = dataclasses.replace(fact, matrix=state["fact_matrix"])
        valid_v = state["valid"]
        gid_v = state["gid"]
        pred = _predictions(state) if model is not None else None
        out = {}
        # One shared count reduction backs every count/mean aggregate.
        count = None
        if any(a.op in ("count", "mean") for a in aggregates):
            ones = valid_v.astype(jnp.float32)
            count = (reduce_fn(gid_v, ones, num_groups)
                     if gid_v is not None else jnp.sum(ones))
        for agg in aggregates:
            if agg.op == "count":
                out[agg.name] = count
                continue
            vals = _agg_values(agg, pred, fact_v, valid_v)
            if gid_v is not None:
                if agg.op in ("min", "max"):
                    # Invalid rows sit in the dropped overflow segment, so
                    # no value masking is needed; min/max lower through
                    # segment ops on both aggregation backends (Fig. 4's
                    # one-hot matmul is additive-only).
                    out[agg.name] = segment_reduce(gid_v, vals, num_groups,
                                                   agg.op)
                elif agg.op == "mean":
                    s = reduce_fn(gid_v, vals, num_groups)
                    c = jnp.maximum(count, 1.0)
                    out[agg.name] = s / (c[:, None] if s.ndim > 1 else c)
                else:
                    out[agg.name] = reduce_fn(gid_v, vals, num_groups)
            elif agg.op in ("min", "max"):
                fill = jnp.inf if agg.op == "min" else -jnp.inf
                mask = valid_v[:, None] if vals.ndim > 1 else valid_v
                r = (jnp.min if agg.op == "min" else jnp.max)(
                    jnp.where(mask, vals, fill), axis=0)
                out[agg.name] = jnp.where(jnp.isfinite(r), r, 0.0)
            elif agg.op == "mean":
                out[agg.name] = (jnp.sum(vals, axis=0)
                                 / jnp.maximum(count, 1.0))
            else:
                out[agg.name] = jnp.sum(vals, axis=0)
        return out

    state = _query_state(star, prefused, gid)
    online_jit = jax.jit(_online)
    pred_jit = jax.jit(_predictions)

    def run_fn(st):
        return online_jit(_program_state(st))

    predict_jit = predict_rows_jit = None
    sp = None
    if q.model is not None:
        def predict_jit(st):
            return pred_jit(_program_state(st))

        if mesh is not None:
            fwd, plan, sharded_state, sp = _make_predict_rows_sharded(
                star, q.model, prefused, backend, plan, mesh, shard_axis,
                shard_threshold_bytes)
            state["sharded"] = sharded_state
            fwd_jit = jax.jit(fwd)

            def predict_rows_jit(row_ids, st):
                return fwd_jit(row_ids, st["sharded"])
        else:
            rows_jit = jax.jit(
                _make_predict_rows(star, q.model, backend, serve_backend,
                                   interpret))

            def predict_rows_jit(row_ids, st):
                return rows_jit(row_ids, _program_state(st))

    stream = None
    if stream_rows is not None:
        # Result widths come from the in-core program's abstract output
        # shapes — eval_shape spends no FLOPs and guarantees the chunk
        # accumulators agree with what the in-core fold produces.
        out_shapes = jax.eval_shape(_online, _program_state(state))
        stream = StreamExecutor(
            star=star, state=state, aggregates=aggregates, model=model,
            num_groups=num_groups if q.group_keys else 0,
            fact_desc=fact_desc, chunk_rows=stream_rows,
            out_shapes=out_shapes)
        if use_pool:
            # Tentpole invariant: pooled artifacts a streamed plan shares
            # are dimension-side and flow to every chunk unchanged.
            assert_pool_dimension_side(
                pool, {"arms": arm_refs, "partials": tuple(partial_keys)},
                state, star)

    return CompiledQuery(
        query=q, plan=plan, backend=backend, join_backend=join_backend,
        agg_backend=agg_backend, serve_backend=serve_backend, star=star,
        prefused=prefused, selectivity=sel, group_codes=uniq, _gid=gid,
        _rows=rows, _run=run_fn, _predict=predict_jit,
        _predict_rows=predict_rows_jit, _state=state, catalog=cat0,
        versions={n: cat0.version(n)
                  for n in participating_tables(source_q)},
        _indices=indices, _source=source_q, _opts=opts, _sp=sp,
        _chains=chains,
        _pool=pool if use_pool else None,
        _pool_refs=({"arms": arm_refs, "partials": tuple(partial_keys)}
                    if use_pool else {}),
        _online_fn=_online, _stream=stream, _rewrites=rewrite_trail)


def _make_predict_rows_sharded(star: StarJoin, model,
                               prefused: Optional[PrefusedStar],
                               backend: str, plan: QueryPlan, mesh,
                               shard_axis: str,
                               shard_threshold_bytes: Optional[int]):
    """Sharded serving path: row tables placed on the mesh, one shard_map.

    Returns ``(forward, plan, sharded_state, sp)`` with the per-arm
    placement recorded on the plan.  The FK→row pointers were resolved
    offline (``join_factored``), so the forward uses global-pointer
    device-local gathers (see ``make_predict_rows_forward``); the placed
    arrays live in ``sharded_state`` so ``refresh`` can re-place updated
    rows and re-dispatch without retracing.
    """
    if backend == "fused":
        tables = list(prefused.partials)
        h = prefused.h
    else:
        tables = [d.dim.matrix @ mapping_matrix(d.dim.columns, d.feature_cols)
                  for d in star.dims]
        h = None
    specs, plan = place_tables(mesh, tables, plan, axis=shard_axis,
                               threshold_bytes=shard_threshold_bytes)
    sp = shard_prefused_partials(
        mesh, [(d.fk_col, None, None, tbl)
               for d, tbl in zip(star.dims, tables)],
        h, specs, shard_axis=shard_axis)
    fn = make_predict_rows_forward(sp, model, backend)
    sharded_state = predict_rows_state(
        sp, tables, [fj.ptr for fj in star.joins],
        [fj.found for fj in star.joins], star.row_valid)
    return fn, plan, sharded_state, sp


def _make_predict_rows(star: StarJoin, model, backend: str,
                       serve_backend: str = "jnp",
                       interpret: bool = False):
    """Row-batched prediction: the serving path (fact rows as requests).

    The returned function takes ``(row_ids, state)`` — the quasi-static
    pointers/partials flow from the state pytree so a refresh re-dispatches
    into the same compiled program.
    """
    if backend == "fused" and serve_backend == "pallas":
        def fn(row_ids, state):
            from repro.kernels import fused_star_gather
            v = jnp.take(state["valid"], row_ids)
            ptrs = jnp.stack([jnp.take(p, row_ids)
                              for p in state["ptrs"]])
            found = jnp.stack([jnp.take(f, row_ids)
                               for f in state["founds"]]).astype(jnp.int32)
            out = fused_star_gather(ptrs, found, list(state["partials"]),
                                    state["h"], interpret=interpret)
            return out * v[:, None].astype(out.dtype)
        return fn

    if backend == "fused":
        def fn(row_ids, state):
            v = jnp.take(state["valid"], row_ids)
            acc = None
            for ptr0, found0, part in zip(state["ptrs"], state["founds"],
                                          state["partials"]):
                ptr = jnp.take(ptr0, row_ids)
                hit = jnp.take(found0, row_ids)
                p = jnp.take(part, ptr, axis=0) * hit[:, None].astype(
                    part.dtype)
                acc = p if acc is None else acc + p
            acc = acc * v[:, None].astype(acc.dtype)
            if state["h"] is None:
                return acc
            eq = (acc == state["h"][None, :].astype(acc.dtype))
            return eq.astype(acc.dtype) * v[:, None].astype(acc.dtype)
        return fn

    def fn(row_ids, state):
        v = jnp.take(state["valid"], row_ids)
        parts = []
        for d, mat, ptr0, found0 in zip(star.dims, state["dim_mats"],
                                        state["ptrs"], state["founds"]):
            proj = mat @ mapping_matrix(d.dim.columns, d.feature_cols)
            ptr = jnp.take(ptr0, row_ids)
            hit = jnp.take(found0, row_ids)
            parts.append(jnp.take(proj, ptr, axis=0)
                         * hit[:, None].astype(proj.dtype))
        t = jnp.concatenate(parts, axis=1) * v[:, None].astype(jnp.float32)
        if serve_backend == "pallas" and isinstance(model, DecisionTreeGEMM):
            from repro.kernels import tree_predict
            out = tree_predict(t, model.F, model.v, model.H, model.h,
                               interpret=interpret)
        else:
            out = model.apply(t)
        return out * v[:, None].astype(out.dtype)
    return fn


def query_from_star(star: StarJoin, fact_name: str = None, *,
                    model=None, aggregates: Tuple[Aggregate, ...] = (),
                    group_keys=(), num_groups: int = 8192
                    ) -> Tuple[Dict[str, Table], PredictiveQuery]:
    """Lift an already-resolved ``StarJoin`` into (catalog, PredictiveQuery).

    Convenience for callers holding legacy ``star_join`` outputs (synthetic
    generators, serving): the compiler re-resolves the joins, so the result
    is equivalent to having built the IR directly.
    """
    fact_name = fact_name or star.fact.name
    catalog = {fact_name: star.fact}
    arms = []
    for d in star.dims:
        catalog[d.dim.name] = d.dim
        arms.append(ArmSpec(d.dim.name, d.fk_col, d.pk_col,
                            tuple(d.feature_cols)))
    if not aggregates and model is not None:
        aggregates = (Aggregate(PREDICTION, "sum", "prediction"),)
    return catalog, PredictiveQuery(
        fact=fact_name, arms=tuple(arms), model=model,
        group_keys=tuple(group_keys), aggregates=tuple(aggregates),
        num_groups=num_groups)
