"""Lower a ``PredictiveQuery`` to one jitted XLA program.

Offline (quasi-static, runs once per (query, catalog)):
  1. selection masks on the fact table and each dimension (``Pred``, §2.2),
  2. factored matching matrices per arm (``join_factored``, Alg. 1 / §3.1),
     with dimension-side predicate masks gathered through the FK pointers —
     the selection vector *folded into* the join validity instead of being
     multiplied through the data,
  3. the model's linear prefix pushed into the dimension tables
     (``prefuse``, Eq. 1/3),
  4. composite group codes + dense group ids (§2.4.2),
  5. the whole-query cost model (``plan_query``) choosing fused/nonfused and
     gather/matmul backends from the measured selectivity.

Online (the single jitted program): Σⱼ Iⱼ Pⱼ gathers (+ ``== h`` for trees),
value expressions, and the group-by reduction composed directly on the fused
prediction output — no intermediate table ever materializes on the fused
path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from ..fusion.operators import DecisionTreeGEMM
from ..fusion.pipeline import (PrefusedStar, predict_fused,
                               predict_fused_kernel, predict_fused_matmul,
                               predict_nonfused, predict_nonfused_kernel,
                               predict_nonfused_matmul, prefuse)
from ..laq.aggregation import (auto_num_groups, composite_code,
                               groupby_codes, matmul_aggregate,
                               segment_aggregate, segment_reduce)
from ..laq.join import join_factored
from ..laq.projection import mapping_matrix
from ..laq.selection import select
from ..laq.star import DimSpec, StarJoin
from ..laq.table import Table
from .ir import (AGG_OPS, PREDICTION, Aggregate, ArmSpec, PredictiveQuery,
                 eval_value)
from .planner import (QueryPlan, effective_serve_backend, place_tables,
                      plan_query, resolve_mesh_serve_backend)
from .sharding import make_predict_rows_forward, shard_prefused_partials


@dataclasses.dataclass
class CompiledQuery:
    """An executable plan: one jitted program + its quasi-static artifacts."""

    query: PredictiveQuery
    plan: QueryPlan
    backend: str                    # "fused" | "nonfused"
    join_backend: str               # "gather" | "matmul"
    agg_backend: str                # "segment" | "matmul"
    serve_backend: str              # "jnp" | "pallas"
    star: StarJoin
    prefused: Optional[PrefusedStar]
    selectivity: float              # measured fraction of surviving fact rows
    group_codes: Optional[jnp.ndarray]   # sorted unique composite codes
    _gid: Optional[jnp.ndarray]
    _rows: jnp.ndarray                   # surviving-row count
    _run: callable
    _predict: Optional[callable]
    _predict_rows: Optional[callable]

    @property
    def is_traced(self) -> bool:
        """True when compiled under an outer trace — such a plan holds
        tracers and must not be cached/reused outside that trace."""
        return isinstance(self._rows, jax.core.Tracer)

    def run(self) -> Dict[str, jnp.ndarray]:
        """Execute the query; returns aggregates (+ "groups", "rows")."""
        out = dict(self._run())
        if self.group_codes is not None:
            out["groups"] = self.group_codes
        out["rows"] = self._rows
        return out

    def predictions(self) -> jnp.ndarray:
        """The (fact_capacity, l) prediction matrix (model queries only)."""
        if self._predict is None:
            raise ValueError("query has no model")
        return self._predict()

    def predict_rows(self, row_ids: jnp.ndarray) -> jnp.ndarray:
        """Batched serving: predictions for a batch of fact row ids.

        On the fused backend this is |arms| gathers into the prefused
        partials + adds — the paper's online phase, at request batch size.
        Out-of-range ids follow ``jnp.take`` fill semantics (NaN rows);
        negative ids wrap like numpy.
        """
        if self._predict_rows is None:
            raise ValueError("query has no model")
        return self._predict_rows(row_ids)


def _static_int(x, default: int) -> int:
    """``int(x)`` when concrete, ``default`` when ``x`` is a tracer."""
    try:
        return int(x)
    except jax.errors.ConcretizationTypeError:
        return default


def _resolve_star(catalog: Mapping[str, Table], q: PredictiveQuery
                  ) -> Tuple[StarJoin, jnp.ndarray]:
    """Joins + combined validity with every selection mask folded in."""
    fact = catalog[q.fact]
    valid = fact.valid_mask()
    for p in q.fact_preds:
        valid = valid & p.mask(fact)
    dims, joins = [], []
    for arm in q.arms:
        dim = catalog[arm.table]
        dims.append(DimSpec(dim, arm.fk_col, arm.pk_col, arm.feature_cols))
        fj = join_factored(fact.key(arm.fk_col), dim.key(arm.pk_col))
        ok = fj.found
        if arm.preds:
            dmask = arm.preds[0].mask(dim)
            for p in arm.preds[1:]:
                dmask = dmask & p.mask(dim)
            ok = ok & jnp.take(dmask, fj.ptr)
        joins.append(fj)
        valid = valid & ok
    star = StarJoin(fact=fact, dims=tuple(dims), joins=tuple(joins),
                    row_valid=valid)
    return star, valid


def _group_columns(catalog: Mapping[str, Table], q: PredictiveQuery,
                   star: StarJoin):
    """Exact int32 group-key columns, gathered through the arm pointers."""
    arm_ptr = {a.table: fj.ptr for a, fj in zip(q.arms, star.joins)}
    cols, bounds = [], []
    for gk in q.group_keys:
        if gk.table == "fact":
            c = star.fact.key(gk.col)
        else:
            c = jnp.take(catalog[gk.table].key(gk.col), arm_ptr[gk.table])
        cols.append(c - jnp.int32(gk.offset))
        bounds.append(gk.bound)
    return cols, bounds


def _check_aggregates(q: PredictiveQuery):
    if not q.aggregates:
        raise ValueError("query has no aggregates")
    names = [a.name for a in q.aggregates]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate aggregate names {names}: each "
                         "aggregate needs a distinct result column name")
    reserved = {"rows", "groups"} & set(names)
    if reserved:
        raise ValueError(f"aggregate names {sorted(reserved)} collide with "
                         "the reserved result keys 'rows'/'groups'")
    for agg in q.aggregates:
        if agg.op not in AGG_OPS:
            raise ValueError(
                f"aggregate op {agg.op!r} (aggregate {agg.name!r}) not one "
                f"of {list(AGG_OPS)}")
        if agg.value == PREDICTION and q.model is None:
            raise ValueError("PREDICTION aggregate requires a model")


def compile_query(catalog: Mapping[str, Table], q: PredictiveQuery, *,
                  backend: str = "auto", join_backend: str = "auto",
                  agg_backend: str = "auto", serve_backend: str = "auto",
                  select_capacity: Optional[int] = None,
                  batches_per_update: float = 1000.0,
                  memory_budget_bytes: Optional[int] = None,
                  interpret: bool = False, mesh=None,
                  shard_axis: str = "model",
                  shard_threshold_bytes: Optional[int] = None
                  ) -> CompiledQuery:
    """Plan + lower ``q`` against ``catalog`` into one jitted program.

    All of ``q.aggregates`` lower into that one program over the shared
    join/model work: ``sum``/``count``/``mean``/``min``/``max``, with mean
    as a fused sum/count (one count reduction shared across every
    count/mean aggregate) and min/max through segment ops on either
    aggregation backend.  ``q.num_groups == "auto"`` sizes the group
    dimension from the measured live code domain (offline concrete path
    only — see :func:`~repro.core.laq.aggregation.auto_num_groups`).

    ``backend`` / ``join_backend`` / ``agg_backend`` override the planner
    ("auto" defers to the cost model); explicit "matmul" backends give the
    paper-faithful reference lowering used by tests and benchmarks.
    ``serve_backend`` picks the physical kernel for the *serving* paths —
    ``predict_rows`` always, and ``predictions`` when the join backend is
    "gather" (the dense "matmul" join is its own paper-faithful lowering):
    "pallas" lowers the fused gather-sum onto ``fused_star_gather`` and
    non-fused trees onto ``tree_predict`` ("auto" picks it on TPU when the
    shapes fit the block specs); ``interpret=True`` runs the kernels in
    interpret mode so the lowering is testable on CPU.

    ``select_capacity`` applies the fact predicates by ``mask_select``
    compaction (§2.2) *before* the joins: surviving rows are packed into a
    fixed buffer of that many rows, shrinking every online shape — the right
    call for very selective queries.  Row ids seen by ``predict_rows`` then
    index the compacted table.

    ``mesh`` shards the *serving* path: each arm's quasi-static row table
    (prefused partial / projected features) is placed per
    ``plan_partition_spec`` and ``predict_rows`` becomes one ``shard_map``
    of device-local gathers + a psum (``core.query.sharding``), bit-exact
    vs the single-device program.  The whole-query aggregate program
    (``run``/``predictions``) stays single-device — it is fact-sized, not
    partial-sized.  ``mesh`` is incompatible with ``serve_backend="pallas"``.
    """
    for arg, allowed in ((backend, ("auto", "fused", "nonfused")),
                         (join_backend, ("auto", "gather", "matmul")),
                         (agg_backend, ("auto", "segment", "matmul")),
                         (serve_backend, ("auto", "jnp", "pallas"))):
        if arg not in allowed:
            raise ValueError(f"backend {arg!r} not one of {allowed}")
    serve_backend = resolve_mesh_serve_backend(serve_backend, mesh)
    _check_aggregates(q)
    if select_capacity is not None:
        fact = select(catalog[q.fact], q.fact_preds,
                      capacity=select_capacity)
        catalog = {**catalog, q.fact: fact}
        q = dataclasses.replace(q, fact_preds=())
    star, valid = _resolve_star(catalog, q)
    fact = star.fact
    rows = jnp.sum(valid.astype(jnp.int32))
    # Offline compilation measures selectivity from the data; when a caller
    # traces compile_query itself (whole pipeline under one outer jit), the
    # counts are abstract — plan with static shapes and selectivity 1.
    n_fact = _static_int(fact.nvalid, fact.capacity)
    try:
        sel = float(rows) / max(n_fact, 1)
    except jax.errors.ConcretizationTypeError:
        sel = 1.0

    # Group codes resolve before planning so ``num_groups="auto"`` can size
    # the group dimension from the measured code domain (the codes are
    # concrete on the offline path) and feed the planner the real G.
    codes = None
    n_live = None
    if q.group_keys:
        cols, bounds = _group_columns(catalog, q, star)
        codes = composite_code(cols, bounds, valid)
        if q.num_groups == "auto":
            n_live = auto_num_groups(codes)
            q = dataclasses.replace(q, num_groups=n_live)
    elif q.num_groups == "auto":
        q = dataclasses.replace(
            q, num_groups=PredictiveQuery.__dataclass_fields__[
                "num_groups"].default)

    out_width = q.model.l if q.model is not None else 1
    # The planner's selectivity term models mask_select compaction (§2.2):
    # online shapes only actually shrink when ``select_capacity`` compacted
    # the fact table (already reflected in n_fact).  The default lowering
    # masks without compacting, so its online cost stays at full capacity —
    # feeding the measured selectivity in would optimize a plan shape that
    # is not the one being executed.
    plan = plan_query(q.model, n_fact,
                      [_static_int(d.dim.nvalid, d.dim.capacity)
                       for d in star.dims],
                      selectivity=1.0,
                      num_groups=q.num_groups if q.group_keys else 0,
                      out_width=out_width,
                      agg_ops=tuple(a.op for a in q.aggregates),
                      batches_per_update=batches_per_update,
                      memory_budget_bytes=memory_budget_bytes)
    backend = plan.backend if backend == "auto" else backend
    join_backend = plan.join_backend if join_backend == "auto" else join_backend
    agg_backend = ((plan.agg.backend if plan.agg else "segment")
                   if agg_backend == "auto" else agg_backend)
    serve_backend = effective_serve_backend(plan, serve_backend, backend,
                                            q.model, len(star.dims))
    if serve_backend != plan.serve_backend:
        plan = dataclasses.replace(
            plan, serve_backend=serve_backend,
            reason=f"{plan.reason}; serve={serve_backend} (caller override)")

    prefused = None
    if q.model is not None and backend == "fused":
        prefused = prefuse(star, q.model)

    uniq = gid = None
    if q.group_keys:
        uniq, gid = groupby_codes(codes, q.num_groups, n_live=n_live)

    reduce_fn = (matmul_aggregate if agg_backend == "matmul"
                 else segment_aggregate)

    def _predictions():
        if backend == "fused":
            if join_backend != "gather":
                return predict_fused_matmul(star, prefused)
            if serve_backend == "pallas":
                return predict_fused_kernel(star, prefused,
                                            interpret=interpret)
            return predict_fused(star, prefused)
        if join_backend != "gather":
            return predict_nonfused_matmul(star, q.model)
        if serve_backend == "pallas":   # resolve_ guarantees a tree model
            return predict_nonfused_kernel(star, q.model,
                                           interpret=interpret)
        return predict_nonfused(star, q.model)

    def _agg_values(agg, pred):
        """Per-row values for one aggregate (sum-masked for additive ops)."""
        if agg.value == PREDICTION:
            return pred                          # already validity-masked
        vals = eval_value(fact, agg.value,
                          query=f"{agg.name!r} on {q.fact!r}")
        if agg.op in ("min", "max"):
            return vals       # invalid rows are masked by gid / ±inf below
        return jnp.where(valid, vals, 0.0)

    def _online():
        pred = _predictions() if q.model is not None else None
        out = {}
        # One shared count reduction backs every count/mean aggregate.
        count = None
        if any(a.op in ("count", "mean") for a in q.aggregates):
            ones = valid.astype(jnp.float32)
            count = (reduce_fn(gid, ones, q.num_groups) if gid is not None
                     else jnp.sum(ones))
        for agg in q.aggregates:
            if agg.op == "count":
                out[agg.name] = count
                continue
            vals = _agg_values(agg, pred)
            if gid is not None:
                if agg.op in ("min", "max"):
                    # Invalid rows sit in the dropped overflow segment, so
                    # no value masking is needed; min/max lower through
                    # segment ops on both aggregation backends (Fig. 4's
                    # one-hot matmul is additive-only).
                    out[agg.name] = segment_reduce(gid, vals, q.num_groups,
                                                   agg.op)
                elif agg.op == "mean":
                    s = reduce_fn(gid, vals, q.num_groups)
                    c = jnp.maximum(count, 1.0)
                    out[agg.name] = s / (c[:, None] if s.ndim > 1 else c)
                else:
                    out[agg.name] = reduce_fn(gid, vals, q.num_groups)
            elif agg.op in ("min", "max"):
                fill = jnp.inf if agg.op == "min" else -jnp.inf
                mask = valid[:, None] if vals.ndim > 1 else valid
                r = (jnp.min if agg.op == "min" else jnp.max)(
                    jnp.where(mask, vals, fill), axis=0)
                out[agg.name] = jnp.where(jnp.isfinite(r), r, 0.0)
            elif agg.op == "mean":
                out[agg.name] = (jnp.sum(vals, axis=0)
                                 / jnp.maximum(count, 1.0))
            else:
                out[agg.name] = jnp.sum(vals, axis=0)
        return out

    predict_jit = predict_rows_jit = None
    if q.model is not None:
        predict_jit = jax.jit(_predictions)
        if mesh is not None:
            fn, plan = _make_predict_rows_sharded(
                star, q.model, prefused, backend, plan, mesh, shard_axis,
                shard_threshold_bytes)
            predict_rows_jit = jax.jit(fn)
        else:
            predict_rows_jit = jax.jit(
                _make_predict_rows(star, q.model, prefused, backend,
                                   serve_backend, interpret))

    return CompiledQuery(
        query=q, plan=plan, backend=backend, join_backend=join_backend,
        agg_backend=agg_backend, serve_backend=serve_backend, star=star,
        prefused=prefused, selectivity=sel, group_codes=uniq, _gid=gid,
        _rows=rows, _run=jax.jit(_online), _predict=predict_jit,
        _predict_rows=predict_rows_jit)


def _make_predict_rows_sharded(star: StarJoin, model,
                               prefused: Optional[PrefusedStar],
                               backend: str, plan: QueryPlan, mesh,
                               shard_axis: str,
                               shard_threshold_bytes: Optional[int]):
    """Sharded serving path: row tables placed on the mesh, one shard_map.

    Returns ``(predict_rows_fn, plan)`` with the per-arm placement recorded
    on the plan.  The FK→row pointers were resolved offline
    (``join_factored``), so the forward uses global-pointer device-local
    gathers (see ``make_predict_rows_forward``).
    """
    if backend == "fused":
        tables = list(prefused.partials)
        h = prefused.h
    else:
        tables = [d.dim.matrix @ mapping_matrix(d.dim.columns, d.feature_cols)
                  for d in star.dims]
        h = None
    specs, plan = place_tables(mesh, tables, plan, axis=shard_axis,
                               threshold_bytes=shard_threshold_bytes)
    sp = shard_prefused_partials(
        mesh, [(d.fk_col, None, None, tbl)
               for d, tbl in zip(star.dims, tables)],
        h, specs, shard_axis=shard_axis)
    fn = make_predict_rows_forward(
        sp, model, backend, [fj.ptr for fj in star.joins],
        [fj.found for fj in star.joins], star.row_valid)
    return fn, plan


def _make_predict_rows(star: StarJoin, model, prefused: Optional[PrefusedStar],
                       backend: str, serve_backend: str = "jnp",
                       interpret: bool = False):
    """Row-batched prediction: the serving path (fact rows as requests)."""
    if backend == "fused" and serve_backend == "pallas":
        def fn(row_ids):
            from repro.kernels import fused_star_gather
            v = jnp.take(star.row_valid, row_ids)
            ptrs = jnp.stack([jnp.take(fj.ptr, row_ids)
                              for fj in star.joins])
            found = jnp.stack([jnp.take(fj.found, row_ids)
                               for fj in star.joins]).astype(jnp.int32)
            out = fused_star_gather(ptrs, found, list(prefused.partials),
                                    prefused.h, interpret=interpret)
            return out * v[:, None].astype(out.dtype)
        return fn

    if backend == "fused":
        def fn(row_ids):
            v = jnp.take(star.row_valid, row_ids)
            acc = None
            for fj, part in zip(star.joins, prefused.partials):
                ptr = jnp.take(fj.ptr, row_ids)
                hit = jnp.take(fj.found, row_ids)
                p = jnp.take(part, ptr, axis=0) * hit[:, None].astype(
                    part.dtype)
                acc = p if acc is None else acc + p
            acc = acc * v[:, None].astype(acc.dtype)
            if prefused.h is None:
                return acc
            eq = (acc == prefused.h[None, :].astype(acc.dtype))
            return eq.astype(acc.dtype) * v[:, None].astype(acc.dtype)
        return fn

    def fn(row_ids):
        v = jnp.take(star.row_valid, row_ids)
        parts = []
        for d, fj in zip(star.dims, star.joins):
            proj = d.dim.matrix @ mapping_matrix(d.dim.columns,
                                                 d.feature_cols)
            ptr = jnp.take(fj.ptr, row_ids)
            hit = jnp.take(fj.found, row_ids)
            parts.append(jnp.take(proj, ptr, axis=0)
                         * hit[:, None].astype(proj.dtype))
        t = jnp.concatenate(parts, axis=1) * v[:, None].astype(jnp.float32)
        if serve_backend == "pallas" and isinstance(model, DecisionTreeGEMM):
            from repro.kernels import tree_predict
            out = tree_predict(t, model.F, model.v, model.H, model.h,
                               interpret=interpret)
        else:
            out = model.apply(t)
        return out * v[:, None].astype(out.dtype)
    return fn


def query_from_star(star: StarJoin, fact_name: str = None, *,
                    model=None, aggregates: Tuple[Aggregate, ...] = (),
                    group_keys=(), num_groups: int = 8192
                    ) -> Tuple[Dict[str, Table], PredictiveQuery]:
    """Lift an already-resolved ``StarJoin`` into (catalog, PredictiveQuery).

    Convenience for callers holding legacy ``star_join`` outputs (synthetic
    generators, serving): the compiler re-resolves the joins, so the result
    is equivalent to having built the IR directly.
    """
    fact_name = fact_name or star.fact.name
    catalog = {fact_name: star.fact}
    arms = []
    for d in star.dims:
        catalog[d.dim.name] = d.dim
        arms.append(ArmSpec(d.dim.name, d.fk_col, d.pk_col,
                            tuple(d.feature_cols)))
    if not aggregates and model is not None:
        aggregates = (Aggregate(PREDICTION, "sum", "prediction"),)
    return catalog, PredictiveQuery(
        fact=fact_name, arms=tuple(arms), model=model,
        group_keys=tuple(group_keys), aggregates=tuple(aggregates),
        num_groups=num_groups)
