"""Multi-query optimizer: shared artifacts across compiled plans (ROADMAP
"Cross-query optimization").

The registry runs 17+ queries that each independently materialize the same
quasi-static artifacts: most share star arms, so most recompute the same PK
sort, the same fact-sized FK probe, the same dimension predicate mask, and
(per model prefix) the same Eq. 1 prefused partial.  This module makes that
work shareable at plan time:

Arm-level content keys
    :func:`query_key` hashes whole queries; the functions here hash the
    *pieces a single arm contributes* — ``("pkindex", table, pk_col)``,
    ``("join", fact, fk_col, table, pk_col)``, ``("dmask", table, preds)``,
    ``("features", table, feature_cols)`` and ``("partial", ...)`` keyed by
    the model-prefix slice content — so two different queries sharing a
    (table, model-prefix, predicate) arm resolve to the same artifact keys
    even when the rest of their plans differ.

``ArtifactPool``
    A reference-counted store of those artifacts, owned by a ``Session``
    and bound to its :class:`~repro.core.laq.Catalog`.  ``acquire_*``
    computes on miss and hands back shared arrays on hit (bit-identical by
    construction: hits are the output of the very computation the cold path
    would run); ``release`` drops references and evicts at zero.  Every
    entry records the catalog versions it was built against and refreshes
    *lazily, exactly once* when fetched stale — N plans referencing one
    artifact pay one delta update between them, which is what makes
    ``Session.refresh()`` O(distinct artifacts) instead of O(plans) for the
    shared quasi-static work.  The delta math per kind mirrors the
    unpooled refresh paths (``PKIndex.extend`` sorted merges, appended-key
    block probes, ``prefuse_rows`` over dirty rows, mask scatters) so a
    pooled refresh stays bit-exact vs a cold rebuild.

Batched multi-query execution
    :func:`stack_key` classifies compiled plans into structural
    compatibility classes (same fact/arm shapes, backends, aggregate list,
    group dimension and state-pytree signature — predicates and group
    bounds live in the state, not the program); :func:`make_stacked_runner`
    vmaps one plan's online program over a leading query axis so
    ``Session.run_all`` executes a whole class as one jitted dispatch.

No compile/serving/session imports happen at module top level (those
modules receive the pool as an opaque argument), keeping the dependency
graph acyclic: ``session → {compile, serving, multiquery}``.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import (Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from ..fusion.operators import DecisionTreeGEMM, LinearOperator
from ..fusion.pipeline import _feature_slices, prefuse_dims, prefuse_rows
from ..laq.catalog import Catalog, CatalogHistoryError, changed_spans
from ..laq.join import FactoredJoin, PKIndex, pk_index
from ..laq.projection import mapping_matrix
from ..laq.star import DimSpec
from ..laq.table import PAD_KEY, Table
from .ir import ArmSpec, Model, PredictiveQuery
from .snowflake import (CollapsedChain, chain_dirty_heads, chain_key,
                        chain_tables, participating_tables, qualified_cols,
                        refresh_chain, resolve_chain, virtual_name)


# --------------------------------------------------------------------------
# Content hashing (models by array bytes)
# --------------------------------------------------------------------------
def _array_key(a) -> tuple:
    arr = np.asarray(a)
    return (arr.shape, arr.dtype.str,
            hashlib.blake2b(arr.tobytes(), digest_size=16).hexdigest())


def model_key(model: Optional[Model]):
    """Content key for a model head; falls back to identity under a trace."""
    if model is None:
        return None
    try:
        if isinstance(model, LinearOperator):
            return ("linear", _array_key(model.L),
                    None if model.bias is None else _array_key(model.bias))
        if isinstance(model, DecisionTreeGEMM):
            return ("tree", _array_key(model.F), _array_key(model.v),
                    _array_key(model.H), _array_key(model.h))
    except (jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        pass
    return ("id", type(model).__name__, id(model))


def _digest(a) -> str:
    arr = np.asarray(a)
    return hashlib.blake2b(
        arr.tobytes() + repr((arr.shape, arr.dtype.str)).encode(),
        digest_size=16).hexdigest()


# --------------------------------------------------------------------------
# Arm-level artifact keys
# --------------------------------------------------------------------------
def pkindex_key(table: str, pk_col: str) -> tuple:
    return ("pkindex", table, pk_col)


def join_key(fact: str, fk_col: str, table: str, pk_col: str) -> tuple:
    return ("join", fact, fk_col, table, pk_col)


def dmask_key(table: str, preds: tuple) -> tuple:
    return ("dmask", table, tuple(preds))


def features_key(table: str, feature_cols: Sequence[str]) -> tuple:
    return ("features", table, tuple(feature_cols))


def partial_key(table: str, feature_cols: Sequence[str], model: Model,
                lo: int, hi: int, j: int = 0) -> tuple:
    """Content key of one arm's Eq. 1/3 prefused partial.

    Linear heads: the partial is ``B_j @ L[lo:hi]`` (the one-hot mapping
    matmul reproduces the slice exactly in fp32), so only the *slice
    content* keys it — two queries placing the same arm at different
    feature offsets still share, as long as their L rows there agree.
    A folded constant bias (rewrite rule) is carried by arm 0's partial,
    so that arm's key pins the bias bytes too.  Tree heads additionally
    depend on the node-ownership mask, which reads the argmax over the
    **full** F, so the key pins (lo, hi) and all of F/v/H.
    """
    if isinstance(model, LinearOperator):
        bias = ()
        if j == 0 and model.bias is not None:
            bias = (("bias", _digest(model.bias)),)
        return ("partial", "linear", table, tuple(feature_cols),
                _digest(np.asarray(model.L)[lo:hi])) + bias
    return ("partial", "tree", table, tuple(feature_cols), int(lo), int(hi),
            _digest(model.F), _digest(model.v), _digest(model.H))


def arm_keys(q: PredictiveQuery) -> Tuple[Tuple[tuple, ...], ...]:
    """Per-arm artifact key sets — the common-subplan signature of ``q``.

    For each arm, the keys of every poolable artifact the arm contributes:
    PK index, FK join probe, predicate mask (when predicated) and model
    partial (when ``q`` has a model).  Two queries share offline work
    exactly where these sets intersect.
    """
    slices = [(0, 0)] * len(q.arms)
    if q.model is not None:
        off = 0
        slices = []
        for arm in q.arms:
            slices.append((off, off + arm.feature_width))
            off += arm.feature_width
    out = []
    for j, (arm, (lo, hi)) in enumerate(zip(q.arms, slices)):
        # Chained arms index/probe against the real head table (shared with
        # flat arms over the same head); the chain collapse and its partial
        # are keyed by the full chain content.
        keys = [pkindex_key(arm.table, arm.pk_col),
                join_key(q.fact, arm.fk_col, arm.table, arm.pk_col)]
        if arm.links:
            keys.append(chain_key(arm))
        elif arm.preds:
            keys.append(dmask_key(arm.table, arm.preds))
        if q.model is not None:
            if arm.links:
                keys.append(partial_key(virtual_name(arm),
                                        qualified_cols(arm), q.model,
                                        lo, hi, j) + (chain_key(arm),))
            else:
                keys.append(partial_key(arm.table, arm.feature_cols,
                                        q.model, lo, hi, j))
        out.append(tuple(keys))
    return tuple(out)


def holds_tracers(catalog, q: PredictiveQuery) -> bool:
    """True when ``q``'s tables or model hold tracers (compile under an
    outer jit).

    Pooled artifacts must be concrete — a cached tracer would leak out of
    its trace, and content keys need ``tobytes()`` — so tracing callers
    bypass the pool entirely.
    """
    tracer = jax.core.Tracer
    for name in participating_tables(q):
        t = catalog[name]
        if isinstance(t.matrix, tracer) or isinstance(t.nvalid, tracer):
            return True
        if any(isinstance(v, tracer) for v in t.keys.values()):
            return True
    if q.model is not None:
        arrays = ((q.model.F, q.model.v, q.model.H)
                  if isinstance(q.model, DecisionTreeGEMM)
                  else (q.model.L,))
        if any(isinstance(a, tracer) for a in arrays):
            return True
    return False


def _mask_rows(dim: Table, preds, ids: np.ndarray) -> jnp.ndarray:
    """Dim-predicate mask on just the (live) rows ``ids``.

    Identical math to the serving runtime's delta-mask helper — the pool's
    scatter refresh must agree bitwise with the unpooled delta path.
    """
    sub = Table(dim.name, dim.columns,
                jnp.take(dim.matrix, jnp.asarray(ids), axis=0),
                {c: jnp.take(v, jnp.asarray(ids))
                 for c, v in dim.keys.items()},
                int(ids.shape[0]))
    # Liveness comes from the *parent* table: the sub-table is fully
    # "valid" by construction, so tombstones must be gathered explicitly.
    m = jnp.take(dim.valid_mask(), jnp.asarray(ids))
    for p in preds:
        m = m & p.mask(sub)
    return m


# --------------------------------------------------------------------------
# The pool
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _PoolEntry:
    """One shared artifact: value + versions + refcount + update counter."""

    key: tuple
    kind: str
    value: object
    versions: Dict[str, int]     # gating tables → catalog version at build
    spec: Dict                   # kind-specific refresh context
    refcount: int = 0
    updates: int = 0             # delta/cold refreshes applied in place

    def nbytes(self) -> int:
        return sum(int(a.size) * a.dtype.itemsize
                   for a in _entry_arrays(self.value))


def _entry_arrays(value) -> List:
    if isinstance(value, PKIndex):
        return [value.sorted_pk, value.order]
    if isinstance(value, CollapsedChain):
        arrs = [value.table.matrix, value.dmask]
        for _name, ptr, found in value.link_ptrs:
            arrs.extend([ptr, found])
        for h in value.hops:
            if h is not None:
                arrs.extend([h.ptr, h.found])
        return arrs
    if isinstance(value, tuple):
        return [v for v in value if v is not None]
    return [value] if value is not None else []


class ArtifactPool:
    """Reference-counted shared quasi-static artifacts for one catalog.

    ``acquire_*`` methods return ``(value, key)`` and take a reference;
    :meth:`get` is the non-refcounting fetch used by plan refresh paths
    (the plan already holds its reference — refetching must not leak
    counts).  Both refresh a stale entry first, exactly once per catalog
    version change no matter how many plans reference it.  :meth:`release`
    drops references and evicts entries nothing points at.
    """

    def __init__(self, catalog):
        self.catalog: Catalog = Catalog.wrap(catalog)
        self._entries: Dict[tuple, _PoolEntry] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- core entry lifecycle ------------------------------------------------
    def _fresh(self, key: tuple, kind: str, tables: Tuple[str, ...],
               build: Callable[[], object], spec: Dict) -> _PoolEntry:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            entry = _PoolEntry(
                key=key, kind=kind, value=build(),
                versions={n: self.catalog.version(n) for n in tables},
                spec=dict(spec))
            self._entries[key] = entry
        else:
            self.hits += 1
            self._refresh_entry(entry)
        return entry

    def get(self, key: tuple):
        """The entry's current value, refreshed if stale (no refcount)."""
        entry = self._entries[key]
        self._refresh_entry(entry)
        return entry.value

    def release(self, keys: Sequence[tuple]) -> int:
        """Drop one reference per key; evict entries reaching zero.

        ``keys`` is the exact multiset the owner acquired (duplicates drop
        multiple references).  Returns the number of evictions.
        """
        evicted = 0
        work = list(keys)
        while work:
            key = work.pop()
            entry = self._entries.get(key)
            if entry is None:
                continue
            entry.refcount -= 1
            if entry.refcount <= 0:
                del self._entries[key]
                evicted += 1
                # Chains hold one reference on each pooled hop probe;
                # evicting the chain drops those too.
                work.extend(entry.spec.get("hops", ()))
        self.evictions += evicted
        return evicted

    def refcount(self, key: tuple) -> int:
        entry = self._entries.get(key)
        return entry.refcount if entry is not None else 0

    def update_count(self, key: tuple) -> int:
        entry = self._entries.get(key)
        return entry.updates if entry is not None else 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        return key in self._entries

    def keys(self):
        return self._entries.keys()

    def stats(self) -> Dict:
        """Pool-wide counters: entries/hits/misses/evictions/updates/bytes
        plus a per-kind entry count."""
        by_kind: Dict[str, int] = collections.Counter(
            e.kind for e in self._entries.values())
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "updates": sum(e.updates for e in self._entries.values()),
            "bytes": sum(e.nbytes() for e in self._entries.values()),
            "by_kind": dict(by_kind),
        }

    def sharing_hint(self, fact: str, arms) -> float:
        """How many plans already share ``(fact, arms)``'s join artifacts.

        Feeds the planner's prefuse amortization: a partial referenced by N
        plans amortizes its build cost over N times the batches.  1.0 when
        nothing is shared yet.
        """
        counts = [self._entries[k].refcount for arm in arms
                  for k in (join_key(fact, arm.fk_col, arm.table,
                                     arm.pk_col),)
                  if k in self._entries]
        return 1.0 + float(max(counts)) if counts else 1.0

    # -- acquire: PK index ---------------------------------------------------
    def _pkindex_entry(self, table: str, pk_col: str) -> _PoolEntry:
        return self._fresh(
            pkindex_key(table, pk_col), "pkindex", (table,),
            lambda: pk_index(self.catalog[table].key(pk_col)),
            {"table": table, "pk_col": pk_col})

    def acquire_pkindex(self, table: str, pk_col: str
                        ) -> Tuple[PKIndex, tuple]:
        entry = self._pkindex_entry(table, pk_col)
        entry.refcount += 1
        return entry.value, entry.key

    # -- acquire: FK join probe ---------------------------------------------
    def acquire_join(self, fact: str, fk_col: str, table: str, pk_col: str
                     ) -> Tuple[Tuple[jnp.ndarray, jnp.ndarray], tuple]:
        """The fact-sized ``(ptr, found)`` probe of one arm — the dominant
        shared artifact (and offline cost) across the registry."""
        def build():
            idx = self._pkindex_entry(table, pk_col).value
            fj = idx.probe(self.catalog[fact].key(fk_col))
            return (fj.ptr, fj.found)
        entry = self._fresh(
            join_key(fact, fk_col, table, pk_col), "join", (fact, table),
            build, {"fact": fact, "fk_col": fk_col, "table": table,
                    "pk_col": pk_col})
        entry.refcount += 1
        return entry.value, entry.key

    # -- acquire: dimension predicate mask ----------------------------------
    def _build_dmask(self, table: str, preds) -> jnp.ndarray:
        dim = self.catalog[table]
        m = dim.valid_mask()
        for p in preds:
            m = m & p.mask(dim)
        return m

    def acquire_dmask(self, table: str, preds
                      ) -> Tuple[jnp.ndarray, tuple]:
        """Row liveness ∧ dimension predicates, in dimension-row order.

        ``Pred.mask`` folds the validity mask itself, so this value is
        boolean-identical on the compile path (which ANDs bare pred masks)
        and the serving path (which ANDs validity explicitly).
        """
        preds = tuple(preds)
        entry = self._fresh(
            dmask_key(table, preds), "dmask", (table,),
            lambda: self._build_dmask(table, preds),
            {"table": table, "preds": preds})
        entry.refcount += 1
        return entry.value, entry.key

    # -- acquire: projected feature tables (nonfused serving) ----------------
    def acquire_features(self, table: str, feature_cols: Sequence[str]
                         ) -> Tuple[jnp.ndarray, tuple]:
        feature_cols = tuple(feature_cols)

        def build():
            dim = self.catalog[table]
            return dim.matrix @ mapping_matrix(dim.columns, feature_cols)
        entry = self._fresh(
            features_key(table, feature_cols), "features", (table,),
            build, {"table": table, "feature_cols": feature_cols})
        entry.refcount += 1
        return entry.value, entry.key

    # -- acquire: collapsed snowflake chains ----------------------------------
    def acquire_chain(self, arm: ArmSpec, *, keep_hops: int = 0
                      ) -> Tuple[CollapsedChain, tuple]:
        """The collapsed chain of one multi-hop arm (see ``snowflake``).

        Keyed by the full chain content (head, hop keys, features, preds),
        gated on every chain table's version.  ``keep_hops`` is a
        refresh-speed hint only — it never changes the collapsed values —
        so plans that disagree on it still share one entry (first build
        wins).

        Each hop's parent→link probe is itself pooled at hop granularity
        (the ``join`` kind, parent table as the probing side): two chains
        sharing a prefix — or a flat arm probing the same link — reuse one
        probe entry instead of recomputing it per chain.  The chain holds
        a reference on each hop key (recorded in ``spec["hops"]``);
        :meth:`release` drops them when the chain is evicted.
        """
        key = chain_key(arm)
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            hop_keys: list = []

            def hop_source(parent, lk):
                _, ik = self.acquire_pkindex(lk.table, lk.pk_col)
                (ptr, found), k = self.acquire_join(
                    parent, lk.fk_col, lk.table, lk.pk_col)
                hop_keys.extend((k, ik))
                return FactoredJoin(ptr, found)

            value = resolve_chain(self.catalog, arm, keep_hops=keep_hops,
                                  hop_source=hop_source)
            entry = _PoolEntry(
                key=key, kind="chain", value=value,
                versions={n: self.catalog.version(n)
                          for n in chain_tables(arm)},
                spec={"arm": arm, "keep_hops": keep_hops,
                      "hops": tuple(hop_keys)})
            self._entries[key] = entry
        else:
            self.hits += 1
            self._refresh_entry(entry)
        entry.refcount += 1
        return entry.value, entry.key

    # -- acquire: prefused partials (one prefuse_dims per miss set) ----------
    def acquire_partials(self, dims: Sequence[DimSpec], model: Model,
                         chains: Sequence[Optional[CollapsedChain]] = ()
                         ) -> Tuple[Tuple[jnp.ndarray, ...],
                                    Optional[jnp.ndarray],
                                    Tuple[tuple, ...]]:
        """Eq. 1/3 partials for a whole arm list: ``(partials, h, keys)``.

        Misses are computed by ONE :func:`prefuse_dims` call over the full
        list — exactly the computation the unpooled compile runs, so hits
        handed back from the pool are bit-identical to what that call
        would have produced for them.

        ``chains`` marks which dims are collapsed snowflake chains (parallel
        to ``dims``; None entries are flat).  A chained partial's key
        carries the chain's content key — the virtual table *name* alone
        would alias chains over the same tables with different hop keys —
        and its refresh gates on every chain table.
        """
        chains = tuple(chains) + (None,) * (len(dims) - len(chains))
        slices = _feature_slices(dims)
        keys, arm_specs = [], []
        for j, (d, (lo, hi), cc) in enumerate(zip(dims, slices, chains)):
            k = partial_key(d.dim.name, d.feature_cols, model, lo, hi, j)
            if cc is not None:
                k = k + (chain_key(cc.arm),)
                arm_specs.append(cc.arm)
            else:
                arm_specs.append((d.dim.name, d.fk_col, d.pk_col,
                                  tuple(d.feature_cols)))
            keys.append(k)
        keys = tuple(keys)
        arm_specs = tuple(arm_specs)
        pre = (prefuse_dims(dims, model)
               if any(k not in self._entries for k in keys) else None)
        parts = []
        for j, (d, key, cc) in enumerate(zip(dims, keys, chains)):
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                gates = (chain_tables(cc.arm) if cc is not None
                         else (d.dim.name,))
                entry = _PoolEntry(
                    key=key, kind="partial", value=pre.partials[j],
                    versions={n: self.catalog.version(n) for n in gates},
                    spec={"arms": arm_specs, "j": j, "model": model})
                self._entries[key] = entry
            else:
                self.hits += 1
                self._refresh_entry(entry)
            entry.refcount += 1
            parts.append(entry.value)
        h = model.h if isinstance(model, DecisionTreeGEMM) else None
        return tuple(parts), h, keys

    # -- lazy, exactly-once refresh ------------------------------------------
    def _refresh_entry(self, entry: _PoolEntry) -> None:
        stale = self.catalog.stale_tables(entry.versions)
        if not stale:
            return
        refresh = getattr(self, f"_refresh_{entry.kind}")
        try:
            deltas = {n: self.catalog.deltas_since(n, entry.versions[n])
                      for n in stale}
            if any(d and changed_spans(d)[2] for d in deltas.values()):
                raise CatalogHistoryError("capacity growth: cold rebuild")
            refresh(entry, deltas)
        except CatalogHistoryError:
            # Staler than the delta log, or shapes changed: rebuild cold.
            # Growth-driven rebuilds change array shapes, which is safe —
            # every referencing plan recompiles on growth before reading.
            entry.value = getattr(self, f"_rebuild_{entry.kind}")(entry)
        entry.versions = {n: self.catalog.version(n)
                          for n in entry.versions}
        entry.updates += 1

    @staticmethod
    def _touched_ids(deltas) -> Optional[np.ndarray]:
        span, dirty, _, deleted = changed_spans(deltas)
        ids = set(dirty) | set(deleted)
        if span is not None:
            ids.update(range(span[0], span[1]))
        return np.asarray(sorted(ids), np.int32) if ids else None

    @staticmethod
    def _pad_ids(ids: np.ndarray) -> np.ndarray:
        """Pad a dirty-row id list up to a power-of-two length.

        Scatter refreshes (``value.at[ids].set(rows)``) specialize the
        jitted update on ``len(ids)``; successive appends rarely dirty the
        exact same number of rows, so every refresh would recompile.
        Padding repeats ``ids[0]`` — duplicate scatter indices carry
        *identical* row values, so the update stays deterministic and
        bit-exact while the shape lands in one of log₂ buckets.
        """
        n = len(ids)
        cap = 1 << max(3, int(np.ceil(np.log2(max(n, 1)))))
        if n == cap:
            return ids
        return np.concatenate(
            [ids, np.full(cap - n, ids[0], ids.dtype)])

    def _rebuild_pkindex(self, entry):
        s = entry.spec
        return pk_index(self.catalog[s["table"]].key(s["pk_col"]))

    def _refresh_pkindex(self, entry, deltas):
        s = entry.spec
        span = changed_spans(deltas[s["table"]]).span
        if span is not None:
            lo, hi = span
            entry.value = entry.value.extend(
                self.catalog[s["table"]].key(s["pk_col"])[lo:hi],
                np.arange(lo, hi))

    def _rebuild_join(self, entry):
        s = entry.spec
        idx = self._pkindex_entry(s["table"], s["pk_col"]).value
        fj = idx.probe(self.catalog[s["fact"]].key(s["fk_col"]))
        return (fj.ptr, fj.found)

    def _refresh_join(self, entry, deltas):
        # The same two-sided delta probe CompiledQuery._refresh_delta runs:
        # appended dim PKs are probed as a sorted block and scattered over
        # the whole fact; appended fact rows probe the (already extended)
        # full index.  Dirty non-key rows never move pointers.
        s = entry.spec
        cat = self.catalog
        fact, dim = cat[s["fact"]], cat[s["table"]]
        ptr = np.array(entry.value[0])
        found = np.array(entry.value[1])
        if s["table"] in deltas:
            span = changed_spans(deltas[s["table"]]).span
            if span is not None:
                lo, hi = span
                nk = np.asarray(dim.key(s["pk_col"]))[lo:hi]
                order = np.argsort(nk, kind="stable")
                snk, srow = nk[order], (lo + order).astype(np.int32)
                fk = np.asarray(fact.key(s["fk_col"]))
                pos = np.searchsorted(snk, fk)
                posc = np.clip(pos, 0, len(snk) - 1)
                hit = (snk[posc] == fk) & (fk != PAD_KEY)
                ptr = np.where(hit, srow[posc], ptr).astype(np.int32)
                found = found | hit
        if s["fact"] in deltas:
            span = changed_spans(deltas[s["fact"]]).span
            if span is not None:
                flo, fhi = span
                idx = self._pkindex_entry(s["table"], s["pk_col"]).value
                fj = idx.probe(fact.key(s["fk_col"])[flo:fhi])
                ptr[flo:fhi] = np.asarray(fj.ptr)
                found[flo:fhi] = np.asarray(fj.found)
        entry.value = (jnp.asarray(ptr), jnp.asarray(found))

    def _rebuild_dmask(self, entry):
        s = entry.spec
        return self._build_dmask(s["table"], s["preds"])

    def _refresh_dmask(self, entry, deltas):
        s = entry.spec
        ids = self._touched_ids(deltas[s["table"]])
        if ids is not None:
            ids = self._pad_ids(ids)
            entry.value = entry.value.at[jnp.asarray(ids)].set(
                _mask_rows(self.catalog[s["table"]], s["preds"], ids))

    def _rebuild_features(self, entry):
        s = entry.spec
        dim = self.catalog[s["table"]]
        return dim.matrix @ mapping_matrix(dim.columns, s["feature_cols"])

    def _refresh_features(self, entry, deltas):
        s = entry.spec
        ids = self._touched_ids(deltas[s["table"]])
        if ids is not None:
            ids = self._pad_ids(ids)
            dim = self.catalog[s["table"]]
            m = mapping_matrix(dim.columns, s["feature_cols"])
            rows = jnp.take(dim.matrix, jnp.asarray(ids), axis=0) @ m
            entry.value = entry.value.at[jnp.asarray(ids)].set(rows)

    def _hop_source_for(self, entry):
        """A ``resolve_chain`` hop source reading this chain's pooled hop
        probes (refreshing each at most once via :meth:`get`); ``None``
        for pre-pooling entries whose spec lacks hop keys."""
        if "hops" not in entry.spec:
            return None

        def hop_source(parent, lk):
            key = join_key(parent, lk.fk_col, lk.table, lk.pk_col)
            if key not in self._entries:
                return None
            ptr, found = self.get(key)
            return FactoredJoin(ptr, found)
        return hop_source

    def _rebuild_chain(self, entry):
        s = entry.spec
        return resolve_chain(self.catalog, s["arm"],
                             keep_hops=s["keep_hops"],
                             hop_source=self._hop_source_for(entry))

    def _refresh_chain(self, entry, deltas):
        hs = self._hop_source_for(entry)
        if hs is None:
            entry.value = refresh_chain(self.catalog, entry.value,
                                        set(deltas))
        else:
            s = entry.spec
            entry.value = resolve_chain(self.catalog, s["arm"],
                                        keep_hops=s["keep_hops"],
                                        hop_source=hs)

    def _partial_dims(self, entry, chains: Optional[Mapping[
            int, CollapsedChain]] = None) -> Tuple[DimSpec, ...]:
        # Chained arm specs are stored as the ArmSpec itself; they resolve
        # through the (possibly freshly re-collapsed) chain's virtual table.
        dims = []
        for i, a in enumerate(entry.spec["arms"]):
            if isinstance(a, ArmSpec):
                cc = (chains or {}).get(i) or resolve_chain(self.catalog, a)
                dims.append(DimSpec(cc.table, a.fk_col, a.pk_col,
                                    tuple(cc.table.columns)))
            else:
                t, fk, pk, fcols = a
                dims.append(DimSpec(self.catalog[t], fk, pk, fcols))
        return tuple(dims)

    def _rebuild_partial(self, entry):
        dims = self._partial_dims(entry)
        return prefuse_dims(dims, entry.spec["model"]).partials[
            entry.spec["j"]]

    def _refresh_partial(self, entry, deltas):
        s = entry.spec
        a = s["arms"][s["j"]]
        if isinstance(a, ArmSpec):
            # Chained partial: re-collapse (cheap dimension-sized gathers),
            # then scatter-refresh exactly the head rows whose virtual
            # matrix rows may differ — the same dirty set the unpooled
            # CompiledQuery._refresh_delta computes.
            cc = resolve_chain(self.catalog, a)
            dims = self._partial_dims(entry, chains={s["j"]: cc})
            touched = {}
            for name, d in deltas.items():
                t = self._touched_ids(d)
                if t is not None:
                    touched[name] = t
            ids = chain_dirty_heads(cc, touched)
        else:
            dims = self._partial_dims(entry)
            ids = self._touched_ids(deltas[dims[s["j"]].dim.name])
        if ids is not None:
            ids = jnp.asarray(self._pad_ids(np.asarray(ids, np.int32)))
            entry.value = entry.value.at[ids].set(
                prefuse_rows(dims, s["model"], s["j"], ids))


# --------------------------------------------------------------------------
# Batched multi-query execution
# --------------------------------------------------------------------------
def state_signature(state) -> tuple:
    """Treedef + per-leaf (shape, dtype) of a program-state pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return (str(treedef),
            tuple((tuple(np.shape(x)), str(jnp.asarray(x).dtype))
                  for x in leaves))


def stack_key(compiled) -> Optional[tuple]:
    """The structural compatibility class of one compiled plan, or ``None``
    when the plan cannot stack (traced, mesh-sharded, or no online fn).

    Two plans with equal keys run the *same* jitted program over different
    state pytrees: predicates and group assignments live in the state
    (``valid``/``gid``), so e.g. the four SSB flights each collapse their
    three variants into one class.  Everything the online closure bakes in
    as a static — backends, aggregate list, group dimension, model content,
    state pytree signature — is part of the key.
    """
    q = compiled.query
    if (getattr(compiled, "_online_fn", None) is None or compiled.is_traced
            or getattr(compiled, "_sp", None) is not None):
        return None
    if getattr(compiled, "_stream", None) is not None:
        # Streaming plans execute chunk-at-a-time with a carried
        # accumulator — there is no single whole-fact state to stack.
        return None
    if getattr(compiled, "_opts", {}).get("select_capacity") is not None:
        # Compacted plans close over a per-plan fact skeleton whose key
        # columns differ between members — not one shared program.
        return None
    sig = state_signature(
        {k: v for k, v in compiled._state.items() if k != "sharded"})
    return ("stack", q.fact,
            tuple((a.table, a.fk_col, a.pk_col, a.feature_cols)
                  for a in q.arms),
            q.aggregates,
            q.num_groups if q.group_keys else None,
            model_key(q.model),
            compiled.backend, compiled.join_backend, compiled.agg_backend,
            compiled.serve_backend, sig)


def make_stacked_runner(online_fn: Callable) -> Callable:
    """One jitted program executing N structurally compatible plans.

    ``online_fn`` is a plan's raw (un-jitted) online closure taking one
    program-state pytree; the runner takes a *stacked* pytree (every leaf
    gains a leading query axis) and vmaps the program over it — one
    dispatch for the whole class.  Gathers, element-wise masking and
    segment reductions are row-independent, so the batched program is
    bit-exact vs per-plan execution (asserted by the tier-1 tests).
    """
    return jax.jit(jax.vmap(online_fn))


def stack_states(states: Sequence) -> object:
    """Stack per-plan program states leaf-wise along a new query axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


# --------------------------------------------------------------------------
# Measurement helpers (benches/tests)
# --------------------------------------------------------------------------
def artifact_bytes(plans) -> int:
    """Resident bytes of *derived* quasi-static artifacts, deduplicated.

    Counts pointers/masks/partials/indices — the arrays compilation
    manufactures — and excludes source tables (``fact_matrix``/
    ``dim_mats``), which alias the catalog across plans whether or not a
    pool is in play and would dilute the sharing ratio.  Arrays shared
    between plans (the pool's whole point) count once, by ``id``.
    """
    seen: Dict[int, int] = {}

    def add(a):
        if a is None:
            return
        arr = a
        seen[id(arr)] = int(arr.size) * arr.dtype.itemsize

    for p in plans:
        state = getattr(p, "_state", None)
        if state is not None and "ptrs" in state:      # CompiledQuery
            for k in ("valid", "gid", "h"):
                add(state.get(k))
            for k in ("ptrs", "founds", "partials"):
                for a in (state.get(k) or ()):
                    add(a)
            for idx in getattr(p, "_indices", ()):
                add(idx.sorted_pk)
                add(idx.order)
        else:                                           # ServingRuntime
            add(getattr(p, "_h", None))
            for a in getattr(p, "_arms", ()):
                if a.index is not None:
                    add(a.index.sorted_pk)
                    add(a.index.order)
                add(a.dmask)
                add(a.table)
    return sum(seen.values())
