"""Core contribution of the paper: LAQ + ML operator fusion."""
from . import laq, fusion

__all__ = ["laq", "fusion"]
