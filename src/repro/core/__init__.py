"""Core contribution of the paper: LAQ + ML operator fusion + the
predictive-query compiler that plans and fuses whole queries."""
from . import laq, fusion, query

__all__ = ["laq", "fusion", "query"]
