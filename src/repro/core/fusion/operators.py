"""ML operators in linear-algebra form (paper §3.2–3.3).

* ``LinearOperator`` — a dense linear map L ∈ R^{k×l} (linear / ridge /
  logistic-regression score layers, PCA projections, ...).
* ``DecisionTreeGEMM`` — Hummingbird's GEMM representation of a decision
  tree (paper Fig. 5): binary feature-selection matrix F ∈ {0,1}^{k×p},
  threshold vector v ∈ R^p, path matrix H ∈ {−1,0,1}^{p×l}, and path-count
  vector h; prediction is ``((X·F > v)·H) == h`` yielding a one-hot leaf
  encoding per row.

  ``h`` is the per-leaf count of *positive* entries of H (the number of
  true-side nodes on the leaf's path): a row matches leaf ℓ iff every
  on-path predicate agrees, which happens exactly when the ±1-weighted sum
  reaches that count.  (The paper calls h "the column sum of H"; with the
  ±1 encoding the consistent choice is the positive part — verified against
  direct tree evaluation in tests.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LinearOperator:
    """predictions = X @ L + bias (k → l).

    ``bias`` is optional (None ≡ zero) and exists for the rewrite engine's
    constant-input folding: an equality predicate that pins feature i to v
    removes row i from L and folds ``v · L[i, :]`` into the bias.  On the
    fused path the bias is folded into arm 0's prefused partial
    (``prefuse_dims``/``prefuse_rows``) — any arm miss invalidates the row,
    whose output is zeroed by the validity mask, so attributing the
    constant term to arm 0 is exact.
    """

    L: jnp.ndarray  # (k, l)
    bias: Optional[jnp.ndarray] = None  # (l,) or None

    @property
    def k(self) -> int:
        return int(self.L.shape[0])

    @property
    def l(self) -> int:
        return int(self.L.shape[1])

    def apply(self, x: jnp.ndarray) -> jnp.ndarray:
        out = x @ self.L
        if self.bias is not None:
            out = out + self.bias[None, :].astype(out.dtype)
        return out

    def compose(self, other: "LinearOperator") -> "LinearOperator":
        """Associativity: (X L₁) L₂ = X (L₁ L₂) — pre-fold chained layers."""
        bias = None
        if self.bias is not None:
            bias = self.bias @ other.L
        if other.bias is not None:
            bias = other.bias if bias is None else bias + other.bias
        return LinearOperator(self.L @ other.L, bias)


@dataclasses.dataclass(frozen=True)
class DecisionTreeGEMM:
    """Hummingbird GEMM decision tree: ((X F > v) H) == h."""

    F: jnp.ndarray  # (k, p) {0,1} feature selection, one 1 per column
    v: jnp.ndarray  # (p,) node thresholds
    H: jnp.ndarray  # (p, l) {−1,0,1} leaf paths
    h: jnp.ndarray  # (l,) positive-entry count per column of H

    @property
    def k(self) -> int:
        return int(self.F.shape[0])

    @property
    def p(self) -> int:
        return int(self.F.shape[1])

    @property
    def l(self) -> int:
        return int(self.H.shape[1])

    def predicates(self, x: jnp.ndarray) -> jnp.ndarray:
        """Step 1–2: (X F > v) ∈ {0,1}^{i×p}."""
        return (x @ self.F > self.v[None, :]).astype(x.dtype)

    def apply(self, x: jnp.ndarray) -> jnp.ndarray:
        """One-hot leaf encoding (i × l) — steps 1–4 of Fig. 5."""
        b = self.predicates(x)
        score = b @ self.H.astype(x.dtype)
        return (score == self.h[None, :].astype(x.dtype)).astype(x.dtype)

    def predict_leaf(self, x: jnp.ndarray) -> jnp.ndarray:
        """Leaf index per row (argmax over the one-hot encoding)."""
        return jnp.argmax(self.apply(x), axis=1)


# --------------------------------------------------------------------------
# Tree construction helpers
# --------------------------------------------------------------------------
def tree_from_arrays(feature: np.ndarray, threshold: np.ndarray, k: int
                     ) -> DecisionTreeGEMM:
    """Build the GEMM form of a *complete* binary tree.

    ``feature[n]``/``threshold[n]`` describe internal node n in level order
    (n ∈ [0, 2^d − 1)); leaves are the 2^d paths.
    """
    p = int(feature.shape[0])
    depth = int(np.log2(p + 1))
    l = p + 1
    F = np.zeros((k, p), np.float32)
    F[feature, np.arange(p)] = 1.0
    H = np.zeros((p, l), np.float32)
    for leaf in range(l):
        node = 0
        for level in range(depth):
            # Bit `depth-1-level` of the leaf id picks the branch at `node`.
            go_right = (leaf >> (depth - 1 - level)) & 1
            H[node, leaf] = 1.0 if go_right else -1.0
            node = 2 * node + 1 + go_right
    h = np.maximum(H, 0.0).sum(axis=0)
    return DecisionTreeGEMM(jnp.asarray(F), jnp.asarray(threshold, np.float32),
                            jnp.asarray(H), jnp.asarray(h, np.float32))


def random_tree(rng: np.random.Generator, k: int, depth: int,
                scale: float = 1.0) -> DecisionTreeGEMM:
    """A random complete tree over k features (benchmarks / tests)."""
    p = 2**depth - 1
    feature = rng.integers(0, k, size=p)
    threshold = rng.normal(0.0, scale, size=p).astype(np.float32)
    return tree_from_arrays(feature, threshold, k)


def reference_tree_eval(feature: np.ndarray, threshold: np.ndarray,
                        x: np.ndarray) -> np.ndarray:
    """Direct (non-LA) tree traversal oracle: leaf index per row."""
    p = feature.shape[0]
    depth = int(np.log2(p + 1))
    out = np.zeros((x.shape[0],), np.int64)
    for r in range(x.shape[0]):
        node = 0
        leaf = 0
        for _ in range(depth):
            right = x[r, feature[node]] > threshold[node]
            leaf = (leaf << 1) | int(right)
            node = 2 * node + 1 + int(right)
        out[r] = leaf
    return out
