"""Operator fusion of ML models into LAQ star joins (paper §3)."""
from .operators import (LinearOperator, DecisionTreeGEMM, tree_from_arrays,
                        random_tree, reference_tree_eval)
from .pipeline import (PrefusedStar, prefuse, prefuse_dims, predict_fused,
                       predict_fused_kernel, predict_fused_matmul,
                       predict_nonfused, predict_nonfused_kernel,
                       predict_nonfused_matmul)
from .planner import FusionDecision, plan_fusion

__all__ = [
    "LinearOperator", "DecisionTreeGEMM", "tree_from_arrays", "random_tree",
    "reference_tree_eval", "PrefusedStar", "prefuse", "prefuse_dims",
    "predict_fused", "predict_fused_kernel", "predict_fused_matmul",
    "predict_nonfused", "predict_nonfused_kernel", "predict_nonfused_matmul",
    "FusionDecision", "plan_fusion",
]
