"""Cost-based fusion planner — the paper's Eq. 2 / Eq. 4 decision boundary.

The paper derives the fusion speedup analytically and leaves "a detailed cost
estimation that can assist with automatic pipeline optimization" to future
work (§6).  We implement it: given the star shape (i fact rows, k features,
r_j dimension rows), the model shape (l outputs, p tree nodes), and the
dimension-table update rate, estimate fused vs non-fused cost per batch and
decide.  The estimate amortizes the pre-fusion cost over the expected number
of batches between dimension updates (paper §4.3 Q6/Q8: "the actual benefits
depend on the update frequency of the dimension tables") and checks the
pre-fused memory footprint (Q6: partials can exceed the original tables when
l > c).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

from .operators import DecisionTreeGEMM, LinearOperator

Model = Union[LinearOperator, DecisionTreeGEMM]


@dataclasses.dataclass(frozen=True)
class FusionDecision:
    fuse: bool
    est_speedup: float          # Eq. 2 / Eq. 4 ratio (steady state)
    amortized_speedup: float    # including pre-fusion amortization
    prefused_bytes: int
    reason: str


def _flops_linear(i: float, k: float, l: float, rows: Sequence[int]):
    # Paper's closed forms (§3.2.1), with c = k/#dims:
    sr = float(sum(rows))
    non = (i * k + k * k / 3.0) * sr + i * k * l
    fus = i * l * sr
    pre = sum(r * k * l for r in rows)  # B(M L): r_j × k × l each
    return non, fus, pre


def _flops_tree(i: float, k: float, p: float, l: float, rows: Sequence[int]):
    sr = float(sum(rows))
    non = (k * k / 3.0 + i * k) * sr + i * k * p + i * p + i * p * l + i * l
    fus = i * l * sr + i * l
    pre = sum(r * (k * p + p + p * l) for r in rows)
    return non, fus, pre


def plan_fusion(model: Model, fact_rows: int, dim_rows: Sequence[int],
                batches_per_update: float = 1000.0,
                memory_budget_bytes: Optional[int] = None,
                selectivity: float = 1.0) -> FusionDecision:
    """Fused-vs-nonfused decision for one predictive query.

    ``selectivity`` is the fraction of fact rows surviving selection +
    join-miss filtering.  Selection precedes prediction in the plan (the
    compiler folds it into the factored-join validity and ``mask_select``
    compaction shrinks the online batch), so every *online* term scales by
    it; the offline pre-fusion cost over the dimension tables does not.
    """
    i = float(fact_rows) * min(max(float(selectivity), 0.0), 1.0)
    k = float(model.k)
    l = float(model.l)
    if isinstance(model, LinearOperator):
        non, fus, pre = _flops_linear(i, k, l, dim_rows)
    else:
        non, fus, pre = _flops_tree(i, k, float(model.p), l, dim_rows)

    est = non / max(fus, 1.0)
    amort = non / max(fus + pre / max(batches_per_update, 1e-9), 1.0)
    prefused_bytes = int(sum(r * l for r in dim_rows)) * 4

    if memory_budget_bytes is not None and prefused_bytes > memory_budget_bytes:
        return FusionDecision(False, est, amort, prefused_bytes,
                              f"prefused partials {prefused_bytes}B exceed "
                              f"budget {memory_budget_bytes}B")
    if amort <= 1.0:
        return FusionDecision(False, est, amort, prefused_bytes,
                              "pre-fusion cost not amortized at this update "
                              f"rate (amortized speedup {amort:.2f}x)")
    return FusionDecision(True, est, amort, prefused_bytes,
                          f"k/l = {k / l:.1f}; est {est:.1f}x, "
                          f"amortized {amort:.1f}x")
