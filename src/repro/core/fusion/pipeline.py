"""Operator fusion of ML predictions into star-join query processing (§3).

The predictive pipeline is ``predictions = model(star_join(fact, dims))``.
Because both the join (LAQ) and the model are linear-algebra programs,
matmul associativity/distributivity lets the model's leading linear
operators be *pushed down* into the (quasi-static) dimension tables:

  linear (Eq. 1):   T·L = I₁(B M₁ L) + I₂(C M₂ L) + I₃(D M₃ L)
  tree   (Eq. 3):   ((T F > v) H) == h
                  = (I₁((B M₁ F > v)⊙W₁)H + I₂(...) + I₃(...)) == h

``prefuse()`` computes the per-dimension partials once; ``predict_fused``
then does only |dims| gathers + adds (+ one compare for trees) per batch —
the paper's up-to-317× speedup.  ``W_j`` is the tree-node ownership mask:
every tree node reads exactly one feature column, which lives in exactly one
dimension table, so masking non-owned nodes makes the partial sums exact
(the paper's "the predicate can be partially evaluated").
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax.numpy as jnp

from ..laq.star import DimSpec, StarJoin, dim_mapping_matrices
from .operators import DecisionTreeGEMM, LinearOperator

Model = Union[LinearOperator, DecisionTreeGEMM]


@dataclasses.dataclass(frozen=True)
class PrefusedStar:
    """Per-dimension pre-fused partials P_j plus the tree's compare vector."""

    partials: Tuple[jnp.ndarray, ...]  # each (r_j, l)
    h: Optional[jnp.ndarray]           # (l,) for trees, None for linear

    def nbytes(self) -> int:
        return sum(int(p.size) * p.dtype.itemsize for p in self.partials)


def _feature_slices(dims: Sequence[DimSpec]):
    """[start, stop) of each dimension's block in T's k feature columns."""
    out = []
    off = 0
    for d in dims:
        out.append((off, off + len(d.feature_cols)))
        off += len(d.feature_cols)
    return out


def prefuse_dims(dims: Sequence[DimSpec], model: Model) -> PrefusedStar:
    """Push the model's linear prefix into dimension tables (Eq. 1/3).

    Operates on bare ``DimSpec``s — no fact table or resolved joins needed,
    which is what lets the serving runtime pre-fuse once and serve arbitrary
    request batches against the partials.
    """
    mats = dim_mapping_matrices(dims)
    parts = []
    if isinstance(model, LinearOperator):
        for j, (d, m) in enumerate(zip(dims, mats)):
            part = d.dim.matrix @ (m @ model.L)              # B M L
            if j == 0 and model.bias is not None:
                # Constant term lives in arm 0's partial: a row missing any
                # arm is invalid and zeroed after the sum, so the bias
                # reaches exactly the rows model.apply would have biased.
                part = part + model.bias[None, :].astype(part.dtype)
            parts.append(part)
        return PrefusedStar(tuple(parts), None)
    # Decision tree: per-dim node-ownership masks W_j from F's column blocks.
    slices = _feature_slices(dims)
    f_owner = jnp.argmax(model.F, axis=0)                     # feature per node
    for d, m, (lo, hi) in zip(dims, mats, slices):
        own = ((f_owner >= lo) & (f_owner < hi)).astype(jnp.float32)  # (p,)
        feats = d.dim.matrix @ (m @ model.F)                  # (r_j, p)
        preds = (feats > model.v[None, :]).astype(jnp.float32) * own[None, :]
        parts.append(preds @ model.H)                         # (r_j, l)
    return PrefusedStar(tuple(parts), model.h)


def prefuse(star: StarJoin, model: Model) -> PrefusedStar:
    """Push the model's linear prefix into each dimension table (Eq. 1/3)."""
    return prefuse_dims(star.dims, model)


def prefuse_rows(dims: Sequence[DimSpec], model: Model, j: int,
                 row_ids: jnp.ndarray) -> jnp.ndarray:
    """Partial rows for dimension ``j`` restricted to ``row_ids``.

    The delta half of incremental prefuse maintenance: Eq. 1/3 partials are
    *row-wise* in the dimension table (row r of ``B (M L)`` reads only row r
    of B), so an append/update only ever dirties the corresponding partial
    rows.  This computes exactly those — the same per-row contractions the
    cold :func:`prefuse_dims` runs over all rows, so scattering the result
    back (:func:`extend_prefused`) reproduces the cold partial bit-exactly.
    """
    mats = dim_mapping_matrices(dims)
    d, m = dims[j], mats[j]
    rows = jnp.take(d.dim.matrix, jnp.asarray(row_ids, jnp.int32), axis=0)
    if isinstance(model, LinearOperator):
        out = rows @ (m @ model.L)
        if j == 0 and model.bias is not None:   # matches prefuse_dims
            out = out + model.bias[None, :].astype(out.dtype)
        return out
    slices = _feature_slices(dims)
    lo, hi = slices[j]
    f_owner = jnp.argmax(model.F, axis=0)
    own = ((f_owner >= lo) & (f_owner < hi)).astype(jnp.float32)
    feats = rows @ (m @ model.F)
    preds = (feats > model.v[None, :]).astype(jnp.float32) * own[None, :]
    return preds @ model.H


def extend_prefused(pre: PrefusedStar, dims: Sequence[DimSpec],
                    model: Model,
                    dirty: Sequence[Optional[jnp.ndarray]]) -> PrefusedStar:
    """Scatter freshly-computed partial rows into the cached partials.

    ``dirty[j]`` is the array of dimension-j row ids to recompute (appended
    span ∪ updated rows), or ``None`` for untouched arms, whose partial
    arrays are reused as-is.  Shapes never change — this is the same-
    capacity delta path; capacity growth goes through a cold ``prefuse``.
    """
    parts = []
    for j, (p, ids) in enumerate(zip(pre.partials, dirty)):
        if ids is None or len(ids) == 0:
            parts.append(p)
            continue
        ids = jnp.asarray(ids, jnp.int32)
        parts.append(p.at[ids].set(prefuse_rows(dims, model, j, ids)))
    return PrefusedStar(tuple(parts), pre.h)


def predict_fused(star: StarJoin, pre: PrefusedStar) -> jnp.ndarray:
    """Online phase: Σⱼ Iⱼ Pⱼ (gathers) and, for trees, `== h`."""
    acc = None
    for fj, p in zip(star.joins, pre.partials):
        part = fj.apply(p)
        acc = part if acc is None else acc + part
    acc = acc * star.row_valid[:, None].astype(acc.dtype)
    if pre.h is None:
        return acc
    eq = (acc == pre.h[None, :].astype(acc.dtype)).astype(acc.dtype)
    return eq * star.row_valid[:, None].astype(acc.dtype)


def predict_fused_matmul(star: StarJoin, pre: PrefusedStar) -> jnp.ndarray:
    """Paper-faithful online phase: dense Iⱼ matmuls (small inputs only)."""
    acc = None
    for d, fj, p in zip(star.dims, star.joins, pre.partials):
        part = fj.dense(d.dim.capacity) @ p
        acc = part if acc is None else acc + part
    acc = acc * star.row_valid[:, None]
    if pre.h is None:
        return acc
    return (acc == pre.h[None, :]).astype(acc.dtype) * star.row_valid[:, None]


def predict_nonfused(star: StarJoin, model: Model) -> jnp.ndarray:
    """Baseline: materialize T, then run the model (separate execution)."""
    t = star.materialize()
    out = model.apply(t)
    return out * star.row_valid[:, None].astype(out.dtype)


def predict_nonfused_matmul(star: StarJoin, model: Model) -> jnp.ndarray:
    """Paper-faithful baseline: dense-I materialization, then the model."""
    t = star.materialize_matmul()
    out = model.apply(t)
    return out * star.row_valid[:, None].astype(out.dtype)


def predict_fused_kernel(star: StarJoin, pre: PrefusedStar, *,
                         interpret: bool = False) -> jnp.ndarray:
    """Online phase on the ``fused_star_gather`` Pallas kernel.

    Same contraction as :func:`predict_fused` — Σⱼ Iⱼ Pⱼ (+ ``== h``) — but
    executed as one scalar-prefetch kernel pass: the FK pointers land in SMEM
    and each partial's rows are DMA'd HBM→VMEM directly, instead of XLA
    gathers.  The per-arm liveness masks are applied inside the kernel; the
    combined row validity is applied after the compare, which matches
    :func:`predict_fused` bit-exactly in fp32 (identical add order).
    """
    from repro.kernels import fused_star_gather

    ptrs = jnp.stack([fj.ptr for fj in star.joins])
    found = jnp.stack([fj.found for fj in star.joins]).astype(jnp.int32)
    out = fused_star_gather(ptrs, found, list(pre.partials), pre.h,
                            interpret=interpret)
    return out * star.row_valid[:, None].astype(out.dtype)


def predict_nonfused_kernel(star: StarJoin, model: Model, *,
                            interpret: bool = False) -> jnp.ndarray:
    """Baseline with the model step on the ``tree_predict`` Pallas kernel.

    Only decision trees have a kernel lowering on the non-fused path
    (``((T F > v) H) == h`` as one fused block); callers must gate on the
    model type — linear heads stay on the XLA matmul.
    """
    from repro.kernels import tree_predict

    t = star.materialize()
    out = tree_predict(t, model.F, model.v, model.H, model.h,
                       interpret=interpret)
    return out * star.row_valid[:, None].astype(out.dtype)
