"""Per-architecture configs (assigned pool) + the paper's pipeline configs."""
from .registry import arch_ids, get_config, get_smoke_config

__all__ = ["arch_ids", "get_config", "get_smoke_config"]
