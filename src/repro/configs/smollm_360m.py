"""smollm-360m [dense] — llama-arch small. [hf:HuggingFaceTB/SmolLM; hf]

32L, d_model=960, 15H (GQA kv=5), d_ff=2560, vocab=49152.
"""
from repro.models import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense", d_model=960, n_heads=15,
        n_kv_heads=5, d_ff=2560, vocab_size=49152,
        pattern=(LayerSpec("attn", "dense"),), n_repeats=32,
        act="swiglu", tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m-smoke", family="dense", d_model=96, n_heads=3,
        n_kv_heads=1, d_ff=256, vocab_size=512,
        pattern=(LayerSpec("attn", "dense"),), n_repeats=2,
        act="swiglu", tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32", remat=False)
