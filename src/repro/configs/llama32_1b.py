"""llama3.2-1b [dense] — small llama3. [hf:meta-llama/Llama-3.2-1B; unverified]

16L, d_model=2048, 32H (GQA kv=8), d_ff=8192, vocab=128256.
"""
from repro.models import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b", family="dense", d_model=2048, n_heads=32,
        n_kv_heads=8, d_ff=8192, vocab_size=128256,
        pattern=(LayerSpec("attn", "dense"),), n_repeats=16,
        act="swiglu", rope_theta=500_000.0, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b-smoke", family="dense", d_model=128, n_heads=4,
        n_kv_heads=1, d_ff=384, vocab_size=512,
        pattern=(LayerSpec("attn", "dense"),), n_repeats=2,
        act="swiglu", rope_theta=500_000.0, tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32", remat=False)
