"""xlstm-125m [ssm] — sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

12L, d_model=768, 4H, vocab=50304, d_ff=0 (blocks are self-contained).
Super-block of 6 (sLSTM at position 3, mLSTM elsewhere — the paper's ~1:7
sLSTM ratio at this depth), repeated 2× → sLSTM at layers 3 and 9.
"""
from repro.models import LayerSpec, ModelConfig, XLSTMSpec


def _pattern():
    return tuple(LayerSpec("slstm" if i == 3 else "mlstm", "none")
                 for i in range(6))


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm", d_model=768, n_heads=4,
        n_kv_heads=4, d_ff=0, vocab_size=50304,
        pattern=_pattern(), n_repeats=2, act="gelu",
        xlstm=XLSTMSpec(proj_factor=2.0), tie_embeddings=True,
        subquadratic=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke", family="ssm", d_model=64, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab_size=512,
        pattern=_pattern(), n_repeats=1, act="gelu",
        xlstm=XLSTMSpec(proj_factor=2.0), tie_embeddings=True,
        subquadratic=True,
        param_dtype="float32", compute_dtype="float32", remat=False)
