"""dbrx-132b [moe] — 16 experts top-4, fine-grained.
[hf:databricks/dbrx-base; unverified]

40L, d_model=6144, 48H (GQA kv=8), expert d_ff=10752, vocab=100352.
"""
from repro.models import LayerSpec, ModelConfig, MoESpec


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe", d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=10752, vocab_size=100352,
        pattern=(LayerSpec("attn", "moe"),), n_repeats=40, act="swiglu",
        rope_theta=500_000.0,
        # TP-within-expert rather than EP: XLA SPMD lowers the EP combine
        # scatter as a replicated-buffer all-reduce (34 GB/device —
        # EXPERIMENTS.md §Perf); revisit with an explicit shard_map
        # all-to-all dispatch.
        moe=MoESpec(n_experts=16, top_k=4, d_expert_ff=10752,
                    shard_experts=False))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-smoke", family="moe", d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=512,
        pattern=(LayerSpec("attn", "moe"),), n_repeats=2, act="swiglu",
        moe=MoESpec(n_experts=4, top_k=2, d_expert_ff=128),
        param_dtype="float32", compute_dtype="float32", remat=False)
