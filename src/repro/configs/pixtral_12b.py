"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]

40L, d_model=5120, 32H (GQA kv=8), d_ff=14336, vocab=131072.
Vision frontend is a STUB per assignment: input_specs() provides
precomputed patch embeddings prepended to the token stream.
"""
from repro.models import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm", d_model=5120, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab_size=131072, head_dim=128,
        pattern=(LayerSpec("attn", "dense"),), n_repeats=40,
        act="swiglu", rope_theta=1_000_000.0,
        frontend="patch", n_patches=256)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b-smoke", family="vlm", d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=384, vocab_size=512, head_dim=32,
        pattern=(LayerSpec("attn", "dense"),), n_repeats=2,
        act="swiglu", frontend="patch", n_patches=8,
        param_dtype="float32", compute_dtype="float32", remat=False)
