"""Architecture registry: ``--arch <id>`` → (full config, smoke config).

Every module below defines ``config()`` (the exact assigned dimensions) and
``smoke_config()`` (same family, reduced — used by CPU smoke tests; FULL
configs are only exercised via the AOT dry-run).
"""
from __future__ import annotations

import importlib

_ARCHS = {
    "whisper-tiny": "whisper_tiny",
    "smollm-360m": "smollm_360m",
    "minitron-4b": "minitron_4b",
    "llama3.2-1b": "llama32_1b",
    "gemma-7b": "gemma_7b",
    "pixtral-12b": "pixtral_12b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "dbrx-132b": "dbrx_132b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "xlstm-125m": "xlstm_125m",
}


def arch_ids():
    return list(_ARCHS.keys())


def _module(arch: str):
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {arch_ids()}")
    return importlib.import_module(f"repro.configs.{_ARCHS[arch]}")


def get_config(arch: str):
    return _module(arch).config()


def get_smoke_config(arch: str):
    return _module(arch).smoke_config()
