"""gemma-7b [dense] — GeGLU, head_dim=256. [arXiv:2403.08295; hf]

28L, d_model=3072, 16H (GQA kv=16), d_ff=24576, vocab=256000.
"""
from repro.models import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense", d_model=3072, n_heads=16,
        n_kv_heads=16, d_ff=24576, vocab_size=256000, head_dim=256,
        pattern=(LayerSpec("attn", "dense"),), n_repeats=28,
        act="geglu", tie_embeddings=True, logit_softcap=30.0)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b-smoke", family="dense", d_model=96, n_heads=2,
        n_kv_heads=2, d_ff=384, vocab_size=512, head_dim=64,
        pattern=(LayerSpec("attn", "dense"),), n_repeats=2,
        act="geglu", tie_embeddings=True, logit_softcap=30.0,
        param_dtype="float32", compute_dtype="float32", remat=False)
