"""minitron-4b [dense] — pruned nemotron. [arXiv:2407.14679; hf]

32L, d_model=3072, 24H (GQA kv=8), d_ff=9216, vocab=256000.
"""
from repro.models import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b", family="dense", d_model=3072, n_heads=24,
        n_kv_heads=8, d_ff=9216, vocab_size=256000,
        pattern=(LayerSpec("attn", "dense"),), n_repeats=32, act="swiglu")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b-smoke", family="dense", d_model=96, n_heads=3,
        n_kv_heads=1, d_ff=288, vocab_size=512,
        pattern=(LayerSpec("attn", "dense"),), n_repeats=2, act="swiglu",
        param_dtype="float32", compute_dtype="float32", remat=False)
