"""whisper-tiny [audio] — enc-dec, conv frontend (stub).
[arXiv:2212.04356; unverified]

4L enc + 4L dec, d_model=384, 6H (GQA kv=6), d_ff=1536, vocab=51865.
The conv frontend is a STUB per assignment: input_specs() provides 1500
precomputed mel-frame embeddings (B, 1500, 384).
"""
from repro.models import LayerSpec, ModelConfig

ENCODER_FRAMES = 1500


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="encdec", d_model=384, n_heads=6,
        n_kv_heads=6, d_ff=1536, vocab_size=51865,
        pattern=(LayerSpec("attn", "dense"),), n_repeats=4,
        act="gelu", n_encoder_layers=4, encoder_seq=ENCODER_FRAMES,
        frontend="audio", tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-smoke", family="encdec", d_model=64, n_heads=2,
        n_kv_heads=2, d_ff=128, vocab_size=512,
        pattern=(LayerSpec("attn", "dense"),), n_repeats=2,
        act="gelu", n_encoder_layers=2, encoder_seq=16,
        frontend="audio", tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32", remat=False)
