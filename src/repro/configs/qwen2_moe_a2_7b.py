"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4, fine-grained experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

24L, d_model=2048, 16H (GQA kv=16), expert d_ff=1408, vocab=151936.
The 4 shared experts are fused into one 4×1408-wide shared MLP (identical
compute).  60 experts don't divide the 16-way model axis, so expert weights
shard like dense weights (TP within expert) instead of EP — see DESIGN.md.
"""
from repro.models import LayerSpec, ModelConfig, MoESpec


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe", d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1408, vocab_size=151936,
        pattern=(LayerSpec("attn", "moe"),), n_repeats=24, act="swiglu",
        moe=MoESpec(n_experts=60, top_k=4, d_expert_ff=1408,
                    n_shared=4, d_shared_ff=4 * 1408, shard_experts=False))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-smoke", family="moe", d_model=64, n_heads=2,
        n_kv_heads=2, d_ff=96, vocab_size=512,
        pattern=(LayerSpec("attn", "moe"),), n_repeats=2, act="swiglu",
        moe=MoESpec(n_experts=6, top_k=2, d_expert_ff=96,
                    n_shared=2, d_shared_ff=192, shard_experts=False),
        param_dtype="float32", compute_dtype="float32", remat=False)
