"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE.
[arXiv:2403.19887; hf]

72L, d_model=8192, 64H (GQA kv=8), d_ff=24576, vocab=65536; MoE 16e top-2.
Super-block = 8 layers: attention at index 4 (the 1:7 ratio), Mamba
elsewhere; MoE replaces the MLP on every second layer.  72 = 9 repeats × 8.
"""
from repro.models import LayerSpec, MambaSpec, ModelConfig, MoESpec


def _pattern():
    layers = []
    for i in range(8):
        mixer = "attn" if i == 4 else "mamba"
        mlp = "moe" if i % 2 == 1 else "dense"
        layers.append(LayerSpec(mixer, mlp))
    return tuple(layers)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid", d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=24576, vocab_size=65536,
        pattern=_pattern(), n_repeats=9, act="swiglu",
        # TP-within-expert (see dbrx config note on the EP combine).
        moe=MoESpec(n_experts=16, top_k=2, d_expert_ff=24576,
                    shard_experts=False),
        mamba=MambaSpec(d_state=16, d_conv=4, expand=2),
        subquadratic=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b-smoke", family="hybrid", d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
        pattern=_pattern(), n_repeats=1, act="swiglu",
        moe=MoESpec(n_experts=4, top_k=2, d_expert_ff=128),
        mamba=MambaSpec(d_state=4, d_conv=4, expand=2),
        subquadratic=True,
        param_dtype="float32", compute_dtype="float32", remat=False)
