"""repro: LAQ + ML operator fusion (SSDBM'23) as a multi-pod JAX framework."""
__version__ = "1.0.0"
