"""Dense MLP blocks: SwiGLU (llama-family), GeGLU (gemma), plain GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .act_sharding import constrain
from .common import act_fn, dense_init


def init_mlp(key, d_model: int, d_ff: int, act: str, dtype):
    gated = act in ("swiglu", "geglu")
    k1, k2 = jax.random.split(key)
    return {
        "wi": dense_init(k1, (d_model, (2 if gated else 1) * d_ff), dtype),
        "wo": dense_init(k2, (d_ff, d_model), dtype),
    }


def mlp(params, x, act: str) -> jnp.ndarray:
    # Megatron TP: hidden activations sharded over the model axis; the wo
    # row-sharded matmul psums partials back to a model-replicated output.
    h = x @ params["wi"]
    h = constrain(h, "dp", None, "tp") if h.ndim == 3 else h
    if act in ("swiglu", "geglu"):
        u, g = jnp.split(h, 2, axis=-1)
        h = act_fn(act)(g) * u
    else:
        h = act_fn(act)(h)
    out = h @ params["wo"]
    return constrain(out, "dp", None, None) if out.ndim == 3 else out
