"""Mixture-of-Experts layer with LAQ-style dispatch.

The routing decision is a row-matching matrix in the paper's sense: token i
"joins" expert-slot j (DESIGN.md §4).  Dispatch is therefore implemented the
way LAQ materializes joins on TPU — *factored*: a capacity-bounded int32
pointer buffer per expert (the join's fixed-capacity selection) followed by
gathers, never a (T×E×C) one-hot dispatch tensor in HBM.  Combine is the
transposed join: a scatter-add weighted by the router gate.

Dispatch is **sequence-local**: routing, the stable sort that groups
token-slots by expert, the capacity cut, and the gather/scatter all carry
the batch dim (B), which is data-parallel-sharded.  A global (B·S)-flat
dispatch sorts and gathers across the whole DP group — XLA materializes
that as all-gathers of full activations per MoE layer (measured: 79 s of
collective time per step on the qwen2-moe train_4k cell; EXPERIMENTS.md
§Perf).  Per-sequence capacity is slightly stricter about hot experts
(standard trade; ``capacity_factor`` compensates).

Top-k routing with per-expert capacity C = round_up(S·k/E · cf, 8); tokens
over capacity are dropped (GShard semantics) — exactly LAQ's fixed-capacity
selection under static shapes.  A Switch-style load-balance auxiliary loss
is returned for training.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .act_sharding import constrain
from .common import act_fn, dense_init
from .config import ModelConfig, round_up
from .mlp import init_mlp, mlp


def init_moe(key, cfg: ModelConfig):
    spec = cfg.moe
    d = cfg.d_model
    gated = cfg.act in ("swiglu", "geglu")
    ks = jax.random.split(key, 4)
    params = {
        "router": dense_init(ks[0], (d, spec.n_experts), jnp.float32),
        "wi": dense_init(ks[1], (spec.n_experts, d,
                                 (2 if gated else 1) * spec.d_expert_ff),
                         cfg.pdtype),
        "wo": dense_init(ks[2], (spec.n_experts, spec.d_expert_ff, d),
                         cfg.pdtype),
    }
    if spec.d_shared_ff:
        params["shared"] = init_mlp(ks[3], d, spec.d_shared_ff, cfg.act,
                                    cfg.pdtype)
    return params


def moe_mlp(params, x: jnp.ndarray, cfg: ModelConfig
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) → (y, aux_loss)."""
    spec = cfg.moe
    b, s, d = x.shape
    e, k = spec.n_experts, spec.top_k

    # ---- routing (B, S, E) -------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss (global statistics, scalar comm).
    me = probs.mean(axis=(0, 1))                                # (E,)
    ce = jnp.zeros((e + 1,), jnp.float32).at[
        expert_ids.reshape(-1)].add(1.0)[:e] / (b * s * k)
    aux = e * jnp.sum(me * ce)

    # ---- sequence-local factored dispatch (fixed-capacity join) -----------
    capacity = round_up(max(int(s * k / e * spec.capacity_factor), 1), 8)
    flat_e = expert_ids.reshape(b, s * k)                       # (B, S·k)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None], (b, s * k))
    flat_gate = gate_vals.reshape(b, s * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)           # per row
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    sorted_tok = jnp.take_along_axis(flat_tok, order, axis=-1)
    sorted_gate = jnp.take_along_axis(flat_gate, order, axis=-1)
    # Rank within expert group = position − first index of the group.
    first = jax.vmap(
        lambda row: jnp.searchsorted(row, row, side="left"))(sorted_e)
    rank = jnp.arange(s * k, dtype=jnp.int32)[None] - first.astype(jnp.int32)
    live = rank < capacity
    slot = jnp.where(live, sorted_e * capacity + rank, e * capacity)
    rows = jnp.arange(b)[:, None]
    # Pointer buffer per row: expert-slot → local token (s = "no row").
    ptr = jnp.full((b, e * capacity + 1), s, jnp.int32).at[
        rows, slot].set(sorted_tok, mode="drop")[:, :-1]
    gates = jnp.zeros((b, e * capacity + 1), jnp.float32).at[
        rows, slot].set(sorted_gate, mode="drop")[:, :-1]

    # ---- expert compute (local gather → grouped GEMM → local scatter) -----
    valid = ptr < s
    xe = jnp.take_along_axis(x, jnp.minimum(ptr, s - 1)[..., None], axis=1)
    xe = xe * valid[..., None].astype(x.dtype)
    xe = xe.reshape(b, e, capacity, d)
    if spec.shard_experts:
        xe = constrain(xe, "dp", "tp", None, None)   # DP tokens × EP experts
    else:
        xe = constrain(xe, "dp", None, None, None)
    h = jnp.einsum("becd,edf->becf", xe, params["wi"].astype(xe.dtype))
    if cfg.act in ("swiglu", "geglu"):
        u, g = jnp.split(h, 2, axis=-1)
        h = act_fn(cfg.act)(g) * u
    else:
        h = act_fn(cfg.act)(h)
    ye = jnp.einsum("becf,efd->becd", h, params["wo"].astype(h.dtype))

    # ---- combine (transposed join: scatter-add with gate weights) ---------
    yflat = constrain(
        ye.reshape(b, e * capacity, d) * gates[..., None].astype(ye.dtype),
        "dp", None, None)
    rows2 = jnp.broadcast_to(rows, ptr.shape)
    # The scatter buffer must be born batch-sharded: an unconstrained zeros
    # buffer made XLA run the EP combine as an all-reduce of a *replicated*
    # (B,S+1,D) fp32 tensor — 34 GB/device, ~100×/step on jamba (§Perf).
    out0 = constrain(jnp.zeros((b, s + 1, d), ye.dtype), "dp", None, None)
    out = out0.at[rows2, ptr].add(yflat, mode="drop")[:, :-1]
    if "shared" in params:
        out = out + mlp(params["shared"], x.reshape(b * s, d),
                        cfg.act).reshape(b, s, d)
    return out, aux
