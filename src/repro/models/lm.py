"""The unified LM covering all ten assigned architectures.

Decoder stack = ``cfg.pattern`` (a super-block of heterogeneous layers)
repeated ``cfg.n_repeats`` times and executed with ``jax.lax.scan`` over the
stacked per-repeat parameters — HLO size and compile time are O(pattern),
not O(depth), which is what makes 72-layer jamba dry-runs tractable at 512
devices.  Enc-dec archs (whisper) add a bidirectional encoder stack and
per-layer cross-attention; VLM/audio frontends are stubs that consume
precomputed patch/frame embeddings (per the assignment).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import blocks
from .act_sharding import constrain
from .common import dense_init, rmsnorm, sinusoidal_positions, softcap
from .config import LayerSpec, ModelConfig


class DecodeState(NamedTuple):
    """Carried serving state: per-layer stacks + position counter."""

    layer_states: Any          # pytree stacked (n_repeats, ...) per pattern pos
    cross_kv: Optional[Any]    # enc-dec: per-layer (k, v) from encoder
    position: jnp.ndarray      # scalar int32


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- init ----
    def init(self, rng) -> dict:
        cfg = self.cfg
        k_embed, k_head, k_layers, k_enc, k_cross = jax.random.split(rng, 5)

        def init_superblock(key):
            ks = jax.random.split(key, len(cfg.pattern))
            return {f"layer{i}": blocks.init_block(ks[i], cfg, spec)
                    for i, spec in enumerate(cfg.pattern)}

        layer_keys = jax.random.split(k_layers, cfg.n_repeats)
        params = {
            # d^-1/2 scale keeps tied-head logits ~N(0,1) at init.
            "embed": dense_init(k_embed, (cfg.padded_vocab, cfg.d_model),
                                cfg.pdtype, scale=cfg.d_model ** -0.5),
            "final_norm": jnp.zeros((cfg.d_model,), cfg.pdtype),
            "blocks": jax.vmap(init_superblock)(layer_keys),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(
                k_head, (cfg.d_model, cfg.padded_vocab), cfg.pdtype)
        if cfg.n_encoder_layers:
            enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
            enc_spec = LayerSpec("attn", "dense")

            def init_enc(key):
                return blocks.init_block(key, cfg, enc_spec)

            params["encoder"] = jax.vmap(init_enc)(enc_keys)
            params["enc_norm"] = jnp.zeros((cfg.d_model,), cfg.pdtype)
            # One cross-attention module per decoder layer (stacked).
            cross_keys = jax.random.split(k_cross, cfg.n_repeats)

            def init_cross(key):
                ks = jax.random.split(key, len(cfg.pattern))
                return {f"layer{i}": {
                    "xnorm": jnp.zeros((cfg.d_model,), cfg.pdtype),
                    "xattn": attn.init_attention(ks[i], cfg),
                } for i in range(len(cfg.pattern))}

            params["cross"] = jax.vmap(init_cross)(cross_keys)
        return params

    # -------------------------------------------------------- embedding ----
    def embed(self, params, tokens: jnp.ndarray) -> jnp.ndarray:
        e = jnp.take(params["embed"], tokens, axis=0)
        return constrain(e.astype(self.cfg.cdtype), "dp", None, None)

    def head_matrix(self, params) -> jnp.ndarray:
        return (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])

    def unembed(self, params, x_normed: jnp.ndarray) -> jnp.ndarray:
        """Project (already final-normed) hidden states to vocab logits."""
        out = x_normed @ self.head_matrix(params).astype(x_normed.dtype)
        # Keep the (B, S, V) logits batch-sharded + vocab-sharded: without
        # this XLA may replicate them (+700 GB/device at train_4k).
        out = constrain(out, "dp", None, "tp")
        return softcap(out.astype(jnp.float32), self.cfg.logit_softcap)

    def logits(self, params, x: jnp.ndarray) -> jnp.ndarray:
        return self.unembed(params,
                            rmsnorm(x, params["final_norm"],
                                    self.cfg.norm_eps))

    # ---------------------------------------------------------- encoder ----
    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        """Bidirectional encoder over precomputed frontend embeddings."""
        cfg = self.cfg
        s = frames.shape[1]
        x = frames.astype(cfg.cdtype) + sinusoidal_positions(
            s, cfg.d_model).astype(cfg.cdtype)[None]
        positions = jnp.broadcast_to(jnp.arange(s), frames.shape[:2])
        enc_spec = LayerSpec("attn", "dense")

        def step(carry, layer_params):
            y, _ = blocks.block_forward(layer_params, carry, cfg, enc_spec,
                                        positions, causal=False)
            return y, None

        fn = jax.checkpoint(step) if cfg.remat else step
        x, _ = jax.lax.scan(fn, x, params["encoder"])
        return rmsnorm(x, params["enc_norm"], cfg.norm_eps)

    def _cross_kv(self, params, enc_out: jnp.ndarray):
        """Precompute per-decoder-layer cross K/V (prefill-time, cached)."""
        cfg = self.cfg

        def per_repeat(cross_params):
            out = {}
            for i in range(len(cfg.pattern)):
                p = cross_params[f"layer{i}"]["xattn"]
                b, t, _ = enc_out.shape
                k = (enc_out @ p["wk"]).reshape(b, t, cfg.n_kv_heads, cfg.hd)
                v = (enc_out @ p["wv"]).reshape(b, t, cfg.n_kv_heads, cfg.hd)
                out[f"layer{i}"] = (k, v)
            return out

        return jax.vmap(per_repeat)(params["cross"])

    # ---------------------------------------------------------- forward ----
    def forward_hidden(self, params, tokens: jnp.ndarray,
                       frames: Optional[jnp.ndarray] = None,
                       patch_embeds: Optional[jnp.ndarray] = None):
        """Final-normed hidden states (B, S_tokens, D) + aux loss."""
        cfg = self.cfg
        x = self.embed(params, tokens)
        if patch_embeds is not None:               # VLM stub: prepend patches
            x = jnp.concatenate(
                [patch_embeds.astype(x.dtype), x], axis=1)
        s = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (x.shape[0], s))

        cross_kv = None
        if cfg.n_encoder_layers:
            enc_out = self.encode(params, frames)
            cross_kv = self._cross_kv(params, enc_out)

        def superblock(x, scanned):
            layer_params = scanned["blocks"]
            aux = jnp.zeros((), jnp.float32)
            for i, spec in enumerate(cfg.pattern):
                x, a = blocks.block_forward(layer_params[f"layer{i}"], x,
                                            cfg, spec, positions)
                aux += a
                if cross_kv is not None:
                    cp = scanned["cross"][f"layer{i}"]
                    k, v = scanned["cross_kv"][f"layer{i}"]
                    h = rmsnorm(x, cp["xnorm"], cfg.norm_eps)
                    x = x + attn.attention_cross(cp["xattn"], h, k, v)
            return x, aux

        scanned = {"blocks": params["blocks"]}
        if cross_kv is not None:
            scanned["cross"] = params["cross"]
            scanned["cross_kv"] = cross_kv

        def step(carry, sc):
            return superblock(carry, sc)

        fn = jax.checkpoint(step) if cfg.remat else step
        x, auxs = jax.lax.scan(fn, x, scanned)
        if patch_embeds is not None:               # only token positions score
            x = x[:, patch_embeds.shape[1]:]
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return x, jnp.sum(auxs)

    def forward(self, params, tokens: jnp.ndarray,
                frames: Optional[jnp.ndarray] = None,
                patch_embeds: Optional[jnp.ndarray] = None):
        """Full-sequence logits (training / prefill)."""
        x, aux = self.forward_hidden(params, tokens, frames=frames,
                                     patch_embeds=patch_embeds)
        return self.unembed(params, x), aux

    # ----------------------------------------------------------- decode ----
    def init_decode_state(self, params, batch: int, max_len: int,
                          frames: Optional[jnp.ndarray] = None) -> DecodeState:
        cfg = self.cfg

        proto = tuple(blocks.init_block_state(cfg, spec, batch, max_len)
                      for spec in cfg.pattern)
        # All-zeros states, stacked over repeats (scan slices the lead dim).
        layer_states = jax.tree.map(
            lambda x: jnp.zeros((cfg.n_repeats,) + x.shape, x.dtype), proto)
        cross_kv = None
        if cfg.n_encoder_layers:
            enc_out = self.encode(params, frames)
            cross_kv = self._cross_kv(params, enc_out)
        return DecodeState(layer_states, cross_kv, jnp.zeros((), jnp.int32))

    def decode_step(self, params, state: DecodeState, token: jnp.ndarray):
        """One serving step. token: (B,) int32 → (logits (B, V), state)."""
        cfg = self.cfg
        x = self.embed(params, token[:, None])

        def step(carry, scanned):
            x = carry
            layer_params = scanned["blocks"]
            layer_states = scanned["state"]
            new_states = []
            for i, spec in enumerate(cfg.pattern):
                x, ns = blocks.block_decode(layer_params[f"layer{i}"], x,
                                            layer_states[i], cfg, spec)
                new_states.append(ns)
                if state.cross_kv is not None:
                    cp = scanned["cross"][f"layer{i}"]
                    k, v = scanned["cross_kv"][f"layer{i}"]
                    h = rmsnorm(x, cp["xnorm"], cfg.norm_eps)
                    x = x + attn.attention_cross(cp["xattn"], h, k, v)
            return x, tuple(new_states)

        scanned = {"blocks": params["blocks"], "state": state.layer_states}
        if state.cross_kv is not None:
            scanned["cross"] = params["cross"]
            scanned["cross_kv"] = state.cross_kv
        x, new_layer_states = jax.lax.scan(step, x, scanned)
        logits = self.logits(params, x)[:, 0]
        return logits, DecodeState(new_layer_states, state.cross_kv,
                                   state.position + 1)
