"""Model zoo: unified LM over the ten assigned architectures."""
from .config import (LayerSpec, MambaSpec, ModelConfig, MoESpec, XLSTMSpec,
                     dense_pattern, round_up)
from .lm import LM, DecodeState

__all__ = ["LayerSpec", "MambaSpec", "ModelConfig", "MoESpec", "XLSTMSpec",
           "dense_pattern", "round_up", "LM", "DecodeState"]
