"""Shared building blocks: norms, RoPE, activations, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (LeCun)."""
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    s = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * s).astype(dtype)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm with fp32 accumulation (bf16-safe)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str):
    return {
        "swiglu": jax.nn.silu,
        "geglu": lambda x: jax.nn.gelu(x, approximate=True),
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "silu": jax.nn.silu,
    }[name]


# ---------------------------------------------------------------- RoPE -----
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings (seq, d)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (dim / d))
    out = jnp.zeros((seq, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out


def softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0:
        return jnp.tanh(logits / cap) * cap
    return logits
