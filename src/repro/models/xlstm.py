"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential scan).  [arXiv:2405.04517]

TPU adaptation notes (DESIGN.md §2):
* mLSTM trains in *chunkwise-parallel* form — quadratic attention-like
  compute inside fixed chunks, a linear recurrence on (C, n) chunk states
  across chunks — linear memory in S, MXU-dense inside chunks.  Decode is
  the O(1) recurrent update.
* Input gates use log-sigmoid (bounded) rather than the paper's raw
  exponential gate; this keeps the chunkwise form overflow-free without the
  max-stabilizer bookkeeping.  Cost/shape characteristics are identical;
  noted as a numerics simplification.
* sLSTM is inherently sequential (recurrent state mixing) → lax.scan.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init
from .config import ModelConfig


def _mlstm_dims(cfg: ModelConfig):
    d_in = int(cfg.xlstm.proj_factor * cfg.d_model)
    dh = d_in // cfg.n_heads
    return d_in, dh


# ============================== mLSTM ======================================
def init_mlstm(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in, _ = _mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "up": dense_init(ks[0], (d, 2 * d_in), cfg.pdtype),     # main, gate
        "wq": dense_init(ks[1], (d_in, d_in), cfg.pdtype),
        "wk": dense_init(ks[2], (d_in, d_in), cfg.pdtype),
        "wv": dense_init(ks[3], (d_in, d_in), cfg.pdtype),
        "wif": dense_init(ks[4], (d_in, 2 * cfg.n_heads), cfg.pdtype),
        "down": dense_init(ks[5], (d_in, d), cfg.pdtype),
    }


def _mlstm_gates(params, xm, cfg):
    h = cfg.n_heads
    gates = (xm @ params["wif"]).astype(jnp.float32)
    li = jax.nn.log_sigmoid(gates[..., :h])        # log input gate ≤ 0
    lf = jax.nn.log_sigmoid(gates[..., h:])        # log forget gate ≤ 0
    return li, lf


def mlstm_forward(params, x: jnp.ndarray, cfg: ModelConfig,
                  chunk: int = 256) -> jnp.ndarray:
    """Chunkwise-parallel mLSTM. x: (B, S, D); S divisible by chunk."""
    b, s, d = x.shape
    nh = cfg.n_heads
    d_in, dh = _mlstm_dims(cfg)
    chunk = min(chunk, s)
    nc = s // chunk
    xz = x @ params["up"]
    xm, z = jnp.split(xz, 2, axis=-1)
    q = (xm @ params["wq"]).reshape(b, s, nh, dh).astype(jnp.float32)
    k = (xm @ params["wk"]).reshape(b, s, nh, dh).astype(jnp.float32) \
        * dh ** -0.5
    v = (xm @ params["wv"]).reshape(b, s, nh, dh).astype(jnp.float32)
    li, lf = _mlstm_gates(params, xm, cfg)                   # (B,S,H)

    # Reshape into chunks: (B, nc, chunk, H, ·)
    cq = q.reshape(b, nc, chunk, nh, dh)
    ck = k.reshape(b, nc, chunk, nh, dh)
    cv = v.reshape(b, nc, chunk, nh, dh)
    cli = li.reshape(b, nc, chunk, nh)
    clf = lf.reshape(b, nc, chunk, nh)
    cum_f = jnp.cumsum(clf, axis=2)                          # within-chunk
    total_f = cum_f[:, :, -1]                                # (B,nc,H)

    # Intra-chunk: y[t] = Σ_{u≤t} exp(cumf_t − cumf_u + li_u)(q_t·k_u) v_u
    decay = cum_f[:, :, :, None, :] - cum_f[:, :, None, :, :] \
        + cli[:, :, None, :, :]                              # (B,nc,t,u,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, -jnp.inf)
    scores = jnp.einsum("bcthd,bcuhd->bctuh", cq, ck) * jnp.exp(decay)
    y_intra = jnp.einsum("bctuh,bcuhd->bcthd", scores, cv)

    # Inter-chunk state recurrence: C_c = exp(total_f) C_{c-1} + Σ_u exp(
    # total_f − cumf_u + li_u) k_u v_uᵀ  (and n likewise with k_u).
    w_u = jnp.exp(total_f[:, :, None] - cum_f + cli)         # (B,nc,chunk,H)
    dC = jnp.einsum("bcuh,bcuhd,bcuhe->bchde", w_u, ck, cv)  # (B,nc,H,dh,dh)
    dn = jnp.einsum("bcuh,bcuhd->bchd", w_u, ck)

    def step(carry, inp):
        c_state, n_state = carry
        dc, dnn, tf = inp                                    # per-chunk
        decay_c = jnp.exp(tf)[:, :, None, None]              # (B,H,1,1)
        c_new = c_state * decay_c + dc
        n_new = n_state * decay_c[..., 0] + dnn
        return (c_new, n_new), (c_state, n_state)

    c0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, nh, dh), jnp.float32)
    (_, _), (c_prev, n_prev) = jax.lax.scan(
        step, (c0, n0),
        (dC.swapaxes(0, 1), dn.swapaxes(0, 1), total_f.swapaxes(0, 1)))
    c_prev = c_prev.swapaxes(0, 1)                           # (B,nc,H,dh,dh)
    n_prev = n_prev.swapaxes(0, 1)

    # Inter-chunk contribution to each position.
    qw = cq * jnp.exp(cum_f)[..., None]                      # (B,nc,t,H,dh)
    y_inter = jnp.einsum("bcthd,bchde->bcthe", qw, c_prev)
    # Normalizer: inter-chunk n·q plus intra-chunk decayed key sums.
    n_inter = jnp.einsum("bcthd,bchd->bcth", qw, n_prev)
    n_intra = jnp.einsum("bctuh,bcuhd,bcthd->bcth",
                         jnp.exp(decay), ck, cq)
    denom = jnp.maximum(jnp.abs(n_inter + n_intra), 1.0)
    y = (y_intra + y_inter) / denom[..., None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    out = y * jax.nn.silu(z)
    return out @ params["down"]


class MLSTMState(NamedTuple):
    c: jnp.ndarray  # (B, H, dh, dh)
    n: jnp.ndarray  # (B, H, dh)


def init_mlstm_state(batch: int, cfg: ModelConfig) -> MLSTMState:
    _, dh = _mlstm_dims(cfg)
    return MLSTMState(jnp.zeros((batch, cfg.n_heads, dh, dh), jnp.float32),
                      jnp.zeros((batch, cfg.n_heads, dh), jnp.float32))


def mlstm_decode_step(params, x, state: MLSTMState, cfg: ModelConfig
                      ) -> Tuple[jnp.ndarray, MLSTMState]:
    """O(1) recurrent step. x: (B, 1, D)."""
    b = x.shape[0]
    nh = cfg.n_heads
    d_in, dh = _mlstm_dims(cfg)
    xz = x @ params["up"]
    xm, z = jnp.split(xz, 2, axis=-1)
    q = (xm @ params["wq"]).reshape(b, nh, dh).astype(jnp.float32)
    k = (xm @ params["wk"]).reshape(b, nh, dh).astype(jnp.float32) * dh ** -0.5
    v = (xm @ params["wv"]).reshape(b, nh, dh).astype(jnp.float32)
    li, lf = _mlstm_gates(params, xm, cfg)                   # (B,1,H)
    fi = jnp.exp(lf[:, 0])[..., None, None]                  # (B,H,1,1)
    ii = jnp.exp(li[:, 0])[..., None, None]
    c_new = state.c * fi + ii * k[..., :, None] * v[..., None, :]
    n_new = state.n * fi[..., 0] + ii[..., 0] * k
    num = jnp.einsum("bhde,bhd->bhe", c_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)), 1.0)
    y = (num / den[..., None]).reshape(b, 1, d_in).astype(x.dtype)
    out = y * jax.nn.silu(z)
    return out @ params["down"], MLSTMState(c_new, n_new)


# ============================== sLSTM ======================================
def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    ks = jax.random.split(key, 3)
    return {
        # Input and recurrent (block-diagonal per head) gate projections.
        "w": dense_init(ks[0], (d, 4 * d), cfg.pdtype),
        "r": dense_init(ks[1], (nh, dh, 4 * dh), cfg.pdtype),
        "b": jnp.zeros((4 * d,), cfg.pdtype),
        "down": dense_init(ks[2], (d, d), cfg.pdtype),
    }


class SLSTMState(NamedTuple):
    c: jnp.ndarray  # (B, D)
    n: jnp.ndarray  # (B, D)
    h: jnp.ndarray  # (B, D)


def init_slstm_state(batch: int, cfg: ModelConfig) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(z, z, z)


def _slstm_step(params, cfg, state: SLSTMState, xt: jnp.ndarray):
    """xt: (B, D) pre-projected input gates; recurrent mixing per head."""
    b, d = state.h.shape
    nh = cfg.n_heads
    dh = d // nh
    hprev = state.h.reshape(b, nh, dh)
    rec = jnp.einsum("bhd,hde->bhe", hprev.astype(jnp.float32),
                     params["r"].astype(jnp.float32)).reshape(b, 4 * d)
    g = xt.astype(jnp.float32) + rec + params["b"].astype(jnp.float32)
    i_, f_, z_, o_ = jnp.split(g, 4, axis=-1)
    i = jax.nn.sigmoid(i_)   # bounded input gate (see module docstring)
    f = jax.nn.sigmoid(f_)
    z = jnp.tanh(z_)
    o = jax.nn.sigmoid(o_)
    c = f * state.c + i * z
    n = f * state.n + i
    h = o * c / jnp.maximum(n, 1.0)
    return SLSTMState(c, n, h)


def slstm_forward(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Sequential scan over S. x: (B, S, D)."""
    xg = x @ params["w"]                                     # (B,S,4D)

    def step(state, xt):
        new = _slstm_step(params, cfg, state, xt)
        return new, new.h

    state0 = init_slstm_state(x.shape[0], cfg)
    _, hs = jax.lax.scan(step, state0, xg.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)                    # (B,S,D)
    return y @ params["down"]


def slstm_decode_step(params, x, state: SLSTMState, cfg: ModelConfig
                      ) -> Tuple[jnp.ndarray, SLSTMState]:
    xg = (x @ params["w"])[:, 0]
    new = _slstm_step(params, cfg, state, xg)
    y = new.h[:, None, :].astype(x.dtype)
    return y @ params["down"], new
