"""Architecture configuration for the assigned model pool.

One ``ModelConfig`` describes any of the ten assigned architectures.  The
layer stack is expressed as a *super-block pattern*: a short list of
``LayerSpec`` repeated ``n_repeats`` times (``jax.lax.scan`` runs over the
repeats, keeping HLO size and compile time independent of depth).  E.g.
jamba-1.5-large is 9 repeats of an 8-layer pattern (7×mamba + 1×attention,
MoE on odd layers); dense archs are N repeats of a single layer.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert_ff: int
    n_shared: int = 0            # shared experts (qwen2-moe), fused into one
    d_shared_ff: int = 0         # total shared-expert hidden width
    capacity_factor: float = 1.25
    shard_experts: bool = True   # EP over the model axis (needs E % model == 0)


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0             # 0 → ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class XLSTMSpec:
    proj_factor: float = 2.0     # mLSTM up-projection
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the super-block pattern."""

    mixer: str          # "attn" | "mamba" | "mlstm" | "slstm"
    mlp: str            # "dense" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[LayerSpec, ...]  # super-block layer pattern
    n_repeats: int                  # total layers = len(pattern) * n_repeats
    head_dim: int = 0               # 0 → d_model // n_heads
    act: str = "swiglu"             # swiglu | geglu | gelu
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    moe: Optional[MoESpec] = None
    mamba: Optional[MambaSpec] = None
    xlstm: Optional[XLSTMSpec] = None
    # Encoder (enc-dec archs); encoder layers use the same width/heads.
    n_encoder_layers: int = 0
    encoder_seq: int = 0            # e.g. whisper: 1500 precomputed frames
    # Modality frontend stub: "none" | "audio" | "patch".  Stubs mean
    # input_specs() provides precomputed frame/patch embeddings (assignment).
    frontend: str = "none"
    n_patches: int = 0              # vlm: patch embeddings prepended
    # Numerics / memory.
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # Attention flavor of the arch ("full" archs skip long_500k).
    subquadratic: bool = False

    # ---- derived ----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.n_repeats

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, 256)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def n_params(self) -> int:
        """Parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.hd
        total = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        per_pattern = 0
        for spec in self.pattern:
            if spec.mixer == "attn":
                per_pattern += d * hd * (self.n_heads + 2 * self.n_kv_heads)
                per_pattern += self.n_heads * hd * d
            elif spec.mixer == "mamba":
                m = self.mamba
                d_in = m.expand * d
                dt_rank = m.dt_rank or -(-d // 16)
                per_pattern += d * 2 * d_in            # in_proj
                per_pattern += m.d_conv * d_in          # conv
                per_pattern += d_in * (dt_rank + 2 * m.d_state)
                per_pattern += dt_rank * d_in + d_in * m.d_state  # dt_proj, A
                per_pattern += d_in * d                 # out_proj
            elif spec.mixer in ("mlstm", "slstm"):
                x = self.xlstm
                d_in = int(x.proj_factor * d) if spec.mixer == "mlstm" else d
                per_pattern += d * d_in * 2 + 4 * d_in * d_in // (
                    1 if spec.mixer == "mlstm" else 1)
                per_pattern += d_in * d
            gates = 2 if self.act in ("swiglu", "geglu") else 1
            if spec.mlp == "dense":
                per_pattern += d * self.d_ff * gates + self.d_ff * d
            elif spec.mlp == "moe":
                e = self.moe
                per_pattern += d * e.n_experts          # router
                per_pattern += e.n_experts * (
                    d * e.d_expert_ff * gates + e.d_expert_ff * d)
                if e.d_shared_ff:
                    per_pattern += d * e.d_shared_ff * gates + e.d_shared_ff * d
            per_pattern += 2 * d                        # norms
        total += per_pattern * self.n_repeats
        # Encoder stack (attention + dense mlp per layer).
        enc = self.n_encoder_layers * (
            d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            + d * self.d_ff * 2 + self.d_ff * d + 4 * d)
        # Decoder cross-attention (enc-dec archs).
        if self.n_encoder_layers:
            enc += self.n_layers * (
                d * hd * (self.n_heads + 2 * self.n_kv_heads)
                + self.n_heads * hd * d + 2 * d)
        return total + enc

    def active_params(self) -> int:
        """Active parameters per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.n_params()
        e = self.moe
        gates = 2 if self.act in ("swiglu", "geglu") else 1
        per_expert = e.d_expert_ff * self.d_model * (gates + 1)
        n_moe_layers = sum(1 for s in self.pattern
                           if s.mlp == "moe") * self.n_repeats
        inactive = per_expert * (e.n_experts - e.top_k) * n_moe_layers
        return self.n_params() - inactive


def dense_pattern(n_layers: int) -> Tuple[Tuple[LayerSpec, ...], int]:
    return (LayerSpec("attn", "dense"),), n_layers
