"""Mamba (S6 selective SSM) block — associative-scan implementation.

The GPU reference implementation is a fused CUDA kernel (hardware-aware
scan).  The TPU-native adaptation (DESIGN.md §2): the recurrence
``h_t = exp(Δ_t A)·h_{t-1} + Δ_t B_t x_t`` is a first-order linear
recurrence, i.e. an associative operation on (decay, increment) pairs, so we
run ``jax.lax.associative_scan`` over the sequence — O(log S) depth, fully
vectorized over (batch, d_inner, d_state), with d_inner sharded over the
`model` mesh axis so the (B,S,d_inner/TP,N) scan intermediates fit VMEM/HBM.
Decode keeps (conv window, h) as explicit state and costs O(1) per token —
this is what makes the 500k-token cell runnable for jamba.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .act_sharding import constrain
from .common import dense_init
from .config import ModelConfig


def _dims(cfg: ModelConfig):
    m = cfg.mamba
    d_in = m.expand * cfg.d_model
    dt_rank = m.dt_rank or -(-cfg.d_model // 16)
    return m, d_in, dt_rank


def init_mamba(key, cfg: ModelConfig):
    m, d_in, dt_rank = _dims(cfg)
    ks = jax.random.split(key, 6)
    # A initialized to -[1..N] (S4D-real), stored as log.
    a_init = jnp.tile(jnp.arange(1, m.d_state + 1, dtype=jnp.float32)[None],
                      (d_in, 1))
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, 2 * d_in), cfg.pdtype),
        "conv_w": dense_init(ks[1], (m.d_conv, d_in), cfg.pdtype),
        "conv_b": jnp.zeros((d_in,), cfg.pdtype),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * m.d_state),
                             cfg.pdtype),
        "dt_proj": dense_init(ks[3], (dt_rank, d_in), cfg.pdtype),
        "dt_bias": jnp.zeros((d_in,), cfg.pdtype),
        "A_log": jnp.log(a_init),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_in, cfg.d_model), cfg.pdtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 window: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv1d. x (B,S,C), w (K,C). window: (B,K-1,C) past."""
    k = w.shape[0]
    if window is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = window.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1]] * w[i][None, None, :]
    return out + b[None, None, :]


def _ssm_params(params, xc, m):
    dt_rank = params["dt_proj"].shape[0]
    proj = xc @ params["x_proj"]
    dt, b_ssm, c_ssm = jnp.split(
        proj, [dt_rank, dt_rank + m.d_state], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"]
                         + params["dt_bias"][None, None, :])
    a = -jnp.exp(params["A_log"].astype(jnp.float32))       # (d_in, N)
    return dt.astype(jnp.float32), b_ssm.astype(jnp.float32), \
        c_ssm.astype(jnp.float32), a


def _scan_op(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def mamba_forward(params, x: jnp.ndarray, cfg: ModelConfig,
                  chunk: int = 256) -> jnp.ndarray:
    """Full-sequence selective scan, **chunked**. x: (B, S, D).

    The one-shot associative scan materializes O(log S) levels of
    (B,S,d_inner,N) fp32 intermediates under autodiff — measured 662 GB of
    temp per device on the jamba train_4k cell (EXPERIMENTS.md §Perf).
    Chunking is the SSD/hardware-aware-scan structure: an associative scan
    *inside* fixed chunks (rematerialized — only the small (dt,B,C,x)
    projections are saved), with the (B,d,N) boundary state carried across
    chunks by lax.scan.  Exactly equal to the unchunked scan.
    """
    m, d_in, _ = _dims(cfg)
    b, s, _ = x.shape
    xz = constrain(x @ params["in_proj"], "dp", None, "tp")
    xi, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xi, params["conv_w"], params["conv_b"]))
    dt, b_ssm, c_ssm, a = _ssm_params(params, xc, m)
    xcf = xc.astype(jnp.float32)

    chunk = _largest_divisor(s, min(chunk, s))
    nch = s // chunk

    def to_chunks(t):
        return t.reshape(b, nch, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(dt), to_chunks(b_ssm), to_chunks(c_ssm), to_chunks(xcf))

    def chunk_body(h0, inp):
        dt_c, b_c, c_c, xc_c = inp                          # (B, chunk, ·)
        da = jnp.exp(dt_c[..., None] * a[None, None])       # (B,chunk,d,N)
        dbx = (dt_c * xc_c)[..., None] * b_c[:, :, None, :]
        a_cum, h_in = jax.lax.associative_scan(_scan_op, (da, dbx), axis=1)
        h = h_in + a_cum * h0[:, None]                      # add carry-in
        y_c = jnp.einsum("bsdn,bsn->bsd", h, c_c)
        return h[:, -1], y_c

    h0 = jnp.zeros((b, d_in, m.d_state), jnp.float32)
    _, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, s, d_in)
    y = y + params["D"].astype(jnp.float32)[None, None] * xcf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["out_proj"]


def _largest_divisor(n: int, cap: int) -> int:
    for c in range(min(cap, n), 0, -1):
        if n % c == 0:
            return c
    return 1


class MambaState(NamedTuple):
    conv: jnp.ndarray  # (B, K-1, d_in) trailing inputs
    h: jnp.ndarray     # (B, d_in, N) SSM state


def init_mamba_state(batch: int, cfg: ModelConfig, dtype) -> MambaState:
    m, d_in, _ = _dims(cfg)
    return MambaState(jnp.zeros((batch, m.d_conv - 1, d_in), dtype),
                      jnp.zeros((batch, d_in, m.d_state), jnp.float32))


def mamba_decode_step(params, x: jnp.ndarray, state: MambaState,
                      cfg: ModelConfig) -> Tuple[jnp.ndarray, MambaState]:
    """Single-token step. x: (B, 1, D); O(1) state update."""
    m, d_in, _ = _dims(cfg)
    xz = x @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                        # (B,1,d_in)
    xc = jax.nn.silu(_causal_conv(xi, params["conv_w"], params["conv_b"],
                                  window=state.conv))
    new_conv = jnp.concatenate([state.conv[:, 1:], xi.astype(state.conv.dtype)],
                               axis=1)
    dt, b_ssm, c_ssm, a = _ssm_params(params, xc, m)
    xcf = xc.astype(jnp.float32)
    da = jnp.exp(dt[:, 0, :, None] * a[None])                # (B,d,N)
    dbx = (dt * xcf)[:, 0, :, None] * b_ssm[:, 0, None, :]
    h = da * state.h + dbx
    y = jnp.einsum("bdn,bn->bd", h, c_ssm[:, 0])[:, None, :]
    y = y + params["D"].astype(jnp.float32)[None, None] * xcf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["out_proj"], MambaState(new_conv, h)
