"""GQA attention: flash (blocked, online-softmax) training/prefill path and
cached decode path.

The flash path is mathematically identical to naive attention (tested) but
never materializes the (S×S) score matrix: lax.scan over KV blocks inside a
scan over Q blocks, carrying (max, denom, acc) — the standard online-softmax
restructuring, which is what makes 32k-token prefill fit in HBM.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .act_sharding import constrain
from .common import apply_rope, dense_init

NEG_INF = -1e30


def init_attention(key, cfg, cross: bool = False):
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), cfg.pdtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), cfg.pdtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), cfg.pdtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), cfg.pdtype),
    }


def _split_heads(x, n_heads, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, hd)


def qkv(params, x, cfg, positions=None, rope: bool = True):
    # Head-sharded (TP) activations; constrain falls back to replicated for
    # archs whose head counts don't divide the model axis (e.g. smollm 15H).
    q = _split_heads(x @ params["wq"], cfg.n_heads, cfg.hd)
    k = _split_heads(x @ params["wk"], cfg.n_kv_heads, cfg.hd)
    v = _split_heads(x @ params["wv"], cfg.n_kv_heads, cfg.hd)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _group(q, n_kv):
    """(B,S,H,hd) → (B,S,KV,G,hd) grouping query heads onto KV heads."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def naive_attention(q, k, v, causal: bool, q_offset: int = 0,
                    kv_len: jnp.ndarray | None = None) -> jnp.ndarray:
    """Reference attention (tests + decode). q:(B,Sq,H,hd) k/v:(B,Skv,KV,hd)."""
    n_kv = k.shape[2]
    qg = _group(q, n_kv)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    sq, skv = q.shape[1], k.shape[1]
    if causal:
        qpos = jnp.arange(sq) + q_offset
        mask = qpos[:, None] >= jnp.arange(skv)[None, :]
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    if kv_len is not None:
        mask = jnp.arange(skv)[None, :] < kv_len[:, None]          # (B, Skv)
        logits = jnp.where(mask[:, None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    b, s = q.shape[0], q.shape[1]
    return out.reshape(b, s, -1).astype(q.dtype)


def _flash_fwd_impl(q, k, v, causal, q_block, kv_block):
    """Forward pass; returns (out (B,S,KV,G,hd) fp32, lse (nq,B,KV,G,qb))."""
    b, s, h, hd = q.shape
    n_kv = k.shape[2]
    g = h // n_kv
    nq, nk = s // q_block, k.shape[1] // kv_block
    scale = hd ** -0.5

    qg = _group(q, n_kv).astype(jnp.float32)             # (B,S,KV,G,hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    q_blocks = qg.reshape(b, nq, q_block, n_kv, g, hd)
    k_blocks = kf.reshape(b, nk, kv_block, n_kv, hd)
    v_blocks = vf.reshape(b, nk, kv_block, n_kv, hd)

    def q_step(_, qi):
        qb_, qidx = qi                                   # (B,qb,KV,G,hd)
        q_pos = qidx * q_block + jnp.arange(q_block)

        def kv_step(carry, kvj):
            m, l, acc = carry
            kb_, vb_, kidx = kvj
            k_pos = kidx * kv_block + jnp.arange(kv_block)
            logits = jnp.einsum("bskgh,btkh->bkgst", qb_, kb_) * scale
            if causal:
                # Additive penalty, not jnp.where on a broadcast pred: XLA
                # hoists loop-invariant masks out of the scan and a stacked
                # (nq·nk·B·KV·G·qb·kb) pred buffer costs GBs (§Perf log).
                pen = (q_pos[:, None] < k_pos[None, :]).astype(
                    jnp.float32) * NEG_INF
                logits = logits + pen[None, None, None]
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgst,btkh->bkgsh", p, vb_)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, n_kv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, n_kv, g, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (k_blocks.swapaxes(0, 1), v_blocks.swapaxes(0, 1),
             jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,KV,G,qb,hd)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))         # (B,KV,G,qb)
        return None, (out.transpose(0, 3, 1, 2, 4), lse)

    _, (outs, lses) = jax.lax.scan(
        q_step, None, (q_blocks.swapaxes(0, 1), jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, n_kv, g, hd)
    return out, lses


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal, q_block, kv_block):
    out, _ = _flash_fwd_impl(q, k, v, causal, q_block, kv_block)
    b, s, h, hd = q.shape
    return out.reshape(b, s, h, hd).astype(q.dtype)


def _flash_vjp_fwd(q, k, v, causal, q_block, kv_block):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_block, kv_block)
    b, s, h, hd = q.shape
    return (out.reshape(b, s, h, hd).astype(q.dtype),
            (q, k, v, out, lse))


def _flash_vjp_bwd(causal, q_block, kv_block, res, do):
    """FlashAttention-2 backward: recompute p per (q,kv) block pair.

    Only O(S) residuals (q,k,v,o,lse) are saved — autodiff through the
    forward scans would otherwise stash every block's probability tensor
    (measured 40 GB/device at train_4k before this custom VJP;
    EXPERIMENTS.md §Perf).
    """
    q, k, v, o, lse = res                         # o: (B,S,KV,G,hd) fp32
    b, s, h, hd = q.shape
    n_kv = k.shape[2]
    g = h // n_kv
    nq, nk = s // q_block, k.shape[1] // kv_block
    scale = hd ** -0.5

    qg = _group(q, n_kv).astype(jnp.float32)
    dog = _group(do, n_kv).astype(jnp.float32)            # (B,S,KV,G,hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    delta = jnp.sum(dog * o, axis=-1)                     # (B,S,KV,G)

    q_blocks = qg.reshape(b, nq, q_block, n_kv, g, hd).swapaxes(0, 1)
    do_blocks = dog.reshape(b, nq, q_block, n_kv, g, hd).swapaxes(0, 1)
    delta_blocks = delta.reshape(b, nq, q_block, n_kv, g) \
        .transpose(1, 0, 3, 4, 2)                         # (nq,B,KV,G,qb)
    k_blocks = kf.reshape(b, nk, kv_block, n_kv, hd).swapaxes(0, 1)
    v_blocks = vf.reshape(b, nk, kv_block, n_kv, hd).swapaxes(0, 1)
    # lse from fwd: (nq, B, KV, G, qb)

    def q_step(carry, qs):
        dk, dv = carry
        qb_, dob_, deltab_, lseb_, qidx = qs
        q_pos = qidx * q_block + jnp.arange(q_block)

        def kv_step(dq_acc_and_kdv, kvj):
            dq_acc, dk_, dv_ = dq_acc_and_kdv
            kb_, vb_, kidx = kvj
            k_pos = kidx * kv_block + jnp.arange(kv_block)
            logits = jnp.einsum("bskgh,btkh->bkgst", qb_, kb_) * scale
            if causal:
                pen = (q_pos[:, None] < k_pos[None, :]).astype(
                    jnp.float32) * NEG_INF
                logits = logits + pen[None, None, None]
            p = jnp.exp(logits - lseb_[..., None])        # (B,KV,G,qb,kb)
            dv_blk = jnp.einsum("bkgst,bskgh->btkh", p, dob_)
            dp = jnp.einsum("bskgh,btkh->bkgst", dob_, vb_)
            ds = p * (dp - deltab_[..., None]) * scale
            dq_blk = jnp.einsum("bkgst,btkh->bskgh", ds, kb_)
            dk_blk = jnp.einsum("bkgst,bskgh->btkh", ds, qb_)
            dk_ = jax.lax.dynamic_update_slice_in_dim(
                dk_, jax.lax.dynamic_slice_in_dim(
                    dk_, kidx * kv_block, kv_block, 1) + dk_blk,
                kidx * kv_block, axis=1)
            dv_ = jax.lax.dynamic_update_slice_in_dim(
                dv_, jax.lax.dynamic_slice_in_dim(
                    dv_, kidx * kv_block, kv_block, 1) + dv_blk,
                kidx * kv_block, axis=1)
            return (dq_acc + dq_blk, dk_, dv_), None

        dq0 = jnp.zeros((b, q_block, n_kv, g, hd), jnp.float32)
        (dq_blk, dk, dv), _ = jax.lax.scan(
            kv_step, (dq0, dk, dv),
            (k_blocks, v_blocks, jnp.arange(nk)))
        return (dk, dv), dq_blk

    dk0 = jnp.zeros((b, k.shape[1], n_kv, hd), jnp.float32)
    dv0 = jnp.zeros_like(dk0)
    (dk, dv), dq_blocks = jax.lax.scan(
        q_step, (dk0, dv0),
        (q_blocks, do_blocks, delta_blocks, lse, jnp.arange(nq)))
    dq = dq_blocks.swapaxes(0, 1).reshape(b, s, h, hd)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def _largest_divisor(n: int, cap: int) -> int:
    for b in range(min(cap, n), 0, -1):
        if n % b == 0:
            return b
    return 1


def flash_attention(q, k, v, causal: bool = True, q_block: int = 512,
                    kv_block: int = 512) -> jnp.ndarray:
    """Blocked online-softmax attention; exact, O(S·block) memory, with a
    FlashAttention-2 custom VJP (recompute-based backward).

    q (B,S,H,hd); k,v (B,S,KV,hd) → (B,S,H·hd).  Block sizes snap to the
    largest divisor of S (e.g. whisper's 1500-frame encoder → 500); if the
    divisor degenerates, fall back to naive attention.
    """
    b, s, h, hd = q.shape
    q_block = _largest_divisor(s, min(q_block, s))
    kv_block = _largest_divisor(k.shape[1], min(kv_block, k.shape[1]))
    if q_block < 64 or kv_block < 64:       # prime-ish lengths: not worth it
        return naive_attention(q, k, v, causal=causal)
    out = _flash(q, k, v, causal, q_block, kv_block)
    return out.reshape(b, s, h * hd)


class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, S_max, KV, hd)
    v: jnp.ndarray
    length: jnp.ndarray   # scalar int32 — tokens already cached


def init_kv_cache(batch: int, max_len: int, cfg, dtype) -> KVCache:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def attention_train(params, x, cfg, positions, causal=True,
                    use_flash=True) -> jnp.ndarray:
    """Full-sequence attention (training / prefill), no cache."""
    q, k, v = qkv(params, x, cfg, positions)
    if use_flash and x.shape[1] > 1024:
        # Expand KV heads to the full head count so the flat head dim
        # shards over the model axis even when TP > n_kv (GQA); per-device
        # bytes are unchanged (each shard holds only its own heads).
        g = cfg.n_heads // cfg.n_kv_heads
        if g > 1:
            k = constrain(jnp.repeat(k, g, axis=2), "dp", None, "tp", None)
            v = constrain(jnp.repeat(v, g, axis=2), "dp", None, "tp", None)
        out = flash_attention(q, k, v, causal=causal)
    else:
        out = naive_attention(q, k, v, causal=causal)
    out = out @ params["wo"]
    return constrain(out, "dp", None, None)


def attention_decode(params, x, cfg, cache: KVCache,
                     rope: bool = True):
    """Single-token decode with KV cache append. x: (B, 1, D)."""
    pos = cache.length[None, None] + jnp.zeros((x.shape[0], 1), jnp.int32)
    q, k, v = qkv(params, x, cfg, pos, rope=rope)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k.astype(cache.k.dtype), cache.length, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v.astype(cache.v.dtype), cache.length, axis=1)
    new_len = cache.length + 1
    kv_len = jnp.full((x.shape[0],), new_len, jnp.int32)
    out = naive_attention(q, k_cache, v_cache, causal=False, kv_len=kv_len)
    return out @ params["wo"], KVCache(k_cache, v_cache, new_len)


def attention_cross(params, x, k, v) -> jnp.ndarray:
    """Cross-attention against precomputed encoder K/V (no RoPE, no mask)."""
    cfg_heads = params["wq"].shape[1] // k.shape[-1]
    q = _split_heads(x @ params["wq"], cfg_heads, k.shape[-1])
    out = naive_attention(q, k, v, causal=False)
    return out @ params["wo"]
