"""Layer blocks: (mixer → residual → MLP/MoE → residual), type-dispatched.

A block's mixer is one of attn / mamba / mlstm / slstm; its MLP slot is
dense / moe / none (xLSTM blocks are self-contained).  Decode state is a
per-block NamedTuple chosen by mixer type; stacks of states are scanned in
lock-step with stacked block params.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba as mb
from . import xlstm as xl
from .common import rmsnorm
from .config import LayerSpec, ModelConfig
from .mlp import init_mlp, mlp
from .moe import init_moe, moe_mlp


def init_block(key, cfg: ModelConfig, spec: LayerSpec):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    params: dict = {"norm1": jnp.zeros((d,), cfg.pdtype)}
    if spec.mixer == "attn":
        params["attn"] = attn.init_attention(ks[0], cfg)
    elif spec.mixer == "mamba":
        params["mamba"] = mb.init_mamba(ks[0], cfg)
    elif spec.mixer == "mlstm":
        params["mlstm"] = xl.init_mlstm(ks[0], cfg)
    elif spec.mixer == "slstm":
        params["slstm"] = xl.init_slstm(ks[0], cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.mlp == "dense":
        params["norm2"] = jnp.zeros((d,), cfg.pdtype)
        params["mlp"] = init_mlp(ks[1], d, cfg.d_ff, cfg.act, cfg.pdtype)
    elif spec.mlp == "moe":
        params["norm2"] = jnp.zeros((d,), cfg.pdtype)
        params["moe"] = init_moe(ks[1], cfg)
    return params


def block_forward(params, x, cfg: ModelConfig, spec: LayerSpec, positions,
                  causal: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence pass. Returns (x, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, params["norm1"], cfg.norm_eps)
    if spec.mixer == "attn":
        mixed = attn.attention_train(params["attn"], h, cfg, positions,
                                     causal=causal)
    elif spec.mixer == "mamba":
        mixed = mb.mamba_forward(params["mamba"], h, cfg)
    elif spec.mixer == "mlstm":
        mixed = xl.mlstm_forward(params["mlstm"], h, cfg)
    else:
        mixed = xl.slstm_forward(params["slstm"], h, cfg)
    x = x + mixed
    if spec.mlp == "dense":
        h = rmsnorm(x, params["norm2"], cfg.norm_eps)
        x = x + mlp(params["mlp"], h, cfg.act)
    elif spec.mlp == "moe":
        h = rmsnorm(x, params["norm2"], cfg.norm_eps)
        y, aux = moe_mlp(params["moe"], h, cfg)
        x = x + y
    return x, aux


def init_block_state(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int) -> Any:
    if spec.mixer == "attn":
        return attn.init_kv_cache(batch, max_len, cfg, cfg.cdtype)
    if spec.mixer == "mamba":
        return mb.init_mamba_state(batch, cfg, cfg.cdtype)
    if spec.mixer == "mlstm":
        return xl.init_mlstm_state(batch, cfg)
    return xl.init_slstm_state(batch, cfg)


def block_decode(params, x, state, cfg: ModelConfig, spec: LayerSpec
                 ) -> Tuple[jnp.ndarray, Any]:
    """Single-token pass. x: (B, 1, D)."""
    h = rmsnorm(x, params["norm1"], cfg.norm_eps)
    if spec.mixer == "attn":
        mixed, state = attn.attention_decode(params["attn"], h, cfg, state)
    elif spec.mixer == "mamba":
        mixed, state = mb.mamba_decode_step(params["mamba"], h, state, cfg)
    elif spec.mixer == "mlstm":
        mixed, state = xl.mlstm_decode_step(params["mlstm"], h, state, cfg)
    else:
        mixed, state = xl.slstm_decode_step(params["slstm"], h, state, cfg)
    x = x + mixed
    if spec.mlp == "dense":
        h = rmsnorm(x, params["norm2"], cfg.norm_eps)
        x = x + mlp(params["mlp"], h, cfg.act)
    elif spec.mlp == "moe":
        h = rmsnorm(x, params["norm2"], cfg.norm_eps)
        y, _ = moe_mlp(params["moe"], h, cfg)
        x = x + y
    return x, state
