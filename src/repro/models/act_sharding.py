"""Activation sharding constraints, injected by the launch layer.

Model code calls ``constrain(x, "dp", None, "tp")`` with logical axis roles;
the launch layer maps roles to the concrete mesh axes before tracing
(``set_activation_sharding``).  Outside a mesh context (unit tests, CPU
examples) everything is a no-op.

Without these constraints XLA's SPMD propagation may choose to replicate
the (B, S, V) logits / loss intermediates — measured +700 GB/device on the
smollm train_4k dry-run cell (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_CTX = {"dp": None, "tp": None, "mesh": None}


def set_activation_sharding(dp_axes: Optional[Tuple[str, ...]],
                            tp_axis: Optional[str], mesh=None):
    _CTX["dp"] = tuple(dp_axes) if dp_axes else None
    _CTX["tp"] = tp_axis
    _CTX["mesh"] = mesh


def clear_activation_sharding():
    set_activation_sharding(None, None, None)


def _resolve(role, size: int):
    if role is None:
        return None
    axes = _CTX["dp"] if role == "dp" else (
        (_CTX["tp"],) if _CTX["tp"] else None)
    if not axes:
        return None
    mesh = _CTX["mesh"]
    if mesh is not None:
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if size % total != 0:
            return None
    return axes if len(axes) > 1 else axes[0]


def constrain(x: jax.Array, *roles) -> jax.Array:
    """with_sharding_constraint by logical role ("dp"/"tp"/None) per dim."""
    if _CTX["dp"] is None and _CTX["tp"] is None:
        return x
    spec = P(*[_resolve(r, d) for r, d in zip(roles, x.shape)])
    mesh = _CTX["mesh"]
    if mesh is not None:
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)
