"""Fault-tolerant runtime: failure detection, elastic re-mesh, stragglers.

At 1000+ nodes the *expected* state is "something is broken".  Three
mechanisms, all mesh-topology-aware and all testable on CPU through
``SimulatedCluster``:

1. **HeartbeatMonitor** — per-host heartbeats with a deadline; hosts missing
   the deadline are declared failed.  (On a real cluster the transport is
   the coordination service / GCS bucket heartbeat files; here it's a
   pluggable clock + store so tests can inject failures deterministically.)
2. **Elastic re-mesh** — given the surviving host set, pick the largest
   valid (pod, data, model) factorization ≤ survivors that preserves the
   model axis (TP size is fixed by the sharding plan; we shed data-parallel
   replicas first — they're stateless beyond the optimizer shards, which
   restore from the last checkpoint).  Returns the new mesh shape + the
   step to resume from.
3. **StragglerMonitor** — EWMA of per-host step times; hosts slower than
   ``threshold ×`` the fleet median for ``patience`` consecutive steps are
   flagged; policy = report / evict (treat as failed → re-mesh).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


# ----------------------------------------------------------- heartbeats ----
class HeartbeatMonitor:
    def __init__(self, hosts: Sequence[int], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        now = clock()
        self.last_seen: Dict[int, float] = {h: now for h in hosts}

    def beat(self, host: int, at: Optional[float] = None):
        self.last_seen[host] = self.clock() if at is None else at

    def failed_hosts(self) -> List[int]:
        now = self.clock()
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout]

    def alive_hosts(self) -> List[int]:
        failed = set(self.failed_hosts())
        return [h for h in self.last_seen if h not in failed]


# ---------------------------------------------------------- re-meshing -----
@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    n_devices: int

    @property
    def data_parallel(self) -> int:
        total = 1
        for s, a in zip(self.shape, self.axes):
            if a in ("pod", "data"):
                total *= s
        return total


def elastic_remesh(alive_devices: int, model_parallel: int,
                   devices_per_pod: int = 256) -> MeshPlan:
    """Largest valid mesh ≤ alive_devices keeping the model axis intact.

    Sheds DP replicas first (model shards must stay complete — losing one
    makes the whole replica unusable).  Multi-pod ("pod" axis) survives only
    if ≥ 2 complete pods remain.
    """
    if alive_devices < model_parallel:
        raise RuntimeError(
            f"cannot keep TP={model_parallel} with {alive_devices} devices")
    dp_total = alive_devices // model_parallel
    pods = alive_devices // devices_per_pod
    dp_per_pod = devices_per_pod // model_parallel
    if pods >= 2 and dp_total >= pods * dp_per_pod:
        return MeshPlan((pods, dp_per_pod, model_parallel),
                        ("pod", "data", "model"),
                        pods * dp_per_pod * model_parallel)
    return MeshPlan((dp_total, model_parallel), ("data", "model"),
                    dp_total * model_parallel)


# ----------------------------------------------------------- stragglers ----
class StragglerMonitor:
    def __init__(self, hosts: Sequence[int], threshold: float = 1.5,
                 patience: int = 3, alpha: float = 0.3):
        self.threshold = threshold
        self.patience = patience
        self.alpha = alpha
        self.ewma: Dict[int, float] = {h: 0.0 for h in hosts}
        self.strikes: Dict[int, int] = {h: 0 for h in hosts}

    def record_step(self, times: Dict[int, float]) -> List[int]:
        """Feed per-host step times; returns hosts flagged as stragglers."""
        for h, t in times.items():
            prev = self.ewma.get(h, 0.0)
            self.ewma[h] = t if prev == 0.0 else \
                self.alpha * t + (1 - self.alpha) * prev
        vals = sorted(v for v in self.ewma.values() if v > 0)
        if not vals:
            return []
        median = vals[len(vals) // 2]
        flagged = []
        for h, v in self.ewma.items():
            if v > self.threshold * median:
                self.strikes[h] = self.strikes.get(h, 0) + 1
                if self.strikes[h] >= self.patience:
                    flagged.append(h)
            else:
                self.strikes[h] = 0
        return flagged


# ------------------------------------------------------ simulated fleet ----
class SimulatedCluster:
    """Deterministic cluster simulation for CPU tests of the FT loop."""

    def __init__(self, n_hosts: int, devices_per_host: int = 4):
        self.n_hosts = n_hosts
        self.devices_per_host = devices_per_host
        self.t = 0.0
        self.failed: set = set()
        self.slow: Dict[int, float] = {}
        self.monitor = HeartbeatMonitor(range(n_hosts), timeout_s=30.0,
                                        clock=lambda: self.t)

    def advance(self, dt: float):
        self.t += dt
        for h in range(self.n_hosts):
            if h not in self.failed:
                self.monitor.beat(h, at=self.t)

    def fail_host(self, host: int):
        self.failed.add(host)

    def make_slow(self, host: int, factor: float):
        self.slow[host] = factor

    def step_times(self, base: float = 1.0) -> Dict[int, float]:
        return {h: base * self.slow.get(h, 1.0)
                for h in range(self.n_hosts) if h not in self.failed}

    @property
    def alive_devices(self) -> int:
        return (self.n_hosts - len(self.failed)) * self.devices_per_host


# ------------------------------------------------------ recovery driver ----
def run_with_recovery(train_loop: Callable, cluster: SimulatedCluster,
                      model_parallel: int, checkpoint_mgr,
                      max_restarts: int = 3):
    """Orchestration skeleton: run → on failure, re-mesh → restore → resume.

    ``train_loop(mesh_plan, start_step)`` runs until it raises
    ``HostFailure`` (simulated) or returns the final step.
    """
    restarts = 0
    plan = elastic_remesh(cluster.alive_devices, model_parallel,
                          devices_per_pod=cluster.alive_devices)
    step = checkpoint_mgr.latest_step() or 0
    while True:
        try:
            return train_loop(plan, step), restarts
        except HostFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            cluster.fail_host(e.host)
            plan = elastic_remesh(cluster.alive_devices, model_parallel,
                                  devices_per_pod=cluster.alive_devices)
            step = checkpoint_mgr.latest_step() or 0


class HostFailure(RuntimeError):
    def __init__(self, host: int):
        super().__init__(f"host {host} failed")
        self.host = host
