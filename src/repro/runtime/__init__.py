"""Distributed runtime: failure detection, elastic re-mesh, stragglers."""
from .fault_tolerance import (HeartbeatMonitor, HostFailure, MeshPlan,
                              SimulatedCluster, StragglerMonitor,
                              elastic_remesh, run_with_recovery)

__all__ = ["HeartbeatMonitor", "HostFailure", "MeshPlan", "SimulatedCluster",
           "StragglerMonitor", "elastic_remesh", "run_with_recovery"]
