"""Sharded checkpointing: atomic, async, resharding-on-restore.

Layout (one directory per step)::

    <dir>/step_000100/
        host_0000.npz        # this host's shards of every leaf
        meta.json            # tree structure, global shapes, step, extras
        COMMITTED            # written last — partial checkpoints are ignored

* Each host writes only the addressable shards it owns (per-leaf local
  slices + index metadata), so checkpoint bandwidth scales with hosts.
* ``save_async`` snapshots to host RAM synchronously (device→host copy) and
  writes in a background thread — the train loop blocks only for the copy,
  the standard TPU checkpoint overlap.
* ``restore`` rebuilds ``jax.Array``s for an *arbitrary* target mesh/
  sharding (elastic restart after re-mesh): every host reads the files
  covering the shard indices it now needs.
* Retention: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save ----
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def save(self, step: int, tree: Any, extras: Optional[dict] = None):
        """Synchronous sharded save (host-local shards + metadata)."""
        self.wait()
        host_data, meta = self._snapshot(step, tree, extras)
        self._write(step, host_data, meta)

    def save_async(self, step: int, tree: Any, extras: Optional[dict] = None):
        """Device→host copy now; file I/O in a background thread."""
        self.wait()
        host_data, meta = self._snapshot(step, tree, extras)
        self._thread = threading.Thread(
            target=self._write, args=(step, host_data, meta), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _snapshot(self, step, tree, extras):
        paths, leaves, _ = _flatten_with_paths(tree)
        host_data = {}
        shard_meta = {}
        for path, leaf in zip(paths, leaves):
            arr = leaf if isinstance(leaf, jax.Array) else jax.numpy.asarray(
                leaf)
            shards = []
            for i, s in enumerate(arr.addressable_shards):
                key = f"{path}::{i}"
                host_data[key] = np.asarray(s.data)
                shards.append({"key": key, "index": _index_to_json(s.index)})
            shard_meta[path] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "shards": shards,
            }
        meta = {"step": step, "leaves": shard_meta, "extras": extras or {},
                "process_index": jax.process_index()}
        return host_data, meta

    def _write(self, step, host_data, meta):
        d = self._step_dir(step)
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(
            tmp, f"host_{jax.process_index():04d}.npz"), **host_data)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        # Atomic commit: rename, then marker file.
        if os.path.exists(d):
            shutil.rmtree(d)
        os.replace(tmp, d)
        with open(os.path.join(d, "COMMITTED"), "w") as f:
            f.write("ok")
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---------------------------------------------------------- restore ----
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            m = re.match(r"step_(\d+)$", name)
            if m and os.path.exists(os.path.join(self.dir, name, "COMMITTED")):
                out.append(int(m.group(1)))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any,
                sharding_fn: Optional[Callable[[str], Any]] = None):
        """Restore into the structure of ``target`` (arrays or
        ShapeDtypeStruct), resharding onto each target leaf's sharding."""
        d = self._step_dir(step)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        files = {}
        for name in os.listdir(d):
            if name.endswith(".npz"):
                files[name] = np.load(os.path.join(d, name))
        paths, leaves, treedef = _flatten_with_paths(target)
        out = []
        for path, leaf in zip(paths, leaves):
            info = meta["leaves"][path]
            full = np.zeros(tuple(info["shape"]), np.dtype(info["dtype"]))
            for shard in info["shards"]:
                for f in files.values():
                    if shard["key"] in f:
                        full[_index_from_json(shard["index"])] = \
                            f[shard["key"]]
                        break
            sharding = (sharding_fn(path) if sharding_fn
                        else getattr(leaf, "sharding", None))
            if sharding is not None:
                arr = jax.device_put(full, sharding)
            else:
                arr = jax.numpy.asarray(full)
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), meta["extras"]


def _index_to_json(index):
    return [[s.start, s.stop, s.step] for s in index]


def _index_from_json(idx):
    return tuple(slice(a, b, c) for a, b, c in idx)
