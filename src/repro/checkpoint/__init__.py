"""Checkpoint substrate: sharded, async, resharding-on-restore."""
from .manager import CheckpointManager

__all__ = ["CheckpointManager"]
