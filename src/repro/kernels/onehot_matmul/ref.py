"""Pure-jnp oracle for the onehot_matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def onehot_matmul_ref(idx: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """out = onehot(idx) @ table with zero rows for out-of-range idx."""
    r = table.shape[0]
    onehot = (idx[:, None] == jnp.arange(r)[None, :]).astype(jnp.float32)
    return onehot @ table.astype(jnp.float32)
