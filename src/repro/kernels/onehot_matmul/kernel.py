"""Pallas TPU kernel: join-as-matmul on the MXU.

``out = onehot(idx) @ table`` — the core MM-Join/materialization primitive
(paper Alg. 1 / §2.3.3) and, identically, MoE dispatch/combine.  The one-hot
row-matching matrix I is *never materialized in HBM*: each (block_n ×
block_r) {0,1} tile is generated in VMEM from the int32 index vector with a
broadcasted-iota compare and immediately contracted on the 128×128 MXU
against the corresponding (block_r × block_d) table tile.

Grid: (n/bn, d/bd, r/br) with the reduction dimension r innermost; the
float32 accumulator lives in the output VMEM block across r steps (standard
TPU matmul accumulation pattern).  Out-of-range indices (padding / missed
joins / dropped tokens) contribute zero rows because their compare never
fires.

VMEM working set per step: bn·br (one-hot tile) + br·bd (table) + bn·bd
(acc) floats — e.g. 128·512·3·4B ≈ 768 KiB, comfortably inside the ~16 MiB
v5e VMEM with double buffering.  All tile dims are multiples of (8, 128) to
align with MXU/VREG lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _onehot_matmul_kernel(idx_ref, tbl_ref, out_ref, *, block_r: int,
                          out_dtype):
    r_step = pl.program_id(2)

    @pl.when(r_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...]                                   # (bn,) int32
    local = idx - r_step * block_r                       # position in r-tile
    bn = idx.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, block_r), 1)
    onehot = (local[:, None] == iota).astype(tbl_ref.dtype)
    out_ref[...] += jnp.dot(onehot, tbl_ref[...],
                            preferred_element_type=out_dtype)


def onehot_matmul_pallas(idx: jnp.ndarray, table: jnp.ndarray, *,
                         block_n: int = 128, block_r: int = 512,
                         block_d: int = 128, interpret: bool = False
                         ) -> jnp.ndarray:
    """out[i, :] = table[idx[i], :] (zero row if idx out of [0, r)).

    Shapes must be pre-padded to block multiples (``ops.onehot_matmul`` does
    this); idx (n,) int32, table (r, d).
    """
    n = idx.shape[0]
    r, d = table.shape
    assert n % block_n == 0 and r % block_r == 0 and d % block_d == 0, (
        n, r, d, block_n, block_r, block_d)
    grid = (n // block_n, d // block_d, r // block_r)
    kernel = functools.partial(_onehot_matmul_kernel, block_r=block_r,
                               out_dtype=jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i, j, k: (i,)),
            pl.BlockSpec((block_r, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(idx, table)
