"""jit'd public wrapper for the onehot_matmul Pallas kernel.

Pads (n, r, d) up to block multiples, invokes the kernel, slices back.
``interpret=True`` executes the kernel body in Python on CPU (used for all
correctness tests in this repo; on a real TPU the same call compiles to
Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import onehot_matmul_pallas


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_n", "block_r", "block_d",
                                             "interpret"))
def onehot_matmul(idx: jnp.ndarray, table: jnp.ndarray, *, block_n: int = 128,
                  block_r: int = 512, block_d: int = 128,
                  interpret: bool = False) -> jnp.ndarray:
    """``onehot(idx) @ table`` — gather rows via the MXU (see kernel.py)."""
    n = idx.shape[0]
    d = table.shape[1]
    # Shrink the reduction tile for small tables, keeping 8-row alignment.
    block_r = min(block_r, ((table.shape[0] + 7) // 8) * 8)
    idx_p = _pad_to(idx.astype(jnp.int32), 0, block_n)
    # Out-of-range padding indices (-1) never match any r-tile.
    idx_p = jnp.where(jnp.arange(idx_p.shape[0]) < n, idx_p, -1)
    tbl_p = _pad_to(_pad_to(table, 0, block_r), 1, block_d)
    out = onehot_matmul_pallas(idx_p, tbl_p, block_n=block_n, block_r=block_r,
                               block_d=block_d, interpret=interpret)
    return out[:n, :d]
