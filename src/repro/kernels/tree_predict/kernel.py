"""Pallas TPU kernel: fused Hummingbird GEMM decision-tree inference.

``out = ((X·F > v)·H) == h`` (paper Fig. 5, steps 1–4) executed in a single
VMEM-resident pass per (row-block × leaf-block): two MXU matmuls and two
vector compares with **no HBM round-trip between steps** — the intermediate
(bn × p) predicate matrix lives only in VREGs/VMEM.  This is the fused
non-pushdown path (used when dimension tables update too often to pre-fuse;
the planner picks between this and ``fused_star_gather``).

Grid: (n/bn, l/bl).  F (k×p), v (p), H (p×bl), h (bl) are small model
constants; X row blocks stream through.  VMEM per step:
bn·k + k·p + bn·p + p·bl + bn·bl floats — for bn=128, k=p=512, bl=128 that
is ≈ 1.6 MiB.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tree_predict_kernel(x_ref, f_ref, v_ref, h_ref, hsum_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)                    # (bn, k)
    feats = jnp.dot(x, f_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)   # (bn, p)
    preds = (feats > v_ref[...].astype(jnp.float32)).astype(jnp.float32)
    score = jnp.dot(preds, h_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)   # (bn, bl)
    out_ref[...] = (score == hsum_ref[...].astype(jnp.float32)
                    ).astype(jnp.float32)


def tree_predict_pallas(x: jnp.ndarray, f: jnp.ndarray, v: jnp.ndarray,
                        h: jnp.ndarray, hsum: jnp.ndarray, *,
                        block_n: int = 128, block_l: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """One-hot leaf predictions (n × l); inputs pre-padded to block multiples.

    x (n,k) batch; f (k,p) feature selector; v (1,p) thresholds;
    h (p,l) ±1 path matrix; hsum (1,l) per-leaf true-side counts.
    """
    n, k = x.shape
    p, l = h.shape
    assert n % block_n == 0 and l % block_l == 0, (n, l, block_n, block_l)
    grid = (n // block_n, l // block_l)
    return pl.pallas_call(
        _tree_predict_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, p), lambda i, j: (0, 0)),
            pl.BlockSpec((1, p), lambda i, j: (0, 0)),
            pl.BlockSpec((p, block_l), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_l), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_l), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, l), jnp.float32),
        interpret=interpret,
    )(x, f, v, h, hsum)
