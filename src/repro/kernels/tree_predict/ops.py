"""jit'd public wrapper for the tree_predict Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..onehot_matmul.ops import _pad_to
from .kernel import tree_predict_pallas


@functools.partial(jax.jit, static_argnames=("block_n", "block_l",
                                             "interpret"))
def tree_predict(x: jnp.ndarray, f: jnp.ndarray, v: jnp.ndarray,
                 h: jnp.ndarray, hsum: jnp.ndarray, *, block_n: int = 128,
                 block_l: int = 128, interpret: bool = False) -> jnp.ndarray:
    """Fused ((x·F > v)·H) == hsum — one-hot leaf encoding (n × l)."""
    n, l = x.shape[0], h.shape[1]
    x_p = _pad_to(x, 0, block_n)
    # Pad leaf dim with NaN counts so padded leaves never match.
    pad_l = (-l) % block_l
    h_p = jnp.pad(h.astype(jnp.float32), ((0, 0), (0, pad_l)))
    hsum_p = jnp.pad(hsum.astype(jnp.float32).reshape(1, -1),
                     ((0, 0), (0, pad_l)), constant_values=jnp.nan)
    out = tree_predict_pallas(x_p, f.astype(jnp.float32),
                              v.astype(jnp.float32).reshape(1, -1),
                              h_p, hsum_p, block_n=block_n, block_l=block_l,
                              interpret=interpret)
    return out[:n, :l]
