"""Pure-jnp oracle for the tree_predict kernel."""
from __future__ import annotations

import jax.numpy as jnp


def tree_predict_ref(x: jnp.ndarray, f: jnp.ndarray, v: jnp.ndarray,
                     h: jnp.ndarray, hsum: jnp.ndarray) -> jnp.ndarray:
    feats = x.astype(jnp.float32) @ f.astype(jnp.float32)
    preds = (feats > v.reshape(1, -1)).astype(jnp.float32)
    score = preds @ h.astype(jnp.float32)
    return (score == hsum.reshape(1, -1)).astype(jnp.float32)
