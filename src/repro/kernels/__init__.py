"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel subpackage has ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd public wrapper), ``ref.py`` (pure-jnp oracle).  All are
validated in interpret mode on CPU; block shapes target TPU v5e VMEM/MXU.
"""
from .onehot_matmul.ops import onehot_matmul
from .onehot_matmul.ref import onehot_matmul_ref
from .fused_star_gather.ops import fused_star_gather
from .fused_star_gather.ref import fused_star_gather_ref
from .tree_predict.ops import tree_predict
from .tree_predict.ref import tree_predict_ref

__all__ = ["onehot_matmul", "onehot_matmul_ref", "fused_star_gather",
           "fused_star_gather_ref", "tree_predict", "tree_predict_ref"]
