"""jit'd public wrapper for fused_star_gather.

Clips pointers into range (liveness is carried by ``found``) and pads the
output width to the fp32 lane multiple (128) before invoking the kernel.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from .kernel import fused_star_gather_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_star_gather(ptrs: jnp.ndarray, found: jnp.ndarray,
                      tables: Sequence[jnp.ndarray],
                      h: jnp.ndarray | None = None, *,
                      interpret: bool = False) -> jnp.ndarray:
    """Serve-time fused star pipeline: Σⱼ Pⱼ[ptrⱼ] (== h).

    Args:
      ptrs:   (J, n) int32 FK pointers into each pre-fused partial.
      found:  (J, n) int32/bool liveness per pointer.
      tables: J arrays (r_j, l) — the pre-fused partials P_j.
      h:      optional (l,) compare vector (decision-tree online phase).
    """
    l = tables[0].shape[1]
    n = ptrs.shape[1]
    if n == 0:
        # Zero-row grid: nothing to DMA, and a (0,)-sized Pallas grid is
        # rejected by the lowering — short-circuit to an empty result.
        return jnp.zeros((0, l), jnp.float32)
    pad_l = (-l) % 128
    tabs = []
    for t in tables:
        t = jnp.pad(t.astype(jnp.float32), ((0, 0), (0, pad_l)))
        tabs.append(t)
    hh = None
    if h is not None:
        # Pad h with NaN so padded output columns compare False (then sliced
        # away anyway).
        hh = jnp.pad(h.astype(jnp.float32), (0, pad_l),
                     constant_values=jnp.nan)
    clipped = []
    for j, t in enumerate(tabs):
        clipped.append(jnp.clip(ptrs[j], 0, t.shape[0] - 1))
    ptrs_c = jnp.stack(clipped).astype(jnp.int32)
    out = fused_star_gather_pallas(ptrs_c, found.astype(jnp.int32), tabs,
                                   hh, interpret=interpret)
    return out[:, :l]
