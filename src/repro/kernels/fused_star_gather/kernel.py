"""Pallas TPU kernel: fused star-pipeline online phase.

After pre-fusion (paper Eq. 1/3) the per-batch work is
``out[i] = Σⱼ Pⱼ[ptrⱼ[i]] · foundⱼ[i]`` and, for decision trees,
``out[i] = (Σⱼ ... ) == h``.  This kernel executes the whole online phase in
one pass with **scalar-prefetched FK pointers**: the int32 pointer arrays are
prefetched into SMEM before the grid starts, and each dimension table's
BlockSpec ``index_map`` reads them to DMA exactly the needed (block of) rows
HBM→VMEM — the same indirect-DMA pattern TPU embedding lookups use.  No
row-matching matrix, no materialized join result, no intermediate HBM
round-trips.

Grid: (n/bn,) row blocks. Each step DMAs ``bn`` rows from each of the J
pre-fused partials (rows of a block are fetched via a per-row index map on a
(1, l)-shaped inner block — Pallas coalesces consecutive DMAs), adds them,
applies the optional ``== h`` compare, and writes the (bn, l) output block.

Implementation note: Pallas BlockSpec index maps must return *block* indices,
so we use block shape (1, l) with grid (n,) — one fact row per grid step,
J+1 row-DMAs per step, all double-buffered by the Pallas pipeline.  VMEM per
step: (J+1)·l floats — trivially small; the kernel is DMA-latency-bound,
which is exactly the roofline position the paper's fusion puts the online
phase in (it removed all the FLOPs).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _star_gather_kernel(*refs, n_dims: int, compare: bool):
    # refs: [ptrs_smem, found_smem] + n_dims table refs (+ h_ref) + out_ref
    ptrs_ref, found_ref = refs[0], refs[1]
    tbl_refs = refs[2:2 + n_dims]
    h_ref = refs[2 + n_dims] if compare else None
    out_ref = refs[-1]
    i = pl.program_id(0)
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for j, tref in enumerate(tbl_refs):
        live = (found_ref[j, i] > 0).astype(jnp.float32)
        acc = acc + tref[...].astype(jnp.float32) * live
    if compare:
        hit = (acc == h_ref[...].astype(jnp.float32))
        acc = hit.astype(jnp.float32)
    out_ref[...] = acc


def fused_star_gather_pallas(ptrs: jnp.ndarray, found: jnp.ndarray,
                             tables: Sequence[jnp.ndarray],
                             h: jnp.ndarray | None = None, *,
                             interpret: bool = False) -> jnp.ndarray:
    """out[i] = Σⱼ tables[j][ptrs[j, i]] · found[j, i]  (== h if given).

    ptrs/found: (J, n) int32; tables[j]: (r_j, l); h: (l,) or None.
    """
    n_dims, n = ptrs.shape
    l = tables[0].shape[1]
    compare = h is not None

    in_specs = [
        pl.BlockSpec((1, l), functools.partial(_tbl_index, j))
        for j in range(n_dims)
    ]
    inputs = list(tables)
    if compare:
        in_specs.append(pl.BlockSpec((1, l), lambda i, ptrs, found: (0, 0)))
        inputs.append(h.reshape(1, l))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, l), lambda i, ptrs, found: (i, 0)),
    )
    kernel = functools.partial(_star_gather_kernel, n_dims=n_dims,
                               compare=compare)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, l), jnp.float32),
        interpret=interpret,
    )(ptrs, found, *inputs)


def _tbl_index(j, i, ptrs_ref, found_ref):
    """Row block of table j for fact row i: the prefetched FK pointer."""
    return (ptrs_ref[j, i], 0)
