"""Pure-jnp oracle for fused_star_gather."""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp


def fused_star_gather_ref(ptrs: jnp.ndarray, found: jnp.ndarray,
                          tables: Sequence[jnp.ndarray],
                          h: jnp.ndarray | None = None) -> jnp.ndarray:
    acc = None
    for j, tbl in enumerate(tables):
        rows = jnp.take(tbl, ptrs[j], axis=0, mode="clip").astype(jnp.float32)
        rows = rows * (found[j][:, None] > 0).astype(jnp.float32)
        acc = rows if acc is None else acc + rows
    if h is not None:
        acc = (acc == h[None, :].astype(jnp.float32)).astype(jnp.float32)
    return acc
