"""Gradient compression for the data-parallel all-reduce.

int8 block-quantization with error feedback: before the DP all-reduce each
worker quantizes its local gradient to int8 with a per-block fp32 scale
(4× wire reduction vs fp32, 2× vs bf16), and the quantization residual is
carried to the next step (error feedback keeps SGD/Adam convergence —
Karimireddy et al., arXiv:1901.09847).  Under jit/SPMD the quantized tensor
is what crosses the ICI/DCN links; the pod axis (cross-pod DCN) is where
this matters most at 512+ chips.

Usage in the train step (microbatch-accumulated grads g, residual r):
    q, scale, r_new = compress(g + r)
    g_hat = decompress(q, scale)          # all-reduced by XLA afterwards
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Compressed(NamedTuple):
    q: jnp.ndarray       # int8 payload (padded flat)
    scale: jnp.ndarray   # (n_blocks,) fp32 per-block scale
    shape: tuple
    dtype: jnp.dtype


def compress(x: jnp.ndarray) -> Tuple[Compressed, jnp.ndarray]:
    """Quantize to int8 blocks. Returns (payload, residual)."""
    xf = x.astype(jnp.float32)
    flat = xf.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat_p = jnp.pad(flat, (0, pad))
    blocks = flat_p.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0          # (nb,)
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127
                 ).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    residual = (flat - deq[:flat.shape[0]]).reshape(x.shape).astype(x.dtype)
    return Compressed(q, scale, x.shape, x.dtype), residual


def decompress(c: Compressed) -> jnp.ndarray:
    flat = (c.q.astype(jnp.float32) * c.scale[:, None]).reshape(-1)
    n = 1
    for s in c.shape:
        n *= s
    return flat[:n].reshape(c.shape).astype(c.dtype)


def compress_tree(grads, residuals):
    """Apply error-feedback compression across a gradient pytree."""
    if residuals is None:
        residuals = jax.tree.map(jnp.zeros_like, grads)
    fed = jax.tree.map(lambda g, r: g + r.astype(g.dtype), grads, residuals)
    comp_res = jax.tree.map(compress, fed,
                            is_leaf=lambda x: isinstance(x, jnp.ndarray))
    def is_pair(x):
        return (isinstance(x, tuple) and len(x) == 2
                and isinstance(x[0], Compressed))
    ghat = jax.tree.map(lambda cr: decompress(cr[0]), comp_res,
                        is_leaf=is_pair)
    new_res = jax.tree.map(lambda cr: cr[1], comp_res, is_leaf=is_pair)
    return ghat, new_res
