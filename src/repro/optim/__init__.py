"""Optimizer substrate: AdamW, schedules, grad compression."""
from .adamw import (AdamWConfig, AdamWState, adamw_init, adamw_update,
                    clip_by_global_norm, global_norm)
from .schedule import constant, warmup_cosine, warmup_linear
from .compression import Compressed, compress, compress_tree, decompress

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "clip_by_global_norm", "global_norm", "constant", "warmup_cosine",
           "warmup_linear", "Compressed", "compress", "compress_tree",
           "decompress"]
