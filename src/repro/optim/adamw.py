"""AdamW optimizer (from scratch — no optax in this environment).

Production posture:
* Optimizer state dtype is configurable (fp32 default, bf16 for the
  biggest configs — halves m/v HBM, the standard large-model trade).
* State shards exactly like the parameters (ZeRO-style: the launch layer
  assigns FSDP PartitionSpecs to params; ``init`` mirrors them).
* Global-norm clipping and decoupled weight decay built in.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Optional[str] = None  # None → fp32


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype) if cfg.state_dtype else jnp.float32
    def zeros(p):
        return jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig,
                 lr_scale: jnp.ndarray | float = 1.0):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, AdamWState(step, new_m, new_v), metrics
