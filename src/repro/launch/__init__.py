"""Launch layer: mesh construction, sharding plans, step builders, dry-run,
roofline analysis, train/serve drivers."""
