"""Sharding rules: parameter/optimizer/activation PartitionSpecs per arch.

2-D logical layout over the physical mesh (pod, data, model):
* **TP** ("model"): attention heads / FFN hidden / vocab / experts.
* **FSDP** ("data"): the other major dim of every weight (ZeRO-3 — params,
  grads and AdamW moments all shard this way; XLA inserts the per-layer
  all-gathers).
* **DP** ("pod"+"data"): batch dim of activations; "pod" is pure DP across
  the slower inter-pod links.

Every rule degrades gracefully: a dim that doesn't divide its mesh axis is
left unsharded (e.g. smollm's 15 heads on a 16-way model axis, qwen2-moe's
60 experts → TP-within-expert instead of EP; DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import dp_axes


def _div(mesh, dim: int, axis) -> bool:
    if axis is None:
        return True
    axes = (axis,) if isinstance(axis, str) else axis
    total = 1
    for a in axes:
        if a not in mesh.axis_names:
            return False
        total *= mesh.shape[a]
    return dim % total == 0


def safe_spec(mesh, shape, *axes):
    """PartitionSpec with divisibility fallback per dim."""
    return P(*[a if _div(mesh, d, a) else None
               for d, a in zip(shape, axes)])


_spec = safe_spec


FSDP = ("pod", "data")  # pod folds into the FSDP axis when present


def param_pspec(path: str, shape, mesh, cfg) -> P:
    """PartitionSpec for one parameter leaf (path is '/'-joined)."""
    parts = path.split("/")
    leaf = parts[-1]
    stacked = parts[0] in ("blocks", "encoder", "cross")
    body = shape[1:] if stacked else shape

    def out(*axes):
        spec = _spec(mesh, body, *axes)
        return P(None, *spec) if stacked else spec

    # ---- embeddings / head -------------------------------------------------
    if leaf == "embed":
        return _spec(mesh, shape, "model", ("pod", "data"))
    if leaf == "lm_head":
        return _spec(mesh, shape, ("pod", "data"), "model")
    if leaf in ("final_norm", "enc_norm"):
        return P(None)
    # ---- norms / small vectors ---------------------------------------------
    if leaf.startswith("norm") or leaf in ("xnorm", "b", "dt_bias", "conv_b"):
        return out(*([None] * len(body)))
    # ---- attention ----------------------------------------------------------
    if len(parts) >= 2 and parts[-2] in ("attn", "xattn"):
        if leaf in ("wq", "wk", "wv"):
            return out(FSDP, "model")
        if leaf == "wo":
            return out("model", FSDP)
    # ---- dense mlp / shared expert ------------------------------------------
    if leaf == "wi" and len(body) == 2:
        return out(FSDP, "model")
    if leaf == "wo" and len(body) == 2:
        return out("model", FSDP)
    # ---- MoE ------------------------------------------------------------------
    if leaf == "router":
        return out(FSDP, None)
    if leaf == "wi" and len(body) == 3:   # (E, D, F)
        if cfg.moe is not None and cfg.moe.shard_experts and _div(
                mesh, body[0], "model"):
            return out("model", FSDP, None)
        return out(None, FSDP, "model")
    if leaf == "wo" and len(body) == 3:   # (E, F, D)
        if cfg.moe is not None and cfg.moe.shard_experts and _div(
                mesh, body[0], "model"):
            return out("model", None, FSDP)
        return out(None, "model", FSDP)
    # ---- mamba -----------------------------------------------------------------
    if leaf == "in_proj":
        return out(FSDP, "model")
    if leaf == "conv_w":
        return out(None, "model")
    if leaf == "x_proj":
        return out("model", None)
    if leaf == "dt_proj":
        return out(None, "model")
    if leaf == "A_log":
        return out("model", None)
    if leaf == "D":
        return out("model")
    if leaf == "out_proj":
        return out("model", FSDP)
    # ---- xLSTM -----------------------------------------------------------------
    if leaf == "up":
        return out(FSDP, "model")
    if leaf in ("wq", "wk", "wv") and len(body) == 2:   # mlstm projections
        return out("model", None)
    if leaf == "wif":
        return out("model", None)
    if leaf == "down":
        return out("model", FSDP)
    if leaf == "w":                                      # slstm input proj
        return out(FSDP, "model")
    if leaf == "r":                                      # (H, dh, 4dh)
        return out(None, None, None)
    # ---- fallback ----------------------------------------------------------------
    return out(*([None] * len(body)))


def _paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in p) for p, _ in flat]
    return paths, [l for _, l in flat], treedef


def param_shardings(params_shape: Any, mesh, cfg):
    """Same-structure tree of NamedShardings for a params (shape) tree."""
    paths, leaves, treedef = _paths(params_shape)
    out = [NamedSharding(mesh, param_pspec(p, l.shape, mesh, cfg))
           for p, l in zip(paths, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_pspec(mesh) -> P:
    return P(dp_axes(mesh))


def cache_pspec(mesh, cfg, batch: int) -> dict:
    """PartitionSpecs for decode state components (see launch/steps.py)."""
    dp = dp_axes(mesh)
    bdim = dp if _div(mesh, batch, dp) else None
    # KV cache (B, S, KV, hd): heads over model when divisible, else the
    # sequence dim (distributed-KV decode for the 500k cell).
    if _div(mesh, cfg.n_kv_heads, "model"):
        kv = P(bdim, None, "model", None)
    else:
        kv = P(bdim, "model" if bdim is not None else ("data", "model"),
               None, None)
    return {
        "kv": kv,
        "mamba_conv": P(bdim, None, "model"),
        "mamba_h": P(bdim, "model", None),
        "mlstm": P(bdim, None, None, None),
        "slstm": P(bdim, None),
        "batch": P(bdim),
    }
