"""Trip-count-aware analysis of compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` visits each while body **once** — for scanned
layer stacks that understates FLOPs/bytes by ~n_layers (verified in
EXPERIMENTS.md §Dry-run notes).  This module re-derives roofline inputs from
``compiled.as_text()`` with loop trip counts applied:

* per-computation symbol table (every ``%name = TYPE op(...)`` line),
* matmul FLOPs from ``dot`` ops (2 · prod(result) · prod(contract dims)),
* collective payloads (operand bytes) for all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute, split by kind,
* an HBM-traffic estimate (operand+result bytes of non-fusion-internal ops,
  assuming perfect reuse inside a fusion),
* recursion through ``fusion``/``call``/``while``/``conditional`` with
  while trip counts read from the loop-condition constant.

All shapes in partitioned HLO are *per-device*, so every returned quantity
is per-device (roofline terms then divide by per-chip peaks — the chip
count cancels).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "s2": 1, "u2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    jax ≤ 0.4.x returns a list with one properties-dict per partition (often
    ``[{...}]``); newer versions return the dict directly.  Returns a single
    flat dict (first partition), ``{}`` when unavailable.
    """
    costs = compiled.cost_analysis()
    if isinstance(costs, (list, tuple)):
        costs = costs[0] if costs else {}
    return dict(costs) if costs else {}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    op: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    table: Dict[str, str]  # %name -> type string


def _split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    head_re = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            m = head_re.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
            continue
        if line == "}":
            comps[cur.name] = cur
            cur = None
            continue
        inst = _parse_instruction(line)
        if inst is not None:
            cur.instructions.append(inst)
            cur.table[inst.name] = inst.type_str
    return comps


def _parse_instruction(line: str) -> Optional[Instruction]:
    if " = " not in line:
        return None
    lhs, rhs = line.split(" = ", 1)
    name = lhs.replace("ROOT", "").strip().lstrip("%")
    rhs = rhs.strip()
    # Type: leading tuple "(...)" or single token.
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str, rest = rhs[:i + 1], rhs[i + 1:].strip()
    else:
        parts = rhs.split(" ", 1)
        if len(parts) != 2:
            return None
        type_str, rest = parts
    p = rest.find("(")
    if p < 0:
        return None
    op = rest[:p]
    depth = 0
    for i in range(p, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            break
    operand_str = rest[p + 1:i]
    attrs = rest[i + 1:]
    operands = re.findall(r"%([\w.\-]+)", operand_str)
    return Instruction(name, type_str, op, operands, attrs)


def _group_size(attrs: str) -> int:
    # Iota form: replica_groups=[groups,size]<=[...]
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    # Explicit form: replica_groups={{0,1},{2,3}}
    m = re.search(r"replica_groups=\{\{([^}]*)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return 1


def _dot_flops(inst: Instruction, comp: Computation) -> float:
    _, out_dims = _shape_dims(inst.type_str)
    out_n = 1
    for d in out_dims:
        out_n *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.attrs)
    if not m or not inst.operands:
        return 2.0 * out_n  # degenerate
    lhs_type = comp.table.get(inst.operands[0], "")
    _, lhs_dims = _shape_dims(lhs_type)
    contract = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contract *= lhs_dims[int(idx)]
    return 2.0 * out_n * contract


_SKIP_MEM_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "after-all", "partition-id", "replica-id",
                 "iota"}


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    wire_bytes: float = 0.0     # ring-algorithm estimate
    mem_bytes: float = 0.0      # HBM traffic estimate
    n_collectives: float = 0.0

    def add(self, other: "Costs", times: float = 1.0):
        self.flops += other.flops * times
        for k in COLLECTIVES:
            self.coll_bytes[k] += other.coll_bytes[k] * times
        self.wire_bytes += other.wire_bytes * times
        self.mem_bytes += other.mem_bytes * times
        self.n_collectives += other.n_collectives * times

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.comps = _split_computations(hlo_text)
        self.raw = hlo_text
        self.entry = self._find_entry(hlo_text)
        self._memo: Dict[str, Costs] = {}

    @staticmethod
    def _find_entry(hlo: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        if m:
            return m.group(1)
        raise ValueError("no ENTRY computation found")

    def _trip(self, cond_name: str) -> int:
        """Loop trip count ≈ the largest integer constant in the condition
        (exact for jax.lax.scan-lowered counted loops)."""
        block = self._raw_block(cond_name)
        consts = [int(x) for x in re.findall(r"constant\((\d+)\)", block)]
        return max(consts) if consts else 1

    def _raw_block(self, comp_name: str) -> str:
        m = re.search(
            r"^(?:ENTRY\s+)?%?" + re.escape(comp_name) + r"\s*\(.*?\{(.*?)^\}",
            self.raw, re.M | re.S)
        return m.group(1) if m else ""

    def costs_of(self, comp_name: str) -> Costs:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        out = Costs()
        if comp is None:
            self._memo[comp_name] = out
            return out
        self._memo[comp_name] = out  # break cycles defensively
        for inst in comp.instructions:
            if inst.op == "dot":
                out.flops += _dot_flops(inst, comp)
            base = inst.op.replace("-start", "")
            if base in COLLECTIVES:
                g = _group_size(inst.attrs)
                result = _shape_bytes(inst.type_str)
                if base == "all-gather":
                    operand = result / max(g, 1)
                    wire = result * (g - 1) / max(g, 1)
                elif base == "all-reduce":
                    operand = result
                    wire = 2.0 * result * (g - 1) / max(g, 1)
                elif base == "reduce-scatter":
                    operand = result * g
                    wire = operand * (g - 1) / max(g, 1)
                else:  # all-to-all / collective-permute
                    operand = result
                    wire = result
                out.coll_bytes[base] += operand
                out.wire_bytes += wire
                out.n_collectives += 1
            # HBM traffic: each materialized result is written once and (on
            # average) read once downstream — counting operands as well
            # would double-count every producer/consumer edge.
            if inst.op not in _SKIP_MEM_OPS:
                out.mem_bytes += 2 * _shape_bytes(inst.type_str)
            # Recurse into called computations.
            if inst.op == "fusion" or inst.op == "call":
                m = re.search(r"calls=%?([\w.\-]+)", inst.attrs)
                if m:
                    sub = self.costs_of(m.group(1))
                    out.flops += sub.flops
                    for k in COLLECTIVES:
                        out.coll_bytes[k] += sub.coll_bytes[k]
                    out.wire_bytes += sub.wire_bytes
                    out.n_collectives += sub.n_collectives
                    # mem: fusion output/operands already counted above.
            elif inst.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", inst.attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", inst.attrs)
                trip = self._trip(mc.group(1)) if mc else 1
                if mb:
                    out.add(self.costs_of(mb.group(1)), times=trip)
            elif inst.op == "conditional":
                for m in re.finditer(r"(?:true_computation|false_computation|"
                                     r"branch_computations=\{)([^},]*)",
                                     inst.attrs):
                    sub = self.costs_of(m.group(1).strip().lstrip("%"))
                    out.add(sub, times=1.0)
        self._memo[comp_name] = out
        return out

    def analyze(self) -> Costs:
        return self.costs_of(self.entry)
