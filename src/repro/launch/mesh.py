"""Production mesh construction.

A function, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model); the pod axis
is data-parallel across the (slower) inter-pod links — gradient all-reduce
is hierarchical: reduce-scatter inside pods, all-reduce across pods.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (pod folds into DP)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
