"""Production mesh construction.

A function, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model); the pod axis
is data-parallel across the (slower) inter-pod links — gradient all-reduce
is hierarchical: reduce-scatter inside pods, all-reduce across pods.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return make_serving_mesh((n // mp, mp))


def make_serving_mesh(shape, axes=("data", "model")):
    """A (data, model) serving mesh of any shape, on any jax version.

    ``jax.make_mesh`` only exists on newer releases; older ones build a
    ``Mesh`` from an explicit device array.  The sharded serving runtime
    shards prefused partials over ``"model"`` and request batches over
    ``"data"``, so this is the mesh constructor the serving tests and
    benchmarks use (on CPU, force devices first with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(tuple(shape), tuple(axes))
    import numpy as np

    n = 1
    for s in shape:
        n *= int(s)
    devices = jax.devices()
    if n > len(devices):
        raise ValueError(f"mesh shape {tuple(shape)} needs {n} devices, "
                         f"have {len(devices)}")
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(tuple(shape)), tuple(axes))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (pod folds into DP)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
