"""Training driver: data pipeline → sharded train loop → checkpoints.

Runs identically on a laptop CPU (host mesh) and a TPU fleet (production
mesh + ``jax.distributed.initialize``).  Fault-tolerance posture:
* resume from the latest committed checkpoint (params, optimizer, data
  iterator state),
* async checkpoint every ``ckpt_every`` steps,
* per-step wall-time fed to the StragglerMonitor; heartbeats via the
  CheckpointManager directory (real clusters swap in their coordination
  service).

Usage (CPU example scale):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import TokenPipeline, TokenPipelineConfig
from repro.models import LM
from repro.models.act_sharding import set_activation_sharding
from repro.optim import AdamWConfig
from repro.runtime import StragglerMonitor

from . import steps as S
from .mesh import dp_axes, make_host_mesh, make_production_mesh
from .sharding import batch_pspec, param_shardings


def train(arch: str, smoke: bool, steps: int, batch: int, seq: int,
          ckpt_dir: str, ckpt_every: int, production: bool = False,
          lr: float = 3e-4, log_every: int = 10):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = LM(cfg)
    mesh = make_production_mesh() if production else make_host_mesh()
    set_activation_sharding(dp_axes(mesh), "model", mesh)
    opt_cfg = AdamWConfig(lr=lr)
    step_fn = S.make_train_step(model, cfg, opt_cfg)

    pipe = TokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, global_batch=batch, seq_len=seq))
    mgr = CheckpointManager(ckpt_dir, keep=3)
    straggler = StragglerMonitor([jax.process_index()])

    with mesh:
        shardings = param_shardings(S.params_shape(model), mesh, cfg)
        init_fn = jax.jit(model.init, out_shardings=shardings)
        params = init_fn(jax.random.PRNGKey(0))
        from repro.optim import adamw_init
        opt_state = jax.jit(
            lambda p: adamw_init(p, opt_cfg))(params)

        start = 0
        latest = mgr.latest_step()
        if latest is not None:
            (params, opt_state), extras = mgr.restore(
                latest, (params, opt_state))
            pipe.restore(extras["pipeline"])
            start = latest
            print(f"[train] resumed from step {latest}")

        jstep = jax.jit(step_fn, donate_argnums=(0, 1))
        bspec = NamedSharding(mesh, batch_pspec(mesh))
        pipe.start()
        losses = []
        for step in range(start, steps):
            t0 = time.perf_counter()
            tokens, labels = pipe.next()
            batch_arrays = {
                "tokens": jax.device_put(tokens, bspec),
                "labels": jax.device_put(labels, bspec),
            }
            if cfg.family == "encdec":
                batch_arrays["frames"] = jax.device_put(
                    np.zeros((tokens.shape[0], cfg.encoder_seq, cfg.d_model),
                             np.float32), bspec)
            if cfg.family == "vlm":
                batch_arrays["patch_embeds"] = jax.device_put(
                    np.zeros((tokens.shape[0], cfg.n_patches, cfg.d_model),
                             np.float32), bspec)
            params, opt_state, metrics = jstep(params, opt_state,
                                               batch_arrays)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            straggler.record_step({jax.process_index(): dt})
            if step % log_every == 0 or step == steps - 1:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f}ms", flush=True)
            if ckpt_every and (step + 1) % ckpt_every == 0:
                mgr.save_async(step + 1, (params, opt_state),
                               extras={"pipeline": pipe.state()})
        pipe.stop()
        mgr.wait()
        return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production", action="store_true",
                    help="use the 256-chip production mesh")
    args = ap.parse_args()
    losses = train(args.arch, args.smoke, args.steps, args.batch, args.seq,
                   args.ckpt_dir, args.ckpt_every, args.production, args.lr)
    print(f"[train] done; loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
