"""Roofline terms for a compiled (arch × shape × mesh) cell.

Hardware model: TPU v5e —
  peak compute   197 TFLOP/s bf16 per chip
  HBM bandwidth  819 GB/s per chip
  ICI            ~50 GB/s per link

Terms (all per-device; partitioned HLO shapes are per-device so chip count
cancels — see hlo_analysis.py):

  compute    = HLO_FLOPs / peak_FLOPs
  memory     = HLO_bytes / HBM_bw
  collective = collective_bytes / link_bw

MODEL_FLOPS = 6·N·D for training (2·N·D inference), N = active params,
D = tokens processed; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat /
redundant-compute waste.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models import ModelConfig

from .hlo_analysis import HloAnalyzer
from .steps import SHAPES

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_dev: float
    mem_bytes_per_dev: float
    coll_bytes_per_dev: float
    wire_bytes_per_dev: float
    n_collectives: float
    coll_by_kind: Dict[str, float]
    model_flops_total: float

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.mem_bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def model_flops_per_dev(self) -> float:
        return self.model_flops_total / max(self.n_devices, 1)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per device): >1 ⇒ HLO undercount,
        <1 ⇒ remat / redundancy / non-model compute."""
        return self.model_flops_per_dev / max(self.flops_per_dev, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs throughput vs peak if the dominant term were the
        only cost — the score we hillclimb: MODEL_FLOPS/(chips·peak) ÷
        max(term)."""
        denom = max(self.t_compute, self.t_memory, self.t_collective)
        ideal = self.model_flops_per_dev / PEAK_FLOPS
        return ideal / max(denom, 1e-30)

    def to_json(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_devices": self.n_devices,
            "flops_per_dev": self.flops_per_dev,
            "mem_bytes_per_dev": self.mem_bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "wire_bytes_per_dev": self.wire_bytes_per_dev,
            "n_collectives": self.n_collectives,
            "coll_by_kind": self.coll_by_kind,
            "model_flops_total": self.model_flops_total,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg: ModelConfig, shape: str) -> float:
    """Analytic model FLOPs for one step of this cell (all chips)."""
    info = SHAPES[shape]
    n_active = cfg.active_params()
    if info["kind"] == "train":
        tokens = info["batch"] * info["seq"]
        flops = 6.0 * n_active * tokens
        # Attention score/value FLOPs (not in 6ND): 12·L_attn·d_head·H·S²·B/2.
        n_attn = sum(1 for s in cfg.pattern
                     if s.mixer == "attn") * cfg.n_repeats
        flops += 6.0 * n_attn * cfg.n_heads * cfg.hd * info["seq"] \
            * tokens
        return flops
    if info["kind"] == "prefill":
        tokens = info["batch"] * info["seq"]
        n_attn = sum(1 for s in cfg.pattern
                     if s.mixer == "attn") * cfg.n_repeats
        return 2.0 * n_active * tokens + 2.0 * n_attn * cfg.n_heads * \
            cfg.hd * info["seq"] * tokens
    # decode: one token per sequence + attention over the KV cache.
    tokens = info["batch"]
    n_attn = sum(1 for s in cfg.pattern if s.mixer == "attn") * cfg.n_repeats
    return (2.0 * n_active * tokens
            + 4.0 * n_attn * cfg.n_kv_heads * cfg.hd * info["seq"] * tokens)


def analyze_cell(arch: str, shape: str, mesh_name: str, n_devices: int,
                 cfg: ModelConfig, hlo_text: str) -> Roofline:
    costs = HloAnalyzer(hlo_text).analyze()
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_dev=costs.flops,
        mem_bytes_per_dev=costs.mem_bytes,
        coll_bytes_per_dev=costs.total_coll_bytes,
        wire_bytes_per_dev=costs.wire_bytes,
        n_collectives=costs.n_collectives,
        coll_by_kind=dict(costs.coll_bytes),
        model_flops_total=model_flops(cfg, shape))
