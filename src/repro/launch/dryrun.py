import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count at first init); this module is the only place the 512-device override
is set — tests and benchmarks see the real single CPU device.

Per cell:
  1. build the full config, ``jax.eval_shape`` the params (no allocation),
  2. attach the sharding plan (launch/sharding.py) to every input,
  3. ``jit(step).lower(...).compile()`` under the production mesh,
  4. record ``memory_analysis()`` (fits-per-device proof),
     ``cost_analysis()``, and the trip-count-corrected HLO roofline terms
     (launch/roofline.py) to ``experiments/dryrun/<cell>.json``.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import arch_ids, get_config
from repro.models import LM
from repro.optim import AdamWConfig

from . import steps as S
from .mesh import make_production_mesh
from .roofline import analyze_cell

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def cell_name(arch: str, shape: str, mesh: str) -> str:
    return f"{arch}__{shape}__{mesh}".replace("/", "_")


def lower_cell(arch: str, shape: str, multi_pod: bool,
               opt_state_dtype: str | None = None):
    """Lower + compile one cell; returns (compiled, cfg, mesh)."""
    cfg = get_config(arch)
    ok, why = S.shape_applicable(cfg, shape)
    if not ok:
        return None, cfg, why
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = LM(cfg)
    kind = S.SHAPES[shape]["kind"]
    from repro.models.act_sharding import set_activation_sharding
    from .mesh import dp_axes as _dpa
    set_activation_sharding(_dpa(mesh), "model", mesh)
    with mesh:
        if kind == "train":
            opt_cfg = AdamWConfig(
                state_dtype=opt_state_dtype
                if opt_state_dtype is not None else
                ("bfloat16" if cfg.n_params() > 5e10 else None))
            n_micro = S.pick_n_micro(cfg, mesh, S.SHAPES[shape]["batch"])
            step = S.make_train_step(model, cfg, opt_cfg, n_micro=n_micro)
            args = (S.shaped_params(model, mesh),
                    S.shaped_opt_state(model, mesh, opt_cfg),
                    S.batch_specs(cfg, mesh, shape))
            jitted = jax.jit(step, donate_argnums=(0, 1))
        elif kind == "prefill":
            step = S.make_prefill_step(model, cfg)
            args = (S.shaped_params(model, mesh),
                    S.batch_specs(cfg, mesh, shape))
            jitted = jax.jit(step)
        else:  # decode
            step = S.make_decode_step(model, cfg)
            from jax.sharding import NamedSharding
            from .sharding import safe_spec
            from .mesh import dp_axes
            b = S.SHAPES[shape]["batch"]
            token = jax.ShapeDtypeStruct(
                (b,), jax.numpy.int32,
                sharding=NamedSharding(mesh, safe_spec(mesh, (b,),
                                                       dp_axes(mesh))))
            args = (S.shaped_params(model, mesh),
                    S.shaped_decode_state(model, cfg, mesh, shape),
                    token)
            jitted = jax.jit(step, donate_argnums=(1,))
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return compiled, cfg, mesh


def run_cell(arch: str, shape: str, mesh_kind: str, outdir: str,
             skip_existing: bool = False) -> dict:
    os.makedirs(outdir, exist_ok=True)
    name = cell_name(arch, shape, mesh_kind)
    path = os.path.join(outdir, name + ".json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    multi_pod = mesh_kind == "multipod"
    t0 = time.time()
    record = {"arch": arch, "shape": shape, "mesh": mesh_kind,
              "n_devices": 512 if multi_pod else 256}
    try:
        compiled, cfg, info = lower_cell(arch, shape, multi_pod)
        if compiled is None:
            record["status"] = "skipped"
            record["reason"] = info
        else:
            mem = compiled.memory_analysis()
            print(mem)
            ca = compiled.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            print({k: v for k, v in ca.items()
                   if k in ("flops", "bytes accessed")})
            roof = analyze_cell(arch, shape, mesh_kind,
                                record["n_devices"], cfg,
                                compiled.as_text())
            record.update({
                "status": "ok",
                "compile_s": time.time() - t0,
                "memory": {
                    "argument_bytes": mem.argument_size_in_bytes,
                    "output_bytes": mem.output_size_in_bytes,
                    "temp_bytes": mem.temp_size_in_bytes,
                    "alias_bytes": mem.alias_size_in_bytes,
                    "code_bytes": mem.generated_code_size_in_bytes,
                },
                "xla_cost_analysis": {
                    "flops": float(ca.get("flops", -1.0)),
                    "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
                },
                "roofline": roof.to_json(),
            })
    except Exception as e:  # a failed cell is a bug — record it loudly
        record["status"] = "failed"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    status = record["status"]
    extra = (f" {record.get('compile_s', 0):.0f}s "
             f"bottleneck={record.get('roofline', {}).get('bottleneck', '-')}"
             if status == "ok" else
             f" ({record.get('reason', record.get('error', ''))[:120]})")
    print(f"[dryrun] {name}: {status}{extra}", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(S.SHAPES) + [None])
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--outdir", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args()

    archs = arch_ids() if (args.all or args.arch is None) else [args.arch]
    shapes = list(S.SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind, args.outdir,
                               skip_existing=args.skip_existing)
                n_fail += rec["status"] == "failed"
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
