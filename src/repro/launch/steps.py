"""Step builders: train_step / prefill_step / decode_step + input specs.

These close over (model, cfg) and are what both the real drivers
(train.py / serve.py) and the AOT dry-run lower.  Shape cells
(assignment):

  train_4k     seq 4,096   global_batch 256   → train_step
  prefill_32k  seq 32,768  global_batch 32    → prefill (forward, last logit)
  decode_32k   KV 32,768   global_batch 128   → decode_step (1 new token)
  long_500k    KV 524,288  global_batch 1     → decode_step (sub-quadratic
                                                archs only)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import LM, ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update

from .mesh import dp_axes
from .sharding import batch_pspec, param_shardings

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic bodies."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("skipped: pure full-attention arch; a 500k KV cache "
                       "presupposes sub-quadratic prefill (DESIGN.md)")
    return True, ""


# ----------------------------------------------------------- loss/steps ----
def make_loss_fn(model: LM, cfg: ModelConfig, loss_chunk: int = 1024):
    """Chunked softmax cross-entropy.

    Materializing (B, S, V) fp32 logits costs e.g. 12.6 GB/device at
    train_4k with a 49k vocab (measured: 54.6 GB temp on the smollm cell).
    Instead we scan over sequence chunks of the final hidden states and
    rematerialize each chunk's logits inside jax.checkpoint — peak logits
    memory drops by S/loss_chunk (EXPERIMENTS.md §Perf)."""

    from repro.models.act_sharding import constrain

    def loss_fn(params, batch):
        kwargs = {}
        if "frames" in batch:
            kwargs["frames"] = batch["frames"]
        if "patch_embeds" in batch:
            kwargs["patch_embeds"] = batch["patch_embeds"]
        hidden, aux = model.forward_hidden(params, batch["tokens"], **kwargs)
        b, s, d = hidden.shape
        chunk = min(loss_chunk, s)
        nchunks = s // chunk
        hc = hidden.reshape(b, nchunks, chunk, d).swapaxes(0, 1)
        lc = batch["labels"].reshape(b, nchunks, chunk).swapaxes(0, 1)

        def chunk_step(carry, xs):
            h, labels = xs
            logits = model.unembed(params, h)            # (B, chunk, V) fp32
            logp = constrain(jax.nn.log_softmax(logits, axis=-1),
                             "dp", None, "tp")
            nll = -jnp.take_along_axis(logp, labels[..., None],
                                       axis=-1).sum()
            zsum = jnp.square(jax.nn.logsumexp(logits, axis=-1)).sum()
            nll_tot, z_tot = carry
            return (nll_tot + nll, z_tot + zsum), None

        (nll_tot, z_tot), _ = jax.lax.scan(
            jax.checkpoint(chunk_step), (jnp.zeros(()), jnp.zeros(())),
            (hc, lc))
        n_tok = b * s
        loss = nll_tot / n_tok
        zloss = 1e-4 * z_tot / n_tok
        return loss + zloss + 0.01 * aux, loss

    return loss_fn


def make_train_step(model: LM, cfg: ModelConfig, opt_cfg: AdamWConfig,
                    n_micro: int = 1):
    """Train step with optional microbatched gradient accumulation.

    ``n_micro > 1`` scans over microbatch slices of the global batch,
    accumulating fp32 grads (sharded like the params) — per-step activation
    memory drops ~n_micro× at the cost of one optimizer update's worth of
    extra grad buffer.  This is what makes the 132B/398B train_4k cells fit
    HBM (EXPERIMENTS.md §Perf)."""
    loss_fn = make_loss_fn(model, cfg)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (tot, nll), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            def step(carry, mb):
                gsum, nll_sum = carry
                (tot, nll), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, nll_sum + nll), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, nll_sum), _ = jax.lax.scan(
                step, (zeros, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            nll = nll_sum / n_micro
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg)
        metrics["loss"] = nll
        return params, opt_state, metrics

    return train_step


def pick_n_micro(cfg: ModelConfig, mesh, batch: int) -> int:
    """Microbatch count for the train cells: big models → smallest
    microbatch the DP sharding allows; mid-size → 4; small → 1."""
    dp_total = _dp_total(mesh)
    cap = max(batch // dp_total, 1)
    n = cfg.n_params()
    if n > 5e10:
        return cap
    if n > 3e9:
        return min(4, cap)
    return 1


def make_prefill_step(model: LM, cfg: ModelConfig):
    def prefill_step(params, batch):
        kwargs = {k: batch[k] for k in ("frames", "patch_embeds")
                  if k in batch}
        logits, _ = model.forward(params, batch["tokens"], **kwargs)
        return logits[:, -1]          # next-token logits only

    return prefill_step


def make_decode_step(model: LM, cfg: ModelConfig):
    def decode_step(params, state, token):
        return model.decode_step(params, state, token)

    return decode_step


# --------------------------------------------------------- shaped inputs ---
def _sds(shape, dtype, mesh, pspec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, pspec))


def params_shape(model: LM) -> Any:
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def shaped_params(model: LM, mesh) -> Any:
    shapes = params_shape(model)
    shard = param_shardings(shapes, mesh, model.cfg)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shard)


def shaped_opt_state(model: LM, mesh, opt_cfg: AdamWConfig) -> Any:
    p_sds = shaped_params(model, mesh)
    o_shape = jax.eval_shape(
        lambda p: adamw_init(p, opt_cfg), params_shape(model))
    # m and v shard exactly like params (ZeRO); step is replicated.
    m = jax.tree.map(lambda s, p: jax.ShapeDtypeStruct(
        s.shape, s.dtype, sharding=p.sharding), o_shape.m, p_sds)
    v = jax.tree.map(lambda s, p: jax.ShapeDtypeStruct(
        s.shape, s.dtype, sharding=p.sharding), o_shape.v, p_sds)
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
    return type(o_shape)(step=step, m=m, v=v)


def batch_specs(cfg: ModelConfig, mesh, shape: str) -> Dict[str, Any]:
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    dp = batch_pspec(mesh)
    bspec = dp if b % max(1, _dp_total(mesh)) == 0 else P(None)
    out = {
        "tokens": _sds((b, s), jnp.int32, mesh, P(*bspec, None)),
        "labels": _sds((b, s), jnp.int32, mesh, P(*bspec, None)),
    }
    if cfg.family == "encdec":
        out["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), cfg.cdtype,
                             mesh, P(*bspec, None, None))
    if cfg.family == "vlm":
        out["patch_embeds"] = _sds((b, cfg.n_patches, cfg.d_model),
                                   cfg.cdtype, mesh, P(*bspec, None, None))
    if info["kind"] != "train":
        out.pop("labels")
    return out


def _dp_total(mesh) -> int:
    t = 1
    for a in dp_axes(mesh):
        t *= mesh.shape[a]
    return t


def shaped_decode_state(model: LM, cfg: ModelConfig, mesh, shape: str):
    """ShapeDtypeStructs (with shardings) for DecodeState of one cell.

    Layout rules (all divisibility-checked by ``safe_spec``):
    * KV caches (R,B,S,KV,hd): batch over DP; KV heads over `model` when
      divisible, else the *sequence* dim over `model` (+`data` too when the
      batch can't shard — the 500k-token distributed-KV layout).
    * Mamba h (R,B,d_in,N): d_in over `model`.  Conv window likewise.
    * mLSTM/sLSTM states: small; batch over DP only.
    """
    from .sharding import safe_spec, _div

    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    dp = dp_axes(mesh)

    frames_sds = None
    if cfg.family == "encdec":
        frames_sds = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model),
                                          cfg.cdtype)
    state_shape = jax.eval_shape(
        functools.partial(model.init_decode_state, batch=b, max_len=s),
        params_shape(model), frames=frames_sds)

    kv_heads_shardable = _div(mesh, cfg.n_kv_heads, "model")
    batch_shardable = _div(mesh, b, dp)
    seq_axes = "model" if batch_shardable else ("data", "model")

    def assign(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        shp = leaf.shape
        if name.endswith("position") or len(shp) == 0:
            return P()
        body = shp[1:]  # all stacked leaves carry a leading n_repeats dim
        if len(body) == 4 and body[-1] == cfg.hd:          # KV cache
            if kv_heads_shardable:
                ps = safe_spec(mesh, body, dp, None, "model", None)
            else:
                ps = safe_spec(mesh, body, dp, seq_axes, None, None)
        elif len(body) == 4:                               # mLSTM C
            ps = safe_spec(mesh, body, dp, None, "model", None)
        elif len(body) == 3 and body[-1] == cfg.hd:        # cross K/V
            ps = safe_spec(mesh, body, dp, None, None)
        elif (len(body) == 3 and cfg.mamba is not None
              and body[-1] == cfg.mamba.d_state):          # mamba h
            ps = safe_spec(mesh, body, dp, "model", None)
        elif len(body) == 3:                               # conv window/mLSTM n
            ps = safe_spec(mesh, body, dp, None, "model")
        elif len(body) == 2:                               # sLSTM states
            ps = safe_spec(mesh, body, dp, None)
        else:
            ps = P(*([None] * len(body)))
        return P(None, *ps)

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shape)
    out = [jax.ShapeDtypeStruct(
        leaf.shape, leaf.dtype,
        sharding=NamedSharding(mesh, assign(path, leaf)))
        for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)
