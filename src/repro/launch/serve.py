"""Serving driver: the paper's predictive pipeline, end to end.

Requests carry one foreign key per star arm (they are *not* fact-row ids —
any incoming key tuple is servable).  The request path:

  1. **Dynamic-batch LAQ + operator fusion** (the paper's contribution):
     per-request feature vectors are produced by the *pre-fused* star
     pipeline — Σⱼ Iⱼ(Bⱼ Mⱼ L) — through ``compile_serving``: one compiled
     plan per padding bucket, PK lookups + gathers + adds, no join
     materialization, no separate ML runtime (paper Eq. 1 / §3.2).
  2. Optionally, an LM consumes the fused features as a conditioning
     vector (soft-prompt added to the first token embedding) and decodes
     a fixed number of tokens with KV caches.

Runs on a laptop CPU (smoke configs) and lowers/compiles identically on
the production mesh (decode cells of the dry-run).  Reports per-bucket
serve-latency percentiles plus per-batch end-to-end percentiles for fused
vs non-fused execution — the paper's speedup, measured end to end.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.fusion import LinearOperator
from repro.core.query import (DEFAULT_BUCKETS, Catalog, Session,
                              query_from_star, requests_from_rows)
from repro.data import generate_star
from repro.models import LM


class FusedFeatureServer:
    """The paper's pipeline as a serving component.

    One :class:`~repro.core.query.Session` binds the synthetic star
    catalog (and the optional serving mesh) and hands out two dynamic-batch
    serving runtimes (fused and non-fused reference) from one fluent
    pipeline.  Requests are batches of per-arm foreign keys served through
    ``ServingRuntime.serve`` — on the fused plan that is one PK lookup +
    gather-add per arm per batch (paper Eq. 1), padded into a fixed set of
    shape buckets so no request ever recompiles.
    """

    def __init__(self, setting: int, sf: float, k: int, l: int,
                 scale: float = 1.0, seed: int = 0,
                 buckets=DEFAULT_BUCKETS, serve_backend: str = "auto",
                 interpret: bool = False, mesh=None,
                 shard_threshold_bytes=None):
        rng = np.random.default_rng(seed)
        self.syn = generate_star(setting, sf, k, seed=seed, scale=scale)
        self.model = LinearOperator(
            jnp.asarray(rng.normal(size=(k, l)).astype(np.float32)))
        tables, self.query = query_from_star(self.syn.star,
                                             model=self.model)
        # Mutable versioned catalog: dimension appends flow through to the
        # live runtimes via ``append_dim`` without restarting the server.
        self.catalog = Catalog(tables)
        self.mesh = mesh
        self.session = Session(self.catalog, mesh=mesh,
                               shard_threshold_bytes=shard_threshold_bytes,
                               interpret=interpret)
        self.builder = self.session.bind(self.query)
        self.runtime_fused = self.builder.serve(
            buckets=buckets, backend="fused", serve_backend=serve_backend)
        self.runtime_nonfused = self.builder.serve(
            buckets=buckets, backend="nonfused",
            serve_backend=serve_backend)
        self.decision = self.runtime_fused.plan.fusion
        self._scheduled = {}

    def runtime(self, fused: bool = True):
        return self.runtime_fused if fused else self.runtime_nonfused

    def scheduled(self, fused: bool = True, **scheduler_opts):
        """The async serving handle for one runtime (lazy registration).

        Registers the runtime on the session's admission scheduler
        (created on first use with ``scheduler_opts`` — ``slo_ms``,
        ``max_queued_rows``, ...) and returns its ``ScheduledPlan``; use
        ``submit_batch`` for the Future-based request path under
        concurrent open-loop traffic.
        """
        if fused not in self._scheduled:
            sched = self.session.scheduler(**scheduler_opts)
            self._scheduled[fused] = sched.register(
                self.runtime(fused), name="fused" if fused else "nonfused")
        return self._scheduled[fused]

    def append_dim(self, table: str, rows) -> dict:
        """Append dimension rows and refresh both live runtimes in place.

        The streaming-append story end to end: ``catalog.append`` bumps the
        table's version; each runtime applies the delta path (extend the PK
        index, prefuse only the new rows) — zero recompiles while the rows
        fit the table's padded capacity — and newly appended keys become
        servable immediately.  A runtime serving through the admission
        scheduler is refreshed behind its drain-then-swap fence, so
        in-flight scheduled batches complete on the old state first.
        Returns the per-runtime refresh decisions.
        """
        self.catalog.append(table, rows)
        return {"fused": self.session._refresh_runtime(self.runtime_fused),
                "nonfused":
                    self.session._refresh_runtime(self.runtime_nonfused)}

    def serve_batch(self, requests, fused: bool = True):
        """Predictions for a batch of per-arm FK requests (any size)."""
        return self.runtime(fused).serve(requests)

    def submit_batch(self, requests, fused: bool = True,
                     lane: str = "interactive"):
        """Async request path: enqueue on the scheduler, get a Future."""
        return self.scheduled(fused).submit(requests, lane=lane)

    def serve_rows(self, row_ids, fused: bool = True):
        """Bridge from the old interface: serve the FKs of fact rows."""
        reqs = requests_from_rows(self.syn.star.fact, self.query, row_ids)
        return self.serve_batch(reqs, fused=fused)

    def random_requests(self, n: int, rng: np.random.Generator):
        """A request batch sampled from the dimension key ranges."""
        reqs = {}
        for arm, rows in zip(self.query.arms, self.syn.dim_rows):
            # ~1/16 of keys miss the dimension: exercises not-found masking.
            keys = rng.integers(0, max(int(rows * 17 / 16), 1), size=n)
            reqs[arm.fk_col] = keys.astype(np.int32)
        return reqs

    def latency_report(self) -> str:
        lines = []
        for name, rt in (("fused", self.runtime_fused),
                         ("nonfused", self.runtime_nonfused)):
            for bucket, st in rt.latency_stats().items():
                compile_ms = st.get("compile_ms")
                extra = (f" compile={compile_ms:.0f}ms"
                         if compile_ms is not None else "")
                pcts = (f"p50={st['p50']:.2f}ms p95={st['p95']:.2f}ms "
                        f"p99={st['p99']:.2f}ms" if st["count"]
                        else "(no steady-state samples)")
                lines.append(f"[serve] {name} bucket={bucket} "
                             f"n={st['count']} {pcts}{extra}")
            lines.append(f"[serve] {name} compiles={rt.num_compiles} "
                         f"(buckets={rt.buckets})")
        for fused, plan in self._scheduled.items():
            st = plan.stats()
            for lane, lt in st["lanes"].items():
                pcts = (f"p50={lt['p50']:.2f}ms p99={lt['p99']:.2f}ms"
                        if lt["count"] else "(no completed requests)")
                lines.append(f"[sched] {plan.name} lane={lane} "
                             f"n={lt['count']} {pcts}")
            lines.append(f"[sched] {plan.name} steps={st['steps']} "
                         f"admitted={st['admitted_rows']} "
                         f"padded={st['padded_rows']} "
                         f"rejected={st['rejected']}")
        return "\n".join(lines)


def run_serving(arch: str, batch: int, decode_steps: int, k: int, l: int,
                repeats: int = 20):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    server = FusedFeatureServer(setting=2, sf=1, k=k, l=min(l, cfg.d_model),
                                scale=0.05)
    print(f"[serve] fusion planner: fuse={server.decision.fuse} "
          f"({server.decision.reason})")
    print(f"[serve] serving plan: backend={server.runtime_fused.backend} "
          f"serve_backend={server.runtime_fused.serve_backend} "
          f"buckets={server.runtime_fused.buckets}")

    rng = np.random.default_rng(1)
    # Ragged warm-up sweep: hit every padding bucket once so the steady
    # state below never traces (compile-once, serve-any-batch).
    for n in [1] + [b for b in server.runtime_fused.buckets]:
        reqs = server.random_requests(n, rng)
        server.serve_batch(reqs, fused=True)
        server.serve_batch(reqs, fused=False)

    # Conditioning projection: fused features → d_model soft prompt.
    proj = jnp.asarray(rng.normal(
        size=(server.model.l, cfg.d_model)).astype(np.float32)) * 0.01

    decode = jax.jit(lm.decode_step)

    def serve_batch(requests, fused: bool):
        t0 = time.perf_counter()
        feats = server.serve_batch(requests, fused=fused)  # (batch, l)
        cond = (feats @ proj)                              # (batch, d_model)
        state = lm.init_decode_state(params, batch, max_len=decode_steps + 1)
        token = jnp.zeros((batch,), jnp.int32)
        # Soft-prompt injection: add the conditioning vector to the first
        # embedding via a one-step biased decode.
        logits, state = decode(params, state, token)
        out = []
        for _ in range(decode_steps):
            token = jnp.argmax(logits + (cond @ lm.head_matrix(params)
                                         .astype(cond.dtype)), axis=-1)
            logits, state = decode(params, state, token.astype(jnp.int32))
            out.append(token)
        jax.block_until_ready(logits)
        return time.perf_counter() - t0, jnp.stack(out, 1)

    lat_fused, lat_non = [], []
    tokens_fused = tokens_non = None
    for i in range(repeats):
        requests = server.random_requests(batch, rng)
        dt, tokens_fused = serve_batch(requests, fused=True)
        lat_fused.append(dt)
        dt, tokens_non = serve_batch(requests, fused=False)
        lat_non.append(dt)
        # Identical tokens either way (fusion is exact — paper Eq. 1).
        np.testing.assert_array_equal(np.asarray(tokens_fused),
                                      np.asarray(tokens_non))

    def pct(a, p):
        return float(np.percentile(np.asarray(a[2:]) * 1e3, p))

    print(f"[serve] batch={batch} decode={decode_steps} "
          f"fused p50={pct(lat_fused,50):.1f}ms p99={pct(lat_fused,99):.1f}ms"
          f" | non-fused p50={pct(lat_non,50):.1f}ms "
          f"p99={pct(lat_non,99):.1f}ms")
    print(server.latency_report())
    return lat_fused, lat_non


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--l", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=10)
    args = ap.parse_args()
    run_serving(args.arch, args.batch, args.decode_steps, args.k, args.l,
                args.repeats)


if __name__ == "__main__":
    main()
