"""Serving driver: the paper's predictive pipeline, end to end.

Batched requests carry foreign keys into a star schema.  The request path:

  1. **LAQ + operator fusion** (the paper's contribution): per-request
     feature vectors are produced by the *pre-fused* star pipeline —
     Σⱼ Iⱼ(Bⱼ Mⱼ L) — gathers + adds, no join materialization, no separate
     ML runtime (paper Eq. 1 / §3.2).
  2. Optionally, an LM consumes the fused features as a conditioning
     vector (soft-prompt added to the first token embedding) and decodes
     a fixed number of tokens with KV caches.

Runs on a laptop CPU (smoke configs) and lowers/compiles identically on
the production mesh (decode cells of the dry-run).  Reports per-batch
latency percentiles for fused vs non-fused execution — the paper's
speedup, measured end to end.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.fusion import LinearOperator
from repro.core.query import compile_query, query_from_star
from repro.data import generate_star
from repro.models import LM


class FusedFeatureServer:
    """The paper's pipeline as a serving component.

    Holds two compiled predictive-query plans (fused and non-fused reference)
    over a synthetic star schema; requests are batches of fact row ids served
    through ``CompiledQuery.predict_rows`` — on the fused plan that is |dims|
    gathers into the prefused partials + adds per batch (paper Eq. 1).
    """

    def __init__(self, setting: int, sf: float, k: int, l: int,
                 scale: float = 1.0, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.syn = generate_star(setting, sf, k, seed=seed, scale=scale)
        self.model = LinearOperator(
            jnp.asarray(rng.normal(size=(k, l)).astype(np.float32)))
        catalog, query = query_from_star(self.syn.star, model=self.model)
        self.plan_fused = compile_query(catalog, query, backend="fused")
        self.plan_nonfused = compile_query(catalog, query, backend="nonfused")
        self.decision = self.plan_fused.plan.fusion

    def features_fused(self):
        return self.plan_fused.predictions()

    def features_nonfused(self):
        return self.plan_nonfused.predictions()

    def serve_batch(self, row_ids, fused: bool = True):
        """Predictions for a request batch of fact row ids."""
        plan = self.plan_fused if fused else self.plan_nonfused
        return plan.predict_rows(row_ids)


def run_serving(arch: str, batch: int, decode_steps: int, k: int, l: int,
                repeats: int = 20):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    server = FusedFeatureServer(setting=2, sf=1, k=k, l=min(l, cfg.d_model),
                                scale=0.05)
    print(f"[serve] fusion planner: fuse={server.decision.fuse} "
          f"({server.decision.reason})")

    # Conditioning projection: fused features → d_model soft prompt.
    rng = np.random.default_rng(1)
    proj = jnp.asarray(rng.normal(
        size=(server.model.l, cfg.d_model)).astype(np.float32)) * 0.01

    decode = jax.jit(lm.decode_step)

    row_ids = jnp.arange(batch, dtype=jnp.int32)   # the request batch

    def serve_batch(fused: bool):
        t0 = time.perf_counter()
        feats = server.serve_batch(row_ids, fused=fused)  # (batch, l)
        cond = (feats @ proj)                             # (batch, d_model)
        state = lm.init_decode_state(params, batch, max_len=decode_steps + 1)
        token = jnp.zeros((batch,), jnp.int32)
        # Soft-prompt injection: add the conditioning vector to the first
        # embedding via a one-step biased decode.
        logits, state = decode(params, state, token)
        out = []
        for _ in range(decode_steps):
            token = jnp.argmax(logits + (cond @ lm.head_matrix(params)
                                         .astype(cond.dtype)), axis=-1)
            logits, state = decode(params, state, token.astype(jnp.int32))
            out.append(token)
        jax.block_until_ready(logits)
        return time.perf_counter() - t0, jnp.stack(out, 1)

    lat_fused, lat_non = [], []
    tokens_fused = tokens_non = None
    for i in range(repeats):
        dt, tokens_fused = serve_batch(fused=True)
        lat_fused.append(dt)
        dt, tokens_non = serve_batch(fused=False)
        lat_non.append(dt)
    # Identical predictions either way (fusion is exact — paper Eq. 1).
    np.testing.assert_array_equal(np.asarray(tokens_fused),
                                  np.asarray(tokens_non))

    def pct(a, p):
        return float(np.percentile(np.asarray(a[2:]) * 1e3, p))

    print(f"[serve] batch={batch} decode={decode_steps} "
          f"fused p50={pct(lat_fused,50):.1f}ms p99={pct(lat_fused,99):.1f}ms"
          f" | non-fused p50={pct(lat_non,50):.1f}ms "
          f"p99={pct(lat_non,99):.1f}ms")
    return lat_fused, lat_non


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--l", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=10)
    args = ap.parse_args()
    run_serving(args.arch, args.batch, args.decode_steps, args.k, args.l,
                args.repeats)


if __name__ == "__main__":
    main()
