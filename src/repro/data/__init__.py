"""Data substrate: SSB benchmark, synthetic star schemas, LM token pipeline."""
from .ssb import SSBData, generate as generate_ssb
from .ssb_queries import (PREDICTIVE_QUERIES, QUERIES, QUERY_IR,
                          compiled_plan, predictive_query_names,
                          query_groups, ssb_catalog, ssb_session)
from .synthetic import SyntheticStar, cardinalities, generate as generate_star
from .tokens import TokenPipeline, TokenPipelineConfig, make_global_batch

__all__ = ["SSBData", "generate_ssb", "QUERIES", "QUERY_IR",
           "PREDICTIVE_QUERIES", "compiled_plan", "predictive_query_names",
           "query_groups", "ssb_catalog", "ssb_session",
           "SyntheticStar", "cardinalities", "generate_star",
           "TokenPipeline", "TokenPipelineConfig", "make_global_batch"]
