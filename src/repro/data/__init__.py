"""Data substrate: SSB benchmark, synthetic star schemas, LM token pipeline."""
from .ssb import SSBData, generate as generate_ssb
from .ssb_queries import QUERIES, query_groups
from .synthetic import SyntheticStar, cardinalities, generate as generate_star
from .tokens import TokenPipeline, TokenPipelineConfig, make_global_batch

__all__ = ["SSBData", "generate_ssb", "QUERIES", "query_groups",
           "SyntheticStar", "cardinalities", "generate_star",
           "TokenPipeline", "TokenPipelineConfig", "make_global_batch"]
