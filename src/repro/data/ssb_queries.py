"""The 13 SSB queries (Q1.1–Q4.3) expressed as LAQ executions.

Each query returns (group_codes, aggregates, meta).  Query group structure
(paper Table 2): QG1 = 1 join + scalar SUM; QG2/3 = 3 joins + group-by-sum +
sort; QG4 = 4 joins + group-by-sum + sort.  Implemented on the factored
MM-Join (star_join) — the paper-faithful dense path is exercised by tests
and the mmjoin benchmarks; running the dense row-matching matrix over
6M-row lineorder is exactly the blow-up the paper reports (§4.2 analysis).
"""
from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp

from repro.core.laq import (DimSpec, Pred, composite_code, groupby_reduce,
                            join_factored, select)
from .ssb import SSBData, N_BRANDS, N_NATIONS

# Registry: name → callable(SSBData) → dict of results.
QUERIES: Dict[str, Callable] = {}


def _register(name):
    def deco(fn):
        QUERIES[name] = fn
        return fn
    return deco


def _arm(fact, dim, fk, pk, preds=()):
    """Join an arm; returns (found_mask, dim_row_ptr, dim_selected_mask)."""
    fj = join_factored(fact.key(fk), dim.key(pk))
    ok = fj.found
    if preds:
        # Dimension predicate evaluated on the joined dim rows (pushdown).
        dmask = Pred(preds[0].col, preds[0].op, preds[0].value).mask(dim)
        for p in preds[1:]:
            dmask = dmask & p.mask(dim)
        ok = ok & jnp.take(dmask, fj.ptr)
    return ok, fj.ptr


# --------------------------------------------------------- query group 1 ---
def _q1(data: SSBData, date_preds, lo_preds):
    lo = data.lineorder
    ok, _ = _arm(lo, data.date, "lo_orderdate", "datekey", date_preds)
    mask = ok & lo.valid_mask()
    for p in lo_preds:
        mask = mask & p.mask(lo)
    revenue = jnp.sum(jnp.where(
        mask, lo.col("lo_extendedprice") * lo.col("lo_discount"), 0.0))
    return {"revenue": revenue, "rows": jnp.sum(mask)}


@_register("Q1.1")
def q11(d):
    return _q1(d, [Pred("d_year", "==", 1993)],
               [Pred("lo_discount", "between", (1, 3)),
                Pred("lo_quantity", "<", 25)])


@_register("Q1.2")
def q12(d):
    return _q1(d, [Pred("d_yearmonthnum", "==", 199401)],
               [Pred("lo_discount", "between", (4, 6)),
                Pred("lo_quantity", "between", (26, 35))])


@_register("Q1.3")
def q13(d):
    return _q1(d, [Pred("d_weeknuminyear", "==", 6),
                   Pred("d_year", "==", 1994)],
               [Pred("lo_discount", "between", (5, 7)),
                Pred("lo_quantity", "between", (26, 35))])


# --------------------------------------------------------- query group 2 ---
def _q2(data: SSBData, part_preds, supp_preds, n_groups=8192):
    lo = data.lineorder
    ok_p, ptr_p = _arm(lo, data.part, "lo_partkey", "partkey", part_preds)
    ok_s, _ = _arm(lo, data.supplier, "lo_suppkey", "suppkey", supp_preds)
    ok_d, ptr_d = _arm(lo, data.date, "lo_orderdate", "datekey")
    valid = lo.valid_mask() & ok_p & ok_s & ok_d
    year = jnp.take(data.date.key("d_year"), ptr_d)
    brand = jnp.take(data.part.key("p_brand1"), ptr_p)
    codes = composite_code([year - 1992, brand], [8, N_BRANDS], valid)
    uniq, (rev,) = groupby_reduce(codes, [jnp.where(
        valid, lo.col("lo_revenue"), 0.0)], n_groups, ("sum",))
    return {"groups": uniq, "revenue": rev, "rows": jnp.sum(valid)}


@_register("Q2.1")
def q21(d):
    return _q2(d, [Pred("p_category", "==", 6)], [Pred("s_region", "==", 1)])


@_register("Q2.2")
def q22(d):
    return _q2(d, [Pred("p_brand1", "between", (253, 260))],
               [Pred("s_region", "==", 2)])


@_register("Q2.3")
def q23(d):
    return _q2(d, [Pred("p_brand1", "==", 260)], [Pred("s_region", "==", 3)])


# --------------------------------------------------------- query group 3 ---
def _q3(data: SSBData, cust_preds, supp_preds, date_preds, group_cols,
        bounds, n_groups=8192):
    lo = data.lineorder
    ok_c, ptr_c = _arm(lo, data.customer, "lo_custkey", "custkey", cust_preds)
    ok_s, ptr_s = _arm(lo, data.supplier, "lo_suppkey", "suppkey", supp_preds)
    ok_d, ptr_d = _arm(lo, data.date, "lo_orderdate", "datekey", date_preds)
    valid = lo.valid_mask() & ok_c & ok_s & ok_d
    cols = []
    for table, ptr, col in group_cols:
        src = {"c": (data.customer, ptr_c), "s": (data.supplier, ptr_s),
               "d": (data.date, ptr_d)}[table]
        cols.append(jnp.take(src[0].key(col), src[1]))
    # Normalize year to small range for the composite code.
    cols = [c - 1992 if b == 8 else c for c, b in zip(cols, bounds)]
    codes = composite_code(cols, bounds, valid)
    uniq, (rev,) = groupby_reduce(codes, [jnp.where(
        valid, lo.col("lo_revenue"), 0.0)], n_groups, ("sum",))
    return {"groups": uniq, "revenue": rev, "rows": jnp.sum(valid)}


@_register("Q3.1")
def q31(d):
    return _q3(d, [Pred("c_region", "==", 2)], [Pred("s_region", "==", 2)],
               [Pred("d_year", "between", (1992, 1997))],
               [("c", None, "c_nation"), ("s", None, "s_nation"),
                ("d", None, "d_year")], [N_NATIONS, N_NATIONS, 8])


@_register("Q3.2")
def q32(d):
    return _q3(d, [Pred("c_nation", "==", 14)], [Pred("s_nation", "==", 14)],
               [Pred("d_year", "between", (1992, 1997))],
               [("c", None, "c_city"), ("s", None, "s_city"),
                ("d", None, "d_year")], [250, 250, 8])


@_register("Q3.3")
def q33(d):
    return _q3(d, [Pred("c_city", "in", (141, 145))],
               [Pred("s_city", "in", (141, 145))],
               [Pred("d_year", "between", (1992, 1997))],
               [("c", None, "c_city"), ("s", None, "s_city"),
                ("d", None, "d_year")], [250, 250, 8])


# --------------------------------------------------------- query group 4 ---
def _q4(data: SSBData, cust_preds, supp_preds, part_preds, group_spec,
        n_groups=8192):
    lo = data.lineorder
    ok_c, ptr_c = _arm(lo, data.customer, "lo_custkey", "custkey", cust_preds)
    ok_s, ptr_s = _arm(lo, data.supplier, "lo_suppkey", "suppkey", supp_preds)
    ok_p, ptr_p = _arm(lo, data.part, "lo_partkey", "partkey", part_preds)
    ok_d, ptr_d = _arm(lo, data.date, "lo_orderdate", "datekey")
    valid = lo.valid_mask() & ok_c & ok_s & ok_p & ok_d
    ptrs = {"c": (data.customer, ptr_c), "s": (data.supplier, ptr_s),
            "p": (data.part, ptr_p), "d": (data.date, ptr_d)}
    cols, bounds = [], []
    for table, col, bound in group_spec:
        src, ptr = ptrs[table]
        c = jnp.take(src.key(col), ptr)
        cols.append(c - 1992 if col == "d_year" else c)
        bounds.append(bound)
    codes = composite_code(cols, bounds, valid)
    profit = jnp.where(valid,
                       lo.col("lo_revenue") - lo.col("lo_supplycost"), 0.0)
    uniq, (prof,) = groupby_reduce(codes, [profit], n_groups, ("sum",))
    return {"groups": uniq, "profit": prof, "rows": jnp.sum(valid)}


@_register("Q4.1")
def q41(d):
    return _q4(d, [Pred("c_region", "==", 1)], [Pred("s_region", "==", 1)],
               [Pred("p_mfgr", "in", (0, 1))],
               [("d", "d_year", 8), ("c", "c_nation", N_NATIONS)])


@_register("Q4.2")
def q42(d):
    return _q4(d, [Pred("c_region", "==", 1)], [Pred("s_region", "==", 1)],
               [Pred("p_mfgr", "in", (0, 1))],
               [("d", "d_year", 8), ("s", "s_nation", N_NATIONS),
                ("p", "p_category", 25)])


@_register("Q4.3")
def q43(d):
    return _q4(d, [Pred("c_region", "==", 1)], [Pred("s_nation", "==", 9)],
               [Pred("p_category", "==", 8)],
               [("d", "d_year", 8), ("s", "s_city", 250),
                ("p", "p_brand1", N_BRANDS)])


def query_groups():
    return {
        "QG1": ["Q1.1", "Q1.2", "Q1.3"],
        "QG2": ["Q2.1", "Q2.2", "Q2.3"],
        "QG3": ["Q3.1", "Q3.2", "Q3.3"],
        "QG4": ["Q4.1", "Q4.2", "Q4.3"],
    }
