"""The 13 SSB queries (Q1.1–Q4.3) + predict-then-aggregate variants, all
expressed through the fluent ``Session`` query-builder API and lowered to
``PredictiveQuery`` IR for the query compiler.

Each query returns (group_codes, aggregates, meta).  Query group structure
(paper Table 2): QG1 = 1 join + scalar SUM; QG2/3 = 3 joins + group-by-sum +
sort; QG4 = 4 joins + group-by-sum + sort.  The compiler lowers every query
onto the factored MM-Join (paper §3.1) with selection folded into the join
validity, and picks the aggregation backend (Fig. 4 matmul vs segment ops)
per query — the paper-faithful dense path stays available as the reference
backend exercised by tests and the mmjoin benchmarks.

``QUERY_IR`` maps each name to a zero-arg builder of the declarative IR —
constructed with the detached fluent builder (``repro.core.query.query``),
so the registry is the reference migration onto the Session surface.
``QUERIES`` keeps the legacy callable(SSBData) → results interface on top
of a per-dataset :class:`~repro.core.query.Session` (``ssb_session``),
whose structural plan cache replaces the old hand-rolled one;
``compiled_plan`` remains as a thin shim over ``Session.compile``.

The P* queries are the paper's §3 predictive pipelines on SSB join shapes:
a model head (``LinearOperator`` / ``DecisionTreeGEMM``) over dimension
features, fused into the star join, with its predictions aggregated.
"""
from __future__ import annotations

import warnings
import weakref
from typing import Callable, Dict

import jax.numpy as jnp
import numpy as np

from repro.core.fusion import LinearOperator, random_tree
from repro.core.laq import Catalog
from repro.core.query import (PREDICTION, GroupKey, PredictiveQuery, Session,
                              query)
from .ssb import SSBData, N_BRANDS, N_NATIONS, N_REGIONS

# Registries: name → zero-arg IR builder, and name → callable(SSBData).
QUERY_IR: Dict[str, Callable[[], PredictiveQuery]] = {}
QUERIES: Dict[str, Callable] = {}
PREDICTIVE_QUERIES: Dict[str, Callable] = {}

#: per-dataset Session cache: SSBData → Session (structural plan cache)
_SESSIONS: "weakref.WeakKeyDictionary[SSBData, Session]" = (
    weakref.WeakKeyDictionary())


def ssb_catalog(data: SSBData) -> Catalog:
    """A mutable versioned :class:`Catalog` over ``data``'s five tables.

    Appends (e.g. new ``date``/``part`` rows as the benchmark "advances in
    time") flow through every Session-cached plan and serving runtime via
    the catalog's version counters + delta refresh.
    """
    return Catalog({"lineorder": data.lineorder, "part": data.part,
                    "supplier": data.supplier, "customer": data.customer,
                    "date": data.date})


def ssb_session(data: SSBData) -> Session:
    """The (cached) Session over ``data``'s catalog.

    One Session per dataset means one structural plan cache: every
    registered query — and any ad-hoc fluent pipeline over the same
    catalog — shares compiled plans across rebuilds of the IR.
    """
    sess = _SESSIONS.get(data)
    if sess is None:
        sess = Session(ssb_catalog(data))
        _SESSIONS[data] = sess
    return sess


def compiled_plan(name: str, data: SSBData, **kwargs):
    """Deprecated shim over ``Session.compile`` (the old entry point).

    Use ``ssb_session(data).compile(QUERY_IR[name](), **kwargs)`` — or a
    fluent ``Session.query(...)`` pipeline — instead; see the migration
    table in :mod:`repro.core.query`.  The shim still routes through the
    session cache, so behaviour is unchanged apart from the warning.
    """
    warnings.warn(
        "compiled_plan() is deprecated; use "
        "ssb_session(data).compile(QUERY_IR[name]()) — see the migration "
        "table in repro.core.query",
        DeprecationWarning, stacklevel=2)
    return ssb_session(data).compile(QUERY_IR[name](), **kwargs)


def _register(name, registry=None):
    def deco(builder):
        QUERY_IR[name] = builder

        def runner(data: SSBData):
            return ssb_session(data).bind(builder()).run()

        QUERIES[name] = runner
        if registry is not None:
            registry[name] = runner
        return builder
    return deco


_REVENUE = ("sum", ("mul", "lo_extendedprice", "lo_discount"))
_YEAR = GroupKey("date", "d_year", 8, offset=1992)


# --------------------------------------------------------- query group 1 ---
def _q1(date_preds, lo_preds):
    return (query("lineorder")
            .join("date", on=("lo_orderdate", "datekey"), where=date_preds)
            .where(*lo_preds)
            .agg(revenue=_REVENUE)
            .build())


@_register("Q1.1")
def q11():
    return _q1([("d_year", "==", 1993)],
               [("lo_discount", "between", (1, 3)),
                ("lo_quantity", "<", 25)])


@_register("Q1.2")
def q12():
    return _q1([("d_yearmonthnum", "==", 199401)],
               [("lo_discount", "between", (4, 6)),
                ("lo_quantity", "between", (26, 35))])


@_register("Q1.3")
def q13():
    return _q1([("d_weeknuminyear", "==", 6), ("d_year", "==", 1994)],
               [("lo_discount", "between", (5, 7)),
                ("lo_quantity", "between", (26, 35))])


# --------------------------------------------------------- query group 2 ---
def _q2(part_preds, supp_preds):
    return (query("lineorder")
            .join("part", on=("lo_partkey", "partkey"), where=part_preds)
            .join("supplier", on=("lo_suppkey", "suppkey"),
                  where=supp_preds)
            .join("date", on=("lo_orderdate", "datekey"))
            .group_by(_YEAR, ("part", "p_brand1", N_BRANDS))
            .agg(revenue="sum(lo_revenue)")
            .build())


@_register("Q2.1")
def q21():
    return _q2([("p_category", "==", 6)], [("s_region", "==", 1)])


@_register("Q2.2")
def q22():
    return _q2([("p_brand1", "between", (253, 260))],
               [("s_region", "==", 2)])


@_register("Q2.3")
def q23():
    return _q2([("p_brand1", "==", 260)], [("s_region", "==", 3)])


# --------------------------------------------------------- query group 3 ---
def _q3(cust_preds, supp_preds, date_preds, group_keys):
    return (query("lineorder")
            .join("customer", on=("lo_custkey", "custkey"),
                  where=cust_preds)
            .join("supplier", on=("lo_suppkey", "suppkey"),
                  where=supp_preds)
            .join("date", on=("lo_orderdate", "datekey"), where=date_preds)
            .group_by(*group_keys)
            .agg(revenue="sum(lo_revenue)")
            .build())


_YEARS_9297 = [("d_year", "between", (1992, 1997))]


@_register("Q3.1")
def q31():
    return _q3([("c_region", "==", 2)], [("s_region", "==", 2)],
               _YEARS_9297,
               [GroupKey("customer", "c_nation", N_NATIONS),
                GroupKey("supplier", "s_nation", N_NATIONS), _YEAR])


@_register("Q3.2")
def q32():
    return _q3([("c_nation", "==", 14)], [("s_nation", "==", 14)],
               _YEARS_9297,
               [("customer", "c_city", 250),
                ("supplier", "s_city", 250), _YEAR])


@_register("Q3.3")
def q33():
    return _q3([("c_city", "in", (141, 145))],
               [("s_city", "in", (141, 145))],
               _YEARS_9297,
               [("customer", "c_city", 250),
                ("supplier", "s_city", 250), _YEAR])


# --------------------------------------------------------- query group 4 ---
def _q4(cust_preds, supp_preds, part_preds, group_keys):
    return (query("lineorder")
            .join("customer", on=("lo_custkey", "custkey"),
                  where=cust_preds)
            .join("supplier", on=("lo_suppkey", "suppkey"),
                  where=supp_preds)
            .join("part", on=("lo_partkey", "partkey"), where=part_preds)
            .join("date", on=("lo_orderdate", "datekey"))
            .group_by(*group_keys)
            .agg(profit=("sum", ("sub", "lo_revenue", "lo_supplycost")))
            .build())


@_register("Q4.1")
def q41():
    return _q4([("c_region", "==", 1)], [("s_region", "==", 1)],
               [("p_mfgr", "in", (0, 1))],
               [_YEAR, ("customer", "c_nation", N_NATIONS)])


@_register("Q4.2")
def q42():
    return _q4([("c_region", "==", 1)], [("s_region", "==", 1)],
               [("p_mfgr", "in", (0, 1))],
               [_YEAR, ("supplier", "s_nation", N_NATIONS),
                ("part", "p_category", 25)])


@_register("Q4.3")
def q43():
    return _q4([("c_region", "==", 1)], [("s_nation", "==", 9)],
               [("p_category", "==", 8)],
               [_YEAR, ("supplier", "s_city", 250),
                ("part", "p_brand1", N_BRANDS)])


# ------------------------------------------ predict-then-aggregate (§3) ----
# SSB join shapes with a fused model head: features come from dimension
# tables, the model's linear prefix is pre-fused into them (Eq. 1/3), and the
# prediction matrix is aggregated directly (Fig. 4 / segment ops).
def _p_star(model, *, num_groups=8):
    """The shared 3-arm P* shape: part/supplier/date features + a head."""
    return (query("lineorder")
            .join("part", on=("lo_partkey", "partkey"),
                  features=("p_size", "p_category"))
            .join("supplier", on=("lo_suppkey", "suppkey"),
                  features=("s_city",))
            .join("date", on=("lo_orderdate", "datekey"),
                  features=("d_month", "d_weeknuminyear"))
            .predict(model)
            .group_by(_YEAR, num_groups=num_groups)
            .agg(prediction=("sum", PREDICTION)))


_P_K = 5   # feature width of the shared P* shape above (2 + 1 + 2)


def _linear_head(k: int, l: int, seed: int = 0) -> LinearOperator:
    rng = np.random.default_rng(seed)
    return LinearOperator(jnp.asarray(
        rng.normal(size=(k, l)).astype(np.float32) / np.sqrt(k)))


def _register_predictive(name):
    return _register(name, registry=PREDICTIVE_QUERIES)


@_register_predictive("P1.linear.year")
def p1():
    """Linear scores over part/supplier/date features, grouped by year."""
    return _p_star(_linear_head(_P_K, 4)).build()


@_register_predictive("P2.linear.select.scalar")
def p2():
    """QG1 shape: date-arm features + fact selection, scalar prediction sum."""
    return (query("lineorder")
            .join("date", on=("lo_orderdate", "datekey"),
                  features=("d_month", "d_weeknuminyear"),
                  where=[("d_year", "between", (1993, 1995))])
            .where(("lo_discount", "between", (1, 3)))
            .predict(_linear_head(2, 3, seed=1))
            .agg(prediction=("sum", PREDICTION))
            .build())


@_register_predictive("P3.tree.year")
def p3():
    """GEMM decision tree (Fig. 5) fused into the star, leaf histogram/year."""
    return _p_star(
        random_tree(np.random.default_rng(2), _P_K, depth=3)).build()


@_register_predictive("P4.tree.select.region")
def p4():
    """Tree head + selective supplier arm, leaf histogram per customer
    region."""
    return (query("lineorder")
            .join("customer", on=("lo_custkey", "custkey"),
                  features=("c_city",))
            .join("supplier", on=("lo_suppkey", "suppkey"),
                  features=("s_city",),
                  where=[("s_region", "in", (0, 1, 2))])
            .join("date", on=("lo_orderdate", "datekey"),
                  features=("d_month",))
            .predict(random_tree(np.random.default_rng(3), 3, depth=2))
            .group_by(("customer", "c_region", N_REGIONS),
                      num_groups=N_REGIONS)
            .agg(prediction=("sum", PREDICTION))
            .build())


def query_groups():
    return {
        "QG1": ["Q1.1", "Q1.2", "Q1.3"],
        "QG2": ["Q2.1", "Q2.2", "Q2.3"],
        "QG3": ["Q3.1", "Q3.2", "Q3.3"],
        "QG4": ["Q4.1", "Q4.2", "Q4.3"],
    }


def predictive_query_names():
    """The predict-then-aggregate variants (kept out of the 13-query SSB
    groups so Fig. 7–9 benchmark semantics stay comparable)."""
    return sorted(PREDICTIVE_QUERIES)
