"""The 13 SSB queries (Q1.1–Q4.3) + predict-then-aggregate variants, all
expressed as ``PredictiveQuery`` IR and executed through the query compiler.

Each query returns (group_codes, aggregates, meta).  Query group structure
(paper Table 2): QG1 = 1 join + scalar SUM; QG2/3 = 3 joins + group-by-sum +
sort; QG4 = 4 joins + group-by-sum + sort.  The compiler lowers every query
onto the factored MM-Join (paper §3.1) with selection folded into the join
validity, and picks the aggregation backend (Fig. 4 matmul vs segment-sum)
per query — the paper-faithful dense path stays available as the reference
backend exercised by tests and the mmjoin benchmarks.

``QUERY_IR`` maps each name to a zero-arg builder of the declarative IR
(data-independent); ``QUERIES`` keeps the legacy callable(SSBData) → results
interface on top of a per-dataset compiled-plan cache.

The P* queries are the paper's §3 predictive pipelines on SSB join shapes:
a model head (``LinearOperator`` / ``DecisionTreeGEMM``) over dimension
features, fused into the star join, with its predictions aggregated.
"""
from __future__ import annotations

import weakref
from typing import Callable, Dict

import jax.numpy as jnp
import numpy as np

from repro.core.fusion import LinearOperator, random_tree
from repro.core.laq import Pred, Table
from repro.core.query import (PREDICTION, Aggregate, ArmSpec, GroupKey,
                              PredictiveQuery, compile_query)
from .ssb import SSBData, N_BRANDS, N_NATIONS, N_REGIONS

# Registries: name → zero-arg IR builder, and name → callable(SSBData).
QUERY_IR: Dict[str, Callable[[], PredictiveQuery]] = {}
QUERIES: Dict[str, Callable] = {}
PREDICTIVE_QUERIES: Dict[str, Callable] = {}

#: compiled-plan cache: SSBData → {query name → CompiledQuery}
_PLANS: "weakref.WeakKeyDictionary[SSBData, dict]" = weakref.WeakKeyDictionary()


def ssb_catalog(data: SSBData) -> Dict[str, Table]:
    return {"lineorder": data.lineorder, "part": data.part,
            "supplier": data.supplier, "customer": data.customer,
            "date": data.date}


def compiled_plan(name: str, data: SSBData, **kwargs):
    """The (cached) compiled plan for a registered query on ``data``.

    The cache key includes the compile options, so requesting a different
    backend recompiles instead of returning the first call's plan.
    """
    plans = _PLANS.setdefault(data, {})
    key = (name, tuple(sorted(kwargs.items())))
    if key not in plans:
        plan = compile_query(ssb_catalog(data), QUERY_IR[name](), **kwargs)
        if plan.is_traced:
            return plan   # built under an outer jit: holds tracers, no cache
        plans[key] = plan
    return plans[key]


def _register(name, registry=None):
    def deco(builder):
        QUERY_IR[name] = builder

        def runner(data: SSBData):
            return compiled_plan(name, data).run()

        QUERIES[name] = runner
        if registry is not None:
            registry[name] = runner
        return builder
    return deco


_REVENUE = Aggregate(("mul", "lo_extendedprice", "lo_discount"), "sum",
                     "revenue")
_YEAR = GroupKey("date", "d_year", 8, offset=1992)


# --------------------------------------------------------- query group 1 ---
def _q1(date_preds, lo_preds):
    return PredictiveQuery(
        fact="lineorder",
        arms=(ArmSpec("date", "lo_orderdate", "datekey",
                      preds=tuple(date_preds)),),
        fact_preds=tuple(lo_preds),
        aggregates=(_REVENUE,))


@_register("Q1.1")
def q11():
    return _q1([Pred("d_year", "==", 1993)],
               [Pred("lo_discount", "between", (1, 3)),
                Pred("lo_quantity", "<", 25)])


@_register("Q1.2")
def q12():
    return _q1([Pred("d_yearmonthnum", "==", 199401)],
               [Pred("lo_discount", "between", (4, 6)),
                Pred("lo_quantity", "between", (26, 35))])


@_register("Q1.3")
def q13():
    return _q1([Pred("d_weeknuminyear", "==", 6), Pred("d_year", "==", 1994)],
               [Pred("lo_discount", "between", (5, 7)),
                Pred("lo_quantity", "between", (26, 35))])


# --------------------------------------------------------- query group 2 ---
def _q2(part_preds, supp_preds):
    return PredictiveQuery(
        fact="lineorder",
        arms=(ArmSpec("part", "lo_partkey", "partkey",
                      preds=tuple(part_preds)),
              ArmSpec("supplier", "lo_suppkey", "suppkey",
                      preds=tuple(supp_preds)),
              ArmSpec("date", "lo_orderdate", "datekey")),
        group_keys=(_YEAR, GroupKey("part", "p_brand1", N_BRANDS)),
        aggregates=(Aggregate("lo_revenue", "sum", "revenue"),))


@_register("Q2.1")
def q21():
    return _q2([Pred("p_category", "==", 6)], [Pred("s_region", "==", 1)])


@_register("Q2.2")
def q22():
    return _q2([Pred("p_brand1", "between", (253, 260))],
               [Pred("s_region", "==", 2)])


@_register("Q2.3")
def q23():
    return _q2([Pred("p_brand1", "==", 260)], [Pred("s_region", "==", 3)])


# --------------------------------------------------------- query group 3 ---
def _q3(cust_preds, supp_preds, date_preds, group_keys):
    return PredictiveQuery(
        fact="lineorder",
        arms=(ArmSpec("customer", "lo_custkey", "custkey",
                      preds=tuple(cust_preds)),
              ArmSpec("supplier", "lo_suppkey", "suppkey",
                      preds=tuple(supp_preds)),
              ArmSpec("date", "lo_orderdate", "datekey",
                      preds=tuple(date_preds))),
        group_keys=tuple(group_keys),
        aggregates=(Aggregate("lo_revenue", "sum", "revenue"),))


_YEARS_9297 = [Pred("d_year", "between", (1992, 1997))]


@_register("Q3.1")
def q31():
    return _q3([Pred("c_region", "==", 2)], [Pred("s_region", "==", 2)],
               _YEARS_9297,
               [GroupKey("customer", "c_nation", N_NATIONS),
                GroupKey("supplier", "s_nation", N_NATIONS), _YEAR])


@_register("Q3.2")
def q32():
    return _q3([Pred("c_nation", "==", 14)], [Pred("s_nation", "==", 14)],
               _YEARS_9297,
               [GroupKey("customer", "c_city", 250),
                GroupKey("supplier", "s_city", 250), _YEAR])


@_register("Q3.3")
def q33():
    return _q3([Pred("c_city", "in", (141, 145))],
               [Pred("s_city", "in", (141, 145))],
               _YEARS_9297,
               [GroupKey("customer", "c_city", 250),
                GroupKey("supplier", "s_city", 250), _YEAR])


# --------------------------------------------------------- query group 4 ---
def _q4(cust_preds, supp_preds, part_preds, group_keys):
    return PredictiveQuery(
        fact="lineorder",
        arms=(ArmSpec("customer", "lo_custkey", "custkey",
                      preds=tuple(cust_preds)),
              ArmSpec("supplier", "lo_suppkey", "suppkey",
                      preds=tuple(supp_preds)),
              ArmSpec("part", "lo_partkey", "partkey",
                      preds=tuple(part_preds)),
              ArmSpec("date", "lo_orderdate", "datekey")),
        group_keys=tuple(group_keys),
        aggregates=(Aggregate(("sub", "lo_revenue", "lo_supplycost"),
                              "sum", "profit"),))


@_register("Q4.1")
def q41():
    return _q4([Pred("c_region", "==", 1)], [Pred("s_region", "==", 1)],
               [Pred("p_mfgr", "in", (0, 1))],
               [_YEAR, GroupKey("customer", "c_nation", N_NATIONS)])


@_register("Q4.2")
def q42():
    return _q4([Pred("c_region", "==", 1)], [Pred("s_region", "==", 1)],
               [Pred("p_mfgr", "in", (0, 1))],
               [_YEAR, GroupKey("supplier", "s_nation", N_NATIONS),
                GroupKey("part", "p_category", 25)])


@_register("Q4.3")
def q43():
    return _q4([Pred("c_region", "==", 1)], [Pred("s_nation", "==", 9)],
               [Pred("p_category", "==", 8)],
               [_YEAR, GroupKey("supplier", "s_city", 250),
                GroupKey("part", "p_brand1", N_BRANDS)])


# ------------------------------------------ predict-then-aggregate (§3) ----
# SSB join shapes with a fused model head: features come from dimension
# tables, the model's linear prefix is pre-fused into them (Eq. 1/3), and the
# prediction matrix is aggregated directly (Fig. 4 / segment-sum).
_P_ARMS = (ArmSpec("part", "lo_partkey", "partkey", ("p_size", "p_category")),
           ArmSpec("supplier", "lo_suppkey", "suppkey", ("s_city",)),
           ArmSpec("date", "lo_orderdate", "datekey",
                   ("d_month", "d_weeknuminyear")))
_P_K = sum(len(a.feature_cols) for a in _P_ARMS)   # 6 features
_PRED_SUM = (Aggregate(PREDICTION, "sum", "prediction"),)


def _linear_head(k: int, l: int, seed: int = 0) -> LinearOperator:
    rng = np.random.default_rng(seed)
    return LinearOperator(jnp.asarray(
        rng.normal(size=(k, l)).astype(np.float32) / np.sqrt(k)))


def _register_predictive(name):
    return _register(name, registry=PREDICTIVE_QUERIES)


@_register_predictive("P1.linear.year")
def p1():
    """Linear scores over part/supplier/date features, grouped by year."""
    return PredictiveQuery(
        fact="lineorder", arms=_P_ARMS, model=_linear_head(_P_K, 4),
        group_keys=(_YEAR,), aggregates=_PRED_SUM, num_groups=8)


@_register_predictive("P2.linear.select.scalar")
def p2():
    """QG1 shape: date-arm features + fact selection, scalar prediction sum."""
    arms = (ArmSpec("date", "lo_orderdate", "datekey",
                    ("d_month", "d_weeknuminyear"),
                    preds=(Pred("d_year", "between", (1993, 1995)),)),)
    return PredictiveQuery(
        fact="lineorder", arms=arms, model=_linear_head(2, 3, seed=1),
        fact_preds=(Pred("lo_discount", "between", (1, 3)),),
        aggregates=_PRED_SUM)


@_register_predictive("P3.tree.year")
def p3():
    """GEMM decision tree (Fig. 5) fused into the star, leaf histogram/year."""
    return PredictiveQuery(
        fact="lineorder", arms=_P_ARMS,
        model=random_tree(np.random.default_rng(2), _P_K, depth=3),
        group_keys=(_YEAR,), aggregates=_PRED_SUM, num_groups=8)


@_register_predictive("P4.tree.select.region")
def p4():
    """Tree head + selective supplier arm, leaf histogram per customer
    region."""
    arms = (ArmSpec("customer", "lo_custkey", "custkey", ("c_city",)),
            ArmSpec("supplier", "lo_suppkey", "suppkey", ("s_city",),
                    preds=(Pred("s_region", "in", (0, 1, 2)),)),
            ArmSpec("date", "lo_orderdate", "datekey", ("d_month",)))
    return PredictiveQuery(
        fact="lineorder", arms=arms,
        model=random_tree(np.random.default_rng(3), 3, depth=2),
        group_keys=(GroupKey("customer", "c_region", N_REGIONS),),
        aggregates=_PRED_SUM, num_groups=N_REGIONS)


def query_groups():
    return {
        "QG1": ["Q1.1", "Q1.2", "Q1.3"],
        "QG2": ["Q2.1", "Q2.2", "Q2.3"],
        "QG3": ["Q3.1", "Q3.2", "Q3.3"],
        "QG4": ["Q4.1", "Q4.2", "Q4.3"],
    }


def predictive_query_names():
    """The predict-then-aggregate variants (kept out of the 13-query SSB
    groups so Fig. 7–9 benchmark semantics stay comparable)."""
    return sorted(PREDICTIVE_QUERIES)
