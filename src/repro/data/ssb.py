"""Star Schema Benchmark (SSB) data generator + the 13 benchmark queries.

Deterministic numpy generation following O'Neil et al. (paper Table 3):
  lineorder  sf·6,000,000      (fact)
  part       200,000·(1+⌊log2 sf⌋)
  supplier   sf·2,000
  customer   sf·30,000
  date       7·365
String dimensions (region, nation, brand, ...) are dictionary-encoded to
small ints at generation (LAQ operates on numeric matrices; the paper's
CuPy implementation likewise numeric-encodes).  Date keys are dense ids
0..2554 with (year, month, weeknum) decode columns — avoids yyyymmdd ints
that exceed float32's exact range.

A ``scale`` multiplier shrinks every cardinality for CPU-sized benchmark
runs while preserving selectivity structure.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import numpy as np

from repro.core.laq import Table

N_REGIONS = 5
N_NATIONS = 25          # 5 per region
CITIES_PER_NATION = 10
N_MFGRS = 5
N_CATEGORIES = 25       # 5 per mfgr
N_BRANDS = 1000         # 40 per category
DATE_DAYS = 7 * 365


@dataclasses.dataclass(eq=False)   # identity hash: used as a plan-cache key
class SSBData:
    lineorder: Table
    part: Table
    supplier: Table
    customer: Table
    date: Table
    sf: float
    scale: float


def _dim_date(rng) -> Dict[str, np.ndarray]:
    dk = np.arange(DATE_DAYS)
    year = 1992 + dk // 365
    dayinyear = dk % 365
    month = np.minimum(dayinyear // 30 + 1, 12)
    weeknum = dayinyear // 7 + 1
    yearmonthnum = (year * 100 + month)
    return {"datekey": dk, "d_year": year, "d_month": month,
            "d_weeknuminyear": weeknum, "d_yearmonthnum": yearmonthnum}


def _dim_part(rng, n) -> Dict[str, np.ndarray]:
    mfgr = rng.integers(0, N_MFGRS, n)
    category = mfgr * 5 + rng.integers(0, 5, n)
    brand = category * 40 + rng.integers(0, 40, n)
    return {"partkey": np.arange(n), "p_mfgr": mfgr, "p_category": category,
            "p_brand1": brand, "p_size": rng.integers(1, 51, n)}


def _dim_geo(rng, n, prefix, key) -> Dict[str, np.ndarray]:
    region = rng.integers(0, N_REGIONS, n)
    nation = region * 5 + rng.integers(0, 5, n)
    city = nation * CITIES_PER_NATION + rng.integers(0, CITIES_PER_NATION, n)
    return {key: np.arange(n), f"{prefix}_region": region,
            f"{prefix}_nation": nation, f"{prefix}_city": city}


def generate(sf: float = 1.0, scale: float = 1.0, seed: int = 0,
             capacity_slack: float = 1.0) -> SSBData:
    """Generate SSB tables at scale factor ``sf``, shrunk by ``scale``."""
    rng = np.random.default_rng(seed)
    n_lo = max(int(sf * 6_000_000 * scale), 32)
    n_part = max(int(200_000 * math.floor(1 + math.log2(max(sf, 1)))
                     * scale), 16)
    n_supp = max(int(sf * 2_000 * scale), 8)
    n_cust = max(int(sf * 30_000 * scale), 8)

    date_cols = _dim_date(rng)
    part_cols = _dim_part(rng, n_part)
    supp_cols = _dim_geo(rng, n_supp, "s", "suppkey")
    cust_cols = _dim_geo(rng, n_cust, "c", "custkey")

    lo = {
        "lo_orderkey": np.arange(n_lo),
        "lo_custkey": rng.integers(0, n_cust, n_lo),
        "lo_partkey": rng.integers(0, n_part, n_lo),
        "lo_suppkey": rng.integers(0, n_supp, n_lo),
        "lo_orderdate": rng.integers(0, DATE_DAYS, n_lo),
        "lo_quantity": rng.integers(1, 51, n_lo),
        "lo_extendedprice": rng.integers(1, 6_000_00, n_lo) / 100.0,
        "lo_discount": rng.integers(0, 11, n_lo),
        "lo_revenue": rng.integers(1, 6_000_00, n_lo) / 100.0,
        "lo_supplycost": rng.integers(1, 1_000_00, n_lo) / 100.0,
    }

    def table(name, cols, keys):
        cap = int(next(iter(cols.values())).shape[0] * capacity_slack)
        return Table.from_columns(name, cols, key_cols=keys, capacity=cap)

    # Integer-coded attribute columns are registered as exact int32 "key"
    # columns too — predicates and group-bys on them must not round-trip
    # through float32.
    return SSBData(
        lineorder=table("lineorder", lo,
                        ("lo_orderkey", "lo_custkey", "lo_partkey",
                         "lo_suppkey", "lo_orderdate", "lo_quantity",
                         "lo_discount")),
        part=table("part", part_cols, tuple(part_cols)),
        supplier=table("supplier", supp_cols, tuple(supp_cols)),
        customer=table("customer", cust_cols, tuple(cust_cols)),
        date=table("date", date_cols, tuple(date_cols)),
        sf=sf, scale=scale)
