"""LM token data pipeline: deterministic, sharded, checkpointable.

Production posture without external deps:
* A synthetic corpus (seeded Zipf mixture — stable statistics across hosts)
  stands in for tokenized shards; swap ``ZipfCorpus`` for a file-backed
  reader on a real cluster (same iterator contract).
* Each host reads only its slice of the global batch
  (``jax.process_index()``-disjoint), the standard multi-host input layout;
  ``make_global_batch`` assembles a globally-sharded array from per-host
  slices via ``jax.make_array_from_process_local_data``.
* Iterator state = (seed, step) — restoring a checkpoint replays the
  pipeline to the exact batch boundary (fault-tolerance requirement).
* Background prefetch thread keeps ``prefetch`` batches ahead of the step.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class TokenPipelineConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    prefetch: int = 2


class ZipfCorpus:
    """Deterministic synthetic token stream (Zipf-ish unigram mixture)."""

    def __init__(self, vocab_size: int, seed: int):
        self.vocab_size = vocab_size
        self.seed = seed

    def batch(self, step: int, rows: int, seq_len: int,
              row_offset: int) -> np.ndarray:
        # Independent per (step, row) streams → any host can regenerate any
        # slice; this is what makes elastic re-sharding trivial.
        out = np.empty((rows, seq_len + 1), np.int32)
        for r in range(rows):
            rng = np.random.default_rng(
                (self.seed, step, row_offset + r))
            u = rng.random(seq_len + 1)
            out[r] = (self.vocab_size ** u - 1).astype(np.int32) % \
                self.vocab_size
        return out


class TokenPipeline:
    """Checkpointable iterator of (tokens, labels) host-local slices."""

    def __init__(self, cfg: TokenPipelineConfig,
                 process_index: Optional[int] = None,
                 process_count: Optional[int] = None):
        self.cfg = cfg
        self.pi = (jax.process_index() if process_index is None
                   else process_index)
        self.pc = (jax.process_count() if process_count is None
                   else process_count)
        assert cfg.global_batch % self.pc == 0
        self.rows_per_host = cfg.global_batch // self.pc
        self.corpus = ZipfCorpus(cfg.vocab_size, cfg.seed)
        self.step = 0
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ---- iterator state (checkpointed) ------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def restore(self, state: dict):
        self.stop()
        self.step = int(state["step"])

    # ---- production --------------------------------------------------------
    def _make(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        raw = self.corpus.batch(step, self.rows_per_host, self.cfg.seq_len,
                                row_offset=self.pi * self.rows_per_host)
        return raw[:, :-1], raw[:, 1:]

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self):
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def stop(self):
        if self._thread is not None:
            self._stop.set()
            while not self._q.empty():
                self._q.get_nowait()
            self._thread.join(timeout=2.0)
            self._thread = None

    def next(self) -> Tuple[np.ndarray, np.ndarray]:
        """Host-local (tokens, labels) for the current step (prefetched)."""
        if self._thread is None:
            batch = self._make(self.step)
            self.step += 1
            return batch
        step, batch = self._q.get()
        assert step == self.step, (step, self.step)
        self.step += 1
        return batch


def make_global_batch(local_tokens: np.ndarray, mesh, pspec):
    """Assemble a globally-sharded array from this host's slice."""
    from jax.sharding import NamedSharding
    sharding = NamedSharding(mesh, pspec)
    global_shape = (local_tokens.shape[0] * jax.process_count(),
                    *local_tokens.shape[1:])
    return jax.make_array_from_process_local_data(sharding, local_tokens,
                                                  global_shape)
