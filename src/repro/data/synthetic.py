"""Synthetic star schema for the operator-fusion experiments (paper Table 4).

Two cardinality settings:
  setting 1: lineorder sf·600,000; part 20,000·⌊1+log2 sf⌋; supplier sf·2,000;
             date 7·365   — "large input, small model"
  setting 2: lineorder sf·3,000;   part  2,000·⌊1+log2 sf⌋; supplier sf·2,000;
             date 7·365   — "small input, large model"
Feature columns are split evenly across the three dimension tables
(paper §3.2: c = k/3) and filled with N(0,1) floats.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np

from repro.core.laq import DimSpec, StarJoin, Table, star_join


@dataclasses.dataclass
class SyntheticStar:
    star: StarJoin
    k: int               # total feature columns
    n_fact: int
    dim_rows: Tuple[int, int, int]


def cardinalities(setting: int, sf: float):
    logf = math.floor(1 + math.log2(max(sf, 1)))
    if setting == 1:
        return (int(sf * 600_000), int(20_000 * logf), int(sf * 2_000),
                7 * 365)
    return (int(sf * 3_000), int(2_000 * logf), int(sf * 2_000), 7 * 365)


def generate(setting: int, sf: float, k: int, seed: int = 0,
             scale: float = 1.0) -> SyntheticStar:
    """Build the star join for cardinality ``setting`` with k features."""
    rng = np.random.default_rng(seed)
    n_fact, n_b, n_c, n_d = [max(int(n * scale), 8)
                             for n in cardinalities(setting, sf)]
    c = k // 3
    widths = [c, c, k - 2 * c]
    specs = []
    for name, n_rows, width in zip("bcd", (n_b, n_c, n_d), widths):
        cols = {f"{name}{j}": rng.normal(size=n_rows).astype(np.float32)
                for j in range(width)}
        cols["pk"] = np.arange(n_rows)
        dim = Table.from_columns(f"dim_{name}", cols, key_cols=("pk",))
        specs.append((dim, n_rows, tuple(f"{name}{j}" for j in range(width))))

    fact_cols = {
        f"fk_{name}": rng.integers(0, n_rows, n_fact)
        for (dim, n_rows, _), name in zip(specs, "bcd")
    }
    fact = Table.from_columns("fact", fact_cols,
                              key_cols=tuple(fact_cols.keys()))
    dim_specs = [DimSpec(dim, f"fk_{name}", "pk", feats)
                 for (dim, _, feats), name in zip(specs, "bcd")]
    return SyntheticStar(star=star_join(fact, dim_specs), k=k,
                         n_fact=n_fact,
                         dim_rows=(specs[0][1], specs[1][1], specs[2][1]))
