"""Open-loop load generator for the admission scheduler (ISSUE 6).

``bench_serving`` measures the closed loop — one caller, one bucketed batch
at a time.  This bench measures what the scheduler adds under *open-loop*
traffic, where requests arrive on their own clock (uniform burst / poisson)
instead of waiting for the previous answer:

  * ``closed_loop``   — synchronous per-request ``serve`` (the baseline the
    scheduler must beat): mean us/request over sequential point lookups.
  * ``open_loop_burst`` — the same requests submitted all at once through
    the scheduler: coalescing packs them into top-bucket steps, so the
    sustained rate is dispatch-bound, not request-bound.  The bench
    *asserts* this beats the closed loop (the ISSUE acceptance criterion),
    and that every future is bit-exact vs the closed-loop outputs.
  * ``poisson``       — arrivals at 1.5x the closed-loop rate; reports the
    submit→result p99 an open-loop client actually observes.
  * ``interactive_under_batch`` — point lookups issued while an oversized
    analytical scan is in flight on the batch lane: chunked admission lets
    them ride along in top-bucket steps instead of queueing behind the
    scan; reports their p99 (the SLO-under-load number).

All rows are lower-is-better microseconds, gated by the CI bench-regression
job against ``benchmarks/baselines/BENCH_scheduler.json`` (its own
``check_regression`` invocation: latency rows are threading-jittery, so the
gate runs with a wider tolerance and no ``--min-us`` floor).

Run:  PYTHONPATH=src python -m benchmarks.bench_scheduler
      [--scale 0.05] [--requests 240] [--json BENCH_scheduler.json]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.launch.serve import FusedFeatureServer

from .common import emit, write_json


def _pct(ts, p):
    return float(np.percentile(np.asarray(ts) * 1e6, p))


def run(scale: float = 0.05, requests: int = 240, k: int = 16, l: int = 4,
        slo_ms: float = 2.0, seed: int = 0):
    server = FusedFeatureServer(setting=2, sf=1, k=k, l=l, scale=scale,
                                seed=seed)
    rt = server.runtime_fused
    rng = np.random.default_rng(seed + 1)
    top = rt.buckets[-1]
    # Warm every bucket so neither loop ever traces mid-measurement.
    for n in [1] + list(rt.buckets):
        server.serve_batch(server.random_requests(n, rng))

    point = [server.random_requests(1, rng) for _ in range(requests)]

    # -- closed loop: sequential synchronous point lookups ------------------
    t0 = time.perf_counter()
    want = [np.asarray(server.serve_batch(r)) for r in point]
    closed_s = time.perf_counter() - t0
    qps_closed = requests / closed_s
    emit("scheduler/closed_loop/us_per_req", closed_s / requests * 1e6,
         f"qps={qps_closed:.0f};n={requests}")

    plan = server.scheduled(slo_ms=slo_ms)

    # -- open-loop burst: all requests in flight at once --------------------
    t0 = time.perf_counter()
    futs = [plan.submit(r) for r in point]
    got = [np.asarray(f.result(120)) for f in futs]
    burst_s = time.perf_counter() - t0
    qps_burst = requests / burst_s
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)   # scheduled ≡ synchronous
    assert qps_burst > qps_closed, (
        f"open-loop coalescing must beat closed-loop serving: "
        f"{qps_burst:.0f} qps <= {qps_closed:.0f} qps")
    emit("scheduler/open_loop_burst/us_per_req", burst_s / requests * 1e6,
         f"qps={qps_burst:.0f};speedup={qps_burst / qps_closed:.1f}x")

    # -- poisson arrivals at 1.5x the closed-loop rate ----------------------
    lat = []
    offered = 1.5 * qps_closed
    gaps = rng.exponential(1.0 / offered, size=requests)
    done = []
    t_start = time.perf_counter()
    next_t = t_start
    for r, gap in zip(point, gaps):
        next_t += gap
        now = time.perf_counter()
        if next_t > now:
            time.sleep(next_t - now)
        t_sub = time.perf_counter()
        done.append((t_sub, plan.submit(r)))
    for t_sub, f in done:
        f.result(120)
        lat.append(time.perf_counter() - t_sub)
    span = time.perf_counter() - t_start
    emit("scheduler/poisson/us_per_req", span / requests * 1e6,
         f"offered_qps={offered:.0f};sustained_qps={requests / span:.0f}")
    emit("scheduler/poisson/p99_us", _pct(lat, 99),
         f"p50_us={_pct(lat, 50):.0f};p95_us={_pct(lat, 95):.0f}")

    # -- point lookups while an analytical scan is in flight ----------------
    scan = server.random_requests(8 * top, rng)
    want_scan = np.asarray(server.serve_batch(scan))
    f_scan = plan.submit(scan, lane="batch")
    ilat = []
    while not f_scan.done():
        r = server.random_requests(1, rng)
        t0 = time.perf_counter()
        np.asarray(plan.submit(r).result(120))
        ilat.append(time.perf_counter() - t0)
    np.testing.assert_array_equal(np.asarray(f_scan.result(0)), want_scan)
    assert ilat, "scan completed before any interleaved lookup was served"
    emit("scheduler/interactive_under_batch/p99_us", _pct(ilat, 99),
         f"n={len(ilat)};scan_rows={8 * top};p50_us={_pct(ilat, 50):.0f}")

    st = plan.stats()
    print(f"[bench] scheduler steps={st['steps']} "
          f"admitted={st['admitted_rows']} padded={st['padded_rows']} "
          f"rejected={st['rejected']}", flush=True)
    return server, plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--requests", type=int, default=240)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--l", type=int, default=4)
    ap.add_argument("--slo-ms", type=float, default=2.0)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    server, plan = run(scale=args.scale, requests=args.requests, k=args.k,
                       l=args.l, slo_ms=args.slo_ms)
    stats = plan.stats()
    server.session.scheduler().close()
    if args.json:
        write_json(args.json, {"bench": "scheduler", "scheduler": stats})


if __name__ == "__main__":
    main()
