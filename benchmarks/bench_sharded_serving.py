"""Sharded vs single-device serving latency across mesh shapes.

One ``FusedFeatureServer`` per mesh shape serves identical request batches
through the single-device runtime and the ``shard_map`` runtime (partials
row-sharded over the model axis, batches over the data axis), emitting
per-size medians plus each runtime's per-bucket percentiles — the scaling
counterpart of ``bench_serving``.

On CPU the mesh is forced with ``--devices N`` (sets
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax loads),
which measures the orchestration overhead of the sharded program — the
memory-capacity win it buys is per-device bytes
(``ShardedPrefusedPartials.nbytes_per_device``), also emitted.

Run:  PYTHONPATH=src python -m benchmarks.bench_sharded_serving
      [--devices 8] [--scale 0.05] [--k 16] [--l 4]
      [--json BENCH_sharded_serving.json]
"""

from __future__ import annotations

import argparse
import os
import sys


def run(mesh_shapes, scale: float, k: int, l: int, seed: int = 0):
    import numpy as np

    from repro.launch.mesh import make_serving_mesh
    from repro.launch.serve import FusedFeatureServer

    from .common import bench, emit

    base = FusedFeatureServer(setting=2, sf=1, k=k, l=l, scale=scale,
                              seed=seed)
    rng = np.random.default_rng(seed + 1)
    buckets = base.runtime_fused.buckets
    sizes = sorted({max(1, b // 2) for b in buckets} | set(buckets))
    sizes.append(2 * buckets[-1] + 3)   # oversize: top-bucket chunks
    requests = {n: base.random_requests(n, rng) for n in sizes}

    for n in sizes:
        us = bench(base.serve_batch, requests[n], True)
        emit(f"sharded_serving/mesh1x1ref/n{n}", us, "single-device fused")

    servers = {}
    for shape in mesh_shapes:
        mesh = make_serving_mesh(shape)
        server = FusedFeatureServer(setting=2, sf=1, k=k, l=l, scale=scale,
                                    seed=seed, mesh=mesh,
                                    shard_threshold_bytes=0)
        servers[shape] = server
        rt = server.runtime_fused
        tag = f"mesh{shape[0]}x{shape[1]}"
        for n in sizes:
            us = bench(server.serve_batch, requests[n], True)
            # Identical math: the sharded runtime must match the reference.
            np.testing.assert_array_equal(
                np.asarray(server.serve_batch(requests[n], True)),
                np.asarray(base.serve_batch(requests[n], True)))
            emit(f"sharded_serving/{tag}/n{n}", us,
                 f"sharded={rt.sharded.num_sharded}/{len(rt.sharded.arms)}"
                 f";buckets={rt.buckets}")
        emit(f"sharded_serving/{tag}/bytes_per_device",
             float(rt.sharded.nbytes_per_device()),
             "quasi-static bytes resident per device")
        emit(f"sharded_serving/{tag}/compiles", float(rt.num_compiles),
             f"traces for {len(sizes)} batch sizes")
    return base, servers


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (must be set before jax "
                         "initializes — this flag handles it)")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--l", type=int, default=4)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    if args.devices:
        if "jax" in sys.modules:
            raise RuntimeError("--devices must be applied before jax loads")
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")
    import jax

    n = len(jax.devices())
    shapes = [(1, n)]
    if n > 1:
        shapes += [(n, 1)]
        half = n // 2
        if half > 1:
            shapes += [(2, half)]
    base, servers = run(shapes, args.scale, args.k, args.l)
    if args.json:
        from .common import write_json

        latency = {"ref": base.runtime_fused.latency_stats()}
        for shape, server in servers.items():
            latency[f"mesh{shape[0]}x{shape[1]}"] = (
                server.runtime_fused.latency_stats())
        write_json(args.json, {"bench": "sharded_serving",
                               "devices": n, "latency": latency})


if __name__ == "__main__":
    main()
