"""Out-of-core fact streaming vs in-core, across SSB scale factors.

The ISSUE 8 concern: chunked execution must (a) stay bit-exact vs the
in-core fused/gather/segment program — the carried segment accumulator
replays the exact same adds — and (b) cost little enough that streaming is
a memory feature, not a throughput cliff.  For each scale this bench runs
the pinned in-core program and the streamed program (chunks sized to a
budget ~1/3 of the fact working set, so every run folds several chunks),
asserts bitwise equality of every output, and emits rows/s for both; the
run fails when streamed throughput at the largest scale drops below
``1 / --max-slowdown`` of in-core (default 1.3x, the acceptance bar).

A second section measures the tombstone lifecycle at the largest scale:
``delete_rows`` + zero-retrace streamed ``refresh`` (vs a cold recompile)
and the post-``compact`` rebuild.

Run:  PYTHONPATH=src python -m benchmarks.bench_outofcore
      [--scales 0.02 0.05 0.1] [--reps 9] [--json BENCH_outofcore.json]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.query import compile_query
from repro.data import QUERY_IR, generate_ssb, ssb_catalog

from .common import emit, write_json

QUERY = "P1.linear.year"
#: The in-core lowering streaming is bit-exact against (the auto planner
#: may pick matmul aggregation at small group counts — a different, valid
#: program whose float adds associate differently).
PINNED = dict(backend="fused", join_backend="gather", agg_backend="segment")


def _bench_run(plan, reps: int) -> float:
    """Best wall time (µs) of ``plan.run()`` — min over reps, matching
    ``common.bench``: scheduler noise on shared runners is additive."""
    jax.block_until_ready(plan.run())          # warm the trace(s)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(plan.run())
        times.append(time.perf_counter() - t0)
    return float(np.min(times) * 1e6)


def _assert_bitexact(streamed, incore, tag: str):
    for k, v in incore.items():
        if not np.array_equal(np.asarray(streamed[k]), np.asarray(v)):
            raise SystemExit(
                f"[bench-outofcore] FAIL {tag}: streamed {k!r} diverged "
                "from the in-core fused/gather/segment program")


def run(scales=(0.02, 0.05, 0.1), reps: int = 9, seed: int = 0,
        max_slowdown: float = 1.3, do_assert: bool = True):
    q = QUERY_IR[QUERY]()
    ratios = {}
    catalog = None
    for scale in scales:
        data = generate_ssb(sf=1, scale=scale, seed=seed,
                            capacity_slack=1.3)
        catalog = ssb_catalog(data)
        fact = catalog[q.fact]
        rows = int(fact.nvalid)
        incore = compile_query(catalog, q, **PINNED)
        # A budget ~1/3 of the resident fact bytes: every scale streams in
        # several budget-sized chunks instead of degenerating to one, while
        # per-chunk dispatch overhead (fixed cost per fold on CPU) stays
        # small enough that the 1.3x throughput bar has real margin.
        fact_bytes = (fact.matrix.size * fact.matrix.dtype.itemsize
                      + sum(k.size * k.dtype.itemsize
                            for k in fact.keys.values()))
        budget = max(int(fact_bytes) // 3, 64 * 1024)
        streamed = compile_query(catalog, q, memory_budget_bytes=budget)
        if streamed._stream is None:
            raise SystemExit(f"[bench-outofcore] FAIL scale={scale}: "
                             f"budget {budget} did not trigger streaming")
        _assert_bitexact(streamed.run(), incore.run(), f"scale={scale}")

        i_us = _bench_run(incore, reps)
        s_us = _bench_run(streamed, reps)
        ratios[scale] = s_us / i_us
        n_chunks = -(-catalog[q.fact].capacity
                     // streamed.plan.stream_chunk_rows)
        emit(f"outofcore/incore/sf{scale}", i_us,
             f"rows={rows};{rows / i_us:.0f} rows/us")
        emit(f"outofcore/stream/sf{scale}", s_us,
             f"rows={rows};chunks={n_chunks};{rows / s_us:.0f} rows/us;"
             f"{ratios[scale]:.2f}x vs incore")

    # Tombstone lifecycle at the largest scale: delete + zero-retrace
    # streamed refresh (vs cold recompile), then the compaction rebuild.
    rng = np.random.default_rng(seed + 1)
    streamed = compile_query(catalog, q,
                             stream_chunk_rows=streamed.plan.stream_chunk_rows)
    streamed.run()
    traces0 = streamed._stream.traces
    n = int(catalog[q.fact].nvalid)
    catalog.delete_rows(q.fact, rng.choice(n, size=n // 100, replace=False))

    t0 = time.perf_counter()
    note = streamed.refresh()
    jax.block_until_ready(streamed.run())
    d_us = (time.perf_counter() - t0) * 1e6
    assert "delta" in note, f"expected delta path, got {note}"
    assert streamed._stream.traces == traces0, "delete refresh retraced"

    t0 = time.perf_counter()
    cold = compile_query(catalog, q,
                         stream_chunk_rows=streamed.plan.stream_chunk_rows)
    out = cold.run()
    jax.block_until_ready(out)
    c_us = (time.perf_counter() - t0) * 1e6
    _assert_bitexact(streamed.run(), out, "refresh-after-delete")
    emit("outofcore/delete_refresh", d_us,
         f"1% tombstones;{c_us / d_us:.1f}x vs cold, 0 retraces")
    emit("outofcore/delete_cold", c_us, "recompile + full rerun")

    catalog.delete_rows(q.fact,
                        rng.choice(n, size=n // 3, replace=False))
    assert catalog.compact(q.fact)
    t0 = time.perf_counter()
    note = streamed.refresh()
    jax.block_until_ready(streamed.run())
    emit("outofcore/compact_rebuild", (time.perf_counter() - t0) * 1e6,
         "tombstone GC: row ids rewrote, recompile")
    assert "compaction" in note, f"expected compaction rebuild, got {note}"

    worst = ratios[max(ratios)]
    if do_assert and worst > max_slowdown:
        raise SystemExit(
            f"[bench-outofcore] FAIL: streaming at the largest scale is "
            f"{worst:.2f}x slower than in-core (acceptance bar: "
            f"{max_slowdown}x)")
    print("[bench-outofcore] stream/incore ratios: "
          + ", ".join(f"sf{s}: {r:.2f}x" for s, r in ratios.items()))
    return ratios


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scales", type=float, nargs="+",
                    default=[0.02, 0.05, 0.1])
    ap.add_argument("--reps", type=int, default=9)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-slowdown", type=float, default=1.3)
    ap.add_argument("--no-assert", action="store_true",
                    help="report ratios without gating on them")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    run(scales=tuple(args.scales), reps=args.reps, seed=args.seed,
        max_slowdown=args.max_slowdown, do_assert=not args.no_assert)
    if args.json:
        write_json(args.json, {"bench": "outofcore", "query": QUERY,
                               "scales": list(args.scales)})


if __name__ == "__main__":
    main()
