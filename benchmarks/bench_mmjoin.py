"""MM-Join physical operators vs the sort-based join (paper §2.3 analysis
+ the companion comparison in [24]).

The paper reports MM-Join's O(n²)-ish spMM cost loses to hash join as data
grows; our TPU-native factored join (searchsorted + gather) plays the hash
join role.  Sweep row counts; emit µs for
  * ``dense``    — paper-faithful one-hot matmul row-matching matrix,
  * ``bcoo``     — BCOO spMM (CSR-equivalent in JAX),
  * ``factored`` — pointer join (ours).
Derived column = slowdown vs factored.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.laq import join_factored, mmjoin_bcoo, mmjoin_dense

from .common import bench, emit


def run(sizes=(256, 1024, 4096, 16384)):
    rng = np.random.default_rng(0)
    for n in sizes:
        n_dim = max(n // 8, 8)
        pk = rng.permutation(n_dim * 2)[:n_dim].astype(np.int32)
        fk = rng.choice(pk, size=n).astype(np.int32)
        fkj, pkj = jnp.asarray(fk), jnp.asarray(pk)

        fact = jax.jit(lambda a, b: join_factored(a, b).ptr)
        us_f = bench(fact, fkj, pkj)
        emit(f"mmjoin/factored/n{n}", us_f, "1.00x")

        if n <= 4096:  # dense I is O(n·n_dim·dom): cap like the paper's OOM
            dense = jax.jit(lambda a, b: mmjoin_dense(a, b, 2 * n_dim))
            us_d = bench(dense, fkj, pkj)
            emit(f"mmjoin/dense/n{n}", us_d, f"{us_d / us_f:.2f}x")
            bcoo = jax.jit(lambda a, b: mmjoin_bcoo(a, b, 2 * n_dim))
            us_b = bench(bcoo, fkj, pkj)
            emit(f"mmjoin/bcoo/n{n}", us_b, f"{us_b / us_f:.2f}x")


if __name__ == "__main__":
    run()
