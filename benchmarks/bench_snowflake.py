"""Snowflake chains: prefuse-through vs materialize vs flat pre-joined.

Three lowerings of the same depth-3 chain query (fact → customer → nation
→ region, features on every hop, a sub-dimension predicate two hops deep):

* **through**      — ``chain_strategy="through"``: the chain collapses to
  pointer compositions each compile; nothing but the head-granularity
  virtual dimension is ever materialized.
* **materialize**  — ``chain_strategy="materialize"``: the planner pins
  hop caching at the deepest hop (costed per chain in ``plan.reason``).
* **flat**         — the schema denormalized offline by
  :func:`materialize_chains`: one real pre-joined dimension, the baseline
  a warehouse would hand-build.  The chain lowerings must match it
  bit-exactly (asserted every run) while skipping the denormalization.

Also measured: offline chain collapse time, and the sub-dimension append
refresh (cached Session plan, delta path) vs a cold recompile — the chain
maintenance win.

Run:  PYTHONPATH=src python -m benchmarks.bench_snowflake
      [--scales 0.02 0.1] [--json BENCH_snowflake.json]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.fusion.operators import LinearOperator
from repro.core.laq import Catalog, Table
from repro.core.query import (Aggregate, ArmSpec, ChainLink, GroupKey,
                              PredictiveQuery, Session, compile_query,
                              materialize_chains, resolve_chain)
from repro.core.query.snowflake import chain_tables

from .common import bench, emit, write_json

BASE_FACT = 1_000_000          # rows at scale 1.0
PAD_GROUP = np.int64(2**31 - 1)


def build(scale: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    n_fact = max(2_000, int(BASE_FACT * scale))
    n_cust, n_nat, n_reg = max(n_fact // 50, 64), 256, 32
    import jax.numpy as jnp

    region = Table.from_columns("region", {
        "r_pk": np.arange(n_reg), "r_g": rng.integers(0, 8, n_reg),
        "r_f0": rng.integers(-4, 5, n_reg)},
        key_cols=("r_pk", "r_g"), capacity=int(n_reg * 1.5))
    nation = Table.from_columns("nation", {
        "n_pk": np.arange(n_nat),
        "n_to_region": rng.integers(0, int(n_reg * 1.1), n_nat),
        "n_f0": rng.integers(-4, 5, n_nat)},
        key_cols=("n_pk", "n_to_region"), capacity=int(n_nat * 1.5))
    customer = Table.from_columns("customer", {
        "c_pk": np.arange(n_cust),
        "c_to_nation": rng.integers(0, int(n_nat * 1.1), n_cust),
        "c_f0": rng.integers(-4, 5, n_cust)},
        key_cols=("c_pk", "c_to_nation"), capacity=int(n_cust * 1.5))
    fact = Table.from_columns("sales", {
        "fk_cust": rng.integers(0, int(n_cust * 1.1), n_fact),
        "s_g": rng.integers(0, 8, n_fact),
        "revenue": rng.integers(-4, 5, n_fact)},
        key_cols=("fk_cust", "s_g"), capacity=int(n_fact * 1.2))
    arm = ArmSpec(
        "customer", "fk_cust", "c_pk", ("c_f0",), (),
        links=(ChainLink("nation", "c_to_nation", "n_pk", ("n_f0",),
                         preds=(("n_f0", ">=", -2),)),
               ChainLink("region", "n_to_region", "r_pk", ("r_f0",),
                         parent="nation")))
    from repro.core.query.session import _as_pred
    import dataclasses
    arm = dataclasses.replace(
        arm, links=tuple(dataclasses.replace(
            lk, preds=tuple(_as_pred(p) for p in lk.preds))
            for lk in arm.links))
    model = LinearOperator(jnp.asarray(
        rng.integers(-2, 3, (3, 2)), jnp.float32))
    q = PredictiveQuery(
        "sales", (arm,), (), model,
        (GroupKey("fact", "s_g", 8), GroupKey("region", "r_g", 8)),
        (Aggregate("revenue", "sum", "rev"),
         Aggregate("@prediction", "sum", "p"),
         Aggregate("*", "count", "n")), 64)
    tables = {"region": region, "nation": nation, "customer": customer,
              "sales": fact}
    return tables, q


def _result_map(res, names):
    groups = np.asarray(res["groups"])
    live = groups != PAD_GROUP
    out = {}
    for n in names:
        v = np.asarray(res[n], np.float64)
        v2 = v if v.ndim > 1 else v[:, None]
        out[n] = {int(g): tuple(v2[i]) for i, g in enumerate(groups)
                  if live[i]}
    return out


def run(scales, seed: int = 0, json_path: str | None = None,
        do_assert: bool = True):
    for scale in scales:
        tables, q = build(scale, seed)
        n = int(tables["sales"].nvalid)
        names = [a.name for a in q.aggregates]

        t0 = time.perf_counter()
        cc = resolve_chain(tables, q.arms[0])
        jax.block_until_ready(cc.table.matrix)
        collapse_us = (time.perf_counter() - t0) * 1e6
        emit(f"snowflake/collapse@{n}", collapse_us,
             f"hops={len(q.arms[0].links)}")

        # Apples-to-apples run comparison: the flat pre-joined schema only
        # carries the chain's PK key, so all three lowerings group on the
        # fact side here; the link-table group key is benched separately.
        qf = type(q)(q.fact, q.arms, q.fact_preds, q.model,
                     (GroupKey("fact", "s_g", 8),), q.aggregates, 8)
        results = {}
        for strategy in ("through", "materialize"):
            plan = compile_query(Catalog(dict(tables)), qf,
                                 chain_strategy=strategy)
            us = bench(plan.run)
            results[strategy] = plan.run()
            note = [r for r in plan.plan.reason.split("; ")
                    if r.startswith("chain[")]
            emit(f"snowflake/run/{strategy}@{n}", us,
                 note[0] if note else "")

        # Flat pre-joined baseline: denormalization cost paid offline.
        t0 = time.perf_counter()
        flat_tables, flat_q = materialize_chains(tables, qf)
        jax.block_until_ready(next(iter(flat_tables.values())).matrix)
        denorm_us = (time.perf_counter() - t0) * 1e6
        flat_cat = Catalog({**{k: v for k, v in tables.items()
                               if k not in chain_tables(q.arms[0])},
                            **flat_tables})
        flat_plan = compile_query(flat_cat, flat_q)
        us = bench(flat_plan.run)
        emit(f"snowflake/run/flat@{n}", us, f"denorm={denorm_us:.0f}us")

        # Grouping by a sub-dimension column (region, two hops deep) —
        # the capability the flat baseline lacks outright.
        link_plan = compile_query(Catalog(dict(tables)), q)
        emit(f"snowflake/run/linkgroup@{n}", bench(link_plan.run),
             "group by region.r_g through the chain")

        if do_assert:
            a = _result_map(results["through"], names)
            assert a == _result_map(results["materialize"], names), \
                "through != materialize"
            assert a == _result_map(flat_plan.run(), names), \
                "chain != flat baseline"

        # Sub-dimension append: cached-plan delta refresh vs cold rebuild.
        rng = np.random.default_rng(seed + 1)
        cat = Catalog(dict(tables))
        sess = Session(cat)
        sess.compile(q).run()
        m = max(1, int(tables["nation"].nvalid) // 100)

        def _append():
            cat.append("nation", {
                "n_pk": np.arange(m) + int(cat["nation"].nvalid),
                "n_to_region": rng.integers(0, 32, m),
                "n_f0": rng.integers(-4, 5, m)})

        # Warmup cycle: the first refresh jit-compiles the m-row scatter
        # updates; steady state (same append size) reuses them.
        _append()
        sess.compile(q).run()
        _append()
        t0 = time.perf_counter()
        warm = sess.compile(q)
        jax.block_until_ready(warm.run()["rows"])
        refresh_us = (time.perf_counter() - t0) * 1e6
        snap = Catalog({k: cat[k] for k in cat})
        t0 = time.perf_counter()
        cold = compile_query(snap, q)
        jax.block_until_ready(cold.run()["rows"])
        cold_us = (time.perf_counter() - t0) * 1e6
        emit(f"snowflake/refresh/delta@{n}", refresh_us,
             f"m={m};{cold_us / max(refresh_us, 1):.1f}x vs cold")
        emit(f"snowflake/refresh/cold@{n}", cold_us, f"m={m}")
        if do_assert:
            assert _result_map(warm.run(), names) == _result_map(
                cold.run(), names), "refresh != cold"

    if json_path:
        write_json(json_path, {"bench": "snowflake", "scales": list(scales)})


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scales", type=float, nargs="+", default=[0.02, 0.1])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None)
    ap.add_argument("--no-assert", action="store_true")
    args = ap.parse_args(argv)
    run(args.scales, seed=args.seed, json_path=args.json,
        do_assert=not args.no_assert)


if __name__ == "__main__":
    main()
