"""Multi-query optimizer: pooled vs independent workload compilation.

The session's :class:`~repro.core.query.ArtifactPool` makes a *workload* —
here the full SSB registry — share one physical copy of every distinct
offline artifact (PK indices, factored join pointers, predicate dim-masks,
Eq. 1 prefused partials).  This bench measures the three payoffs:

* **compile** — total offline compile time of the registry, independent
  (``compile_query`` per query, no pool) vs pooled (one fresh
  ``ArtifactPool`` shared across the sweep).  Pool hits skip PK argsorts,
  probe passes and prefuse matmuls outright.
* **bytes**   — resident derived-artifact bytes across the compiled
  workload (:func:`~repro.core.query.artifact_bytes`, deduplicated by
  array identity): N plans sharing an arm hold ONE pointer array.
* **refresh** — a 1% ``part`` append under plans sharing that arm:
  independent plans each re-extend/re-probe their private copies; pooled
  plans refresh the shared artifact ONCE (asserted via the pool's
  per-entry update counters) and rebind.

Every pooled plan's results are asserted bit-identical to its independent
twin, and the run fails unless pooling wins ≥ ``--min-speedup`` (default
2x, the acceptance bar) on BOTH total compile time and resident bytes.

Run:  PYTHONPATH=src python -m benchmarks.bench_multiquery
      [--scale 0.02] [--reps 3] [--json BENCH_multiquery.json]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.laq import Catalog
from repro.core.query import (ArtifactPool, Session, artifact_bytes,
                              compile_query)
from repro.data import QUERY_IR, generate_ssb, ssb_catalog

from .common import emit, write_json

SHARED_ARM_QUERIES = ("Q2.1", "Q2.2", "Q2.3")   # all join the part arm


def _part_block(rng, start: int, m: int):
    """``m`` fresh part rows with new keys ``start..start+m``."""
    mfgr = rng.integers(0, 5, m)
    category = mfgr * 5 + rng.integers(0, 5, m)
    return {"partkey": start + np.arange(m), "p_mfgr": mfgr,
            "p_category": category,
            "p_brand1": category * 40 + rng.integers(0, 40, m),
            "p_size": rng.integers(1, 51, m)}


def _compile_registry(catalog, names, pool=None):
    t0 = time.perf_counter()
    plans = [compile_query(catalog, QUERY_IR[n](), pool=pool)
             for n in names]
    jax.block_until_ready([p._state["valid"] for p in plans])
    return plans, (time.perf_counter() - t0) * 1e6


def run(scale: float = 0.02, reps: int = 3, seed: int = 0,
        min_speedup: float = 2.0, do_assert: bool = True):
    data = generate_ssb(sf=1, scale=scale, seed=seed, capacity_slack=1.6)
    catalog = ssb_catalog(data)
    names = sorted(QUERY_IR)
    rng = np.random.default_rng(seed + 1)

    # -- compile: whole registry, independent vs pooled ----------------------
    indep_times, pooled_times = [], []
    indep_plans = pooled_plans = None
    for _ in range(reps):
        indep_plans, us = _compile_registry(catalog, names)
        indep_times.append(us)
        pooled_plans, us = _compile_registry(catalog, names,
                                             pool=ArtifactPool(catalog))
        pooled_times.append(us)
    for n, a, b in zip(names, pooled_plans, indep_plans):
        ra, rb = a.run(), b.run()
        for k in rb:
            np.testing.assert_array_equal(
                np.asarray(ra[k]), np.asarray(rb[k]),
                err_msg=f"pooled {n}:{k} diverged from independent")
    c_us, p_us = float(np.min(indep_times)), float(np.min(pooled_times))
    compile_speedup = c_us / p_us
    emit("multiquery/compile/independent", c_us,
         f"queries={len(names)};private artifacts per plan")
    emit("multiquery/compile/pooled", p_us,
         f"queries={len(names)};{compile_speedup:.1f}x vs independent")

    # -- bytes: resident derived artifacts across the workload --------------
    indep_bytes = artifact_bytes(indep_plans)
    pooled_bytes = artifact_bytes(pooled_plans)
    bytes_ratio = indep_bytes / max(pooled_bytes, 1)
    emit("multiquery/bytes/independent", float(indep_bytes),
         "unit=bytes;sum of private derived arrays")
    emit("multiquery/bytes/pooled", float(pooled_bytes),
         f"unit=bytes;{bytes_ratio:.1f}x smaller (shared physical arrays)")

    # -- refresh: 1% part append, O(artifacts) not O(plans) ------------------
    sess = Session(Catalog({n: catalog[n] for n in catalog}))
    shared = [sess.compile(QUERY_IR[n]()) for n in SHARED_ARM_QUERIES]
    private_cat = Catalog({n: catalog[n] for n in catalog})
    private = [compile_query(private_cat, QUERY_IR[n]())
               for n in SHARED_ARM_QUERIES]
    n_part = int(np.asarray(sess.catalog["part"].nvalid))
    m = max(1, n_part // 100)
    next_key = n_part
    s_times, i_times = [], []
    for _ in range(reps):
        block = _part_block(rng, next_key, m)
        next_key += m
        sess.catalog.append("part", block)
        updates0 = sess.pool.stats()["updates"]
        t0 = time.perf_counter()
        out = sess.refresh()
        jax.block_until_ready([p._state["valid"] for p in shared])
        s_times.append((time.perf_counter() - t0) * 1e6)
        touched = sess.pool.stats()["updates"] - updates0
        stale = {k for p in shared for k in p._pool_keys() if "part" in k}
        assert all("delta" in line for line in out.values()), out
        assert touched == len(stale), \
            f"refresh touched {touched} artifacts, expected {len(stale)} " \
            f"(one per distinct stale artifact)"
        private_cat.append("part", block)
        t0 = time.perf_counter()
        for p in private:
            line = p.refresh()
            assert "delta" in line, line
        jax.block_until_ready([p._state["valid"] for p in private])
        i_times.append((time.perf_counter() - t0) * 1e6)
    for n, a, b in zip(SHARED_ARM_QUERIES, shared, private):
        ra, rb = a.run(), b.run()
        for k in rb:
            np.testing.assert_array_equal(
                np.asarray(ra[k]), np.asarray(rb[k]),
                err_msg=f"post-refresh {n}:{k} diverged")
    s_us, i_us = float(np.min(s_times)), float(np.min(i_times))
    emit("multiquery/refresh1pct/independent", i_us,
         f"plans={len(private)};each refreshes private part artifacts")
    emit("multiquery/refresh1pct/pooled", s_us,
         f"plans={len(shared)};shared part artifacts updated once "
         f"({i_us / s_us:.1f}x vs independent)")

    if do_assert:
        fails = []
        if compile_speedup < min_speedup:
            fails.append(f"registry compile only {compile_speedup:.2f}x "
                         f"faster pooled (bar: {min_speedup}x)")
        if bytes_ratio < min_speedup:
            fails.append(f"resident artifact bytes only {bytes_ratio:.2f}x "
                         f"smaller pooled (bar: {min_speedup}x)")
        if fails:
            raise SystemExit("[bench-multiquery] FAIL: " + "; ".join(fails))
    print(f"[bench-multiquery] pooled wins: compile {compile_speedup:.1f}x, "
          f"bytes {bytes_ratio:.1f}x, 1%-append refresh {i_us / s_us:.1f}x")
    return {"compile_speedup": compile_speedup, "bytes_ratio": bytes_ratio,
            "refresh_speedup": i_us / s_us}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--min-speedup", type=float, default=2.0)
    ap.add_argument("--no-assert", action="store_true",
                    help="report ratios without gating on them")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    run(scale=args.scale, reps=args.reps, seed=args.seed,
        min_speedup=args.min_speedup, do_assert=not args.no_assert)
    if args.json:
        write_json(args.json, {"bench": "multiquery",
                               "queries": sorted(QUERY_IR)})


if __name__ == "__main__":
    main()
