"""Fused vs non-fused end-to-end latency of compiled predictive queries.

Runs representative SSB shapes through the ``Session`` query-builder — QG1
(1 join + scalar sum), QG2 (3 joins + group-by-sum) — plus the
predict-then-aggregate variants (P1 linear head, P3 GEMM tree head), each
compiled twice: the fused plan (prefused partials, gathers + segment ops)
and the non-fused reference (materialize T, model matmul).  The ratio is
the paper's §3 speedup measured on the *whole* query, aggregation included.

The ``multiagg`` rows execute one fused program computing several named
aggregates (sum + mean + count over shared join/model work) on both
aggregation backends — the multi-aggregate lowering's cost trajectory,
gated by the CI bench-regression job like every other row.

Run:  PYTHONPATH=src python -m benchmarks.bench_predictive_queries
      [--sf 1.0] [--scale 0.003] [--json BENCH_predictive_queries.json]
"""
from __future__ import annotations

import argparse

from repro.core.query import PREDICTION
from repro.data import QUERY_IR, generate_ssb, ssb_session

from .common import bench, emit, write_json

SCALE = 0.003   # shrink factor vs true SSB (CPU-sized)

#: QG1 shape (1 join, scalar), QG2 shape (3 joins, group-by), and their
#: model-headed counterparts (P2 = QG1 shape, P1/P3 = QG2 shape).
SHAPES = ["Q1.1", "Q2.1", "P2.linear.select.scalar", "P1.linear.year",
          "P3.tree.year"]

#: Shapes re-run with a multi-aggregate head: one compiled program, several
#: named aggregates (relational sum+mean+count, and mean/count over the
#: model's prediction matrix).
MULTI_AGG = ["Q2.1", "P1.linear.year"]


def _multiagg_builder(sess, name):
    b = sess.bind(QUERY_IR[name]())
    if b.model is not None:
        return b.agg(pred_mean=("mean", PREDICTION), n="count")
    return b.agg(rev_mean="mean(lo_revenue)", rev_max="max(lo_revenue)",
                 n="count")


def run(sf: float = 1.0, scale: float = SCALE):
    data = generate_ssb(sf=sf, scale=scale, seed=0)
    sess = ssb_session(data)
    for name in SHAPES:
        b = sess.bind(QUERY_IR[name]())
        fused = b.compile(backend="fused")
        us_fused = bench(fused.run)
        emit(f"predictive/{name}/fused", us_fused,
             f"rows={int(fused.run()['rows'])};"
             f"measured_sel={fused.selectivity:.3f};{fused.plan.reason}")
        if b.model is not None:
            non = b.compile(backend="nonfused")
            us_non = bench(non.run)
            emit(f"predictive/{name}/nonfused", us_non,
                 f"speedup={us_non / max(us_fused, 1e-9):.2f}x")
        matmul = b.compile(backend="fused", agg_backend="matmul")
        emit(f"predictive/{name}/agg_matmul", bench(matmul.run),
             "Fig.4 one-hot matmul aggregation")
    for name in MULTI_AGG:
        mb = _multiagg_builder(sess, name)
        n_aggs = len(mb.build().aggregates)
        for agg_backend in ("segment", "matmul"):
            compiled = mb.compile(backend="fused", agg_backend=agg_backend)
            emit(f"predictive/{name}/multiagg_{agg_backend}",
                 bench(compiled.run),
                 f"{n_aggs} named aggregates, one fused program")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=1.0)
    ap.add_argument("--scale", type=float, default=SCALE,
                    help="shrink factor vs true SSB (CI smoke uses ~0.001)")
    ap.add_argument("--json", default=None,
                    help="write rows to this JSON artifact path")
    args = ap.parse_args()
    run(sf=args.sf, scale=args.scale)
    if args.json:
        write_json(args.json, {"bench": "predictive_queries",
                               "sf": args.sf, "scale": args.scale})


if __name__ == "__main__":
    main()
