"""Bench-regression gate: compare BENCH_*.json against committed baselines.

The CI ``bench-smoke`` job used to be a crash gate only — benches ran, their
JSON uploaded, and a 100x slowdown sailed through green.  This script turns
the artifacts into a gate: every row of a current ``BENCH_*.json`` is
compared against the same-named row in ``benchmarks/baselines/<file>`` and
the run fails when ``current > baseline * tolerance``.

Cross-machine noise policy:

* ``--tolerance`` (default 1.5x) is the headline knob.
* ``--min-us`` skips rows where *both* sides are below the floor — µs-scale
  rows on shared CI runners are dominated by scheduler noise.
* ``--normalize`` divides every current value by the run's median
  current/baseline ratio first (clamped at 1.0 — a faster machine must not
  amplify mild raw ratios into failures), gating *relative* regressions
  (one bench slowing down vs. its siblings) while absorbing a uniformly
  slower machine.  CI uses this: baselines are seeded from a developer
  box, not the runner fleet.  The trade-off — a uniform slowdown of every
  row is absorbed too — is deliberate; the matching absolute check runs on
  machines that match the baselines (``--tolerance`` without
  ``--normalize``).

Rows present only in the current run are reported as new (not a failure);
rows that vanished are reported (not a failure — renames happen); zero
comparable rows *is* a failure, so an empty/renamed baseline can't produce
a vacuous pass.  ``--update`` rewrites the baselines from the current files
instead of checking (run it when a speedup or an intentional change moves
the floor).

Usage (the exact CI invocation):
    python -m benchmarks.check_regression BENCH_predictive_queries.json \
        BENCH_serving.json --baseline-dir benchmarks/baselines \
        --tolerance 1.5 --min-us 200 --normalize
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Dict, List, Tuple


def load_rows(path: str) -> Dict[str, float]:
    """name -> us_per_call for one BENCH_*.json artifact."""
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in payload["rows"]}


def compare(current: Dict[str, float], baseline: Dict[str, float], *,
            tolerance: float = 1.5, min_us: float = 0.0,
            normalize: bool = False
            ) -> Tuple[List[str], int, List[str]]:
    """Gate one artifact against its baseline.

    Returns ``(regressions, compared_count, notes)``; ``regressions`` is
    empty when the gate passes.  Pure function — the unit tests drive it
    directly with injected slowdowns.
    """
    common = sorted(set(current) & set(baseline))
    notes = [f"new row (no baseline): {n}" for n in sorted(
        set(current) - set(baseline))]
    notes += [f"baseline row missing from run: {n}" for n in sorted(
        set(baseline) - set(current))]
    scale = 1.0
    if normalize and common:
        # Median over the rows actually gated: sub-floor rows are scheduler
        # noise and must not set the scale the real rows are judged by.  With
        # fewer than 3 gated rows the median is degenerate (a single row
        # would normalize away its own regression), so fall back to absolute.
        ratios = sorted(
            current[n] / baseline[n] for n in common
            if baseline[n] > 0
            and not (current[n] <= min_us and baseline[n] <= min_us))
        if len(ratios) >= 3:
            # Clamped at 1.0: normalization exists to absorb a *slower*
            # machine.  On a faster-than-baseline run a sub-1 scale would
            # divide every row upward and flag rows whose raw ratio is well
            # under tolerance (1.2x raw → 1.6x "normalized") — a faster
            # machine can only ever make the gate stricter in absolute
            # terms, never manufacture a regression.
            scale = max(ratios[len(ratios) // 2], 1.0)
            notes.append(f"normalize: median current/baseline = "
                         f"{ratios[len(ratios) // 2]:.3f}x, scale "
                         f"{scale:.3f}x")
        else:
            notes.append(f"normalize: only {len(ratios)} gated rows — "
                         "too few for a median, using absolute comparison")
    regressions = []
    compared = 0
    for name in common:
        cur, base = current[name], baseline[name]
        if cur <= min_us and base <= min_us:
            notes.append(f"below --min-us floor ({min_us}us), skipped: "
                         f"{name} ({cur:.1f} vs {base:.1f})")
            continue
        compared += 1
        adjusted = cur / scale
        if base > 0 and adjusted > base * tolerance:
            regressions.append(
                f"{name}: {cur:.1f}us vs baseline {base:.1f}us "
                f"({cur / base:.2f}x raw, {adjusted / base:.2f}x normalized, "
                f"tolerance {tolerance}x)")
    return regressions, compared, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail when BENCH_*.json regress vs committed baselines")
    ap.add_argument("current", nargs="+",
                    help="BENCH_*.json files from this run")
    ap.add_argument("--baseline-dir", default="benchmarks/baselines")
    ap.add_argument("--tolerance", type=float, default=1.5,
                    help="fail when current > baseline * tolerance")
    ap.add_argument("--min-us", type=float, default=0.0,
                    help="skip rows where both sides are below this")
    ap.add_argument("--normalize", action="store_true",
                    help="divide by the median current/baseline ratio "
                         "(gates relative regressions across machines)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite baselines from the current files")
    args = ap.parse_args(argv)

    if args.update:
        os.makedirs(args.baseline_dir, exist_ok=True)
        for path in args.current:
            dst = os.path.join(args.baseline_dir, os.path.basename(path))
            shutil.copyfile(path, dst)
            print(f"[bench-gate] baseline updated: {dst}")
        return 0

    failed = False
    total_compared = 0
    for path in args.current:
        base_path = os.path.join(args.baseline_dir, os.path.basename(path))
        if not os.path.exists(base_path):
            print(f"[bench-gate] FAIL {path}: no baseline at {base_path} "
                  "(seed it with --update)")
            failed = True
            continue
        regressions, compared, notes = compare(
            load_rows(path), load_rows(base_path), tolerance=args.tolerance,
            min_us=args.min_us, normalize=args.normalize)
        total_compared += compared
        for n in notes:
            print(f"[bench-gate] {path}: {n}")
        if regressions:
            failed = True
            for r in regressions:
                print(f"[bench-gate] REGRESSION {path}: {r}")
        else:
            print(f"[bench-gate] OK {path}: {compared} rows within "
                  f"{args.tolerance}x of baseline")
    if total_compared == 0:
        print("[bench-gate] FAIL: no comparable rows — baselines empty or "
              "bench names diverged; refusing a vacuous pass")
        failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
