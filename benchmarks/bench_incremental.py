"""Incremental refresh vs cold re-prefuse across dimension-append fractions.

The paper's §4.3 Q6/Q8 concern: prefused evaluation only amortizes if
dimension updates don't force a rebuild.  This bench appends
0.1% / 1% / 10% of the SSB ``part`` dimension to a live fused serving
runtime and measures, for each append:

* **cold**  — the pre-Catalog recourse: a fresh ``compile_serving`` on the
  updated catalog (full prefuse over every dimension row, PK re-argsort,
  and a new trace+XLA compile of the serving bucket) + one serve,
* **delta** — ``ServingRuntime.refresh()``: sorted-merge ``PKIndex.extend``,
  Eq. 1 partials prefused for ONLY the appended rows, mask scatter, zero
  retraces + the same serve.

Every serve is asserted bit-identical between the two runtimes, and the
run fails if the 1%-append delta path is not ≥ ``--min-speedup`` (default
5x, the ISSUE 5 acceptance bar) faster than cold.

Run:  PYTHONPATH=src python -m benchmarks.bench_incremental
      [--scale 0.05] [--reps 3] [--json BENCH_incremental.json]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.laq import Catalog
from repro.core.query import compile_serving
from repro.data import QUERY_IR, generate_ssb, ssb_catalog

from .common import emit, write_json

FRACTIONS = (0.001, 0.01, 0.1)
QUERY = "P1.linear.year"


def _part_block(rng, start: int, m: int):
    """``m`` fresh part rows with new keys ``start..start+m``."""
    mfgr = rng.integers(0, 5, m)
    category = mfgr * 5 + rng.integers(0, 5, m)
    return {"partkey": start + np.arange(m), "p_mfgr": mfgr,
            "p_category": category,
            "p_brand1": category * 40 + rng.integers(0, 40, m),
            "p_size": rng.integers(1, 51, m)}


def _timed(fn) -> float:
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6


def run(scale: float = 0.05, reps: int = 4, seed: int = 0,
        min_speedup: float = 5.0, do_assert: bool = True):
    # capacity_slack leaves padded rows for every appended block of the run
    # to land in without a shape change (the delta path's precondition).
    data = generate_ssb(sf=1, scale=scale, seed=seed, capacity_slack=1.6)
    catalog = ssb_catalog(data)
    q = QUERY_IR[QUERY]()
    rng = np.random.default_rng(seed + 1)
    n_part0 = int(data.part.nvalid)

    rt = compile_serving(catalog, q, backend="fused", buckets=(64,))
    reqs = {a.fk_col: rng.integers(
        0, 64, 64).astype(np.int32) for a in q.arms}
    rt.serve(reqs)                       # warm the single bucket
    next_key = n_part0

    speedups = {}
    for frac in FRACTIONS:
        m = max(1, int(n_part0 * frac))
        d_times, c_times = [], []
        for _ in range(reps):
            catalog.append("part", _part_block(rng, next_key, m))
            next_key += m

            def delta():
                line = rt.refresh()
                assert "delta" in line, f"expected delta path, got {line}"
                return rt.serve(reqs)

            d_times.append(_timed(delta))

            def cold():
                fresh = compile_serving(catalog, q, backend="fused",
                                        buckets=(64,))
                return fresh.serve(reqs), fresh

            t0 = time.perf_counter()
            out, fresh = cold()
            jax.block_until_ready(out)
            c_times.append((time.perf_counter() - t0) * 1e6)
            np.testing.assert_array_equal(
                np.asarray(rt.serve(reqs)), np.asarray(out),
                err_msg="delta refresh diverged from cold rebuild")
        # Min over reps, matching ``common.bench``: scheduler stalls on
        # shared runners are additive, the best observation is the cost.
        d_us, c_us = float(np.min(d_times)), float(np.min(c_times))
        speedups[frac] = c_us / d_us
        tag = f"append{frac:.1%}"
        emit(f"incremental/cold/{tag}", c_us,
             f"m={m};full prefuse + re-sort + retrace")
        emit(f"incremental/delta/{tag}", d_us,
             f"m={m};refresh: {speedups[frac]:.1f}x vs cold, 0 retraces")
        assert rt.num_compiles == 1, "delta path must never retrace"

    if do_assert and speedups[0.01] < min_speedup:
        raise SystemExit(
            f"[bench-incremental] FAIL: delta refresh at a 1% append is "
            f"only {speedups[0.01]:.2f}x faster than cold re-prefuse "
            f"(acceptance bar: {min_speedup}x)")
    print(f"[bench-incremental] delta vs cold speedups: "
          + ", ".join(f"{f:.1%}: {s:.1f}x" for f, s in speedups.items()))
    return speedups


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--reps", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--min-speedup", type=float, default=5.0)
    ap.add_argument("--no-assert", action="store_true",
                    help="report speedups without gating on them")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    run(scale=args.scale, reps=args.reps, seed=args.seed,
        min_speedup=args.min_speedup, do_assert=not args.no_assert)
    if args.json:
        write_json(args.json, {"bench": "incremental", "query": QUERY,
                               "fractions": list(FRACTIONS)})


if __name__ == "__main__":
    main()
