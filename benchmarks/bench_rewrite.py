"""Rewrite engine: distilled predicate plans vs predict-then-filter.

One query family, two lowerings of identical semantics:

* **off** — ``rewrite="off"``: the plan gathers every model feature, runs
  the tree as a GEMM (Fig. 5) over all fact rows, and filters on the
  prediction (``model_preds`` folded into validity).
* **on**  — the default: ``distill_tree_filter`` compiles the satisfying
  leaf's path conditions into ordinary dimension predicates and drops the
  model from the online phase entirely — the join+predict program
  degenerates to a pure relational aggregate.

Prediction filters are quasi-static — the fold runs when the star
assembles, so steady-state ``run()`` is near-identical for both plans
(emitted as a parity row).  Where dropping the model pays is the *online
maintenance cycle*: every data change re-assembles validity, and the
unrewritten plan must re-run the full fact-sized tree GEMM each time.
The bench drives append → ``refresh()`` → answer cycles through both
plans, asserts them bit-equal (the rewrite contract), and gates the
distilled cycle at ≥ 2x faster (the ISSUE 10 acceptance gate).  Also
measured: the rewrite pass itself (pure IR analysis, no data), and a
constant-input fold on a linear model (trajectory row, no gate).

Run:  PYTHONPATH=src python -m benchmarks.bench_rewrite
      [--scale 0.02] [--json BENCH_rewrite.json]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax.numpy as jnp

from repro.core.fusion.operators import LinearOperator, tree_from_arrays
from repro.core.laq import Catalog, Table
from repro.core.laq.selection import Pred
from repro.core.query import (Aggregate, ArmSpec, GroupKey,
                              PredictionFilter, PredictiveQuery,
                              compile_query, rewrite_query)

from .common import bench, emit, write_json

BASE_FACT = 1_000_000          # rows at scale 1.0
K = 16                         # model feature width
DEPTH = 7                      # tree depth: 127 nodes / 128 leaves
PAD_GROUP = np.int64(2**31 - 1)


def _distillable_tree(rng: np.random.Generator):
    """A complete depth-``DEPTH`` tree whose all-right leaf is reachable.

    Right branches are ``feature > v``: giving the all-right path distinct
    features keeps its conjunction consistent, so filtering on that leaf
    distills to at most ``DEPTH`` ordinary predicates.  Every other node
    draws random features/thresholds — the rewrite only reads the chosen
    leaf's path.
    """
    p = 2 ** DEPTH - 1
    feature = rng.integers(0, K, p)
    threshold = rng.integers(-3, 4, p).astype(np.float32)
    node, level = 0, 0
    while node < p:
        feature[node] = level % K
        threshold[node] = np.float32(-2 + (level // K))
        node, level = 2 * node + 2, level + 1
    return tree_from_arrays(feature, threshold, K)


def build(scale: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    n_fact = max(2_000, int(BASE_FACT * scale))
    n_dim = max(n_fact // 50, 64)
    dim_cols = {"d_pk": np.arange(n_dim)}
    for k in range(K):
        dim_cols[f"d_f{k}"] = rng.integers(-4, 5, n_dim)
    dim = Table.from_columns("dim", dim_cols, key_cols=("d_pk",),
                             capacity=int(n_dim * 1.5))
    fact = Table.from_columns("fact", {
        "fk": rng.integers(0, int(n_dim * 1.1), n_fact),
        "f_g": rng.integers(0, 8, n_fact),
        "revenue": rng.integers(-4, 5, n_fact)},
        key_cols=("fk", "f_g"), capacity=int(n_fact * 1.2))
    model = _distillable_tree(rng)
    arm = ArmSpec("dim", "fk", "d_pk",
                  tuple(f"d_f{k}" for k in range(K)), ())
    q = PredictiveQuery(
        "fact", (arm,), (), model, (GroupKey("fact", "f_g", 8),),
        (Aggregate("revenue", "sum", "rev"), Aggregate("*", "count", "n")),
        8, model_preds=(PredictionFilter(model.l - 1, "==", 1.0),))
    return {"dim": dim, "fact": fact}, q


def _result_map(res, names):
    groups = np.asarray(res["groups"])
    live = groups != PAD_GROUP
    out = {}
    for n in names:
        v = np.asarray(res[n], np.float64)
        v2 = v if v.ndim > 1 else v[:, None]
        out[n] = {int(g): tuple(v2[i]) for i, g in enumerate(groups)
                  if live[i]}
    return out


def run(scale: float, seed: int = 0, json_path: str | None = None,
        do_assert: bool = True):
    tables, q = build(scale, seed)
    n = int(tables["fact"].nvalid)
    names = [a.name for a in q.aggregates]

    # The rewrite pass itself: pure IR/model analysis, no fact data.
    t0 = time.perf_counter()
    rw = rewrite_query(tables, q)
    rewrite_us = (time.perf_counter() - t0) * 1e6
    assert rw.changed and rw.query.model is None, rw.trail
    emit(f"rewrite/pass@{n}", rewrite_us,
         f"{len(rw.trail)} firings, {len(rw.query.arms[0].preds)} preds")

    cat_on, cat_off = Catalog(dict(tables)), Catalog(dict(tables))
    plan_on = compile_query(cat_on, q)
    plan_off = compile_query(cat_off, q, rewrite="off")
    assert any("distill" in t for t in plan_on._rewrites), plan_on._rewrites

    # Steady-state run() parity row: the prediction fold is quasi-static,
    # so both plans execute the same relational program between refreshes.
    us_run = bench(plan_on.run)
    emit(f"rewrite/run/steady@{n}", us_run,
         f"{bench(plan_off.run) / max(us_run, 1e-9):.2f}x off/on parity")

    # The gated metric: data-change → answer.  Each cycle appends m fact
    # rows and refreshes; the unrewritten plan re-runs the fact-sized tree
    # GEMM inside the validity fold, the distilled plan only probes deltas.
    m = max(1, n // 100)

    def make_cycle(cat, plan, salt):
        rng = np.random.default_rng(seed + salt)
        n_dim = int(tables["dim"].nvalid)

        def cycle():
            cat.append("fact", {
                "fk": rng.integers(0, int(n_dim * 1.1), m),
                "f_g": rng.integers(0, 8, m),
                "revenue": rng.integers(-4, 5, m)})
            plan.refresh()
            return plan.run()["rows"]
        return cycle

    us_on = bench(make_cycle(cat_on, plan_on, 2))
    us_off = bench(make_cycle(cat_off, plan_off, 2))
    speedup = us_off / max(us_on, 1e-9)
    emit(f"rewrite/cycle/on@{n}", us_on, f"m={m}; distilled: model dropped")
    emit(f"rewrite/cycle/off@{n}", us_off,
         f"m={m}; tree GEMM p={2 ** DEPTH - 1}; "
         f"distill speedup {speedup:.1f}x")

    if do_assert:
        # Same appends (same salt) on both catalogs: results must agree
        # bit-for-bit after all the refresh cycles above.
        a, b = (_result_map(plan_on.run(), names),
                _result_map(plan_off.run(), names))
        assert a == b, "rewritten != unrewritten"
        assert speedup >= 2.0, (
            f"distilled cycle only {speedup:.2f}x faster (gate: 2x)")

    # Trajectory row: constant-input folding on a linear model (no gate).
    rng = np.random.default_rng(seed + 1)
    model = LinearOperator(jnp.asarray(
        rng.integers(-2, 3, (K, 2)), jnp.float32))
    arm = q.arms[0]
    ql = PredictiveQuery(
        "fact", (ArmSpec(arm.table, arm.fk_col, arm.pk_col,
                         arm.feature_cols, (Pred("d_f0", "==", 2),)),),
        (), model, q.group_keys,
        (Aggregate("@prediction", "sum", "p"), Aggregate("*", "count", "n")),
        8)
    pl_on = compile_query(Catalog(dict(tables)), ql)
    pl_off = compile_query(Catalog(dict(tables)), ql, rewrite="off")
    us_lin = bench(pl_on.run)
    emit(f"rewrite/run/fold@{n}", us_lin,
         f"{us_lin and bench(pl_off.run) / us_lin:.2f}x vs off; "
         + ";".join(t.split("(")[0] for t in pl_on._rewrites))
    if do_assert:
        lnames = [a.name for a in ql.aggregates]
        assert _result_map(pl_on.run(), lnames) == _result_map(
            pl_off.run(), lnames), "folded != unrewritten"

    if json_path:
        write_json(json_path, {"bench": "rewrite", "scale": scale})


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None)
    ap.add_argument("--no-assert", action="store_true")
    args = ap.parse_args(argv)
    run(args.scale, args.seed, args.json, do_assert=not args.no_assert)


if __name__ == "__main__":
    main()
