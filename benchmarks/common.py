"""Benchmark harness utilities: timing, CSV/JSON output."""
from __future__ import annotations

import json
import time
from typing import Callable, List, Optional

import jax
import numpy as np

HEADER = "name,us_per_call,derived"
_rows: List[str] = []


def emit(name: str, us: float, derived: str = ""):
    row = f"{name},{us:.1f},{derived}"
    _rows.append(row)
    print(row, flush=True)


def rows():
    return list(_rows)


def write_json(path: str, meta: Optional[dict] = None):
    """Dump every row emitted so far as a JSON benchmark artifact.

    The CI bench-smoke job uploads these (``BENCH_*.json``) on every PR —
    a crash gate plus a perf trajectory, not a regression gate.
    """
    recs = []
    for row in _rows:
        name, us, derived = row.split(",", 2)
        recs.append({"name": name, "us_per_call": float(us),
                     "derived": derived})
    payload = {"backend": jax.default_backend(), "rows": recs}
    if meta:
        payload.update(meta)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[bench] wrote {path} ({len(recs)} rows)", flush=True)


def bench(fn: Callable, *args, warmup: int = 2, iters: int = 7) -> float:
    """Best wall time (µs) of a jitted callable (block_until_ready).

    The *minimum* over ``iters`` timed calls, not the median: scheduler
    noise on shared runners is strictly additive (multi-ms stalls land on
    random iterations), so the min is the stable estimator of the true
    cost — a real slowdown raises every observation including the best one,
    while a noisy neighbour can no longer flip the regression gate.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.min(times) * 1e6)
