"""Benchmark harness utilities: timing, CSV output."""
from __future__ import annotations

import time
from typing import Callable, List

import jax
import numpy as np

HEADER = "name,us_per_call,derived"
_rows: List[str] = []


def emit(name: str, us: float, derived: str = ""):
    row = f"{name},{us:.1f},{derived}"
    _rows.append(row)
    print(row, flush=True)


def rows():
    return list(_rows)


def bench(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (µs) of a jitted callable (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)
