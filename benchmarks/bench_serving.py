"""Dynamic-batch serving latency across padding buckets and backends.

One ``compile_serving`` plan per backend serves a ragged sweep of request
batch sizes; every size lands in one of the fixed padding buckets, so the
steady state never recompiles.  Emits per-size medians plus the runtime's
own per-bucket percentiles — the serving-side counterpart of
``bench_predictive_queries`` (which measures whole-query aggregation).

Run:  PYTHONPATH=src python -m benchmarks.bench_serving
      [--scale 0.05] [--k 16] [--l 4] [--json BENCH_serving.json]
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.launch.serve import FusedFeatureServer

from .common import bench, emit, write_json


def run(
    scale: float = 0.05,
    k: int = 16,
    l: int = 4,
    serve_backend: str = "auto",
    interpret: bool = False,
    seed: int = 0,
):
    server = FusedFeatureServer(
        setting=2,
        sf=1,
        k=k,
        l=l,
        scale=scale,
        seed=seed,
        serve_backend=serve_backend,
        interpret=interpret,
    )
    rng = np.random.default_rng(seed + 1)
    buckets = server.runtime_fused.buckets
    sizes = sorted({max(1, b // 2) for b in buckets} | set(buckets))
    sizes.append(2 * buckets[-1] + 3)  # oversize: served in top-bucket chunks
    for fused in (True, False):
        name = "fused" if fused else "nonfused"
        runtime = server.runtime(fused)
        for n in sizes:
            reqs = server.random_requests(n, rng)
            us = bench(server.serve_batch, reqs, fused)
            emit(
                f"serving/{name}/n{n}",
                us,
                f"buckets={buckets};serve_backend={runtime.serve_backend}",
            )
        emit(
            f"serving/{name}/compiles",
            float(runtime.num_compiles),
            f"traces for {len(sizes)} batch sizes",
        )
    return server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--l", type=int, default=4)
    ap.add_argument("--serve-backend", default="auto")
    ap.add_argument("--interpret", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    server = run(
        scale=args.scale,
        k=args.k,
        l=args.l,
        serve_backend=args.serve_backend,
        interpret=args.interpret,
    )
    if args.json:
        latency = {
            "fused": server.runtime_fused.latency_stats(),
            "nonfused": server.runtime_nonfused.latency_stats(),
        }
        write_json(args.json, {"bench": "serving", "latency": latency})


if __name__ == "__main__":
    main()
