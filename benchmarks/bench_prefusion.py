"""Paper Figures 16 & 21: pre-fusion cost vs online join-computation cost.

The fusion trade-off: pre-fused partials are recomputed whenever dimension
tables change.  Measures the pre-fusion stage and the online stage
separately across output widths l (linear) and leaf counts (tree) —
reproducing the paper's observation that the linear/online stage dominates
until l grows past ~512, after which pre-fusion dominates and fusion pays
off only for slowly-changing dimensions (the planner's amortization
input).
"""
from __future__ import annotations

import jax
import numpy as np
import jax.numpy as jnp

from repro.core.fusion import (LinearOperator, predict_fused, prefuse,
                               random_tree)
from repro.data import generate_star

from .common import bench, emit

SCALE = 0.01


def run():
    rng = np.random.default_rng(0)
    for l in (64, 256, 512, 1024, 2048):
        syn = generate_star(2, 2, 512, scale=SCALE)
        model = LinearOperator(jnp.asarray(
            rng.normal(size=(512, l)).astype(np.float32)))
        pre_fn = jax.jit(lambda: prefuse(syn.star, model).partials)
        us_pre = bench(pre_fn)
        pre = prefuse(syn.star, model)
        online = jax.jit(lambda: predict_fused(syn.star, pre))
        us_on = bench(online)
        emit(f"prefusion/linear_l{l}/prefuse", us_pre, "")
        emit(f"prefusion/linear_l{l}/online", us_on,
             f"prefuse_share={us_pre / (us_pre + us_on):.2f}")
    for depth in (6, 8, 10):
        syn = generate_star(2, 2, 256, scale=SCALE)
        tree = random_tree(rng, 256, depth)
        pre_fn = jax.jit(lambda: prefuse(syn.star, tree).partials)
        us_pre = bench(pre_fn)
        pre = prefuse(syn.star, tree)
        online = jax.jit(lambda: predict_fused(syn.star, pre))
        us_on = bench(online)
        emit(f"prefusion/tree_d{depth}/prefuse", us_pre, "")
        emit(f"prefusion/tree_d{depth}/online", us_on,
             f"prefuse_share={us_pre / (us_pre + us_on):.2f}")


if __name__ == "__main__":
    run()
