"""Kernel-level microbenchmarks: Pallas primitives vs jnp references.

Pallas interpret mode is a correctness vehicle, not a perf vehicle, so on
CPU the timed engine is the jnp reference path; the Pallas kernels are
asserted equal first (shape sweep) and their VMEM working sets reported
(derived column) — the quantity that matters for TPU block-shape choice.
"""
from __future__ import annotations

import jax
import numpy as np
import jax.numpy as jnp

from repro.kernels import (fused_star_gather, fused_star_gather_ref,
                           onehot_matmul, onehot_matmul_ref, tree_predict,
                           tree_predict_ref)
from repro.core.fusion import random_tree

from .common import bench, emit


def run():
    rng = np.random.default_rng(0)
    # onehot_matmul (join-as-matmul / MoE dispatch)
    for n, r, d in ((1024, 4096, 256), (8192, 16384, 512)):
        idx = jnp.asarray(rng.integers(0, r, n), jnp.int32)
        tbl = jnp.asarray(rng.normal(size=(r, d)), jnp.float32)
        got = onehot_matmul(idx[:128], tbl, block_n=8, block_r=128,
                            block_d=128, interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(onehot_matmul_ref(idx[:128],
                                                                tbl)),
                                   rtol=1e-5)
        fn = jax.jit(lambda i, t: onehot_matmul_ref(i, t))
        us = bench(fn, idx, tbl)
        vmem_kb = (128 * 512 + 512 * 128 + 128 * 128) * 4 / 1024
        emit(f"kernels/onehot_matmul/n{n}_r{r}_d{d}", us,
             f"vmem_tile={vmem_kb:.0f}KiB")

    # fused_star_gather (serve-time fused pipeline)
    for n, l in ((4096, 64), (16384, 256)):
        tables = [jnp.asarray(rng.normal(size=(r, l)), jnp.float32)
                  for r in (2048, 2048, 512)]
        ptrs = jnp.asarray(np.stack(
            [rng.integers(0, t.shape[0], n) for t in tables]), jnp.int32)
        found = jnp.ones((3, n), jnp.int32)
        got = fused_star_gather(ptrs[:, :64], found[:, :64], tables,
                                interpret=True)
        np.testing.assert_allclose(
            np.asarray(got),
            np.asarray(fused_star_gather_ref(ptrs[:, :64], found[:, :64],
                                             tables)), rtol=1e-5)
        fn = jax.jit(lambda p, f: fused_star_gather_ref(p, f, tables))
        us = bench(fn, ptrs, found)
        emit(f"kernels/fused_star_gather/n{n}_l{l}", us,
             f"row_dma={(3 + 1) * l * 4}B/step")

    # tree_predict (fused GEMM tree inference)
    for n, k, depth in ((4096, 128, 6), (16384, 256, 8)):
        tree = random_tree(rng, k, depth)
        x = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
        got = tree_predict(x[:128], tree.F, tree.v, tree.H, tree.h,
                           block_n=8, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(got),
            np.asarray(tree_predict_ref(x[:128], tree.F, tree.v, tree.H,
                                        tree.h)))
        fn = jax.jit(lambda a: tree_predict_ref(a, tree.F, tree.v, tree.H,
                                                tree.h))
        us = bench(fn, x)
        p, l = 2**depth - 1, 2**depth
        vmem_kb = (128 * k + k * p + 128 * p + p * 128 + 128 * 128) * 4 / 1024
        emit(f"kernels/tree_predict/n{n}_k{k}_l{l}", us,
             f"vmem_tile={vmem_kb:.0f}KiB")


if __name__ == "__main__":
    run()
