"""Paper Figures 10–11: per-operator breakdown of query group 4.

Times the stages of Q4.2 separately: domain/pointer generation (the
paper's "domain generation"), the four join-arm resolutions, predicate
evaluation, and group-by aggregation.  The paper finds joins dominate and
domain generation takes a similar share within joins — checked here on
the factored engine, plus the effect of the paper's suggested domain
*caching* (§4.2 Q3), which we implement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.laq import (Pred, composite_code, default_domain_cache,
                            groupby_reduce, join_factored, key_domain)
from repro.data import generate_ssb

from .common import bench, emit


def run(sf=4, scale=0.003):
    data = generate_ssb(sf=sf, scale=scale, seed=0)
    lo = data.lineorder
    arms = [(data.customer, "lo_custkey", "custkey"),
            (data.supplier, "lo_suppkey", "suppkey"),
            (data.part, "lo_partkey", "partkey"),
            (data.date, "lo_orderdate", "datekey")]

    # Stage 1: domain generation (sorted union) per join arm.
    total_dom = 0.0
    for dim, fk, pk in arms:
        fn = jax.jit(lambda a=lo.key(fk), b=dim.key(pk):
                     key_domain([a, b], size=dim.capacity * 2))
        us = bench(fn)
        total_dom += us
    emit(f"breakdown/domain_gen/sf{sf}", total_dom, "4 arms")

    # Domain caching (paper's suggested optimization — ours to measure).
    t_cold = total_dom
    cache = default_domain_cache
    for dim, fk, pk in arms:
        cache.get_or_build([(dim.name, pk)], [lo.key(fk), dim.key(pk)],
                           size=dim.capacity * 2)
    t_warm = 0.0
    for dim, fk, pk in arms:
        fn = jax.jit(lambda d=dim, f=fk, p=pk: cache._store[
            cache._key([(d.name, p)])])
        t_warm += bench(fn)
    emit(f"breakdown/domain_cached/sf{sf}", t_warm,
         f"{t_cold / max(t_warm, 1e-9):.0f}x_faster")

    # Stage 2: join-arm pointer resolution.
    total_join = 0.0
    for dim, fk, pk in arms:
        fn = jax.jit(lambda a=lo.key(fk), b=dim.key(pk):
                     join_factored(a, b).ptr)
        total_join += bench(fn)
    emit(f"breakdown/joins/sf{sf}", total_join, "4 arms")

    # Stage 3: predicates + group-by aggregation (rest of Q4.2).
    def agg():
        ok_c = join_factored(lo.key("lo_custkey"), data.customer.key("custkey"))
        ok_s = join_factored(lo.key("lo_suppkey"), data.supplier.key("suppkey"))
        ok_p = join_factored(lo.key("lo_partkey"), data.part.key("partkey"))
        ok_d = join_factored(lo.key("lo_orderdate"), data.date.key("datekey"))
        valid = (lo.valid_mask() & ok_c.found & ok_s.found & ok_p.found
                 & ok_d.found)
        valid &= jnp.take(Pred("c_region", "==", 1).mask(data.customer),
                          ok_c.ptr)
        year = jnp.take(data.date.key("d_year"), ok_d.ptr)
        nation = jnp.take(data.supplier.key("s_nation"), ok_s.ptr)
        cat = jnp.take(data.part.key("p_category"), ok_p.ptr)
        codes = composite_code([year - 1992, nation, cat], [8, 25, 25], valid)
        profit = jnp.where(valid, lo.col("lo_revenue")
                           - lo.col("lo_supplycost"), 0.0)
        return groupby_reduce(codes, [profit], 4096, ("sum",))

    us_all = bench(jax.jit(agg))
    emit(f"breakdown/q42_full/sf{sf}", us_all,
         f"joins_share={total_join / us_all:.2f}")


if __name__ == "__main__":
    run()
