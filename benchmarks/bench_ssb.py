"""Paper Figures 7–9: SSB query latency across scale factors and queries.

Runs the full 13-query SSB suite through the LAQ engine (factored MM-Join
physical operators) at several scale factors, at laptop scale
(cardinalities shrunk by ``SCALE``, selectivity structure preserved).
Per-query latencies mirror Fig. 8/9; per-sf means mirror Fig. 7.  The
join-algorithm comparison underlying the paper's analysis (MM-Join dense /
spMM vs sort-based join) is in ``bench_mmjoin.py``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.data import QUERY_IR, generate_ssb, query_groups, ssb_session

from .common import bench, emit

SCALE = 0.003   # shrink factor vs true SSB (CPU-sized)


def run(sfs=(1, 2, 4)):
    for sf in sfs:
        data = generate_ssb(sf=sf, scale=SCALE, seed=0)
        session = ssb_session(data)
        groups = query_groups()
        total_us = 0.0
        for gname, qnames in groups.items():
            g_us = 0.0
            for qname in qnames:
                # Offline (joins/selection/codes) happens at compile; the
                # benchmarked call is the query's single jitted online plan.
                fn = session.compile(QUERY_IR[qname]()).run
                us = bench(fn)
                g_us += us
                emit(f"ssb/{qname}/sf{sf}", us,
                     f"rows={int(jnp.asarray(fn()['rows']))}")
            total_us += g_us
            emit(f"ssb/{gname}/sf{sf}", g_us / len(qnames), "group-mean")
        emit(f"ssb/all/sf{sf}", total_us / 13, "mean-13-queries")


if __name__ == "__main__":
    run()
