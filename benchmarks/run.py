"""Benchmark orchestrator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus heatmap blocks).
"""
from __future__ import annotations

import time

from . import (bench_breakdown, bench_fusion_linear, bench_fusion_tree,
               bench_kernels, bench_mmjoin, bench_prefusion, bench_ssb)
from .common import HEADER


def main() -> None:
    print(HEADER)
    t0 = time.time()
    for name, mod in [
        ("ssb (Fig.7-9)", bench_ssb),
        ("mmjoin (§2.3/[24])", bench_mmjoin),
        ("breakdown (Fig.10-11)", bench_breakdown),
        ("fusion_linear (Fig.12-15)", bench_fusion_linear),
        ("fusion_tree (Fig.17-20)", bench_fusion_tree),
        ("prefusion (Fig.16,21)", bench_prefusion),
        ("kernels", bench_kernels),
    ]:
        print(f"# --- {name} ---", flush=True)
        mod.run()
    print(f"# total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
