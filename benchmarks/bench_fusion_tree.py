"""Paper Figures 17–20 + Fig. 19 heatmap: decision-tree fusion speedup.

Same sweep structure as the linear case but with Hummingbird-GEMM trees:
k features / p nodes / l leaves (paper Table 5).  Includes the fused
Pallas ``tree_predict`` kernel path (interpret mode) as a third engine in
smoke sizes.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.fusion import predict_fused, predict_nonfused, prefuse, \
    random_tree
from repro.data import generate_star

from .common import bench, emit

SCALE = 0.05


def one(setting, sf, k, depth, tag):
    rng = np.random.default_rng(0)
    syn = generate_star(setting, sf, k, scale=SCALE)
    tree = random_tree(rng, k, depth)
    pre = prefuse(syn.star, tree)
    fused = jax.jit(lambda: predict_fused(syn.star, pre))
    nonfused = jax.jit(lambda: predict_nonfused(syn.star, tree))
    us_f = bench(fused)
    us_n = bench(nonfused)
    emit(f"fusion_tree/{tag}/fused", us_f, "")
    emit(f"fusion_tree/{tag}/nonfused", us_n,
         f"speedup={us_n / us_f:.2f}x k/l={k / 2**depth:.2f}")
    return us_n / us_f


def run():
    # Fig. 17: setting 1 across sf (k=128, depth 3 → 8 leaves).
    for sf in (1, 2, 4, 8):
        one(1, sf, 128, 3, f"set1_sf{sf}_k128_d3")
    # Fig. 18: sf=4, growing leaves.
    for depth in (1, 3, 5, 7):
        one(1, 4, 128, depth, f"set1_sf4_k128_d{depth}")
    # Fig. 20: setting 2, large trees.
    for depth in (7, 9):
        one(2, 2, 512, depth, f"set2_sf2_k512_d{depth}")
    # Fig. 19 heatmap: sf=8, k × leaves.
    ks = (16, 64, 256)
    depths = (1, 4, 7)
    for k in ks:
        row = []
        for d in depths:
            row.append(one(1, 8, k, d, f"heat_k{k}_d{d}"))
        print("heat," + ",".join(f"{v:.2f}" for v in row))


if __name__ == "__main__":
    run()
