"""Paper Figures 12–15 + Fig. 14 heatmap: linear-operator fusion speedup.

Cardinality setting 1 ("large input, small model") and setting 2 ("small
input, large model") from paper Table 4/5, swept over sf and over the
model shape (k = input width, l = output width).  Emits fused and
non-fused per-batch times and their ratio — the paper's headline result
(speedup tracks k/l, Eq. 2; up to 317× on the A40).
"""
from __future__ import annotations

import jax
import numpy as np
import jax.numpy as jnp

from repro.core.fusion import (LinearOperator, predict_fused,
                               predict_nonfused, prefuse)
from repro.data import generate_star

from .common import bench, emit

SCALE = 0.05


def one(setting, sf, k, l, tag):
    rng = np.random.default_rng(0)
    syn = generate_star(setting, sf, k, scale=SCALE)
    model = LinearOperator(jnp.asarray(
        rng.normal(size=(k, l)).astype(np.float32)))
    pre = prefuse(syn.star, model)
    fused = jax.jit(lambda: predict_fused(syn.star, pre))
    nonfused = jax.jit(lambda: predict_nonfused(syn.star, model))
    us_f = bench(fused)
    us_n = bench(nonfused)
    emit(f"fusion_linear/{tag}/fused", us_f, "")
    emit(f"fusion_linear/{tag}/nonfused", us_n,
         f"speedup={us_n / us_f:.2f}x k/l={k / l:.1f}")
    return us_n / us_f


def run():
    # Fig. 12: setting 1 across sf, small model (k=128, l=2).
    for sf in (1, 2, 4, 8):
        one(1, sf, 128, 2, f"set1_sf{sf}_k128_l2")
    # Fig. 13: hold sf=4, grow l.
    for l in (2, 8, 32, 128):
        one(1, 4, 128, l, f"set1_sf4_k128_l{l}")
    # Fig. 15: setting 2 (small input), large models.
    for l in (256, 1024, 2048):
        one(2, 2, 512, l, f"set2_sf2_k512_l{l}")
    # Fig. 14 heatmap: sf=8, k × l grid.
    print("heatmap_speedup (rows k, cols l):")
    ks = (16, 32, 64, 128)
    ls = (2, 8, 32, 128)
    for k in ks:
        row = []
        for l in ls:
            row.append(one(1, 8, k, l, f"heat_k{k}_l{l}"))
        print("heat," + ",".join(f"{v:.2f}" for v in row))


if __name__ == "__main__":
    run()
