"""Quickstart: LAQ + operator fusion, then sharded serving, in ~100 lines.

Builds a small star schema, runs a relational query through linear-algebra
operators, fuses a linear model into the dimension tables (paper Eq. 1),
shows fused == non-fused with far less online work — then partitions the
prefused partials across a forced multi-device mesh and serves request
batches from device-local gathers, bit-identical to the one-device path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

# Force 8 host devices so the sharded-serving section below has a real mesh
# even on a laptop CPU.  Must happen before jax first initializes.
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import jax.numpy as jnp
import numpy as np

from repro.core.fusion import LinearOperator, plan_fusion, predict_fused, \
    predict_nonfused, prefuse
from repro.core.laq import DimSpec, Pred, Table, select, star_join
from repro.core.query import compile_serving, query_from_star
from repro.launch.mesh import make_serving_mesh

rng = np.random.default_rng(0)

# -- 1. Relations (a fact table + two dimension tables) ---------------------
customers = Table.from_columns("customers", {
    "custkey": np.arange(100),
    "age": rng.integers(18, 80, 100).astype(np.float32),
    "spend": rng.gamma(2.0, 50.0, 100).astype(np.float32),
}, key_cols=("custkey",))

products = Table.from_columns("products", {
    "prodkey": np.arange(40),
    "price": rng.gamma(2.0, 20.0, 40).astype(np.float32),
    "rating": rng.uniform(1, 5, 40).astype(np.float32),
}, key_cols=("prodkey",))

orders = Table.from_columns("orders", {
    "o_custkey": rng.integers(0, 100, 500),
    "o_prodkey": rng.integers(0, 40, 500),
    "quantity": rng.integers(1, 9, 500).astype(np.float32),
}, key_cols=("o_custkey", "o_prodkey"))

# -- 2. Relational ops as linear algebra ------------------------------------
big_orders = select(orders, [Pred("quantity", ">", 5.0)])
print(f"selection kept {int(big_orders.nvalid)}/500 rows")

star = star_join(orders, [
    DimSpec(customers, "o_custkey", "custkey", ("age", "spend")),
    DimSpec(products, "o_prodkey", "prodkey", ("price", "rating")),
])
features = star.materialize()           # T = Σⱼ Iⱼ Bⱼ Mⱼ   (500 × 4)
print("star-join feature matrix:", features.shape)

# -- 3. Operator fusion (the paper's contribution) ---------------------------
model = LinearOperator(jnp.asarray(rng.normal(size=(4, 1)), jnp.float32))
decision = plan_fusion(model, fact_rows=500, dim_rows=[100, 40])
print(f"planner: fuse={decision.fuse} — {decision.reason}")

pre = prefuse(star, model)              # Bⱼ Mⱼ L pushed into the dims
fused = predict_fused(star, pre)        # online: 2 gathers + 1 add
nonfused = predict_nonfused(star, model)
np.testing.assert_allclose(np.asarray(fused), np.asarray(nonfused),
                           rtol=1e-5, atol=1e-5)
print("fused == non-fused ✓ ; online FLOPs per row:",
      f"fused={model.l * 2}, non-fused={4 * 2 + 4 * model.l * 2}")

# -- 4. Sharded serving: the partials across a device mesh -------------------
# Requests are per-arm foreign keys (not fact rows); compile_serving compiles
# the online phase alone.  With a mesh, each partial row-shards over the
# "model" axis (per-shard PK-index slices → device-local probes + gathers,
# one psum) and the request batch shards over "data"; partials under the
# byte threshold — forced to 0 here so the toy tables shard — replicate.
catalog, query = query_from_star(star, model=model)
mesh = make_serving_mesh((2, 4))        # 8 forced host devices
runtime = compile_serving(catalog, query, buckets=(8, 64),
                          mesh=mesh, shard_threshold_bytes=0)
reference = compile_serving(catalog, query, buckets=(8, 64))
requests = {"o_custkey": np.array([3, 7, 999, 42], np.int32),   # 999: miss
            "o_prodkey": np.array([0, 11, 5, 39], np.int32)}
sharded_preds = runtime.serve(requests)
np.testing.assert_array_equal(np.asarray(sharded_preds),
                              np.asarray(reference.serve(requests)))
print(f"sharded == single-device ✓ on mesh {dict(mesh.shape)}; "
      f"placement={[str(s) for s in runtime.plan.partition_specs]}; "
      f"{runtime.sharded.nbytes_per_device()}B of partials per device")
