"""Quickstart: LAQ + operator fusion in ~60 lines.

Builds a small star schema, runs a relational query through linear-algebra
operators, then fuses a linear model into the dimension tables (paper
Eq. 1) and shows fused == non-fused with far less online work.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.fusion import LinearOperator, plan_fusion, predict_fused, \
    predict_nonfused, prefuse
from repro.core.laq import DimSpec, Pred, Table, select, star_join

rng = np.random.default_rng(0)

# -- 1. Relations (a fact table + two dimension tables) ---------------------
customers = Table.from_columns("customers", {
    "custkey": np.arange(100),
    "age": rng.integers(18, 80, 100).astype(np.float32),
    "spend": rng.gamma(2.0, 50.0, 100).astype(np.float32),
}, key_cols=("custkey",))

products = Table.from_columns("products", {
    "prodkey": np.arange(40),
    "price": rng.gamma(2.0, 20.0, 40).astype(np.float32),
    "rating": rng.uniform(1, 5, 40).astype(np.float32),
}, key_cols=("prodkey",))

orders = Table.from_columns("orders", {
    "o_custkey": rng.integers(0, 100, 500),
    "o_prodkey": rng.integers(0, 40, 500),
    "quantity": rng.integers(1, 9, 500).astype(np.float32),
}, key_cols=("o_custkey", "o_prodkey"))

# -- 2. Relational ops as linear algebra ------------------------------------
big_orders = select(orders, [Pred("quantity", ">", 5.0)])
print(f"selection kept {int(big_orders.nvalid)}/500 rows")

star = star_join(orders, [
    DimSpec(customers, "o_custkey", "custkey", ("age", "spend")),
    DimSpec(products, "o_prodkey", "prodkey", ("price", "rating")),
])
features = star.materialize()           # T = Σⱼ Iⱼ Bⱼ Mⱼ   (500 × 4)
print("star-join feature matrix:", features.shape)

# -- 3. Operator fusion (the paper's contribution) ---------------------------
model = LinearOperator(jnp.asarray(rng.normal(size=(4, 1)), jnp.float32))
decision = plan_fusion(model, fact_rows=500, dim_rows=[100, 40])
print(f"planner: fuse={decision.fuse} — {decision.reason}")

pre = prefuse(star, model)              # Bⱼ Mⱼ L pushed into the dims
fused = predict_fused(star, pre)        # online: 2 gathers + 1 add
nonfused = predict_nonfused(star, model)
np.testing.assert_allclose(np.asarray(fused), np.asarray(nonfused),
                           rtol=1e-5, atol=1e-5)
print("fused == non-fused ✓ ; online FLOPs per row:",
      f"fused={model.l * 2}, non-fused={4 * 2 + 4 * model.l * 2}")
