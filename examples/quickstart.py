"""Quickstart: the Session query-builder API, end to end, in ~130 lines.

Builds a small star schema, then drives the paper's whole thesis — the
predictive pipeline σ ⋈ model γ as ONE linear-algebra program — through the
single fluent entry point, ``repro.core.query.Session``:

  1. declare the pipeline once (joins, predicates, model head, group-by,
     *several named aggregates*),
  2. ``.run()`` the whole-query aggregate program (sum/mean/count fused
     over shared join+model work, ``num_groups="auto"``),
  3. ``.rows()`` row predictions, fused == non-fused (paper Eq. 1),
  4. ``.serve()`` the bucketed dynamic-batch runtime — including sharded
     across a forced multi-device mesh, bit-identical to one device,
  5. append dimension rows through the versioned ``Catalog`` — every cached
     plan and serving runtime refreshes *in place* (delta prefuse, zero
     recompiles), bit-identical to a cold rebuild,
  6. run a *workload* at once with ``Session.run_all`` — the multi-query
     optimizer shares physical artifacts (PK indices, join pointers,
     prefused partials) across plans through the session's reference-
     counted ``ArtifactPool`` and stacks compatible plans into one vmapped
     program, so a refresh touches each shared artifact once,
  7. go out-of-core: stream the fact axis chunk-at-a-time under a memory
     budget (bit-identical to in-core), tombstone-*delete* fact rows with
     a zero-retrace refresh, and ``compact()`` the tombstones away,
  8. chain joins into *snowflake* dimensions — a ``.join`` whose FK lives
     on an already-joined table hangs a sub-dimension off that arm; the
     compiler collapses the chain offline, the planner explains its
     prefuse-vs-materialize choice, and sub-dimension appends refresh the
     collapsed chain in place (the subsystem is fuzzed nightly against a
     float64 numpy oracle — ``scripts/fuzz_repro.py``).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

# Force 8 host devices so the sharded-serving section below has a real mesh
# even on a laptop CPU.  Must happen before jax first initializes.
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import jax.numpy as jnp
import numpy as np

from repro.core.fusion import LinearOperator
from repro.core.laq import Table
from repro.core.query import PREDICTION, Catalog, Session
from repro.launch.mesh import make_serving_mesh

rng = np.random.default_rng(0)

# -- 1. Relations (a fact table + two dimension tables) ---------------------
# A Catalog is the mutable, *versioned* data surface: appends/updates bump
# per-table version counters and every cached plan refreshes incrementally.
# (A plain {name: Table} dict also works — it wraps read-only.)  The
# ``capacity=64`` over-allocation on products leaves padded rows for the
# appends in steps 6–8 to land in without changing any array shape.
catalog = Catalog({
    "customers": Table.from_columns("customers", {
        "custkey": np.arange(100),
        "age": rng.integers(18, 80, 100).astype(np.float32),
        "spend": rng.gamma(2.0, 50.0, 100).astype(np.float32),
    }, key_cols=("custkey",)),
    "products": Table.from_columns("products", {
        "prodkey": np.arange(40),
        "price": rng.gamma(2.0, 20.0, 40).astype(np.float32),
        "rating": rng.uniform(1, 5, 40).astype(np.float32),
        "category": rng.integers(0, 4, 40),
    }, key_cols=("prodkey", "category"), capacity=64),
    "orders": Table.from_columns("orders", {
        "o_custkey": rng.integers(0, 100, 500),
        "o_prodkey": rng.integers(0, 40, 500),
        "quantity": rng.integers(1, 9, 500).astype(np.float32),
    }, key_cols=("o_custkey", "o_prodkey")),
})

# -- 2. One fluent pipeline: σ ⋈ model γ -------------------------------------
model = LinearOperator(jnp.asarray(rng.normal(size=(4, 1)), jnp.float32))
sess = Session(catalog)
pipeline = (sess.query("orders")
            .join("customers", on=("o_custkey", "custkey"),
                  features=["age", "spend"])
            .join("products", on=("o_prodkey", "prodkey"),
                  features=["price", "rating"],
                  where=[("rating", ">", 1.5)])
            .where(("quantity", ">", 2.0))
            .predict(model)
            .group_by(("products", "category", 4), num_groups="auto")
            .agg(qty="sum(quantity)",          # several named aggregates,
                 score=("mean", PREDICTION),   # one compiled program
                 n="count",
                 q_max="max(quantity)"))
print("plan:", pipeline.explain())

# -- 3. .run(): the whole-query aggregate program ----------------------------
res = pipeline.run()
print(f"groups={np.asarray(res['groups'])} n={np.asarray(res['n'])}")
print(f"mean prediction per category: {np.asarray(res['score']).ravel()}")
# The Fig. 4 paper-faithful one-hot matmul backend computes the same thing.
ref = pipeline.run(agg_backend="matmul")
np.testing.assert_allclose(np.asarray(res["qty"]), np.asarray(ref["qty"]),
                           rtol=1e-6)
assert sess.num_plans == 2, "one plan per backend, cached by structure"
print("segment == matmul aggregation ✓")

# -- 4. .rows(): row predictions, fused == non-fused (paper Eq. 1) -----------
ids = np.array([0, 3, 17, 42], np.int32)
fused = pipeline.rows(ids)                       # prefused partials: gathers
nonfused = pipeline.rows(ids, backend="nonfused")  # materialize T, then L
np.testing.assert_allclose(np.asarray(fused), np.asarray(nonfused),
                           rtol=1e-5, atol=1e-5)
print("fused == non-fused row predictions ✓", np.asarray(fused).ravel())

# -- 5. .serve(): dynamic batches, sharded across a mesh ---------------------
# Requests are per-arm foreign keys (not fact rows).  A mesh-bound Session
# row-shards each prefused partial over the "model" axis (per-shard PK-index
# slices → device-local probes + gathers, one psum) and shards the request
# batch over "data"; the threshold is forced to 0 so the toy tables shard.
mesh_sess = Session(catalog, mesh=make_serving_mesh((2, 4)),
                    shard_threshold_bytes=0)
serving = mesh_sess.bind(pipeline.build()).serve(buckets=(8, 64))
reference = pipeline.serve(buckets=(8, 64))
requests = {"o_custkey": np.array([3, 7, 999, 42], np.int32),   # 999: miss
            "o_prodkey": np.array([0, 11, 5, 39], np.int32)}
np.testing.assert_array_equal(np.asarray(serving.serve(requests)),
                              np.asarray(reference.serve(requests)))
print(f"sharded == single-device ✓ on mesh {dict(serving.mesh.shape)}; "
      f"placement={[str(s) for s in serving.plan.partition_specs]}; "
      f"{serving.sharded.nbytes_per_device()}B of partials per device")

# -- 6. Appending dimension rows: incremental prefuse maintenance ------------
# New products arrive.  ``catalog.append`` is transactional: it bumps the
# table's version and logs the delta.  The appended rows fit products'
# padded capacity (64), so every derived artifact refreshes *in place* —
# PK index sorted-merge extend, Eq. 1 partials prefused for ONLY the 6 new
# rows, predicate masks scattered — and the already-compiled programs keep
# executing from the jit cache: zero recompiles, never a stale partial.
catalog.append("products", {
    "prodkey": np.arange(40, 46),
    "price": rng.gamma(2.0, 20.0, 6).astype(np.float32),
    "rating": rng.uniform(1, 5, 6).astype(np.float32),
    "category": rng.integers(0, 4, 6),
})
compiles_before = reference.num_compiles
print("refresh:", reference.refresh())           # explicit, on a runtime
requests = {"o_custkey": np.array([3, 7], np.int32),
            "o_prodkey": np.array([41, 45], np.int32)}   # the NEW keys
assert reference.num_compiles == compiles_before, "delta refresh retraced!"
assert np.any(np.asarray(reference.serve(requests)) != 0), "new keys live"

# Session caches are *version-keyed*: the next lookup of any cached plan or
# runtime sees the version bump and refreshes it before returning — a
# Session can never serve pre-append state.  Bit-exact vs a cold rebuild:
res2 = pipeline.run()                            # same plan object, refreshed
cold = Session(catalog).bind(pipeline.build()).run()
for key in ("qty", "score", "n", "q_max"):
    np.testing.assert_array_equal(np.asarray(res2[key]),
                                  np.asarray(cold[key]))
sharded2 = mesh_sess.bind(pipeline.build()).serve(buckets=(8, 64))
np.testing.assert_array_equal(np.asarray(sharded2.serve(requests)),
                              np.asarray(reference.serve(requests)))
print(f"append → refresh ≡ cold rebuild ✓ "
      f"(products now v{catalog.version('products')}, "
      f"{int(catalog['products'].nvalid)} rows; plans cached: "
      f"{sess.num_plans})")

# -- 7. serve(async_=True): the admission scheduler --------------------------
# Synchronous .serve() is a closed loop — right for batch scoring, wrong for
# many concurrent callers.  async_=True registers the same cached runtime on
# the session's AdmissionScheduler: submissions queue per plan, coalesce
# into bucket-shaped batches under a latency SLO, and one drain thread
# serves every registered plan.  Oversized analytical batches are admitted
# in top-bucket chunks on the "batch" lane, so interactive point lookups
# ride along in the same steps instead of queueing behind the scan — and
# everything stays bit-exact vs the synchronous path.
plan = sess.bind(pipeline.build()).serve(buckets=(8, 64), async_=True)
scan = {"o_custkey": rng.integers(0, 20, 200).astype(np.int32),   # 4 chunks
        "o_prodkey": rng.integers(0, 46, 200).astype(np.int32)}
lookup = {"o_custkey": np.array([3], np.int32),
          "o_prodkey": np.array([41], np.int32)}
f_scan = plan.submit(scan, lane="batch")         # Future, chunked admission
f_point = plan.submit(lookup)                    # interleaves with the scan
np.testing.assert_array_equal(np.asarray(f_point.result(30)),
                              np.asarray(reference.serve(lookup)))
np.testing.assert_array_equal(np.asarray(f_scan.result(30)),
                              np.asarray(reference.serve(scan)))
# Data refreshes fence first (drain-then-swap): in-flight requests finish on
# their generation before the swap — never a request spanning two versions.
catalog.append("products", {
    "prodkey": np.arange(46, 48), "price": np.float32([8.0, 9.0]),
    "rating": np.float32([4.5, 3.0]), "category": np.int64([1, 2])})
print("fenced refresh:", sess.scheduler().refresh())
st = plan.stats()
print(f"scheduled serving ✓ steps={st['steps']} "
      f"admitted={st['admitted_rows']} rows "
      f"(backpressure bound rejects with SchedulerBackpressureError; "
      f"tune via sess.scheduler(slo_ms=..., max_queued_rows=...))")
sess.scheduler().close()

# -- 8. Multi-query: shared artifacts + batched execution --------------------
# A Session is a *multi-query* optimizer.  Every plan it compiles acquires
# its physical artifacts — PK indices, factored join pointers, predicate
# masks, Eq. 1 prefused partials — from one reference-counted pool keyed by
# arm content, so a workload of N queries over the same star holds ONE copy
# of each distinct artifact, and a dimension append refreshes it ONCE, not
# once per plan.
variants = [pipeline] + [
    (sess.query("orders")
     .join("customers", on=("o_custkey", "custkey"),
           features=["age", "spend"])
     .join("products", on=("o_prodkey", "prodkey"),
           features=["price", "rating"],
           where=[("rating", ">", 1.5)])
     .where(("quantity", ">", float(thr)))       # only the predicate varies:
     .predict(model)                             # joins/partials are shared
     .group_by(("products", "category", 4), num_groups="auto")
     .agg(qty="sum(quantity)", score=("mean", PREDICTION), n="count",
          q_max="max(quantity)"))
    for thr in (1.0, 4.0, 6.0)]
results = sess.run_all(variants)                 # ONE stacked program: the
for r, b in zip(results, variants):              # four plans share a vmapped
    np.testing.assert_array_equal(               # dispatch, bit-exact vs the
        np.asarray(r["qty"]), np.asarray(b.run()["qty"]))  # per-plan path
stats = sess.pool.stats()
print(f"run_all over {len(variants)} variants ✓ pool: "
      f"{stats['entries']} shared artifacts "
      f"({stats['hits']} hits / {stats['misses']} misses, "
      f"{stats['bytes']}B resident, by kind {stats['by_kind']})")
# Structured explains, unified across the surface: str() is the legacy
# one-liner, .as_dict() the machine-readable form, and shared_artifacts
# names the pool keys this plan holds references to.
report = pipeline.explain()
print(f"explain: kind={report.kind} shares {len(report.shared_artifacts)} "
      f"pooled artifacts; trail={list(report.trail)[-1:]}")
# One more append: every plan above is stale, but the pool refreshes each
# distinct artifact exactly once — O(artifacts), not O(plans).
catalog.append("products", {
    "prodkey": np.arange(48, 50),
    "price": np.float32([5.0, 6.0]), "rating": np.float32([2.5, 4.0]),
    "category": np.int64([0, 3])})
updates_before = sess.pool.stats()["updates"]
sess.refresh()
print(f"append → {sess.pool.stats()['updates'] - updates_before} pooled "
      f"artifact updates for {sess.num_plans} cached plans ✓")
sess.evict()                                     # release pool references
assert sess.pool.stats()["entries"] == 0
print("evict → pool drained ✓")

# -- 9. Out-of-core: stream the fact axis, delete rows, compact --------------
# When facts outgrow device memory, a streaming Session folds the SAME
# fused program chunk-at-a-time through a carried segment accumulator —
# bit-identical to in-core, because the chunked fold replays exactly the
# same adds in the same order.  ``memory_budget_bytes`` sizes chunks
# automatically (and auto-streams any plan whose working set exceeds it);
# ``stream_chunk_rows`` pins the chunk size explicitly.
stream_sess = Session(catalog, stream_chunk_rows=128)
q9 = (stream_sess.query("orders")
      .join("customers", on=("o_custkey", "custkey"),
            features=["age", "spend"])
      .join("products", on=("o_prodkey", "prodkey"),
            features=["price", "rating"], where=[("rating", ">", 1.5)])
      .where(("quantity", ">", 2.0))
      .predict(model)
      .group_by(("products", "category", 4), num_groups="auto")
      .agg(qty="sum(quantity)", score=("mean", PREDICTION), n="count"))
plan9 = q9.compile()
# ``stream_chunk_rows=0`` turns streaming OFF for one compile (overrides
# win), pinned to the exact lowering the chunked fold replays:
incore9 = q9.compile(stream_chunk_rows=0, backend="fused",
                     join_backend="gather", agg_backend="segment")
for k, v in incore9.run().items():
    np.testing.assert_array_equal(np.asarray(plan9.run()[k]), np.asarray(v))
print("streamed == in-core bitwise ✓ |",
      plan9.explain().as_dict()["extras"]["stream"])

# Deleting fact rows is a tombstone fold: shapes, keys and row placement
# all survive, so every chunk revalidates through the SAME traced program —
# a delta refresh with zero retraces, exactly like the appends above.
traces0 = plan9._stream.traces
catalog.delete_rows("orders", np.arange(0, 500, 5))      # every 5th order
note9 = plan9.refresh()
assert plan9._stream.traces == traces0, "delete refresh retraced!"
cold9 = Session(catalog, stream_chunk_rows=128).compile(q9.build())
for k, v in cold9.run().items():
    np.testing.assert_array_equal(np.asarray(plan9.run()[k]), np.asarray(v))
print(f"delete → {note9} — 0 retraces, ≡ cold rebuild ✓")

# ``compact()`` garbage-collects tombstones once the dead fraction passes a
# threshold.  Row ids are rewritten, so this is the one lifecycle step that
# must recompile — and the refresh note names the reason.
catalog.delete_rows("orders", np.arange(250, 500))       # bulk churn
assert catalog.compact("orders")
note9 = plan9.refresh()
assert "compaction" in note9
print(f"compact → {note9}; "
      f"{int(np.asarray(catalog['orders'].valid_mask()).sum())} live rows ✓")

# -- 10. Snowflake chains: multi-hop dimensions ------------------------------
# Dimensions can have dimensions.  A chained ``.join`` whose FK lives on an
# already-joined table (or an explicit ``via=[...]``) hangs sub-dimensions
# off an arm, TPC-DS-style; the compiler collapses the chain offline into
# one head-granularity virtual dimension (factored joins compose
# associatively), prefuses it like any flat arm, and the planner explains
# its prefuse-through vs materialize-at-hop choice per chain.
snow = Catalog({
    "countries": Table.from_columns("countries", {
        "co_key": np.arange(4), "tax": np.float32([0., 1., 2., 1.]),
        "co_zone": np.int64([0, 1, 1, 2])},
        key_cols=("co_key", "co_zone"), capacity=8),
    "cities": Table.from_columns("cities", {
        "ci_key": np.arange(12), "ci_country": rng.integers(0, 4, 12),
        "density": rng.integers(1, 5, 12).astype(np.float32)},
        key_cols=("ci_key", "ci_country"), capacity=16),
    "stores": Table.from_columns("stores", {
        "st_key": np.arange(30), "st_city": rng.integers(0, 14, 30),
        "sqm": rng.integers(1, 9, 30).astype(np.float32)},
        key_cols=("st_key", "st_city"), capacity=40),
    "visits": Table.from_columns("visits", {
        "v_store": rng.integers(0, 32, 400),
        "basket": rng.integers(1, 20, 400).astype(np.float32)},
        key_cols=("v_store",)),
})
snow_sess = Session(snow)
chain_model = LinearOperator(jnp.asarray(rng.normal(size=(3, 1)),
                                         jnp.float32))
q10 = (snow_sess.query("visits")
       .join("stores", on=("v_store", "st_key"), features=["sqm"])
       .join("cities", on=("st_city", "ci_key"),       # FK is on stores →
             features=["density"])                     # chains, not a star
       .join("countries", on=("ci_country", "co_key"), # chains off cities
             features=["tax"], where=[("tax", "<=", 1.5)])
       .predict(chain_model)
       .group_by(("countries", "co_zone", 3), num_groups=3)  # 2 hops deep
       .agg(basket="sum(basket)", score=("mean", PREDICTION), n="count"))
assert len(q10.build().arms) == 1                      # one arm, two links
plan10 = q10.compile()
chain_note = [r for r in plan10.plan.reason.split("; ")
              if r.startswith("chain[")][0]
res10 = q10.run()
print(f"snowflake ✓ {chain_note}")
print(f"  per-zone baskets={np.asarray(res10['basket']).ravel()}")

# Sub-dimension appends refresh the collapsed chain in place — cached plans
# stay bit-identical to a cold rebuild, exactly like flat-arm appends.
snow.append("cities", {"ci_key": np.arange(12, 14),
                       "ci_country": np.int64([3, 0]),
                       "density": np.float32([2.0, 4.0])})
res10b = q10.run()                                     # refreshed in place
for k, v in Session(snow).compile(q10.build()).run().items():
    np.testing.assert_array_equal(np.asarray(res10b[k]), np.asarray(v))
print("sub-dimension append → chain refresh ≡ cold rebuild ✓")
# The whole subsystem is fuzzed nightly against a float64 numpy oracle:
# replay any reported case with `python scripts/fuzz_repro.py --seed N`.

# -- 11. Query/model co-optimization: the IR rewrite engine ------------------
# Because query and model are one algebraic program, optimization crosses
# the boundary between them.  Filter on a tree model's prediction with
# ``.predict(tree, where=[(leaf, "==", 1.0)])``: when the filter selects
# exactly one leaf, the rewrite engine distills that leaf's root-to-leaf
# path into ordinary dimension predicates and DROPS the model — the
# predict-then-filter query runs as a pure relational aggregate, and every
# data refresh skips the fact-sized tree GEMM.  All rewrites are exact:
# ``rewrite="off"`` (the escape hatch) must reproduce results bit-for-bit.
from repro.core.fusion.operators import tree_from_arrays

# Depth-2 stump over [sqm, density, tax]: leaf 3 ⟺ sqm > 4 ∧ sqm > 2.
big_tree = tree_from_arrays(np.array([0, 1, 0]),
                            np.array([4., 2., 2.], np.float32), 3)
q11 = (snow_sess.query("visits")
       .join("stores", on=("v_store", "st_key"), features=["sqm"])
       .join("cities", on=("st_city", "ci_key"), features=["density"])
       .join("countries", on=("ci_country", "co_key"), features=["tax"])
       .predict(big_tree, where=[(3, "==", 1.0)])   # big-store visits only
       .agg(basket="sum(basket)", n="count"))
plan11 = q11.compile()
trail = dict(plan11.explain().extras)["rewrites"]
assert any("distill" in t for t in trail)           # also in plan.reason
res11 = q11.run()
off11 = snow_sess.compile(q11.build(), rewrite="off")
np.testing.assert_array_equal(np.asarray(res11["basket"]),
                              np.asarray(off11.run()["basket"]))
print(f"rewrite ✓ {trail[0]}")
print(f"  big-store baskets={np.asarray(res11['basket']).ravel()} "
      f"over n={int(np.asarray(res11['n']).ravel()[0])} visits — no model "
      "online, bit-equal to rewrite='off'")
