"""Train a reduced-config LM for a few hundred steps on CPU.

Exercises the full training substrate: token pipeline → sharded train step
(AdamW, clipping, z-loss) → async checkpoints → resume.

Run:  PYTHONPATH=src python examples/train_lm.py [--arch xlstm-125m]
"""
import argparse
import shutil

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    ckpt = "/tmp/repro_example_ckpt"
    shutil.rmtree(ckpt, ignore_errors=True)
    losses = train(args.arch, smoke=True, steps=args.steps, batch=8,
                   seq=128, ckpt_dir=ckpt, ckpt_every=50)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")
    # Resume from checkpoint for a handful more steps (restart path).
    more = train(args.arch, smoke=True, steps=args.steps + 10, batch=8,
                 seq=128, ckpt_dir=ckpt, ckpt_every=0)
    print(f"resumed and ran {len(more)} more steps; final {more[-1]:.3f}")


if __name__ == "__main__":
    main()
