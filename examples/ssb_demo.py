"""SSB demo: run the Star Schema Benchmark queries through the LAQ engine.

Generates a CPU-scale SSB instance and executes all 13 queries, printing
result cardinalities and a few group-by outputs.

Run:  PYTHONPATH=src python examples/ssb_demo.py [--sf 2]
"""
import argparse
import time

import jax
import numpy as np

from repro.core.laq import PAD_GROUP, decode_composite
from repro.data import QUERIES, generate_ssb


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=1)
    ap.add_argument("--scale", type=float, default=0.003)
    args = ap.parse_args()

    data = generate_ssb(sf=args.sf, scale=args.scale, seed=0)
    print(f"SSB sf={args.sf} (scaled ×{args.scale}): "
          f"lineorder={int(data.lineorder.nvalid)} rows")

    for name, q in QUERIES.items():
        fn = jax.jit(lambda d=data, qq=q: qq(d))
        fn()  # compile
        t0 = time.perf_counter()
        res = jax.block_until_ready(fn())
        dt = (time.perf_counter() - t0) * 1e3
        key = next(k for k in ("revenue", "profit", "prediction")
                   if k in res)
        vals = np.asarray(res[key])
        if "groups" not in res:
            print(f"{name}: rows={int(res['rows']):7d} "
                  f"{key}_total={float(vals.sum()):.2f}  ({dt:.1f} ms)")
        else:
            groups = np.asarray(res["groups"])
            live = groups != PAD_GROUP
            print(f"{name}: rows={int(res['rows']):7d} "
                  f"groups={int(live.sum()):5d} "
                  f"{key}_total={vals.sum():.2f}  ({dt:.1f} ms)")
    # Show a decoded group-by result (Q2.1 = year × brand).
    res = QUERIES["Q2.1"](data)
    groups = np.asarray(res["groups"])
    rev = np.asarray(res["revenue"])
    live = groups != PAD_GROUP
    year, brand = decode_composite(groups[live][:5], [8, 1000])
    print("Q2.1 head: year", np.asarray(year) + 1992, "brand",
          np.asarray(brand), "revenue", rev[live][:5].round(1))


if __name__ == "__main__":
    main()
