"""End-to-end serving driver (the paper's kind of system, as deployed).

Batched requests → pre-fused star pipeline (paper Eq. 1) for per-request
features → LM decode conditioned on those features, with KV caches.
Reports latency percentiles fused vs non-fused and verifies the outputs
are identical (fusion is exact).

Run:  PYTHONPATH=src python examples/fused_serving.py
"""
from repro.launch.serve import run_serving

if __name__ == "__main__":
    run_serving(arch="smollm-360m", batch=4, decode_steps=8, k=96, l=8,
                repeats=10)
