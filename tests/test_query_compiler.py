"""Predictive-query compiler vs brute-force oracles + planner boundaries.

Every registered SSB query (the 13 relational ones and the predict-then-
aggregate P* variants) is compiled fused and checked against:
  * the pure-numpy ``np_predictive_query`` oracle,
  * the paper-faithful reference backends (non-fused / one-hot matmul), and
tree-head queries must match the non-fused path *bitwise* (the GEMM tree is
exact integer arithmetic in f32 — paper Eq. 3).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.fusion import DecisionTreeGEMM, LinearOperator, plan_fusion
from repro.core.laq import PAD_GROUP
from repro.core.query import (compile_query, plan_aggregation,
                              plan_query)
from repro.data import (QUERY_IR, generate_ssb, predictive_query_names,
                        ssb_catalog)
from helpers_relational import np_predictive_query

SSB_NAMES = [n for n in QUERY_IR if n.startswith("Q")]
PRED_NAMES = predictive_query_names()


@pytest.fixture(scope="module")
def data():
    return generate_ssb(sf=1, scale=0.0005, seed=5)


@pytest.fixture(scope="module")
def catalog(data):
    return ssb_catalog(data)


def _engine_maps(res, names):
    """{group code: aggregate row} per aggregate name (live groups only)."""
    out = {}
    if "groups" in res:
        groups = np.asarray(res["groups"])
        live = groups != PAD_GROUP
        for name in names:
            vals = np.asarray(res[name])
            v2 = vals if vals.ndim > 1 else vals[:, None]
            out[name] = {int(g): v2[i]
                         for i, g in enumerate(groups) if live[i]}
    return out


def _assert_matches_oracle(compiled, q, catalog):
    res = compiled.run()
    want = np_predictive_query(q, catalog)
    assert int(res["rows"]) == want["rows"]
    names = [a.name for a in q.aggregates]
    if want["groups"] is None:
        for a in q.aggregates:
            got = np.atleast_1d(np.asarray(res[a.name]))
            tol = 1e-6 * max(want["abs_scale"][a.name], 1.0)
            np.testing.assert_allclose(got, np.atleast_1d(want["scalars"][
                a.name]), rtol=1e-4, atol=tol)
        return
    got_maps = _engine_maps(res, names)
    for a in q.aggregates:
        got = got_maps[a.name]
        want_g = {c: v[a.name] for c, v in want["groups"].items()}
        # Engine emits a group for every surviving row; zero-valued groups
        # may legitimately exist on both sides.
        assert set(got) == set(want_g), a.name
        tol = 1e-6 * max(want["abs_scale"][a.name], 1.0)
        for c, v in want_g.items():
            np.testing.assert_allclose(got[c], v, rtol=1e-4, atol=tol,
                                       err_msg=f"{a.name} group {c}")


# ----------------------------------------------------- engine vs numpy oracle
@pytest.mark.parametrize("name", SSB_NAMES)
def test_ssb_query_fused_matches_oracle(name, data, catalog):
    q = QUERY_IR[name]()
    _assert_matches_oracle(compile_query(catalog, q), q, catalog)


@pytest.mark.parametrize("name", PRED_NAMES)
def test_predictive_query_fused_matches_oracle(name, data, catalog):
    q = QUERY_IR[name]()
    compiled = compile_query(catalog, q, backend="fused")
    assert compiled.backend == "fused"
    _assert_matches_oracle(compiled, q, catalog)


# ------------------------------------------- fused vs reference backends
@pytest.mark.parametrize("name", SSB_NAMES)
def test_ssb_query_agg_backends_agree(name, data, catalog):
    q = QUERY_IR[name]()
    auto = compile_query(catalog, q).run()
    matmul = compile_query(catalog, q, agg_backend="matmul").run()
    for a in q.aggregates:
        np.testing.assert_allclose(np.asarray(auto[a.name]),
                                   np.asarray(matmul[a.name]),
                                   rtol=1e-5, atol=1e-2)


@pytest.mark.parametrize("name", PRED_NAMES)
def test_predictive_fused_equals_nonfused(name, data, catalog):
    q = QUERY_IR[name]()
    fused = compile_query(catalog, q, backend="fused")
    non = compile_query(catalog, q, backend="nonfused")
    assert non.prefused is None
    a = np.asarray(fused.predictions())
    b = np.asarray(non.predictions())
    if isinstance(q.model, DecisionTreeGEMM):
        # Eq. 3 is exact small-integer arithmetic in f32: bitwise equal.
        np.testing.assert_array_equal(a, b)
    else:
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_tree_query_matmul_join_backend_bitmatches(data, catalog):
    q = QUERY_IR["P4.tree.select.region"]()
    gather = compile_query(catalog, q, backend="fused",
                           join_backend="gather")
    matmul = compile_query(catalog, q, backend="fused",
                           join_backend="matmul")
    np.testing.assert_array_equal(np.asarray(gather.predictions()),
                                  np.asarray(matmul.predictions()))


# --------------------------------------------------------- batched serving
def test_predict_rows_matches_full_predictions(data, catalog):
    q = QUERY_IR["P1.linear.year"]()
    for backend in ("fused", "nonfused"):
        compiled = compile_query(catalog, q, backend=backend)
        ids = jnp.asarray([0, 1, 5, 17, 100, 2999], jnp.int32)
        got = np.asarray(compiled.predict_rows(ids))
        want = np.asarray(compiled.predictions())[np.asarray(ids)]
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_select_capacity_compaction_equivalent(data, catalog):
    """mask_select pre-compaction (§2.2) preserves query results."""
    for name in ("Q1.2", "P2.linear.select.scalar"):
        q = QUERY_IR[name]()
        base = compile_query(catalog, q).run()
        comp = compile_query(catalog, q, select_capacity=1024).run()
        assert int(base["rows"]) == int(comp["rows"]), name
        for a in q.aggregates:
            np.testing.assert_allclose(np.asarray(base[a.name]),
                                       np.asarray(comp[a.name]),
                                       rtol=1e-5, atol=1e-3, err_msg=name)


def test_compile_query_traceable_under_outer_jit(data, catalog):
    """Whole-pipeline tracing (joins + codes + reduction in one program)."""
    import jax
    q = QUERY_IR["Q1.1"]()
    traced = jax.jit(lambda: compile_query(catalog, q).run()["revenue"])()
    eager = compile_query(catalog, q).run()["revenue"]
    np.testing.assert_allclose(np.asarray(traced), np.asarray(eager),
                               rtol=1e-6)


def test_compiled_plan_cache_respects_kwargs(data):
    """Different compile options must not hit the same cache entry."""
    from repro.data import compiled_plan
    a = compiled_plan("Q2.1", data)
    b = compiled_plan("Q2.1", data, agg_backend="matmul")
    assert a.agg_backend == "segment"
    assert b.agg_backend == "matmul"
    assert a is not b
    assert compiled_plan("Q2.1", data) is a


def test_plan_cache_not_poisoned_by_outer_trace(data):
    """A plan compiled under an outer jit must not be cached: the later
    eager call would hit its leaked tracers (UnexpectedTracerError)."""
    import jax
    from repro.data import QUERIES
    traced = jax.jit(lambda: QUERIES["Q1.3"](data)["revenue"])()
    eager = QUERIES["Q1.3"](data)["revenue"]   # must not raise
    np.testing.assert_allclose(np.asarray(traced), np.asarray(eager),
                               rtol=1e-6)


def test_no_model_query_raises_on_predictions(data, catalog):
    compiled = compile_query(catalog, QUERY_IR["Q1.1"]())
    with pytest.raises(ValueError):
        compiled.predictions()
    with pytest.raises(ValueError):
        compiled.predict_rows(jnp.arange(4))


def test_groupby_overflow_raises_instead_of_truncating(data, catalog):
    """ROADMAP "Group-overflow detection": more distinct live group codes
    than ``num_groups`` used to silently collapse the overflow groups into
    unique()'s padded tail, dropping them from every aggregate.  The
    offline concrete-array resolution now counts and raises."""
    import dataclasses

    from repro.core.laq import groupby_codes

    codes = jnp.asarray(np.array([1, 2, 3, 4, 5, PAD_GROUP], np.int32))
    with pytest.raises(ValueError, match="group-by overflow"):
        groupby_codes(codes, num_groups=3)
    # Exactly num_groups live codes is fine (PAD_GROUP rows don't count).
    uniq, gid = groupby_codes(codes, num_groups=5)
    assert list(np.asarray(uniq)) == [1, 2, 3, 4, 5]
    assert int(np.asarray(gid)[-1]) == 5  # padded row → overflow segment
    # End to end: a grouped query sized below its measured group count must
    # refuse to compile rather than return silently wrong aggregates.
    q = QUERY_IR["P1.linear.year"]()
    assert q.group_keys
    with pytest.raises(ValueError, match="group-by overflow"):
        compile_query(catalog, dataclasses.replace(q, num_groups=1))


# --------------------------------------------------------- planner boundaries
def _toy_model(k=6, l=4):
    rng = np.random.default_rng(0)
    return LinearOperator(jnp.asarray(rng.normal(size=(k, l)), jnp.float32))


def test_plan_fusion_memory_budget_exceeded():
    d = plan_fusion(_toy_model(), 10_000, [100, 100, 100],
                    memory_budget_bytes=1)
    assert not d.fuse
    assert "budget" in d.reason
    assert d.prefused_bytes > 1


def test_plan_fusion_amortization_below_one():
    d = plan_fusion(_toy_model(), 64, [4096, 4096],
                    batches_per_update=1e-6)
    assert not d.fuse
    assert d.amortized_speedup <= 1.0
    assert "not amortized" in d.reason


def test_plan_fusion_selectivity_can_flip_decision():
    # High-update regime (paper §4.3 Q6/Q8: dims updated faster than one
    # batch): a selective query leaves too little online work to amortize
    # pre-fusion, while the same query unselected still fuses.
    model = _toy_model(k=64, l=2)
    kw = dict(batches_per_update=0.01)
    hi = plan_fusion(model, 100_000, [1000], selectivity=1.0, **kw)
    lo = plan_fusion(model, 100_000, [1000], selectivity=0.001, **kw)
    assert hi.fuse
    assert not lo.fuse
    assert lo.amortized_speedup < hi.amortized_speedup


def test_plan_aggregation_backend_crossover():
    small = plan_aggregation(100_000, num_groups=4, out_width=4)
    large = plan_aggregation(100_000, num_groups=8192, out_width=1)
    assert small.backend == "matmul"
    assert large.backend == "segment"
    assert large.matmul_flops > large.segment_flops


def test_plan_query_join_backend_by_size():
    tiny = plan_query(None, 64, [16, 16])
    big = plan_query(None, 1_000_000, [10_000])
    assert tiny.join_backend == "matmul"
    assert big.join_backend == "gather"
    assert tiny.fusion is None and tiny.agg is None


def test_compile_respects_memory_budget(data, catalog):
    q = QUERY_IR["P1.linear.year"]()
    compiled = compile_query(catalog, q, memory_budget_bytes=1)
    assert compiled.backend == "nonfused"
    assert compiled.prefused is None
    _assert_matches_oracle(compiled, q, catalog)
