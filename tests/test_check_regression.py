"""The bench-regression gate must demonstrably fire on a 10x slowdown.

Drives ``benchmarks.check_regression`` both through its pure ``compare``
function and through ``main`` on real JSON files (the CI invocation path),
including the injected-10x-slowdown acceptance case, the normalize mode,
the min-us noise floor, and the vacuous-pass guard.
"""

import json

from benchmarks.check_regression import compare, load_rows, main


def _write_bench(path, rows):
    payload = {"backend": "cpu",
               "rows": [{"name": n, "us_per_call": us, "derived": ""}
                        for n, us in rows.items()]}
    path.write_text(json.dumps(payload))


BASE = {"serving/fused/n8": 500.0, "serving/fused/n64": 900.0,
        "serving/nonfused/n8": 800.0, "query/Q1.1": 1200.0}


def test_gate_fires_on_injected_10x_slowdown(tmp_path):
    cur = dict(BASE)
    cur["serving/fused/n8"] = BASE["serving/fused/n8"] * 10.0
    regressions, compared, _ = compare(cur, BASE, tolerance=1.5)
    assert compared == len(BASE)
    assert len(regressions) == 1 and "serving/fused/n8" in regressions[0]
    # Through the CLI (the CI invocation): exit code 1.
    base_dir = tmp_path / "baselines"
    base_dir.mkdir()
    _write_bench(base_dir / "BENCH_serving.json", BASE)
    _write_bench(tmp_path / "BENCH_serving.json", cur)
    rc = main([str(tmp_path / "BENCH_serving.json"),
               "--baseline-dir", str(base_dir), "--tolerance", "1.5"])
    assert rc == 1


def test_gate_fires_on_10x_even_normalized(tmp_path):
    """--normalize absorbs machine speed, not a single bench regressing."""
    cur = {n: us * 1.3 for n, us in BASE.items()}   # uniformly slower runner
    cur["query/Q1.1"] = BASE["query/Q1.1"] * 10.0   # plus one real regression
    regressions, _, _ = compare(cur, BASE, tolerance=1.5, normalize=True)
    assert len(regressions) == 1 and "query/Q1.1" in regressions[0]
    # The same uniformly-slower run without the injection passes normalized
    # (and would fail the absolute gate, by design).
    uniform = {n: us * 1.3 for n, us in BASE.items()}
    assert compare(uniform, BASE, tolerance=1.5, normalize=True)[0] == []
    assert compare(uniform, BASE, tolerance=1.2, normalize=False)[0] != []


def test_within_tolerance_passes(tmp_path):
    cur = {n: us * 1.4 for n, us in BASE.items()}
    regressions, compared, _ = compare(cur, BASE, tolerance=1.5)
    assert regressions == [] and compared == len(BASE)
    base_dir = tmp_path / "baselines"
    base_dir.mkdir()
    _write_bench(base_dir / "BENCH_serving.json", BASE)
    _write_bench(tmp_path / "BENCH_serving.json", cur)
    assert main([str(tmp_path / "BENCH_serving.json"),
                 "--baseline-dir", str(base_dir)]) == 0


def test_min_us_floor_skips_noise_rows():
    base = {"tiny": 40.0, "real": 5000.0}
    cur = {"tiny": 400.0, "real": 5100.0}           # 10x on a 40us row
    regressions, compared, _ = compare(cur, base, tolerance=1.5, min_us=500.0)
    assert regressions == [] and compared == 1
    # The floor only protects rows small on *both* sides.
    regressions, _, _ = compare({"real": 50000.0, "tiny": 40.0}, base,
                                tolerance=1.5, min_us=500.0)
    assert len(regressions) == 1


def test_normalize_scale_ignores_sub_floor_noise_rows():
    """Noise rows must not set the scale the real rows are judged by."""
    base = {"tiny/a": 40.0, "tiny/b": 50.0, "tiny/c": 45.0,
            "real/a": 5000.0, "real/b": 6000.0, "real/c": 7000.0,
            "real/d": 8000.0}
    cur = dict(base)
    for t in ("tiny/a", "tiny/b", "tiny/c"):
        cur[t] = base[t] * 3.0                  # 3x scheduler jitter
    cur["real/d"] = base["real/d"] * 4.0        # one genuine 4x regression
    regressions, compared, _ = compare(cur, base, tolerance=1.5,
                                       min_us=500.0, normalize=True)
    # Were the 3x noise rows allowed into the median, the scale would be 3
    # and the 4x regression would normalize to 1.33x — under tolerance.
    assert compared == 4
    assert len(regressions) == 1 and "real/d" in regressions[0]


def test_normalize_never_amplifies_on_a_faster_machine():
    """A run globally *faster* than baseline must not turn mild raw ratios
    into failures: the scale clamps at 1.0 (sub-1 medians would divide a
    1.2x-raw row up to 1.6x 'normalized')."""
    cur = {n: us * 0.7 for n, us in BASE.items()}   # uniformly faster runner
    cur["query/Q1.1"] = BASE["query/Q1.1"] * 1.3    # mild, within tolerance
    regressions, _, _ = compare(cur, BASE, tolerance=1.5, normalize=True)
    assert regressions == []
    # A genuine relative regression still fires through its raw ratio.
    cur["query/Q1.1"] = BASE["query/Q1.1"] * 2.0
    regressions, _, _ = compare(cur, BASE, tolerance=1.5, normalize=True)
    assert len(regressions) == 1 and "query/Q1.1" in regressions[0]


def test_normalize_degenerate_row_count_falls_back_to_absolute():
    """A single gated row must not normalize away its own regression."""
    base = {"tiny": 40.0, "real": 5000.0}
    cur = {"tiny": 40.0, "real": 10000.0}
    regressions, compared, notes = compare(cur, base, tolerance=1.5,
                                           min_us=500.0, normalize=True)
    assert compared == 1
    assert len(regressions) == 1 and "real" in regressions[0]
    assert any("too few" in n for n in notes)


def test_new_and_missing_rows_are_notes_not_failures():
    cur = {"brand/new": 100.0, "query/Q1.1": 1200.0}
    regressions, compared, notes = compare(cur, BASE, tolerance=1.5)
    assert regressions == [] and compared == 1
    assert any("new row" in n for n in notes)
    assert any("missing" in n for n in notes)


def test_vacuous_pass_refused(tmp_path):
    base_dir = tmp_path / "baselines"
    base_dir.mkdir()
    _write_bench(base_dir / "BENCH_serving.json", {"renamed/away": 1.0})
    _write_bench(tmp_path / "BENCH_serving.json", {"other/name": 1.0})
    assert main([str(tmp_path / "BENCH_serving.json"),
                 "--baseline-dir", str(base_dir)]) == 1


def test_missing_baseline_fails_and_update_seeds(tmp_path):
    _write_bench(tmp_path / "BENCH_new.json", BASE)
    base_dir = tmp_path / "baselines"
    rc = main([str(tmp_path / "BENCH_new.json"),
               "--baseline-dir", str(base_dir)])
    assert rc == 1
    assert main([str(tmp_path / "BENCH_new.json"),
                 "--baseline-dir", str(base_dir), "--update"]) == 0
    assert load_rows(str(base_dir / "BENCH_new.json")) == BASE
    assert main([str(tmp_path / "BENCH_new.json"),
                 "--baseline-dir", str(base_dir)]) == 0
