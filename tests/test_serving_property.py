"""Property test: ``compile_serving`` on random request batches is
equivalent to ``CompiledQuery.predict_rows`` on the corresponding fact rows,
across fused/nonfused × gather/kernel backends and ragged batch sizes that
hit every padding bucket (including chunked oversize batches).
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (requirements-dev)",
)
from hypothesis import given, settings, strategies as st

from repro.core.query import compile_query, compile_serving, requests_from_rows
from repro.data import QUERY_IR, generate_ssb, predictive_query_names, ssb_catalog

BUCKETS = (4, 16, 64)
BACKENDS = [
    ("fused", "jnp"),
    ("fused", "pallas"),
    ("nonfused", "jnp"),
    ("nonfused", "pallas"),
]

_data = None
_catalog = None
_cache = {}


def _setup():
    global _data, _catalog
    if _catalog is None:
        _data = generate_ssb(sf=1, scale=0.0005, seed=5)
        _catalog = ssb_catalog(_data)
    return _catalog


def _pair(name, backend, serve_backend):
    key = (name, backend, serve_backend)
    if key not in _cache:
        catalog = _setup()
        q = QUERY_IR[name]()
        compiled = compile_query(catalog, q, backend=backend)
        runtime = compile_serving(
            catalog,
            q,
            backend=backend,
            serve_backend=serve_backend,
            buckets=BUCKETS,
            interpret=serve_backend == "pallas",
        )
        fact = catalog[q.fact]
        ok = np.asarray(fact.valid_mask())
        for p in q.fact_preds:
            ok = ok & np.asarray(p.mask(fact))
        _cache[key] = (q, compiled, runtime, np.nonzero(ok)[0])
    return _cache[key]


@pytest.mark.parametrize("name", predictive_query_names())
@settings(max_examples=12, deadline=None)
@given(
    combo=st.sampled_from(BACKENDS),
    seed=st.integers(0, 2**31 - 2),
    size=st.integers(1, 80),
)
def test_serving_equivalent_to_predict_rows(name, combo, seed, size):
    backend, serve_backend = combo
    q, compiled, runtime, passing = _pair(name, backend, serve_backend)
    rng = np.random.default_rng(seed)
    ids = rng.choice(passing, size=size)
    catalog = _setup()
    got = np.asarray(runtime.serve(requests_from_rows(catalog[q.fact], q, ids)))
    want = np.asarray(compiled.predict_rows(jnp.asarray(ids, jnp.int32)))
    np.testing.assert_array_equal(got, want)
    # Bucketing never leaks padding and never recompiles past the bucket set.
    assert got.shape == (size, runtime.out_width)
    assert runtime.num_compiles <= len(BUCKETS)
