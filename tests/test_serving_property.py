"""Property test: ``compile_serving`` on random request batches is
equivalent to ``CompiledQuery.predict_rows`` on the corresponding fact rows,
across fused/nonfused × gather/kernel backends and ragged batch sizes that
hit every padding bucket (including chunked oversize batches).
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (requirements-dev)",
)
from hypothesis import given, settings, strategies as st

from repro.core.query import compile_query, compile_serving, requests_from_rows
from repro.data import QUERY_IR, generate_ssb, predictive_query_names, ssb_catalog

BUCKETS = (4, 16, 64)
BACKENDS = [
    ("fused", "jnp"),
    ("fused", "pallas"),
    ("nonfused", "jnp"),
    ("nonfused", "pallas"),
]

_data = None
_catalog = None
_cache = {}


def _setup():
    global _data, _catalog
    if _catalog is None:
        _data = generate_ssb(sf=1, scale=0.0005, seed=5)
        _catalog = ssb_catalog(_data)
    return _catalog


def _pair(name, backend, serve_backend):
    key = (name, backend, serve_backend)
    if key not in _cache:
        catalog = _setup()
        q = QUERY_IR[name]()
        compiled = compile_query(catalog, q, backend=backend)
        runtime = compile_serving(
            catalog,
            q,
            backend=backend,
            serve_backend=serve_backend,
            buckets=BUCKETS,
            interpret=serve_backend == "pallas",
        )
        fact = catalog[q.fact]
        ok = np.asarray(fact.valid_mask())
        for p in q.fact_preds:
            ok = ok & np.asarray(p.mask(fact))
        _cache[key] = (q, compiled, runtime, np.nonzero(ok)[0])
    return _cache[key]


@pytest.mark.parametrize("name", predictive_query_names())
@settings(max_examples=12, deadline=None)
@given(
    combo=st.sampled_from(BACKENDS),
    seed=st.integers(0, 2**31 - 2),
    size=st.integers(1, 80),
)
def test_serving_equivalent_to_predict_rows(name, combo, seed, size):
    backend, serve_backend = combo
    q, compiled, runtime, passing = _pair(name, backend, serve_backend)
    rng = np.random.default_rng(seed)
    ids = rng.choice(passing, size=size)
    catalog = _setup()
    got = np.asarray(runtime.serve(requests_from_rows(catalog[q.fact], q, ids)))
    want = np.asarray(compiled.predict_rows(jnp.asarray(ids, jnp.int32)))
    np.testing.assert_array_equal(got, want)
    # Bucketing never leaks padding and never recompiles past the bucket set.
    assert got.shape == (size, runtime.out_width)
    assert runtime.num_compiles <= len(BUCKETS)


# ------------------------------------------------- request normalization
def _norm_runtime():
    _, _, runtime, passing = _pair(predictive_query_names()[0],
                                   "fused", "jnp")
    return runtime, passing


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 2), size=st.integers(0, 40))
def test_three_request_forms_are_equivalent(seed, size):
    """Mapping / per-arm sequence / stacked array normalize identically —
    including the zero-row path, which returns an empty (0, out_width)."""
    runtime, _ = _norm_runtime()
    rng = np.random.default_rng(seed)
    cols = [rng.integers(0, 1000, size).astype(np.int32)
            for _ in runtime.request_keys]
    as_mapping = dict(zip(runtime.request_keys, cols))
    as_seq = [c.copy() for c in cols]
    as_stack = np.stack(cols, axis=0)     # arm-major (num_arms, n)
    outs = [np.asarray(runtime.serve(r))
            for r in (as_mapping, as_seq, as_stack)]
    assert outs[0].shape == (size, runtime.out_width)
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 2), size=st.integers(1, 20),
       arm=st.integers(0, 10), delta=st.integers(1, 5))
def test_ragged_and_missing_columns_are_named_errors(seed, size, arm, delta):
    runtime, _ = _norm_runtime()
    rng = np.random.default_rng(seed)
    keys = runtime.request_keys
    cols = {k: rng.integers(0, 1000, size).astype(np.int32) for k in keys}
    bad_key = keys[arm % len(keys)]
    ragged = dict(cols)
    ragged[bad_key] = rng.integers(0, 1000, size + delta).astype(np.int32)
    with pytest.raises(ValueError, match="ragged"):
        runtime.serve(ragged)
    missing = {k: v for k, v in cols.items() if k != bad_key}
    if missing != cols:
        with pytest.raises(KeyError, match=bad_key):
            runtime.serve(missing)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 2), size=st.integers(1, 20),
       pos=st.integers(0, 400))
def test_sentinel_valued_keys_are_rejected(seed, size, pos):
    """PAD_KEY-valued request keys are indistinguishable from padding and
    used to score silently as zero; now a named error."""
    from repro.core.laq import PAD_KEY
    from repro.core.query import SentinelKeyError
    runtime, _ = _norm_runtime()
    rng = np.random.default_rng(seed)
    cols = {k: rng.integers(0, 1000, size).astype(np.int32)
            for k in runtime.request_keys}
    k = runtime.request_keys[pos % len(runtime.request_keys)]
    cols[k][pos % size] = PAD_KEY
    with pytest.raises(SentinelKeyError, match="padding sentinel"):
        runtime.serve(cols)
