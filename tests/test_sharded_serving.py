"""Sharded prefused partials vs the single-device serving runtime.

The contract under test (ISSUE 3 acceptance):
  * on a forced multi-device host (CI: ``XLA_FLAGS=
    --xla_force_host_platform_device_count=8``), sharded ``compile_serving``
    output is bit-exact vs the single-device jnp reference for every
    PREDICTIVE_QUERIES entry, every bucket size, and mesh shapes (1,8),
    (2,4), (8,1),
  * no recompilation across ragged batches (trace/cache counts, same as
    test_serving.py),
  * placement: partials below the byte threshold replicate, larger ones
    row-shard, and non-divisible row counts fall back to replication via
    ``safe_spec`` (the 15-heads-on-16-way rule, applied to partials),
  * ``CompiledQuery.predict_rows`` with a mesh matches the unsharded path.

The single-device mesh tests always run, so tier-1 exercises the shard_map
program on every platform; the multi-device matrix needs 8 host devices and
skips elsewhere (the CI ``multi-device`` job provides them).
"""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.laq import shard_pk_index, shard_rows
from repro.core.query import (
    compile_query,
    compile_serving,
    plan_partition_spec,
    plan_query,
    requests_from_rows,
)
from repro.data import QUERY_IR, generate_ssb, predictive_query_names, ssb_catalog
from repro.launch.mesh import make_serving_mesh
from repro.launch.sharding import param_pspec, safe_spec

PRED_NAMES = predictive_query_names()
BUCKETS = (8, 32)
MESH_SHAPES = [(1, 8), (2, 4), (8, 1)]
# Sizes covering every bucket (exact + padded) plus the chunked oversize path.
BATCH_SIZES = (3, 8, 20, 32, 70)

needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


@pytest.fixture(scope="module")
def data():
    return generate_ssb(sf=1, scale=0.0005, seed=5)


@pytest.fixture(scope="module")
def catalog(data):
    return ssb_catalog(data)


@pytest.fixture(scope="module")
def plans():
    """Per-module cache: compiled plans/runtimes are reused across tests."""
    return {}


def _runtime(plans, catalog, name, **kwargs):
    kwargs.setdefault("buckets", BUCKETS)
    mesh = kwargs.pop("mesh", None)
    mesh_key = None if mesh is None else tuple(mesh.devices.shape)
    key = ("serve", name, mesh_key, tuple(sorted(kwargs.items())))
    if key not in plans:
        plans[key] = compile_serving(catalog, QUERY_IR[name](), mesh=mesh,
                                     **kwargs)
    return plans[key]


def _random_requests(q, catalog, n, rng):
    """Live dimension keys mixed with guaranteed misses (as test_serving)."""
    reqs = {}
    for arm in q.arms:
        dim = catalog[arm.table]
        live = np.asarray(dim.key(arm.pk_col))[: int(dim.nvalid)]
        keys = rng.choice(live, size=n)
        miss = rng.random(n) < 0.25
        keys = np.where(miss, rng.integers(-3, 0, size=n), keys)
        reqs[arm.fk_col] = keys.astype(np.int32)
    return reqs


# --------------------------------------------- single-device mesh (tier-1)
@pytest.mark.parametrize("backend", ["fused", "nonfused"])
def test_sharded_serving_single_device_mesh(backend, catalog, plans):
    """The shard_map program is exercised even on one device."""
    name = PRED_NAMES[0]
    q = QUERY_IR[name]()
    mesh = make_serving_mesh((1, 1))
    ref = _runtime(plans, catalog, name, backend=backend)
    sh = _runtime(plans, catalog, name, backend=backend, mesh=mesh,
                  shard_threshold_bytes=0)
    assert sh.mesh is mesh
    assert sh.sharded is not None and sh.sharded.num_sharded > 0
    rng = np.random.default_rng(3)
    for n in BATCH_SIZES:
        reqs = _random_requests(q, catalog, n, rng)
        np.testing.assert_array_equal(
            np.asarray(sh.serve(reqs)), np.asarray(ref.serve(reqs)))


def test_sharded_serving_rejects_pallas(catalog):
    q = QUERY_IR[PRED_NAMES[0]]()
    mesh = make_serving_mesh((1, 1))
    with pytest.raises(ValueError, match="pallas"):
        compile_serving(catalog, q, mesh=mesh, serve_backend="pallas")
    with pytest.raises(ValueError, match="pallas"):
        compile_query(catalog, q, mesh=mesh, serve_backend="pallas")


# ------------------------------------------------- multi-device bit-exact
@needs_8_devices
@pytest.mark.parametrize("shape", MESH_SHAPES, ids=lambda s: f"{s[0]}x{s[1]}")
@pytest.mark.parametrize("backend", ["fused", "nonfused"])
@pytest.mark.parametrize("name", PRED_NAMES)
def test_sharded_matches_single_device(name, backend, shape, catalog, plans):
    """Sharded serving ≡ single-device jnp reference, bitwise in fp32."""
    q = QUERY_IR[name]()
    mesh = make_serving_mesh(shape)
    ref = _runtime(plans, catalog, name, backend=backend)
    sh = _runtime(plans, catalog, name, backend=backend, mesh=mesh,
                  shard_threshold_bytes=0)
    rng = np.random.default_rng(11)
    for n in BATCH_SIZES:
        reqs = _random_requests(q, catalog, n, rng)
        np.testing.assert_array_equal(
            np.asarray(sh.serve(reqs)),
            np.asarray(ref.serve(reqs)),
            err_msg=f"{name} {backend} mesh={shape} n={n}",
        )


@needs_8_devices
@pytest.mark.parametrize("shape", MESH_SHAPES, ids=lambda s: f"{s[0]}x{s[1]}")
def test_sharded_no_recompile_across_ragged_batches(shape, catalog):
    """One trace per bucket for life, exactly like the unsharded runtime."""
    q = QUERY_IR["P1.linear.year"]()
    mesh = make_serving_mesh(shape)
    runtime = compile_serving(catalog, q, buckets=BUCKETS, mesh=mesh,
                              shard_threshold_bytes=0)
    rng = np.random.default_rng(0)
    sizes = [1, 3, 8, 9, 20, 31, 32, 33, 70, 100]
    for n in sizes:
        out = runtime.serve(_random_requests(q, catalog, n, rng))
        assert out.shape == (n, runtime.out_width)
    assert runtime.num_compiles == len(BUCKETS)
    cache = runtime.jit_cache_size()
    if cache is not None:
        assert cache == len(BUCKETS)
    for n in sizes:
        runtime.serve(_random_requests(q, catalog, n, rng))
    assert runtime.num_compiles == len(BUCKETS)


@needs_8_devices
@pytest.mark.parametrize("shape", MESH_SHAPES, ids=lambda s: f"{s[0]}x{s[1]}")
@pytest.mark.parametrize("backend", ["fused", "nonfused"])
def test_sharded_predict_rows_matches(backend, shape, catalog, plans):
    """compile_query(mesh=...) predict_rows ≡ the unsharded program."""
    name = "P3.tree.year" if backend == "nonfused" else "P2.linear.select.scalar"
    q = QUERY_IR[name]()
    mesh = make_serving_mesh(shape)
    ref = compile_query(catalog, q, backend=backend)
    sh = compile_query(catalog, q, backend=backend, mesh=mesh,
                       shard_threshold_bytes=0)
    assert sh.plan.partition_specs is not None
    ids = jnp.asarray([0, 1, 5, 17, 100, 2999], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(sh.predict_rows(ids)), np.asarray(ref.predict_rows(ids))
    )


@needs_8_devices
def test_sharded_predict_rows_out_of_range_nan_semantics(catalog):
    """Out-of-range row ids keep the unsharded NaN-fill contract.

    The sharded gather clips pointers into the local block, which would
    silently turn ``jnp.take``'s NaN fill into 0.0 — the forward reproduces
    the fill explicitly, even when every arm is row-sharded.
    """
    q = QUERY_IR["P1.linear.year"]()
    mesh = make_serving_mesh((1, 8))
    ref = compile_query(catalog, q, backend="fused")
    sh = compile_query(catalog, q, backend="fused", mesh=mesh,
                       shard_threshold_bytes=0)
    cap = catalog[q.fact].capacity
    ids = jnp.asarray([0, cap + 7, 10**7, -1, 5], jnp.int32)
    want = np.asarray(ref.predict_rows(ids))
    assert np.isnan(want[1]).all() and np.isnan(want[2]).all()
    np.testing.assert_array_equal(np.asarray(sh.predict_rows(ids)), want)


@needs_8_devices
def test_sharded_serving_matches_predict_rows(catalog, plans):
    """The serving ≡ predict_rows contract survives sharding end to end."""
    name = "P1.linear.year"
    q = QUERY_IR[name]()
    mesh = make_serving_mesh((2, 4))
    compiled = compile_query(catalog, q, backend="fused", mesh=mesh,
                             shard_threshold_bytes=0)
    runtime = _runtime(plans, catalog, name, backend="fused", mesh=mesh,
                       shard_threshold_bytes=0)
    fact = catalog[q.fact]
    ok = np.asarray(fact.valid_mask())
    for p in q.fact_preds:
        ok = ok & np.asarray(p.mask(fact))
    ids = np.nonzero(ok)[0][:50]
    got = np.asarray(runtime.serve(requests_from_rows(fact, q, ids)))
    want = np.asarray(compiled.predict_rows(jnp.asarray(ids, jnp.int32)))
    np.testing.assert_array_equal(got, want)


@needs_8_devices
def test_bucket_rounding_to_dp_multiples(catalog):
    """Buckets round up to DP-size multiples so padded batches divide."""
    q = QUERY_IR["P1.linear.year"]()
    mesh = make_serving_mesh((8, 1))
    runtime = compile_serving(catalog, q, buckets=(3, 9), mesh=mesh)
    assert runtime.buckets == (8, 16)
    out = runtime.serve(
        _random_requests(q, catalog, 5, np.random.default_rng(0)))
    assert out.shape == (5, runtime.out_width)


@needs_8_devices
def test_placement_threshold_and_divisibility(catalog):
    """Placement: small → replicate; large → shard; non-divisible → safe."""
    q = QUERY_IR["P1.linear.year"]()
    mesh = make_serving_mesh((2, 4))
    # Huge threshold: everything replicates, still bit-exact (covered above).
    repl = compile_serving(catalog, q, mesh=mesh,
                           shard_threshold_bytes=1 << 40)
    assert all(spec[0] is None for spec in repl.plan.partition_specs)
    assert repl.sharded.num_sharded == 0
    # Zero threshold: shard wherever rows divide the 4-way model axis; the
    # date dim (2555 rows) does not divide 4 and must fall back.
    sh = compile_serving(catalog, q, mesh=mesh, shard_threshold_bytes=0)
    rows = {a.fk_col: catalog[a.table].capacity for a in q.arms}
    for arm, spec in zip(q.arms, sh.plan.partition_specs):
        expected = "model" if rows[arm.fk_col] % 4 == 0 else None
        assert spec[0] == expected, (arm.fk_col, spec)
    assert 0 < sh.sharded.num_sharded < len(q.arms)
    assert sh.sharded.nbytes_per_device() < repl.sharded.nbytes_per_device()


# ------------------------------------------------ per-shard PKIndex slices
def test_shard_pk_index_probe_reconstructs_global():
    rng = np.random.default_rng(0)
    pk = jnp.asarray(rng.permutation(64).astype(np.int32))
    sidx = shard_pk_index(pk, 4)
    assert sidx.num_shards == 4 and sidx.rows_per_shard == 16
    queries = jnp.asarray([0, 7, 13, 63, 64, -1], jnp.int32)
    hits = np.zeros(queries.shape[0], bool)
    resolved = np.zeros(queries.shape[0], np.int64)
    for s in range(4):
        fj = sidx.shard(s).probe(queries)
        found = np.asarray(fj.found)
        # Shard-local row offsets lift to global rows by the block offset.
        resolved[found] = np.asarray(fj.ptr)[found] + s * 16
        assert not np.any(hits & found), "two shards claimed one key"
        hits |= found
    full = np.asarray(pk)
    for i, k in enumerate(np.asarray(queries)):
        if 0 <= k < 64:
            assert hits[i] and full[resolved[i]] == k
        else:
            assert not hits[i]


def test_shard_pk_index_and_shard_rows_validate():
    pk = jnp.arange(10, dtype=jnp.int32)
    with pytest.raises(ValueError, match="shard"):
        shard_pk_index(pk, 3)
    with pytest.raises(ValueError, match="shard"):
        shard_rows(jnp.zeros((10, 2)), 4)
    assert shard_rows(jnp.zeros((12, 2)), 4).shape == (4, 3, 2)


# -------------------------------- safe_spec / param_pspec fallback (15-on-16)
def _stub_mesh(**axes):
    """A mesh stand-in for divisibility logic (no devices needed)."""
    return types.SimpleNamespace(axis_names=tuple(axes), shape=dict(axes))


def test_safe_spec_divisibility_fallback():
    mesh = _stub_mesh(data=1, model=16)
    # 15 rows on a 16-way axis: the dim is left unsharded, not an error.
    assert safe_spec(mesh, (15, 64), "model", None) == P(None, None)
    assert safe_spec(mesh, (32, 64), "model", None) == P("model", None)
    # Axis tuples multiply; missing axes fall back too.
    assert safe_spec(mesh, (16, 4), ("data", "model"), None) == P(
        ("data", "model"), None)
    assert safe_spec(mesh, (8, 4), ("pod", "data"), None) == P(None, None)


def test_param_pspec_divisibility_fallback():
    mesh = _stub_mesh(pod=1, data=2, model=16)
    cfg = types.SimpleNamespace(moe=None)
    # 15 attention heads' worth of columns on a 16-way model axis.
    assert param_pspec("blocks/0/attn/wq", (4, 64, 15), mesh, cfg) == P(
        None, ("pod", "data"), None)
    assert param_pspec("blocks/0/attn/wq", (4, 64, 32), mesh, cfg) == P(
        None, ("pod", "data"), "model")


def test_plan_partition_spec_applies_fallback_to_partials():
    """The 15-on-16 rule, applied to a prefused partial's row count."""
    mesh = _stub_mesh(data=1, model=16)
    spec, why = plan_partition_spec(mesh, (15, 4), threshold=0)
    assert spec == P(None, None) and "safe_spec fallback" in why
    spec, why = plan_partition_spec(mesh, (64, 4), threshold=0)
    assert spec == P("model", None) and "row-shard" in why
    spec, why = plan_partition_spec(mesh, (64, 4), threshold=1 << 30)
    assert spec == P(None, None) and "replicate small" in why
    spec, why = plan_partition_spec(None, (64, 4), threshold=0)
    assert spec == P(None, None) and "no mesh" in why


def test_plan_query_records_partition_specs():
    from repro.core.fusion import LinearOperator

    rng = np.random.default_rng(0)
    model = LinearOperator(jnp.asarray(rng.normal(size=(6, 4)), jnp.float32))
    mesh = _stub_mesh(data=1, model=16)
    plan = plan_query(model, 1024, [64, 15], out_width=4, mesh=mesh,
                      shard_threshold_bytes=0)
    assert plan.partition_specs == (P("model", None), P(None, None))
    assert "place=" in plan.reason
    meshless = plan_query(model, 1024, [64, 15], out_width=4)
    assert meshless.partition_specs is None
