"""Pure-numpy relational oracle used to validate LAQ operators."""
from __future__ import annotations

import numpy as np


def np_equijoin_pairs(keys_r: np.ndarray, keys_s: np.ndarray):
    """All matching (i, j) row pairs of an equi-join, as a set."""
    out = set()
    index = {}
    for j, k in enumerate(keys_s):
        index.setdefault(int(k), []).append(j)
    for i, k in enumerate(keys_r):
        for j in index.get(int(k), ()):
            out.add((i, j))
    return out


def np_groupby_sum(keys_r, values_r, keys_s, groups_s):
    """Oracle for SELECT SUM(R.val) ... JOIN ... GROUP BY S.val.

    A fact row contributes once per matching S row (join semantics).
    """
    out = {}
    for j, k in enumerate(keys_s):
        g = int(groups_s[j])
        for i, kr in enumerate(keys_r):
            if int(kr) == int(k):
                out[g] = out.get(g, 0.0) + float(values_r[i])
    return out


def _np_table_views(t):
    """(float64 column dict, exact int key dict) of a Table's live rows."""
    n = int(t.nvalid)
    m = np.asarray(t.matrix)
    cols = {c: m[:n, i].astype(np.float64) for i, c in enumerate(t.columns)}
    keys = {c: np.asarray(v)[:n] for c, v in t.keys.items()}
    return cols, keys


def _np_pred_mask(p, cols, keys):
    """Mirror of ``Pred.mask`` (keys preferred over float columns)."""
    src = keys[p.col] if p.col in keys else cols[p.col]
    if p.op == "between":
        lo, hi = p.value
        return (src >= lo) & (src <= hi)
    if p.op == "in":
        return np.isin(src, np.asarray(list(p.value)))
    import operator
    ops = {"==": operator.eq, "!=": operator.ne, "<": operator.lt,
           "<=": operator.le, ">": operator.gt, ">=": operator.ge}
    return ops[p.op](src, p.value)


def _np_value(cols, expr):
    """Mirror of ``repro.core.query.eval_value`` on numpy columns."""
    if isinstance(expr, str):
        return cols[expr]
    op, *args = expr
    if op == "col":
        return cols[args[0]]
    a, b = (_np_value(cols, x) for x in args)
    return {"add": lambda: a + b, "sub": lambda: a - b,
            "mul": lambda: a * b, "div": lambda: a / b}[op]()


def _np_model_apply(model, x):
    """Mirror of LinearOperator / DecisionTreeGEMM apply, in float64."""
    if hasattr(model, "L"):
        return x @ np.asarray(model.L, np.float64)
    f = np.asarray(model.F, np.float64)
    v = np.asarray(model.v, np.float64)
    h = np.asarray(model.H, np.float64)
    hh = np.asarray(model.h, np.float64)
    b = (x @ f > v[None, :]).astype(np.float64)
    return (b @ h == hh[None, :]).astype(np.float64)


def np_predictive_query(q, catalog):
    """Brute-force oracle for a ``PredictiveQuery`` over Table catalogs.

    Returns ``{"rows": int, "groups": {code: {agg: value}} | None,
    "scalars": {agg: value} | None, "abs_scale": {agg: float}}`` —
    ``abs_scale`` is the Σ|contribution| per aggregate, for tolerance
    scaling of float32-engine comparisons.
    """
    fact = catalog[q.fact]
    fcols, fkeys = _np_table_views(fact)
    n = len(next(iter(fcols.values()))) if fcols else int(fact.nvalid)
    valid = np.ones(n, bool)
    for p in q.fact_preds:
        valid &= _np_pred_mask(p, fcols, fkeys)

    arm_ptr, arm_keys = {}, {}
    feat_parts = []
    for arm in q.arms:
        dcols, dkeys = _np_table_views(catalog[arm.table])
        pkmap = {int(k): i for i, k in enumerate(dkeys[arm.pk_col])}
        ptr = np.asarray([pkmap.get(int(k), -1) for k in fkeys[arm.fk_col]])
        ok = ptr >= 0
        if arm.preds:
            dmask = np.ones(len(dkeys[arm.pk_col]), bool)
            for p in arm.preds:
                dmask &= _np_pred_mask(p, dcols, dkeys)
            ok = ok & dmask[np.clip(ptr, 0, None)]
        valid &= ok
        arm_ptr[arm.table] = ptr
        arm_keys[arm.table] = dkeys
        for c in arm.feature_cols:
            feat_parts.append(dcols[c][np.clip(ptr, 0, None)])

    pred = None
    if q.model is not None:
        x = np.stack(feat_parts, axis=1) if feat_parts else np.zeros((n, 0))
        pred = _np_model_apply(q.model, x)

    codes = None
    if q.group_keys:
        codes = np.zeros(n, np.int64)
        for gk in q.group_keys:
            col = (fkeys[gk.col] if gk.table == "fact"
                   else arm_keys[gk.table][gk.col][
                       np.clip(arm_ptr[gk.table], 0, None)])
            codes = codes * int(gk.bound) + (col.astype(np.int64) - gk.offset)

    group_rows = None
    if q.group_keys:
        group_rows = {}
        for i in np.nonzero(valid)[0]:
            group_rows.setdefault(int(codes[i]), []).append(i)

    def _reduce(arr, op):
        """One aggregate over the (rows, width) slice of one group/scalar."""
        if op == "count":
            return np.asarray([float(arr.shape[0])])
        if op == "mean":
            return arr.mean(axis=0)
        if op == "min":
            return arr.min(axis=0)
        if op == "max":
            return arr.max(axis=0)
        return arr.sum(axis=0)

    groups = {} if q.group_keys else None
    scalars = None if q.group_keys else {}
    abs_scale = {}
    for agg in q.aggregates:
        op = getattr(agg, "op", "sum")
        if op == "count":
            v2 = np.ones((n, 1))
        else:
            vals = (pred if agg.value == "@prediction"  # query.ir.PREDICTION
                    else _np_value(fcols, agg.value))
            v2 = vals if vals.ndim > 1 else vals[:, None]
        live = np.abs(v2[valid])
        abs_scale[agg.name] = float(
            live.mean() if op in ("mean", "min", "max") and live.size
            else live.sum())
        if q.group_keys:
            for code, idx in group_rows.items():
                groups.setdefault(code, {})[agg.name] = _reduce(v2[idx], op)
        else:
            scalars[agg.name] = _reduce(v2[valid], op)
    return {"rows": int(valid.sum()), "groups": groups, "scalars": scalars,
            "abs_scale": abs_scale}


def np_star_join(fact_keys: list, dims: list):
    """Oracle star join.

    fact_keys: list of per-arm FK arrays (len = n_dims), same length rows.
    dims: list of (pk_array, feature_matrix).
    Returns (row_ids, feature_matrix) of surviving fact rows.
    """
    n = len(fact_keys[0])
    rows, feats = [], []
    for i in range(n):
        parts = []
        ok = True
        for fk, (pk, fm) in zip(fact_keys, dims):
            matches = np.nonzero(pk == fk[i])[0]
            if len(matches) != 1:
                ok = False
                break
            parts.append(fm[matches[0]])
        if ok:
            rows.append(i)
            feats.append(np.concatenate(parts))
    if not feats:
        return np.zeros((0,), np.int64), np.zeros((0, 0), np.float32)
    return np.asarray(rows), np.stack(feats).astype(np.float32)
