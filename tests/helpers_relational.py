"""Pure-numpy relational oracle used to validate LAQ operators."""
from __future__ import annotations

import numpy as np


def np_equijoin_pairs(keys_r: np.ndarray, keys_s: np.ndarray):
    """All matching (i, j) row pairs of an equi-join, as a set."""
    out = set()
    index = {}
    for j, k in enumerate(keys_s):
        index.setdefault(int(k), []).append(j)
    for i, k in enumerate(keys_r):
        for j in index.get(int(k), ()):
            out.add((i, j))
    return out


def np_groupby_sum(keys_r, values_r, keys_s, groups_s):
    """Oracle for SELECT SUM(R.val) ... JOIN ... GROUP BY S.val.

    A fact row contributes once per matching S row (join semantics).
    """
    out = {}
    for j, k in enumerate(keys_s):
        g = int(groups_s[j])
        for i, kr in enumerate(keys_r):
            if int(kr) == int(k):
                out[g] = out.get(g, 0.0) + float(values_r[i])
    return out


def np_star_join(fact_keys: list, dims: list):
    """Oracle star join.

    fact_keys: list of per-arm FK arrays (len = n_dims), same length rows.
    dims: list of (pk_array, feature_matrix).
    Returns (row_ids, feature_matrix) of surviving fact rows.
    """
    n = len(fact_keys[0])
    rows, feats = [], []
    for i in range(n):
        parts = []
        ok = True
        for fk, (pk, fm) in zip(fact_keys, dims):
            matches = np.nonzero(pk == fk[i])[0]
            if len(matches) != 1:
                ok = False
                break
            parts.append(fm[matches[0]])
        if ok:
            rows.append(i)
            feats.append(np.concatenate(parts))
    if not feats:
        return np.zeros((0,), np.int64), np.zeros((0, 0), np.float32)
    return np.asarray(rows), np.stack(feats).astype(np.float32)
