"""Versioned Catalog + incremental prefuse maintenance (ISSUE 5).

The contract under test:
  * ``append → refresh`` is **bit-exact** vs a cold rebuild on the updated
    catalog — property-tested across fused/nonfused × segment/matmul for
    the whole-query program, and across fused/nonfused (and a (1,8) mesh,
    when 8 host devices exist) for the serving runtime,
  * the delta path never retraces: ``ServingRuntime.num_compiles`` is
    unchanged across a same-shape refresh, and latency windows reset so
    post-refresh percentiles never mix pre-refresh samples,
  * Session caches are version-keyed: a cached plan/runtime can never serve
    pre-append partials,
  * ``DomainCache.refresh`` grows geometrically instead of silently
    truncating when the merged unique set exceeds capacity (regression),
  * ``PKIndex.extend`` is array-identical to a cold ``pk_index``,
  * capacity growth falls back to recompile/rebuild with a named
    ``explain()`` reason,
  * plain-dict catalogs auto-wrap read-only (back-compat shim).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fusion import LinearOperator, random_tree
from repro.core.laq import (PAD_KEY, Catalog, CatalogReadOnlyError,
                            DomainCache, Table, pk_index)
from repro.core.query import (PREDICTION, Aggregate, ArmSpec, GroupKey,
                              PredictiveQuery, Session, compile_query,
                              compile_serving)
from repro.core.laq.selection import Pred
from repro.launch.mesh import make_serving_mesh

needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


# --------------------------------------------------------------------- data
def star_catalog(seed: int, n_d1: int = 24, n_d2: int = 10,
                 n_fact: int = 64, slack: int = 16) -> Catalog:
    """A 2-arm star with padded dimension capacity for appends to land in."""
    rng = np.random.default_rng(seed)
    d1 = {"pk": np.arange(n_d1) * 2,      # sparse keys: FKs can miss
          "a": rng.normal(size=n_d1), "b": rng.normal(size=n_d1)}
    d2 = {"pk2": np.arange(n_d2),
          "c": rng.normal(size=n_d2),
          "g": rng.integers(0, 4, n_d2)}
    f = {"fk1": rng.integers(0, 2 * (n_d1 + slack), n_fact),
         "fk2": rng.integers(0, n_d2 + slack // 2, n_fact),
         "val": rng.normal(size=n_fact)}
    return Catalog({
        "d1": Table.from_columns("d1", d1, key_cols=("pk",),
                                 capacity=n_d1 + slack),
        "d2": Table.from_columns("d2", d2, key_cols=("pk2", "g"),
                                 capacity=n_d2 + slack),
        "fact": Table.from_columns("fact", f, key_cols=("fk1", "fk2"),
                                   capacity=n_fact + slack),
    })


def d1_rows(rng, m, start):
    return {"pk": start * 2 + 1 + 2 * np.arange(m),   # odd keys: fresh
            "a": rng.normal(size=m), "b": rng.normal(size=m)}


def d2_rows(rng, m, start):
    return {"pk2": start + np.arange(m), "c": rng.normal(size=m),
            "g": rng.integers(0, 4, m)}


def _query(model, group: bool) -> PredictiveQuery:
    gk = (GroupKey("d2", "g", 4),) if group else ()
    return PredictiveQuery(
        fact="fact",
        arms=(ArmSpec("d1", "fk1", "pk", ("a", "b"),
                      (Pred("a", ">", -1.0),)),
              ArmSpec("d2", "fk2", "pk2", ("c",))),
        fact_preds=(Pred("val", ">", -2.0),),
        model=model,
        group_keys=gk,
        aggregates=(Aggregate(PREDICTION, "sum", "pred"),
                    Aggregate("val", "mean", "v"),
                    Aggregate("*", "count", "n")),
        num_groups=4 if group else 8192)


def _models(seed=0):
    rng = np.random.default_rng(seed)
    return [LinearOperator(jnp.asarray(
        rng.normal(size=(3, 2)).astype(np.float32))),
        random_tree(rng, 3, depth=2)]


def assert_results_equal(a, b):
    assert a.keys() == b.keys()
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=k)


# ------------------------------------------- append → refresh ≡ cold rebuild
@pytest.mark.parametrize("backend", ["fused", "nonfused"])
@pytest.mark.parametrize("agg_backend", ["segment", "matmul"])
def test_refresh_equals_cold_rebuild_run(backend, agg_backend):
    for model in _models():
        cat = star_catalog(seed=7)
        q = _query(model, group=True)
        cq = compile_query(cat, q, backend=backend, agg_backend=agg_backend)
        rng = np.random.default_rng(11)
        cat.append("d1", d1_rows(rng, 5, start=24))
        cat.append("d2", d2_rows(rng, 3, start=10))
        cat.append("fact", {"fk1": [1, 49, 3], "fk2": [10, 12, 0],
                            "val": [0.5, -0.5, 1.5]})
        line = cq.refresh()
        assert "delta" in line
        cold = compile_query(cat, q, backend=backend,
                             agg_backend=agg_backend)
        assert_results_equal(cq.run(), cold.run())
        ids = np.arange(0, 67, 5, dtype=np.int32)
        np.testing.assert_array_equal(np.asarray(cq.predict_rows(ids)),
                                      np.asarray(cold.predict_rows(ids)))


@pytest.mark.parametrize("backend", ["fused", "nonfused"])
def test_refresh_equals_cold_rebuild_serving(backend):
    for model in _models(seed=3):
        cat = star_catalog(seed=8)
        q = _query(model, group=False)
        rt = compile_serving(cat, q, backend=backend, buckets=(8, 32))
        reqs = {"fk1": np.array([0, 2, 49, 51, 99], np.int32),
                "fk2": np.array([0, 9, 10, 12, 3], np.int32)}
        rt.serve(reqs)
        n0 = rt.num_compiles
        rng = np.random.default_rng(12)
        cat.append("d1", d1_rows(rng, 5, start=24))
        cat.append("d2", d2_rows(rng, 3, start=10))
        line = rt.refresh()
        assert "delta" in line
        assert rt.num_compiles == n0, "delta refresh must not retrace"
        cold = compile_serving(cat, q, backend=backend, buckets=(8, 32))
        np.testing.assert_array_equal(np.asarray(rt.serve(reqs)),
                                      np.asarray(cold.serve(reqs)))


@needs_8_devices
@pytest.mark.parametrize("shape", [(1, 8), (2, 4)])
def test_refresh_sharded_serving_bit_exact(shape):
    cat = star_catalog(seed=9, n_d1=32, n_d2=16)
    model = _models(seed=5)[0]
    q = _query(model, group=False)
    mesh = make_serving_mesh(shape)
    rt = compile_serving(cat, q, backend="fused", mesh=mesh,
                         shard_threshold_bytes=0, buckets=(8,))
    reqs = {"fk1": np.array([0, 2, 65, 67, 99], np.int32),
            "fk2": np.array([0, 9, 16, 18, 3], np.int32)}
    rt.serve(reqs)
    n0 = rt.num_compiles
    rng = np.random.default_rng(13)
    cat.append("d1", d1_rows(rng, 6, start=32))
    cat.append("d2", d2_rows(rng, 4, start=16))
    assert "delta" in rt.refresh()
    assert rt.num_compiles == n0
    cold_sharded = compile_serving(cat, q, backend="fused", mesh=mesh,
                                   shard_threshold_bytes=0, buckets=(8,))
    cold_single = compile_serving(cat, q, backend="fused", buckets=(8,))
    out = np.asarray(rt.serve(reqs))
    np.testing.assert_array_equal(out, np.asarray(cold_sharded.serve(reqs)))
    np.testing.assert_array_equal(out, np.asarray(cold_single.serve(reqs)))


# ------------------------------------------------------- hypothesis property
def test_property_append_refresh_equals_cold():
    """Property: build on a prefix of the dimension rows, append the rest,
    refresh — results must be bitwise the cold compile on the full catalog,
    for run(), predict_rows() AND serving, across every backend combo."""
    pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (requirements-dev)")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        split=st.floats(0.1, 0.9),
        backend=st.sampled_from(["fused", "nonfused"]),
        agg_backend=st.sampled_from(["segment", "matmul"]),
        tree=st.booleans(),
        group=st.booleans(),
    )
    def check(seed, split, backend, agg_backend, tree, group):
        _check_append_refresh(seed, split, backend, agg_backend, tree,
                              group)

    check()


def _check_append_refresh(seed, split, backend, agg_backend, tree, group):
    rng = np.random.default_rng(seed)
    n_d1, n_d2 = 20, 12
    m1 = max(1, min(n_d1 - 1, int(n_d1 * split)))
    m2 = max(1, min(n_d2 - 1, int(n_d2 * split)))
    d1 = {"pk": np.arange(n_d1) * 2, "a": rng.normal(size=n_d1),
          "b": rng.normal(size=n_d1)}
    d2 = {"pk2": np.arange(n_d2), "c": rng.normal(size=n_d2),
          "g": rng.integers(0, 4, n_d2)}
    f = {"fk1": rng.integers(0, 2 * n_d1 + 4, 48),
         "fk2": rng.integers(0, n_d2 + 2, 48),
         "val": rng.normal(size=48)}
    model = (random_tree(rng, 3, depth=2) if tree
             else LinearOperator(jnp.asarray(
                 rng.normal(size=(3, 2)).astype(np.float32))))

    def tables(prefix1, prefix2):
        return {
            "d1": Table.from_columns(
                "d1", {k: v[:prefix1] for k, v in d1.items()},
                key_cols=("pk",), capacity=n_d1),
            "d2": Table.from_columns(
                "d2", {k: v[:prefix2] for k, v in d2.items()},
                key_cols=("pk2", "g"), capacity=n_d2),
            "fact": Table.from_columns("fact", f, key_cols=("fk1", "fk2")),
        }

    q = _query(model, group=group)
    warm_cat = Catalog(tables(m1, m2))
    warm = compile_query(warm_cat, q, backend=backend,
                         agg_backend=agg_backend)
    warm_cat.append("d1", {k: v[m1:] for k, v in d1.items()})
    warm_cat.append("d2", {k: v[m2:] for k, v in d2.items()})
    warm.refresh()
    cold = compile_query(Catalog(tables(n_d1, n_d2)), q, backend=backend,
                         agg_backend=agg_backend)
    assert_results_equal(warm.run(), cold.run())
    ids = np.arange(48, dtype=np.int32)
    np.testing.assert_array_equal(np.asarray(warm.predict_rows(ids)),
                                  np.asarray(cold.predict_rows(ids)))

    # The serving runtime over the same split (fact-free online phase).
    warm_rt_cat = Catalog(tables(m1, m2))
    rt = compile_serving(warm_rt_cat, q, backend=backend, buckets=(16,))
    warm_rt_cat.append("d1", {k: v[m1:] for k, v in d1.items()})
    warm_rt_cat.append("d2", {k: v[m2:] for k, v in d2.items()})
    rt.refresh()
    cold_rt = compile_serving(Catalog(tables(n_d1, n_d2)), q,
                              backend=backend, buckets=(16,))
    reqs = {"fk1": f["fk1"][:16], "fk2": f["fk2"][:16]}
    np.testing.assert_array_equal(np.asarray(rt.serve(reqs)),
                                  np.asarray(cold_rt.serve(reqs)))


# ----------------------------------------------------- staleness (Session)
def test_session_cache_never_serves_stale_partials():
    cat = star_catalog(seed=21)
    model = _models(seed=2)[0]
    sess = Session(cat)
    q = _query(model, group=False)
    builder = sess.bind(q)
    r0 = builder.run()
    rt = builder.serve(buckets=(8,))
    # Keys 55 (odd d1 key) and 10/11 (d2) do not exist yet.
    reqs = {"fk1": np.array([55, 55], np.int32),
            "fk2": np.array([10, 11], np.int32)}
    assert np.all(np.asarray(rt.serve(reqs)) == 0)
    rng = np.random.default_rng(22)
    new_d1 = d1_rows(rng, 4, start=24)
    new_d1["a"] = np.abs(new_d1["a"])   # pass the d1 arm's a > -1 predicate
    cat.append("d1", new_d1)
    cat.append("d2", d2_rows(rng, 4, start=10))
    # Same cached objects come back — refreshed, never pre-append state.
    r1 = builder.run()
    assert sess.num_plans == 1
    assert float(r1["n"]) >= float(r0["n"])
    rt2 = builder.serve(buckets=(8,))
    assert rt2 is rt
    assert np.any(np.asarray(rt2.serve(reqs)) != 0), \
        "version-keyed cache served pre-append partials"
    cold = Session(cat).bind(q)
    assert_results_equal(r1, cold.run())
    np.testing.assert_array_equal(
        np.asarray(rt2.serve(reqs)),
        np.asarray(cold.serve(buckets=(8,)).serve(reqs)))


def test_session_refresh_eager():
    cat = star_catalog(seed=23)
    sess = Session(cat)
    q = _query(_models(seed=4)[0], group=False)
    sess.bind(q).run()
    sess.bind(q).serve(buckets=(8,))
    rng = np.random.default_rng(24)
    cat.append("d1", d1_rows(rng, 2, start=24))
    out = sess.refresh()
    assert len(out) == 2          # one plan + one runtime refreshed
    assert all("delta" in line for line in out.values())
    assert sess.refresh() == {}   # converged


# ------------------------------------------------- fallback + update paths
def test_capacity_growth_falls_back_with_named_reason():
    cat = star_catalog(seed=25, slack=2)
    q = _query(_models(seed=6)[0], group=True)
    cq = compile_query(cat, q)
    rt = compile_serving(cat, q, buckets=(8,))
    rt.serve({"fk1": np.zeros(3, np.int32), "fk2": np.zeros(3, np.int32)})
    rng = np.random.default_rng(26)
    cat.append("d1", d1_rows(rng, 8, start=24))   # overflows slack=2 → grow
    assert cat.deltas_since("d1", 0)[0].grew
    line = cq.refresh()
    assert "recompile(capacity-growth:d1" in line
    assert "capacity-growth" in cq.plan.reason
    line = rt.refresh()
    assert "rebuild(capacity-growth:d1" in line
    assert rt.num_compiles == 0   # fresh jit cache
    cold = compile_query(cat, q)
    assert_results_equal(cq.run(), cold.run())
    cold_rt = compile_serving(cat, q, buckets=(8,))
    reqs = {"fk1": np.array([1, 53], np.int32),
            "fk2": np.array([0, 1], np.int32)}
    np.testing.assert_array_equal(np.asarray(rt.serve(reqs)),
                                  np.asarray(cold_rt.serve(reqs)))


def test_update_column_refreshes_partials():
    cat = star_catalog(seed=27)
    q = _query(_models(seed=8)[0], group=False)
    cq = compile_query(cat, q, backend="fused")
    rt = compile_serving(cat, q, backend="fused", buckets=(8,))
    cat.update_column("d1", "a", [0, 3, 5], [2.0, -3.0, 0.25])
    assert "delta" in cq.refresh()
    assert "delta" in rt.refresh()
    cold = compile_query(cat, q, backend="fused")
    assert_results_equal(cq.run(), cold.run())
    cold_rt = compile_serving(cat, q, backend="fused", buckets=(8,))
    reqs = {"fk1": np.array([0, 6, 10], np.int32),
            "fk2": np.array([0, 1, 2], np.int32)}
    np.testing.assert_array_equal(np.asarray(rt.serve(reqs)),
                                  np.asarray(cold_rt.serve(reqs)))


def test_update_key_column_rejected():
    cat = star_catalog(seed=28)
    with pytest.raises(ValueError, match="key column"):
        cat.update_column("d1", "pk", [0], [999])


def test_append_is_transactional():
    cat = star_catalog(seed=29)
    v0 = cat.version("d1")
    t0 = cat["d1"]
    with pytest.raises(ValueError, match="missing columns"):
        cat.append("d1", {"pk": [999]})
    with pytest.raises(ValueError, match="ragged"):
        cat.append("d1", {"pk": [999], "a": [1.0, 2.0], "b": [0.0]})
    assert cat.version("d1") == v0 and cat["d1"] is t0


# ------------------------------------------------- stats reset (satellite)
def test_latency_stats_reset_across_refresh():
    cat = star_catalog(seed=31)
    q = _query(_models(seed=9)[0], group=False)
    rt = compile_serving(cat, q, buckets=(8,), sync_stats=True)
    reqs = {"fk1": np.array([0, 2], np.int32),
            "fk2": np.array([0, 1], np.int32)}
    for _ in range(3):
        rt.serve(reqs)
    stats = rt.latency_stats()
    assert stats[8]["count"] == 2 and "compile_ms" in stats[8]
    n0 = rt.num_compiles
    rng = np.random.default_rng(32)
    cat.append("d1", d1_rows(rng, 2, start=24))
    rt.refresh()
    post = rt.latency_stats()
    assert post[8]["count"] == 0 and "p50" not in post[8], \
        "post-refresh percentiles must not mix pre-refresh samples"
    # The compile record is per cache *generation*, not per window: a delta
    # refresh keeps it (no retrace happened).
    assert post[8]["compile_ms"] == stats[8]["compile_ms"]
    assert rt.num_compiles == n0, "delta refresh adds no traces"
    rt.serve(reqs)
    assert rt.num_compiles == n0, "refreshed state re-dispatches cached jit"
    assert rt.latency_stats()[8]["count"] == 1


def test_compile_records_survive_rebuild_per_generation():
    """Regression: a post-rebuild retrace of an already-seen bucket used to
    overwrite ``_compile_s[bucket]``, losing the first generation's compile
    time while ``num_compiles`` claimed a fresh generation."""
    cat = star_catalog(seed=33, slack=2)
    q = _query(_models(seed=10)[0], group=False)
    rt = compile_serving(cat, q, buckets=(8,))
    reqs = {"fk1": np.array([0, 2], np.int32),
            "fk2": np.array([0, 1], np.int32)}
    rt.serve(reqs)
    assert rt.generation == 0
    gen0 = rt.compile_history()[0][8]
    rng = np.random.default_rng(34)
    cat.append("d1", d1_rows(rng, 6, start=24))   # exceeds capacity slack
    rt.refresh()                                  # → rebuild: new generation
    rt.serve(reqs)                                # retrace of bucket 8
    assert rt.generation == 1
    hist = rt.compile_history()
    assert len(hist) == 2 and hist[0][8] == gen0, \
        "rebuild retrace must archive, not overwrite, generation-0 compiles"
    assert rt.latency_stats()[8]["compile_ms"] == hist[1][8]


# ----------------------------------------------- DomainCache capacity (bug)
def test_domain_cache_refresh_grows_instead_of_truncating():
    """Regression: the old jnp.unique(size=cap) merge silently dropped the
    largest keys once the merged unique set exceeded the cached capacity."""
    cache = DomainCache()
    keys = jnp.asarray(np.arange(8, dtype=np.int32))
    dom = cache.get_or_build([("r", "k")], [keys], size=8)
    assert dom.shape == (8,)
    new = jnp.asarray(np.arange(100, 106, dtype=np.int32))
    merged = cache.refresh([("r", "k")], new)
    live = np.asarray(merged)[np.asarray(merged) != PAD_KEY]
    assert merged.shape[0] == 16            # geometric growth, not 8
    assert set(live.tolist()) == set(range(8)) | set(range(100, 106)), \
        "refresh dropped keys"
    with pytest.raises(ValueError, match="capacity"):
        cache.refresh([("r", "k")],
                      jnp.asarray(np.arange(200, 220, dtype=np.int32)),
                      grow=False)


def test_domain_cache_refresh_table_hook():
    cache = DomainCache()
    cache.get_or_build([("d1", "pk")],
                       [jnp.asarray(np.arange(4, dtype=np.int32))], size=8)
    cat = star_catalog(seed=33)
    cat.domain_cache = cache
    rng = np.random.default_rng(34)
    cat.append("d1", d1_rows(rng, 2, start=24))
    dom = np.asarray(cache.get_or_build(
        [("d1", "pk")], [], size=8))
    assert 49 in dom.tolist()               # appended key merged in


# ------------------------------------------------------ PKIndex.extend
def test_pk_index_extend_matches_cold_rebuild():
    rng = np.random.default_rng(41)
    keys = rng.permutation(np.arange(0, 200, 3))[:40].astype(np.int32)
    cap = 64
    pk = np.full(cap, PAD_KEY, np.int32)
    pk[:30] = keys[:30]
    idx = pk_index(jnp.asarray(pk))
    pk2 = pk.copy()
    pk2[30:40] = keys[30:40]
    ext = idx.extend(keys[30:40], np.arange(30, 40))
    cold = pk_index(jnp.asarray(pk2))
    np.testing.assert_array_equal(np.asarray(ext.sorted_pk),
                                  np.asarray(cold.sorted_pk))
    np.testing.assert_array_equal(np.asarray(ext.order),
                                  np.asarray(cold.order))
    assert ext.n_live == 40
    with pytest.raises(ValueError, match="uniqueness"):
        ext.extend(keys[:1], np.array([40]))
    with pytest.raises(ValueError, match="capacity"):
        ext.extend(np.arange(1000, 1030, dtype=np.int32), np.arange(30))


# ------------------------------------------------------ back-compat shims
def test_plain_dict_catalogs_wrap_read_only():
    cat = star_catalog(seed=51)
    plain = dict(cat.snapshot())
    q = _query(_models(seed=10)[0], group=False)
    cq = compile_query(plain, q)                 # Mapping shim
    rt = compile_serving(plain, q, buckets=(8,))
    sess = Session(plain)                        # Session shim
    assert isinstance(sess.catalog, Catalog) and sess.catalog.read_only
    with pytest.raises(CatalogReadOnlyError):
        sess.catalog.append("d1", d1_rows(np.random.default_rng(0), 1,
                                          start=24))
    # Read-only catalogs never change version: refresh is a clean no-op.
    assert "no-op" in cq.refresh()
    assert "no-op" in rt.refresh()
    assert_results_equal(cq.run(), sess.bind(q).run())


def test_catalog_versions_and_deltas():
    cat = star_catalog(seed=52)
    assert cat.versions(("d1", "d2")) == (("d1", 0), ("d2", 0))
    rng = np.random.default_rng(53)
    cat.append("d1", d1_rows(rng, 2, start=24))
    cat.append("d1", d1_rows(rng, 2, start=26))
    assert cat.version("d1") == 2
    assert len(cat.deltas_since("d1", 0)) == 2
    assert len(cat.deltas_since("d1", 1)) == 1
    with pytest.raises(ValueError, match="forward"):
        cat.deltas_since("d1", 5)
    d = cat.deltas_since("d1", 0)[0]
    assert (d.kind, d.lo, d.hi) == ("append", 24, 26)


def test_zero_row_mutations_are_version_noops():
    """Regression: an empty append/update must not bump the version (there
    is nothing to refresh) nor poison later delta refreshes."""
    cat = star_catalog(seed=61)
    q = _query(_models(seed=12)[0], group=False)
    rt = compile_serving(cat, q, buckets=(8,))
    cq = compile_query(cat, q)
    empty = {c: np.empty(0) for c in cat["d1"].columns}
    assert cat.append("d1", empty) == 0 and cat.version("d1") == 0
    assert cat.update_column("d1", "a", [], []) == 0
    assert "no-op" in rt.refresh() and "no-op" in cq.refresh()
    rng = np.random.default_rng(62)
    cat.append("d1", d1_rows(rng, 2, start=24))
    assert "delta" in rt.refresh() and "delta" in cq.refresh()
    cold = compile_serving(cat, q, buckets=(8,))
    reqs = {"fk1": np.array([49, 51], np.int32),
            "fk2": np.array([0, 1], np.int32)}
    np.testing.assert_array_equal(np.asarray(rt.serve(reqs)),
                                  np.asarray(cold.serve(reqs)))


def test_delta_log_is_bounded_and_staleness_rebuilds():
    """Regression: the per-table delta log must not grow without bound; an
    artifact staler than the log's retention rebuilds instead of crashing."""
    cat = star_catalog(seed=63)
    cat.MAX_DELTA_LOG = 4
    q = _query(_models(seed=13)[0], group=False)
    rt = compile_serving(cat, q, buckets=(8,))
    cq = compile_query(cat, q)
    rng = np.random.default_rng(64)
    for i in range(6):                       # > MAX_DELTA_LOG appends
        cat.append("d1", d1_rows(rng, 1, start=24 + i))
    assert len(cat.deltas_since("d1", cat.version("d1") - 1)) == 1
    assert len(cat._deltas["d1"]) == 4      # bounded
    with pytest.raises(ValueError, match="compacted"):
        cat.deltas_since("d1", 0)
    assert "history-compacted" in rt.refresh()   # rebuild, not a crash
    assert "history-compacted" in cq.refresh()
    cold = compile_serving(cat, q, buckets=(8,))
    reqs = {"fk1": np.array([49, 59], np.int32),
            "fk2": np.array([0, 1], np.int32)}
    np.testing.assert_array_equal(np.asarray(rt.serve(reqs)),
                                  np.asarray(cold.serve(reqs)))
    assert_results_equal(cq.run(), compile_query(cat, q).run())


def test_bulk_update_logs_span_not_id_tuple():
    """Regression: huge update_column calls must not pin per-row id tuples
    in the delta log forever — they compact to one covering span."""
    cat = star_catalog(seed=65)
    cat.UPDATE_ROWS_MAX = 4
    q = _query(_models(seed=14)[0], group=False)
    cq = compile_query(cat, q, backend="fused")
    ids = np.arange(2, 10)                   # 8 > UPDATE_ROWS_MAX
    cat.update_column("d1", "a", ids, np.linspace(-1, 1, 8))
    d = cat.deltas_since("d1", 0)[0]
    assert d.rows == () and (d.lo, d.hi) == (2, 10)
    assert "delta" in cq.refresh()
    assert_results_equal(cq.run(),
                         compile_query(cat, q, backend="fused").run())


def test_duplicate_pk_append_rejected_before_commit():
    """Regression: appending a duplicate primary key must fail *at append*
    (transactionally — version unchanged, no poisoned delta), not later
    inside every artifact's refresh, forever."""
    cat = star_catalog(seed=56)
    q = _query(_models(seed=15)[0], group=False)
    rt = compile_serving(cat, q, buckets=(8,))   # teaches PK cols
    v0 = cat.version("d1")
    rng = np.random.default_rng(57)
    dup = d1_rows(rng, 2, start=24)
    dup["pk"] = np.array([0, 49])                # 0 already exists
    with pytest.raises(ValueError, match="already exist in unique key"):
        cat.append("d1", dup)
    assert cat.version("d1") == v0               # transactional: no commit
    assert "no-op" in rt.refresh()               # nothing poisoned
    dup_block = d1_rows(rng, 2, start=24)
    dup_block["pk"] = np.array([49, 49])         # dup within the block
    with pytest.raises(ValueError, match="within the appended block"):
        cat.append("d1", dup_block)
    cat.append("d1", d1_rows(rng, 2, start=24))  # clean append still works
    assert "delta" in rt.refresh()


def test_refresh_decisions_accumulate_on_explain():
    cat = star_catalog(seed=54)
    q = _query(_models(seed=11)[0], group=False)
    cq = compile_query(cat, q)
    assert "no-op" in cq.refresh()          # nothing pending
    rng = np.random.default_rng(55)
    cat.append("d1", d1_rows(rng, 1, start=24))
    cq.refresh()
    reasons = cq.plan.reason
    assert "refresh=no-op" in reasons and "refresh=delta" in reasons, \
        "every refresh decision must land on explain()"


def test_refresh_trail_on_explain_is_bounded():
    """Regression: a streaming artifact refreshed per batch must not grow
    plan.reason (and memory) without bound — only the base reason plus a
    bounded tail of recent decisions is kept."""
    cat = star_catalog(seed=58, slack=96)    # 40 appends stay in capacity
    q = _query(_models(seed=16)[0], group=False)
    cq = compile_query(cat, q)
    rt = compile_serving(cat, q, buckets=(8,))
    base_cq, base_rt = len(cq.plan.reason), len(rt.plan.reason)
    rng = np.random.default_rng(59)
    for i in range(40):
        cat.append("d1", {"pk": [101 + 2 * i], "a": rng.normal(size=1),
                          "b": rng.normal(size=1)})
        cq.refresh()
        rt.refresh()
    assert len(cq.plan.reason) < base_cq + 8 * 80
    assert len(rt.plan.reason) < base_rt + 8 * 80
    assert "refresh=delta" in cq.plan.reason
