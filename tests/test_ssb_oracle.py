"""SSB queries through the LAQ engine vs brute-force numpy oracles."""
import numpy as np
import pytest

from repro.core.laq import PAD_GROUP, decode_composite
from repro.data import QUERIES, generate_ssb
from repro.data.ssb import N_BRANDS


@pytest.fixture(scope="module")
def data():
    return generate_ssb(sf=1, scale=0.001, seed=3)


def _np_cols(table, *cols):
    n = int(table.nvalid)
    out = []
    for c in cols:
        src = table.keys.get(c)
        out.append(np.asarray(src)[:n] if src is not None
                   else np.asarray(table.matrix)[:n, table.col_index(c)])
    return out


@pytest.mark.slow
def test_q11_matches_bruteforce(data):
    lo, date = data.lineorder, data.date
    od, disc, qty, price = _np_cols(lo, "lo_orderdate", "lo_discount",
                                    "lo_quantity", "lo_extendedprice")
    dk, year = _np_cols(date, "datekey", "d_year")
    y = {int(k): int(v) for k, v in zip(dk, year)}
    mask = (np.vectorize(lambda k: y.get(int(k), 0))(od) == 1993)
    mask &= (disc >= 1) & (disc <= 3) & (qty < 25)
    want_rows = int(mask.sum())
    want_rev = float((price[mask] * disc[mask]).sum())
    got = QUERIES["Q1.1"](data)
    assert int(got["rows"]) == want_rows
    assert float(got["revenue"]) == pytest.approx(want_rev, rel=1e-5)


@pytest.mark.slow
def test_q21_groups_match_bruteforce(data):
    lo, date, part, supp = (data.lineorder, data.date, data.part,
                            data.supplier)
    od, pk_fk, sk_fk, rev = _np_cols(lo, "lo_orderdate", "lo_partkey",
                                     "lo_suppkey", "lo_revenue")
    dk, year = _np_cols(date, "datekey", "d_year")
    ppk, cat, brand = _np_cols(part, "partkey", "p_category", "p_brand1")
    spk, sreg = _np_cols(supp, "suppkey", "s_region")
    ymap = {int(k): int(v) for k, v in zip(dk, year)}
    pmap = {int(k): (int(c), int(b)) for k, c, b in zip(ppk, cat, brand)}
    smap = {int(k): int(r) for k, r in zip(spk, sreg)}
    want = {}
    for i in range(len(od)):
        p = pmap.get(int(pk_fk[i]))
        s = smap.get(int(sk_fk[i]))
        yv = ymap.get(int(od[i]))
        if p is None or s is None or yv is None:
            continue
        if p[0] == 6 and s == 1:  # category == 6, region == 1
            key = (yv, p[1])
            want[key] = want.get(key, 0.0) + float(rev[i])
    got = QUERIES["Q2.1"](data)
    groups = np.asarray(got["groups"])
    revs = np.asarray(got["revenue"])
    live = groups != PAD_GROUP
    yr, br = decode_composite(groups[live], [8, N_BRANDS])
    got_map = {(int(y) + 1992, int(b)): float(r)
               for y, b, r in zip(np.asarray(yr), np.asarray(br), revs[live])
               if float(r) != 0.0}
    for key, val in want.items():
        assert got_map.get(key, 0.0) == pytest.approx(val, rel=1e-4), key
    for key, val in got_map.items():
        assert key in want or val == pytest.approx(0.0, abs=1e-3)


@pytest.mark.slow
def test_q41_profit_total_matches_bruteforce(data):
    lo = data.lineorder
    ck, sk, pk, od, rev, cost = _np_cols(
        lo, "lo_custkey", "lo_suppkey", "lo_partkey", "lo_orderdate",
        "lo_revenue", "lo_supplycost")
    cpk, creg = _np_cols(data.customer, "custkey", "c_region")
    spk, sreg = _np_cols(data.supplier, "suppkey", "s_region")
    ppk, mfgr = _np_cols(data.part, "partkey", "p_mfgr")
    dk = _np_cols(data.date, "datekey")[0]
    cmap = {int(k): int(v) for k, v in zip(cpk, creg)}
    smap = {int(k): int(v) for k, v in zip(spk, sreg)}
    pmap = {int(k): int(v) for k, v in zip(ppk, mfgr)}
    dset = set(int(k) for k in dk)
    total = 0.0
    nrows = 0
    for i in range(len(ck)):
        if (cmap.get(int(ck[i])) == 1 and smap.get(int(sk[i])) == 1
                and pmap.get(int(pk[i])) in (0, 1) and int(od[i]) in dset):
            total += float(rev[i]) - float(cost[i])
            nrows += 1
    got = QUERIES["Q4.1"](data)
    assert int(got["rows"]) == nrows
    assert float(np.asarray(got["profit"]).sum()) == pytest.approx(
        total, rel=1e-4)
