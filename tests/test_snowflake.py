"""Snowflake chain tests: the compiler's collapsed-chain lowering must be
bit-exact with (a) materializing each chain as a flat pre-joined dimension,
(b) the float64 numpy oracle, and (c) its own cold rebuild after
sub-dimension appends — across fused/nonfused × segment/matmul.  Plus the
IR/builder validation surface and the pooled/serving chain paths.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.fusion.operators import LinearOperator
from repro.core.laq import Catalog, Table
from repro.core.query import (Aggregate, ArmSpec, ArtifactPool, ChainLink,
                              GroupKey, PredictiveQuery, Session,
                              compile_query, compile_serving,
                              requests_from_rows)
from repro.core.query.snowflake import (chain_key, chain_tables,
                                        materialize_chains,
                                        participating_tables, resolve_chain,
                                        virtual_name)
from repro.core.query.workload import np_oracle, np_serving_oracle

import jax.numpy as jnp

COMBOS = [(b, a) for b in ("fused", "nonfused")
          for a in ("segment", "matmul")]


def _snowflake_tables(seed=0, n_fact=40):
    """fact → customer → nation → region, integer-valued, with FK misses."""
    rng = np.random.default_rng(seed)
    region = Table.from_columns("region", {
        "r_pk": np.arange(4), "r_g": rng.integers(0, 3, 4),
        "r_f0": rng.integers(-4, 5, 4)},
        key_cols=("r_pk", "r_g"), capacity=8)
    nation = Table.from_columns("nation", {
        "n_pk": np.arange(6), "n_to_region": rng.integers(0, 6, 6),
        "n_f0": rng.integers(-4, 5, 6)},
        key_cols=("n_pk", "n_to_region"), capacity=12)
    customer = Table.from_columns("customer", {
        "c_pk": np.arange(12), "c_to_nation": rng.integers(0, 8, 12),
        "c_f0": rng.integers(-4, 5, 12)},
        key_cols=("c_pk", "c_to_nation"), capacity=20)
    fact = Table.from_columns("sales", {
        "fk_cust": rng.integers(0, 14, n_fact),
        "s_g": rng.integers(0, 3, n_fact),
        "revenue": rng.integers(-4, 5, n_fact)},
        key_cols=("fk_cust", "s_g"), capacity=64)
    return {"region": region, "nation": nation, "customer": customer,
            "sales": fact}


CHAIN_ARM = ArmSpec(
    "customer", "fk_cust", "c_pk", ("c_f0",), (),
    links=(ChainLink("nation", "c_to_nation", "n_pk", ("n_f0",)),
           ChainLink("region", "n_to_region", "r_pk", ("r_f0",),
                     parent="nation")))


def _chain_query(model=True, groups=True, preds=False):
    arm = CHAIN_ARM
    fact_preds = ()
    if preds:
        # Sub-dimension predicate two hops deep + a fact-side one: both
        # must fold into the chain validity / row mask identically across
        # every lowering.
        links = (dataclasses.replace(arm.links[0],
                                     preds=(("n_f0", ">=", -2),)),
                 arm.links[1])
        arm = dataclasses.replace(arm, links=links)
        fact_preds = (("revenue", "<=", 3),)
    m = (LinearOperator(jnp.asarray([[1.0], [2.0], [-1.0]], jnp.float32))
         if model else None)
    gks = ((GroupKey("fact", "s_g", 3), GroupKey("region", "r_g", 3))
           if groups else ())
    aggs = (Aggregate("revenue", "sum", "rev"),
            Aggregate("*", "count", "n"))
    if model:
        aggs += (Aggregate("@prediction", "sum", "p"),)
    return PredictiveQuery("sales", (arm,), fact_preds, m, gks, aggs, 9)


def _norm_query(q):
    """Fold tuple preds into Pred objects via the builder-free path."""
    from repro.core.query.session import _as_pred
    arms = tuple(dataclasses.replace(
        a, preds=tuple(_as_pred(p) for p in a.preds),
        links=tuple(dataclasses.replace(
            lk, preds=tuple(_as_pred(p) for p in lk.preds))
            for lk in a.links)) for a in q.arms)
    return dataclasses.replace(
        q, arms=arms, fact_preds=tuple(_as_pred(p) for p in q.fact_preds))


def _res_maps(res, names):
    from repro.core.query.workload import _engine_maps
    if "groups" in res:
        return _engine_maps(res, names)
    return {n: np.asarray(res[n], np.float64) for n in names}


def _assert_equal_results(a, b, names):
    assert int(a["rows"]) == int(b["rows"])
    ma, mb = _res_maps(a, names), _res_maps(b, names)
    for n in names:
        if isinstance(ma[n], dict):
            assert set(ma[n]) == set(mb[n])
            for c in ma[n]:
                np.testing.assert_array_equal(ma[n][c], mb[n][c])
        else:
            np.testing.assert_array_equal(ma[n], mb[n])


# --------------------------------------------------------------------------
# Tentpole property: prefuse ≡ materialized flat join ≡ float64 oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend,agg_backend", COMBOS)
def test_chain_prefuse_equals_flat_and_oracle(backend, agg_backend):
    tables = _snowflake_tables()
    q = _norm_query(_chain_query(preds=True))

    res = compile_query(Catalog(dict(tables)), q, backend=backend,
                        agg_backend=agg_backend).run()
    want = np_oracle(tables, q)
    from repro.core.query.workload import _compare
    assert _compare(res, want, q, f"{backend}/{agg_backend}") == []

    # The flat-star baseline gathers non-head group-key columns through the
    # chain's composed pointers, so grouping on a sub-dimension two hops
    # deep (region.r_g) checks bit-exactly against it too.
    names = [a.name for a in q.aggregates]
    flat_tables, flat_q = materialize_chains(tables, q)
    assert flat_q.group_keys[1].table == virtual_name(q.arms[0])
    flat_cat = Catalog({**{k: v for k, v in tables.items()
                           if k not in chain_tables(q.arms[0])},
                        **flat_tables})
    flat = compile_query(flat_cat, flat_q, backend=backend,
                         agg_backend=agg_backend).run()
    _assert_equal_results(res, flat, names)


@pytest.mark.parametrize("strategy", ["through", "materialize", "auto"])
def test_chain_strategy_bit_equal_and_explained(strategy):
    tables = _snowflake_tables()
    q = _norm_query(_chain_query(preds=True))
    plan = compile_query(Catalog(dict(tables)), q,
                         chain_strategy=strategy)
    assert "chain[" in plan.plan.reason
    assert virtual_name(q.arms[0]) in plan.plan.reason
    want = np_oracle(tables, q)
    from repro.core.query.workload import _compare
    assert _compare(plan.run(), want, q, strategy) == []


def test_chain_without_model_or_groups():
    tables = _snowflake_tables(seed=3)
    for model, groups in ((False, True), (True, False), (False, False)):
        q = _norm_query(_chain_query(model=model, groups=groups))
        res = compile_query(Catalog(dict(tables)), q).run()
        from repro.core.query.workload import _compare
        assert _compare(res, np_oracle(tables, q), q,
                        f"m={model} g={groups}") == []


# --------------------------------------------------------------------------
# Refresh: sub-dimension appends through the chain == cold rebuild
# --------------------------------------------------------------------------
def test_refresh_after_subdim_append_equals_cold():
    tables = _snowflake_tables(seed=1)
    q = _norm_query(_chain_query())
    cat = Catalog(dict(tables))
    sess = Session(cat)
    sess.compile(q).run()

    rng = np.random.default_rng(11)
    # Append to every chain hop + the fact, one at a time, re-checking
    # the cached plan against a cold compile after each.
    appends = [
        ("nation", {"n_pk": [6, 7], "n_to_region": [1, 9],
                    "n_f0": [2, -3]}),
        ("region", {"r_pk": [4], "r_g": [1], "r_f0": [0]}),
        ("customer", {"c_pk": [12, 13], "c_to_nation": [7, 2],
                      "c_f0": [1, 4]}),
        ("sales", {"fk_cust": rng.integers(0, 14, 3), "s_g": [0, 2, 1],
                   "revenue": [3, -1, 0]}),
    ]
    from repro.core.query.workload import _compare
    for name, rows in appends:
        cat.append(name, {k: np.asarray(v) for k, v in rows.items()})
        res = sess.compile(q).run()
        snap = {n: cat[n] for n in cat}
        want = np_oracle(snap, q)
        assert _compare(res, want, q, f"refresh[{name}]") == []
        cold = compile_query(Catalog(snap), q).run()
        assert _compare(cold, want, q, f"cold[{name}]") == []


def test_resolve_chain_refresh_matches_cold_collapse():
    tables = _snowflake_tables(seed=2)
    cat = Catalog(dict(tables))
    arm = _norm_query(_chain_query()).arms[0]
    cc = resolve_chain(cat, arm, keep_hops=len(arm.links))
    cat.append("nation", {"n_pk": np.array([6]),
                          "n_to_region": np.array([2]),
                          "n_f0": np.array([-1])})
    from repro.core.query.snowflake import refresh_chain
    warm = refresh_chain(cat, cc, {"nation"})
    cold = resolve_chain(cat, arm)
    np.testing.assert_array_equal(np.asarray(warm.dmask),
                                  np.asarray(cold.dmask))
    np.testing.assert_array_equal(np.asarray(warm.table.matrix),
                                  np.asarray(cold.table.matrix))


# --------------------------------------------------------------------------
# IR validation (satellite a)
# --------------------------------------------------------------------------
def test_duplicate_alias_rejected():
    arm = CHAIN_ARM
    with pytest.raises(ValueError, match="duplicate table alias"):
        PredictiveQuery("sales", (arm, arm))
    dup_link = dataclasses.replace(
        arm, links=arm.links + (ChainLink("nation", "x", "n_pk"),))
    with pytest.raises(ValueError, match="duplicate table alias 'nation'"):
        PredictiveQuery("sales", (dup_link,))


def test_non_parent_first_chain_rejected():
    bad = dataclasses.replace(
        CHAIN_ARM,
        links=(ChainLink("region", "n_to_region", "r_pk",
                         parent="nation"),
               ChainLink("nation", "c_to_nation", "n_pk")))
    with pytest.raises(ValueError, match="declared parent-first"):
        PredictiveQuery("sales", (bad,))
    selfref = dataclasses.replace(
        CHAIN_ARM,
        links=(ChainLink("nation", "c_to_nation", "n_pk",
                         parent="region"),))
    with pytest.raises(ValueError, match="parent 'region'"):
        PredictiveQuery("sales", (selfref,))


def test_chain_key_ignores_fk_and_names_hops():
    a1 = CHAIN_ARM
    a2 = dataclasses.replace(a1, fk_col="other_fk")
    assert chain_key(a1) == chain_key(a2)  # FK is the fact's business
    a3 = dataclasses.replace(a1, links=a1.links[:1])
    assert chain_key(a1) != chain_key(a3)
    assert virtual_name(a1) == "customer->nation->region"
    assert set(participating_tables(PredictiveQuery("sales", (a1,)))) == {
        "sales", "customer", "nation", "region"}


# --------------------------------------------------------------------------
# Builder surface: via=, chained joins, link parsing
# --------------------------------------------------------------------------
def _bound_session():
    return Session(Catalog(dict(_snowflake_tables())))


def test_builder_via_equals_explicit_ir():
    sess = _bound_session()
    q = (sess.query("sales")
         .join("customer", on=("fk_cust", "c_pk"), features=["c_f0"],
               via=[("nation", "c_to_nation", "n_pk", ["n_f0"]),
                    {"table": "region", "fk_col": "n_to_region",
                     "pk_col": "r_pk", "features": ["r_f0"],
                     "parent": "nation"}])
         .build())
    assert q.arms == _norm_query(_chain_query(model=False,
                                              groups=False)).arms


def test_builder_chained_join_auto_attaches():
    sess = _bound_session()
    q = (sess.query("sales")
         .join("customer", on=("fk_cust", "c_pk"), features=["c_f0"])
         .join("nation", on=("c_to_nation", "n_pk"), features=["n_f0"])
         .join("region", on=("n_to_region", "r_pk"), features=["r_f0"])
         .build())
    assert len(q.arms) == 1
    assert [lk.table for lk in q.arms[0].links] == ["nation", "region"]
    # The chained form runs and matches the oracle end to end.
    res = compile_query(sess.catalog, dataclasses.replace(
        q, aggregates=(Aggregate("revenue", "sum", "rev"),),
        num_groups=1)).run()
    want = np_oracle({n: sess.catalog[n] for n in sess.catalog},
                     dataclasses.replace(
                         q, aggregates=(Aggregate("revenue", "sum",
                                                  "rev"),), num_groups=1))
    assert int(res["rows"]) == want["rows"]


def test_builder_bad_links_are_named_errors():
    sess = _bound_session()
    b = sess.query("sales").join("customer", on=("fk_cust", "c_pk"))
    with pytest.raises(ValueError, match="unknown keys"):
        b.join("nation", on=("c_to_nation", "n_pk"),
               via=[{"table": "nation", "fk_col": "c_to_nation",
                     "pk_col": "n_pk", "banana": 1}])
    with pytest.raises(ValueError, match="unparseable chain link"):
        b.join("nation", on=("c_to_nation", "n_pk"), via=[("nation",)])
    with pytest.raises(ValueError, match="missing key"):
        b.join("nation", on=("c_to_nation", "n_pk"),
               via=[{"table": "nation", "fk_col": "c_to_nation"}])


def test_builder_detached_never_auto_chains():
    from repro.core.query import query
    q = (query("sales")
         .join("customer", on=("fk_cust", "c_pk"))
         .join("nation", on=("c_to_nation", "n_pk"))
         .build())
    # Detached builders have no catalog to inspect: both joins stay arms.
    assert len(q.arms) == 2 and not q.arms[0].links


# --------------------------------------------------------------------------
# Pooled chains (multi-query sharing)
# --------------------------------------------------------------------------
def test_pooled_chain_shared_and_refreshed_once():
    tables = _snowflake_tables(seed=4)
    cat = Catalog(dict(tables))
    pool = ArtifactPool(cat)
    q1 = _norm_query(_chain_query())
    q2 = _norm_query(dataclasses.replace(
        _chain_query(), aggregates=(Aggregate("revenue", "max", "mx"),)))
    p1 = compile_query(cat, q1, pool=pool)
    p2 = compile_query(cat, q2, pool=pool)
    st = pool.stats()
    assert st["by_kind"].get("chain") == 1      # one collapsed chain shared
    ck = chain_key(q1.arms[0])
    assert pool.refcount(ck) >= 2

    cat.append("region", {"r_pk": np.array([4, 5]),
                          "r_g": np.array([2, 0]),
                          "r_f0": np.array([3, -4])})
    p1.refresh()
    p2.refresh()                                # second refresh is a no-op
    r1, r2 = p1.run(), p2.run()
    assert pool.update_count(ck) == 1           # refreshed exactly once
    snap = {n: cat[n] for n in cat}
    from repro.core.query.workload import _compare
    assert _compare(r1, np_oracle(snap, q1), q1, "pooled-q1") == []
    assert _compare(r2, np_oracle(snap, q2), q2, "pooled-q2") == []

    p1.close()
    p2.close()
    assert pool.stats()["entries"] == 0


# --------------------------------------------------------------------------
# Serving chains
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["fused", "nonfused"])
def test_serving_chain_matches_oracle(backend):
    tables = _snowflake_tables(seed=5)
    q = _norm_query(_chain_query(groups=False))
    cat = Catalog(dict(tables))
    rt = compile_serving(cat, q, backend=backend)
    n = int(tables["sales"].nvalid)
    got = np.asarray(rt.serve(requests_from_rows(tables["sales"], q,
                                                 np.arange(n))))
    np.testing.assert_array_equal(got.astype(np.float64),
                                  np_serving_oracle(tables, q))


def test_serving_chain_append_rebuilds_and_matches_cold():
    tables = _snowflake_tables(seed=6)
    q = _norm_query(_chain_query(groups=False))
    cat = Catalog(dict(tables))
    rt = compile_serving(cat, q)
    cat.append("nation", {"n_pk": np.array([6]),
                          "n_to_region": np.array([0]),
                          "n_f0": np.array([4])})
    note = rt.refresh()
    assert "chain tables changed" in note and "nation" in note
    snap = {n: cat[n] for n in cat}
    reqs = requests_from_rows(snap["sales"], q,
                              np.arange(int(snap["sales"].nvalid)))
    warm = np.asarray(rt.serve(reqs))
    cold = np.asarray(compile_serving(Catalog(snap), q).serve(reqs))
    np.testing.assert_array_equal(warm, cold)
    np.testing.assert_array_equal(warm.astype(np.float64),
                                  np_serving_oracle(snap, q))
